//! API-compatible stub of the `xla` crate (xla_extension 0.5.x via the
//! PJRT C API) for the offline build environment.
//!
//! Host-side `Literal` construction/extraction is implemented for real
//! (the runtime's literal helpers and their tests run against it);
//! device execution (`PjRtClient::compile` / `execute`) returns an
//! "unavailable" error, which the engine surfaces cleanly — all
//! engine/coordinator tests skip when `artifacts/` is absent, exactly as
//! on a fresh checkout. Swap this path dependency for the real crate to
//! run PJRT (see DESIGN.md §Substitutions).

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: this build uses the offline xla stub (vendor/xla); \
         link the real xla_extension crate to execute PJRT artifacts"
    ))
}

// ---------------------------------------------------------------------------
// Literals (functional)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-resident typed array, mirroring `xla::Literal`.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Element types a `Literal` can hold / yield.
pub trait NativeType: Copy + Sized {
    fn extract(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn extract(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn extract(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: Data::F32(data.to_vec()) }
    }

    /// Scalar i32 literal (decode position etc.).
    pub fn scalar(v: i32) -> Literal {
        Literal { dims: vec![], data: Data::I32(vec![v]) }
    }

    /// Tuple literal (what executables return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: Data::Tuple(elems) }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret with new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape element count mismatch: have {}, want {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Extract a flat vector of the requested element type.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(t) => Ok(t),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

// ---------------------------------------------------------------------------
// HLO artifacts (parse-only)
// ---------------------------------------------------------------------------

/// Parsed HLO module. The stub only checks the file exists and is
/// non-empty; the real crate parses HLO text into a proto.
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error(format!("reading HLO text `{}`: {e}", p.display())))?;
        if text.trim().is_empty() {
            return Err(Error(format!("HLO text `{}` is empty", p.display())));
        }
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

// ---------------------------------------------------------------------------
// PJRT client / executables (stubbed)
// ---------------------------------------------------------------------------

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PJRT compilation"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PJRT buffer transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        let t = Literal::tuple(vec![Literal::vec1(&[1.0]), Literal::scalar(2)]);
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert!(Literal::vec1(&[1.0]).to_tuple().is_err());
    }

    #[test]
    fn client_compiles_to_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        assert!(c.compile(&XlaComputation).is_err());
    }

    #[test]
    fn hlo_from_missing_file_errors() {
        assert!(HloModuleProto::from_text_file("/definitely/not/here.hlo.txt").is_err());
    }
}
