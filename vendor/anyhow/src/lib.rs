//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this shim provides
//! exactly the surface the workspace uses: `Error`, `Result`, the
//! `anyhow!` / `bail!` / `ensure!` macros, and the `Context` extension
//! trait for `Result` and `Option`. Context is stored as a message
//! chain (outermost first); `{:#}` renders the full chain joined by
//! `": "`, matching real anyhow's alternate formatting closely enough
//! for error-message assertions.

use std::error::Error as StdError;
use std::fmt;

/// An error value carrying a chain of human-readable messages,
/// outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` produces).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { chain: vec![m.to_string()] }
    }

    /// Construct from a std error, flattening its source chain.
    pub fn from_std<E: StdError>(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }

    /// Prepend a context message (what `.context(...)` does).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost (most recent context) message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain joined, like real anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts via `?`. `Error` itself deliberately does NOT
// implement `std::error::Error`, so this blanket impl cannot conflict
// with the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::from_std(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from_std(e).context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_std(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            // no format! here: stringified source could contain braces
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest.json")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest.json");
        let full = format!("{e:#}");
        assert!(full.contains("reading manifest.json"));
        assert!(full.contains("no such file"));
    }

    #[test]
    fn macros_work() {
        fn inner(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 3);
            if x == 4 {
                bail!("four is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(1).unwrap(), 1);
        assert!(inner(11).unwrap_err().to_string().contains("too big"));
        assert!(inner(3).unwrap_err().to_string().contains("condition failed"));
        assert!(inner(4).unwrap_err().to_string().contains("four"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("recorder vanished").unwrap_err();
        assert_eq!(e.to_string(), "recorder vanished");
        let v = Some(5u32);
        assert_eq!(v.with_context(|| "x").unwrap(), 5);
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("no such file"));
    }
}
