//! Minimal stand-in for the `log` crate facade (offline registry).
//!
//! `error!`/`warn!` go to stderr; `info!`/`debug!`/`trace!` compile the
//! format arguments (so they stay type-checked) but emit nothing — the
//! serving loop is latency-sensitive and has no configured logger.

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        eprintln!("[error] {}", format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        eprintln!("[warn] {}", format!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {{
        let _ = format_args!($($arg)*);
    }};
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {{
        let _ = format_args!($($arg)*);
    }};
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {{
        let _ = format_args!($($arg)*);
    }};
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand() {
        crate::debug!("value {}", 42);
        crate::info!("{}", "x");
        crate::trace!("t");
    }
}
