//! Figure 18 (repo extension): the overlapped I/O–compute pipeline with
//! speculative next-layer prefetch, on the Figure-10 overall workload.
//!
//! Sweeps the speculative read budget × DRAM cache ratio and reports,
//! against the synchronous baseline (prefetch off — bit-identical to the
//! historical timeline):
//!
//!   * simulated end-to-end token latency (compute + unhidden flash),
//!   * overlap ratio (fraction of flash busy time hidden under compute),
//!   * speculative hit ratio and wasted volume.
//!
//! A second table toggles access collapse under prefetch, completing the
//! budget × cache × collapse ablation axis.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_experiment, run_spec, System, SystemSpec};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner(
        "Figure 18",
        "overlapped pipeline: e2e latency + overlap vs prefetch budget (OnePlus 12)",
    );

    let models = ["OPT-350M", "OPT-1.3B"];
    let budgets_kb = [64usize, 256, 1024];
    let cache_ratios = [0.05, 0.1, 0.2];

    let mut t = Table::new(&[
        "model", "cache", "budget", "e2e ms", "overlap", "pf hit", "waste MB/tok",
        "vs sync",
    ]);
    for m in models {
        for &ratio in &cache_ratios {
            let mut w = bench_workload(m, 0, DatasetProfile::alpaca());
            w.cache_ratio = ratio;
            let sync = run_experiment(&w, System::Ripple).unwrap();
            t.row(&[
                m.into(),
                format!("{ratio:.2}"),
                "sync".into(),
                format!("{:.2}", sync.e2e_ms()),
                "-".into(),
                "-".into(),
                "-".into(),
                "1.00x".into(),
            ]);
            for &kb in &budgets_kb {
                let mut wp = w.clone();
                wp.prefetch.enabled = true;
                wp.prefetch.budget_bytes = kb * 1024;
                let r = run_experiment(&wp, System::Ripple).unwrap();
                let waste_mb = r.metrics.totals.prefetch_wasted_bundles as f64
                    * r.bundle_bytes as f64
                    / r.metrics.tokens.max(1) as f64
                    / 1e6
                    * r.layer_scale;
                t.row(&[
                    m.into(),
                    format!("{ratio:.2}"),
                    format!("{kb}KB"),
                    format!("{:.2}", r.e2e_ms()),
                    format!("{:.0}%", r.overlap_ratio() * 100.0),
                    format!("{:.0}%", r.metrics.prefetch_hit_ratio() * 100.0),
                    format!("{waste_mb:.2}"),
                    format!("{:.2}x", sync.e2e_ms() / r.e2e_ms()),
                ]);
            }
        }
    }
    println!("\n(a) prefetch budget x cache ratio (collapse on)");
    t.print();

    // (b) collapse toggle under a fixed budget: speculation and gap
    // merging compose — collapse shrinks both demand and speculative
    // command counts.
    let mut tb = Table::new(&["collapse", "prefetch", "e2e ms", "overlap", "cmds/tok"]);
    let w = bench_workload("OPT-350M", 0, DatasetProfile::alpaca());
    for collapse in [false, true] {
        for prefetch in [false, true] {
            let mut wx = w.clone();
            wx.prefetch.enabled = prefetch;
            wx.prefetch.budget_bytes = 256 * 1024;
            let spec = SystemSpec {
                ripple_placement: true,
                collapse,
                cache_policy: if collapse { "linking" } else { "s3fifo" },
                dense: false,
                sub_reads: 1,
            };
            let r = run_spec(&wx, spec, &wx.dataset.clone()).unwrap();
            tb.row(&[
                if collapse { "on" } else { "off" }.into(),
                if prefetch { "on" } else { "off" }.into(),
                format!("{:.2}", r.e2e_ms()),
                format!("{:.0}%", r.overlap_ratio() * 100.0),
                format!(
                    "{:.1}",
                    r.metrics.totals.commands as f64 / r.metrics.tokens.max(1) as f64
                        * r.layer_scale
                ),
            ]);
        }
    }
    println!("\n(b) collapse x prefetch (budget 256KB, cache 0.1)");
    tb.print();
}
