//! Figure 18 (repo extension): the overlapped I/O–compute pipeline with
//! speculative next-layer prefetch, on the Figure-10 overall workload.
//!
//! Sweeps the speculative read budget × DRAM cache ratio against the
//! synchronous baseline (prefetch off — bit-identical to the historical
//! timeline), plus the collapse × prefetch toggle rows.
//!
//! Thin wrapper over the `fig18` scenario preset (see
//! `harness::presets`): the same scenarios and metrics, rendered via
//! the generic harness report (the sync row of each model × cache
//! block is the 1.00× reference; speedups are the e2e ratios).
//! `ripple bench --preset fig18` additionally writes the
//! `BENCH_fig18.json` artifact, and `--baseline` diffs prior runs.

use ripple::bench::banner;
use ripple::harness::{default_threads, preset, run_matrix};

fn main() {
    banner(
        "Figure 18",
        "overlapped pipeline: e2e latency + overlap vs prefetch budget (OnePlus 12)",
    );
    let matrix = preset("fig18").expect("fig18 preset");
    let report = run_matrix(&matrix, default_threads()).expect("fig18 sweep");
    print!("{}", report.to_markdown(None));
}
