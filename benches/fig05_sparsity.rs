//! Figure 5: inference I/O latency and achieved bandwidth of OPT-350M
//! under varying activation sparsity ratios, structural placement.
//! Shape to reproduce: less data does NOT mean proportionally less time —
//! scattered small reads keep the device IOPS-bound, so latency stays
//! high (approaching the dense-streaming latency) while achieved
//! bandwidth collapses.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, dense_stream_load_ms, run_experiment, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 5", "OPT-350M: latency + achieved bandwidth vs sparsity ratio");
    let mut t = Table::new(&["active ratio", "io ms/token", "achieved bw MB/s"]);
    for ratio in [0.05, 0.1, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let mut w = bench_workload("OPT-350M", 0, DatasetProfile::alpaca());
        w.model.sparsity = ratio;
        w.cache_ratio = 0.0; // isolate raw access behaviour, as in the paper
        let r = run_experiment(&w, System::LlmFlash).unwrap();
        t.row(&[
            format!("{:.0}%", ratio * 100.0),
            format!("{:.1}", r.latency_ms()),
            format!("{:.0}", r.metrics.raw_bandwidth() / 1e6),
        ]);
    }
    t.print();
    let dense = dense_stream_load_ms(
        &ripple::config::model_by_name("OPT-350M").unwrap(),
        &ripple::config::devices()[0],
        1.0,
    );
    println!("dense sequential streaming of the full model: {dense:.1} ms/token");
    println!("paper: sparse scattered reads approach (or exceed) dense latency");
}
