//! Table 1: per-token latency breakdown (compute vs load) when 50% of
//! model parameters are offloaded to flash, llama.cpp-style execution
//! (structural layout, unbundled per-matrix reads, 50% DRAM-resident).
//! Reproduces the shape: the load share dominates everywhere and the
//! denser ReLU-Llama/Mistral models pay far more than the sparse OPTs
//! (paper: 71.9% -> 97.7% load ratio).

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, compute_sparse_ms_per_token, run_experiment, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Table 1", "latency breakdown at 50% flash offload (OnePlus 12)");
    let dev = &ripple::config::devices()[0];
    let mut t = Table::new(&["Model", "Compute", "Load", "Total", "Load Ratio"]);
    for name in ["OPT-350M", "OPT-1.3B", "OPT-6.7B", "Llama2-7B", "Mistral-7B"] {
        let mut w = bench_workload(name, 0, DatasetProfile::alpaca());
        // 50% offload ~= 50% of bundles DRAM-resident
        w.cache_ratio = 0.5;
        let r = run_experiment(&w, System::LlamaCpp).unwrap();
        let compute = compute_sparse_ms_per_token(&w.model, dev);
        let load = r.latency_ms();
        let total = compute + load;
        t.row(&[
            name.into(),
            format!("{compute:.0} ms"),
            format!("{load:.0} ms"),
            format!("{total:.0} ms"),
            format!("{:.1}%", 100.0 * load / total),
        ]);
    }
    t.print();
    println!("paper: load ratio 71.9% (OPT-350M) .. 97.7% (Mistral-7B)");
}
