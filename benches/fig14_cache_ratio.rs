//! Figure 14: per-token I/O latency at varying DRAM cache ratios —
//! RIPPLE vs LLMFlash. Paper: RIPPLE at ratio r matches the baseline at
//! ~1.36-1.50x the DRAM budget (memory savings).

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_experiment, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 14", "latency vs DRAM cache ratio (alpaca)");
    let ratios = [0.0, 0.05, 0.1, 0.2, 0.3, 0.4];
    for m in ["OPT-1.3B", "Llama2-7B"] {
        println!("\n{m}");
        let mut t = Table::new(&["cache ratio", "LLMFlash ms", "RIPPLE ms", "speedup"]);
        let mut flash_at: Vec<(f64, f64)> = Vec::new();
        let mut ripple_at: Vec<(f64, f64)> = Vec::new();
        for r in ratios {
            let mut w = bench_workload(m, 0, DatasetProfile::alpaca());
            w.cache_ratio = r;
            let f = run_experiment(&w, System::LlmFlash).unwrap();
            let p = run_experiment(&w, System::Ripple).unwrap();
            flash_at.push((r, f.latency_ms()));
            ripple_at.push((r, p.latency_ms()));
            t.row(&[
                format!("{r:.2}"),
                format!("{:.1}", f.latency_ms()),
                format!("{:.1}", p.latency_ms()),
                format!("{:.2}x", f.latency_ms() / p.latency_ms()),
            ]);
        }
        t.print();
        // memory saving: smallest ripple ratio that beats the baseline at 0.2
        let base = flash_at.iter().find(|(r, _)| *r == 0.2).unwrap().1;
        if let Some((r, _)) = ripple_at.iter().find(|(_, l)| *l <= base) {
            if *r > 0.0 {
                println!("RIPPLE@{r:.2} <= LLMFlash@0.20 -> {:.2}x DRAM saving", 0.2 / r);
            } else {
                println!("RIPPLE needs no cache to beat LLMFlash@0.20");
            }
        }
    }
    println!("\npaper: DRAM savings up to 1.50x / 1.36x on the two models");
}
