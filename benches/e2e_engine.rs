//! End-to-end engine benchmark (§Perf): opt-micro decode through the
//! full three-layer stack — PJRT attention + Pallas-derived sparse FFN +
//! RIPPLE I/O pipeline — plus isolated hot-path micro-benchmarks
//! (placement search, per-token planning, flash-sim overhead).
//! Skips gracefully when artifacts/ is absent.

use ripple::bench::{banner, time_fn};
use ripple::bench::workloads::{bench_workload, System};
use ripple::engine::{Engine, EngineOptions};
use ripple::runtime::{artifacts_available, default_artifacts_dir};
use ripple::trace::DatasetProfile;

fn main() {
    banner("E2E", "opt-micro serving + hot-path micro-benchmarks");

    // --- hot path: per-token I/O planning (no engine needed) ---------
    let w = bench_workload("OPT-6.7B", 0, DatasetProfile::alpaca());
    let calib = w.calibration_trace();
    let (layouts, place_secs) =
        ripple::bench::workloads::layouts_for(System::Ripple, &calib, w.knn, w.threads);
    println!("placement search (2 layers, {} neurons): {place_secs:.2}s", calib.per_layer);

    let eval = w.eval_trace(&w.dataset);
    let bundle_bytes = w.model.bundle_bytes(w.precision);
    let space = ripple::neuron::NeuronSpace::new(
        w.sim_layers,
        w.model.neurons_per_layer,
        bundle_bytes,
    );
    let mut cache = ripple::cache::NeuronCache::from_config(
        "linking",
        (space.total() as f64 * 0.1) as usize,
        ripple::cache::KeySpace::of(&space),
        7,
    )
    .unwrap();
    let mut pipeline = ripple::pipeline::IoPipeline::new(
        ripple::pipeline::PipelineConfig {
            bundle_bytes,
            collapse: true,
            initial_threshold: 4,
            max_threshold: 16,
            window: 16,
            sub_reads_per_run: 1,
        },
        space.clone(),
        layouts,
    );
    let mut sim = ripple::flash::UfsSim::new(w.device.clone(), space.image_bytes());
    let mut it = 0usize;
    let (mean, min, _max) = time_fn(4, 32, || {
        let tok = &eval.tokens[it % eval.tokens.len()];
        it += 1;
        pipeline.step_token(&mut cache, &mut sim, tok)
    });
    println!(
        "per-token planning+sim (OPT-6.7B, {} active/layer): mean {:.1}us min {:.1}us",
        w.model.activated_per_layer(),
        mean / 1e3,
        min / 1e3
    );

    // --- end to end on the real engine --------------------------------
    let dir = default_artifacts_dir();
    if !artifacts_available(&dir) {
        println!("artifacts/ not built — skipping engine benchmark");
        return;
    }
    for batch in [1usize, 4] {
        let opts = EngineOptions { batch, ..Default::default() };
        let mut engine = Engine::load(&dir, opts).unwrap();
        let prompts: Vec<Vec<u8>> = (0..batch).map(|i| {
            format!("request {i}: the quick brown ").into_bytes()
        }).collect();
        let t0 = std::time::Instant::now();
        let n_tokens = 32;
        let outs = engine.generate(&prompts, n_tokens, false).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        let total: usize = outs.iter().map(Vec::len).sum();
        println!(
            "engine batch={batch}: {total} tokens in {dt:.2}s -> {:.1} tok/s wall, \
             sim I/O {:.3} ms/token, IOPS {:.0}, eff bw {:.1} MB/s, cache hit {:.0}%",
            total as f64 / dt,
            engine.io_metrics.mean_latency_ns() / 1e6,
            engine.io_metrics.iops(),
            engine.io_metrics.effective_bandwidth() / 1e6,
            100.0 * engine.io_metrics.totals.cached_bundles as f64
                / engine.io_metrics.totals.demanded_bundles.max(1) as f64,
        );
    }
}
