//! Figure 16: per-token I/O latency of RIPPLE on the three phones.
//! Paper: OP12 ~ Ace3 (same UFS 4.0; storage dominates, not SoC),
//! Ace2 roughly half the performance (UFS 3.1).

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_experiment, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 16", "per-token I/O latency across devices (alpaca)");
    let devices = ripple::config::devices();
    let mut t = Table::new(&["model", "OnePlus 12", "OnePlus Ace 3", "OnePlus Ace 2"]);
    for m in ["OPT-1.3B", "OPT-6.7B", "Llama2-7B"] {
        let mut row = vec![m.to_string()];
        let mut lat = Vec::new();
        for di in 0..devices.len() {
            let w = bench_workload(m, di, DatasetProfile::alpaca());
            let r = run_experiment(&w, System::Ripple).unwrap();
            lat.push(r.latency_ms());
            row.push(format!("{:.1} ms", r.latency_ms()));
        }
        t.row(&row);
        println!(
            "  {m}: Ace2/OP12 = {:.2}x (paper: ~2x), Ace3/OP12 = {:.2}x (paper: ~1x)",
            lat[2] / lat[0],
            lat[1] / lat[0]
        );
    }
    t.print();
}
