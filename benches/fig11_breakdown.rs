//! Figure 11: performance breakdown — starting from LLMFlash, add the
//! offline stage (co-activation placement), then the online stage
//! (access collapse + linking-aligned cache). Paper: offline ~1.30x,
//! online ~1.26x, combined ~1.68x on average.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_experiment, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 11", "breakdown: LLMFlash -> +offline -> +online (alpaca)");
    let models = ["OPT-350M", "OPT-1.3B", "OPT-6.7B", "Llama2-7B", "Mistral-7B"];
    let mut t = Table::new(&[
        "model", "LLMFlash ms", "+offline ms", "+online ms", "offline x", "online x", "total x",
    ]);
    let mut geo_off = 1.0f64;
    let mut geo_on = 1.0f64;
    let mut n = 0u32;
    for m in models {
        let w = bench_workload(m, 0, DatasetProfile::alpaca());
        let base = run_experiment(&w, System::LlmFlash).unwrap();
        let off = run_experiment(&w, System::RippleOffline).unwrap();
        let full = run_experiment(&w, System::Ripple).unwrap();
        let x_off = base.latency_ms() / off.latency_ms();
        let x_on = off.latency_ms() / full.latency_ms();
        geo_off *= x_off;
        geo_on *= x_on;
        n += 1;
        t.row(&[
            m.into(),
            format!("{:.1}", base.latency_ms()),
            format!("{:.1}", off.latency_ms()),
            format!("{:.1}", full.latency_ms()),
            format!("{x_off:.2}x"),
            format!("{x_on:.2}x"),
            format!("{:.2}x", base.latency_ms() / full.latency_ms()),
        ]);
    }
    t.print();
    println!(
        "geomean: offline {:.2}x, online {:.2}x (paper avg: 1.30x / 1.26x, 1.68x combined)",
        geo_off.powf(1.0 / n as f64),
        geo_on.powf(1.0 / n as f64)
    );
}
