//! Figure 15: input sensitivity — a placement optimized on dataset X
//! evaluated on dataset Y (3x3 matrix). Paper: off-diagonal performance
//! stays close to diagonal, suggesting co-activation is model-intrinsic.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_experiment_eval, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 15", "cross-dataset placement transfer (OPT-350M)");
    let datasets = DatasetProfile::all();
    let mut t = Table::new(&["placed on \\ eval on", "alpaca", "openwebtext", "wikitext"]);
    let mut diag = Vec::new();
    let mut off = Vec::new();
    for place_ds in &datasets {
        let mut row = vec![place_ds.name.to_string()];
        for eval_ds in &datasets {
            let w = bench_workload("OPT-350M", 0, place_ds.clone());
            let r = run_experiment_eval(&w, System::Ripple, eval_ds).unwrap();
            row.push(format!("{:.1} ms", r.latency_ms()));
            if place_ds.name == eval_ds.name {
                diag.push(r.latency_ms());
            } else {
                off.push(r.latency_ms());
            }
        }
        t.row(&row);
    }
    t.print();
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "diagonal mean {:.1} ms, off-diagonal mean {:.1} ms ({:+.1}%)",
        mean(&diag),
        mean(&off),
        100.0 * (mean(&off) / mean(&diag) - 1.0)
    );
    println!("paper: placements transfer across datasets with limited degradation");
}
