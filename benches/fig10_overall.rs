//! Figure 10: overall performance — I/O latency per token (a) and
//! effective bandwidth (b) for RIPPLE vs Llama.cpp vs LLMFlash across
//! all five models and three datasets on the OnePlus 12, DRAM cache
//! ratio 0.1, S3-FIFO in every system.
//!
//! Paper headline shape: RIPPLE up to 5.93x over llama.cpp and 3.23x
//! over LLMFlash on latency; up to 4.32x / 2.13x on bandwidth; large
//! wins on sparse OPTs, modest (~10-14%) on dense Mistral.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_experiment, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 10", "overall latency + effective bandwidth (OnePlus 12, cache 0.1)");
    let models = ["OPT-350M", "OPT-1.3B", "OPT-6.7B", "Llama2-7B", "Mistral-7B"];
    let mut lat = Table::new(&[
        "model", "dataset", "llama.cpp ms", "LLMFlash ms", "RIPPLE ms",
        "vs cpp", "vs flash",
    ]);
    let mut bw = Table::new(&[
        "model", "dataset", "llama.cpp MB/s", "LLMFlash MB/s", "RIPPLE MB/s",
        "vs cpp", "vs flash",
    ]);
    let mut max_cpp = 0.0f64;
    let mut max_flash = 0.0f64;
    for m in models {
        for ds in DatasetProfile::all() {
            let w = bench_workload(m, 0, ds.clone());
            let cpp = run_experiment(&w, System::LlamaCpp).unwrap();
            let flash = run_experiment(&w, System::LlmFlash).unwrap();
            let rip = run_experiment(&w, System::Ripple).unwrap();
            let s_cpp = cpp.latency_ms() / rip.latency_ms();
            let s_flash = flash.latency_ms() / rip.latency_ms();
            max_cpp = max_cpp.max(s_cpp);
            max_flash = max_flash.max(s_flash);
            lat.row(&[
                m.into(),
                ds.name.into(),
                format!("{:.1}", cpp.latency_ms()),
                format!("{:.1}", flash.latency_ms()),
                format!("{:.1}", rip.latency_ms()),
                format!("{s_cpp:.2}x"),
                format!("{s_flash:.2}x"),
            ]);
            let (bc, bf, br) = (
                cpp.metrics.effective_bandwidth() / 1e6,
                flash.metrics.effective_bandwidth() / 1e6,
                rip.metrics.effective_bandwidth() / 1e6,
            );
            bw.row(&[
                m.into(),
                ds.name.into(),
                format!("{bc:.0}"),
                format!("{bf:.0}"),
                format!("{br:.0}"),
                format!("{:.2}x", br / bc),
                format!("{:.2}x", br / bf),
            ]);
        }
    }
    println!("\n(a) I/O latency per token");
    lat.print();
    println!("\n(b) effective bandwidth");
    bw.print();
    println!(
        "\nmax speedup: {max_cpp:.2}x vs llama.cpp, {max_flash:.2}x vs LLMFlash \
         (paper: up to 5.93x / 3.23x)"
    );
}
