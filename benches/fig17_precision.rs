//! Figure 17: per-token I/O latency at fp32/fp16/int8 neuron precision.
//! Lower precision shrinks bundles (more IOPS-bound), yet RIPPLE keeps
//! scaling: paper reports an average 1.65x speedup from 16- to 8-bit.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_experiment, System};
use ripple::config::Precision;
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 17", "precision sweep (alpaca, RIPPLE)");
    let mut t = Table::new(&["model", "fp32 ms", "fp16 ms", "int8 ms", "16->8 speedup"]);
    for m in ["OPT-1.3B", "OPT-6.7B", "Llama2-7B"] {
        let mut lat = Vec::new();
        for prec in [Precision::Fp32, Precision::Fp16, Precision::Int8] {
            let mut w = bench_workload(m, 0, DatasetProfile::alpaca());
            w.precision = prec;
            let r = run_experiment(&w, System::Ripple).unwrap();
            lat.push(r.latency_ms());
        }
        t.row(&[
            m.into(),
            format!("{:.1}", lat[0]),
            format!("{:.1}", lat[1]),
            format!("{:.1}", lat[2]),
            format!("{:.2}x", lat[1] / lat[2]),
        ]);
    }
    t.print();
    println!("paper: consistent scaling with precision; avg 1.65x from fp16 to int8");
}
