//! Figure 4: read bandwidth vs continuous I/O size on all three
//! devices. Near-linear growth below the ~24KB knee (IOPS-bound),
//! saturation beyond — this is the calibration curve of the UFS sim.

use ripple::bench::banner;
use ripple::config::devices;
use ripple::flash::{ReadCmd, UfsSim};
use ripple::util::stats::Table;

fn main() {
    banner("Figure 4", "bandwidth vs continuous I/O size");
    let sizes: Vec<usize> = [4, 8, 12, 16, 24, 32, 64, 128, 256, 512, 1024]
        .iter()
        .map(|k| k * 1024)
        .collect();
    let mut t = Table::new(&["io size", "OnePlus 12", "OnePlus Ace 3", "OnePlus Ace 2"]);
    for &sz in &sizes {
        let mut row = vec![format!("{}KB", sz / 1024)];
        for dev in devices() {
            let sim = UfsSim::new(dev, (sz * 64) as u64);
            let cmds: Vec<ReadCmd> = (0..64)
                .map(|i| ReadCmd { offset: (i * sz) as u64, len: sz })
                .collect();
            let r = sim.time_batch(&cmds);
            row.push(format!("{:.2} GB/s", r.bytes as f64 / r.elapsed_ns));
        }
        t.row(&row);
    }
    t.print();
    println!("knee (IOPS->bandwidth bound): OP12/Ace3 ~24KB, Ace2 ~24KB at half the rate");
}
