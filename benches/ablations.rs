//! Design-choice ablations (DESIGN.md §Perf / §Experiment-index):
//!   A. greedy-search kNN width vs quality & search cost
//!   B. fixed vs adaptive collapse threshold
//!   C. linking-aligned admission parameters (segment_p)
//!   D. calibration-budget sensitivity
//! These back the constants baked into the defaults (knn=48,
//! adaptive window=16, segment_min=4 / segment_p=0.25, calib≈256).

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, layouts_for, System, Workload};
use ripple::cache::{Admission, NeuronCache, S3Fifo};
use ripple::flash::UfsSim;
use ripple::metrics::RunMetrics;
use ripple::neuron::NeuronSpace;
use ripple::pipeline::{IoPipeline, PipelineConfig};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

/// Run the eval stream through a custom pipeline configuration.
fn run_custom(
    w: &Workload,
    knn: usize,
    collapse: bool,
    fixed_threshold: Option<u32>,
    admission: Admission,
) -> (RunMetrics, f64) {
    let mut wk = w.clone();
    wk.knn = knn;
    let calib = wk.calibration_trace();
    let (layouts, place_secs) = layouts_for(System::Ripple, &calib, wk.knn, wk.threads);
    let bundle_bytes = wk.model.bundle_bytes(wk.precision);
    let space = NeuronSpace::new(wk.sim_layers, wk.model.neurons_per_layer, bundle_bytes);
    let cache = NeuronCache::new(
        Box::new(S3Fifo::new((space.total() as f64 * wk.cache_ratio) as usize)),
        admission,
        wk.seed,
    );
    let max_threshold = ((wk.device.knee_bytes() / bundle_bytes as f64) as u32).max(1);
    let (initial, max_t) = match fixed_threshold {
        // fixed: pin by making min == max == value via window too large to adapt
        Some(t) => (t, t),
        None => (4, max_threshold),
    };
    let mut pipeline = IoPipeline::new(
        PipelineConfig {
            bundle_bytes,
            collapse,
            initial_threshold: initial,
            max_threshold: max_t.max(initial),
            window: if fixed_threshold.is_some() { usize::MAX } else { 16 },
            sub_reads_per_run: 1,
        },
        space.clone(),
        layouts,
        cache,
    );
    let mut sim = UfsSim::new(wk.device.clone(), space.image_bytes());
    let eval = wk.eval_trace(&wk.dataset);
    let mut m = RunMetrics::new();
    for tok in &eval.tokens {
        let t = pipeline.step_token(&mut sim, tok);
        m.record(&t, bundle_bytes);
    }
    (m, place_secs)
}

fn main() {
    let linking = Admission::Linking { segment_min: 4, segment_p: 0.25 };
    let w = bench_workload("OPT-1.3B", 0, DatasetProfile::alpaca());
    let scale = w.layer_scale();

    banner("Ablation A", "greedy-search kNN width (OPT-1.3B)");
    let mut t = Table::new(&["knn", "io ms/token", "mean access len", "search s"]);
    for knn in [4, 8, 16, 32, 64] {
        let (m, secs) = run_custom(&w, knn, true, None, linking);
        t.row(&[
            knn.to_string(),
            format!("{:.1}", m.mean_latency_ns() * scale / 1e6),
            format!("{:.2}", m.mean_access_len()),
            format!("{secs:.2}"),
        ]);
    }
    t.print();

    banner("Ablation B", "fixed vs adaptive collapse threshold (OPT-1.3B)");
    let mut t = Table::new(&["threshold", "io ms/token", "extra bundles/token", "eff bw MB/s"]);
    for (label, fixed, collapse) in [
        ("off", Some(0), false),
        ("1", Some(1), true),
        ("2", Some(2), true),
        ("4", Some(4), true),
        ("8", Some(8), true),
        ("16", Some(16), true),
        ("adaptive", None, true),
    ] {
        let (m, _) = run_custom(&w, 32, collapse, fixed, linking);
        t.row(&[
            label.into(),
            format!("{:.1}", m.mean_latency_ns() * scale / 1e6),
            format!("{:.1}", m.totals.extra_bundles as f64 / m.tokens as f64),
            format!("{:.0}", m.effective_bandwidth() / 1e6),
        ]);
    }
    t.print();

    banner("Ablation C", "linking admission segment_p (OPT-1.3B)");
    let mut t = Table::new(&["segment_p", "io ms/token", "cache hit %", "mean access len"]);
    for p in [0.0, 0.25, 0.5, 1.0] {
        let adm = Admission::Linking { segment_min: 4, segment_p: p };
        let (m, _) = run_custom(&w, 32, true, None, adm);
        t.row(&[
            format!("{p:.2}"),
            format!("{:.1}", m.mean_latency_ns() * scale / 1e6),
            format!(
                "{:.1}",
                100.0 * m.totals.cached_bundles as f64
                    / m.totals.demanded_bundles.max(1) as f64
            ),
            format!("{:.2}", m.mean_access_len()),
        ]);
    }
    // plain (non-linking) admission for contrast
    let (m, _) = run_custom(&w, 32, true, None, Admission::All);
    t.row(&[
        "admit-all".into(),
        format!("{:.1}", m.mean_latency_ns() * scale / 1e6),
        format!(
            "{:.1}",
            100.0 * m.totals.cached_bundles as f64 / m.totals.demanded_bundles.max(1) as f64
        ),
        format!("{:.2}", m.mean_access_len()),
    ]);
    t.print();

    banner("Ablation D", "calibration budget (OPT-1.3B, tokens)");
    let mut t = Table::new(&["calib tokens", "io ms/token", "mean access len"]);
    for calib in [32, 64, 128, 256, 512] {
        let mut wk = w.clone();
        wk.calib_tokens = calib;
        let (m, _) = run_custom(&wk, 32, true, None, linking);
        t.row(&[
            calib.to_string(),
            format!("{:.1}", m.mean_latency_ns() * scale / 1e6),
            format!("{:.2}", m.mean_access_len()),
        ]);
    }
    t.print();
}
