//! Design-choice ablations (DESIGN.md §Perf / §Experiment-index):
//!   A. greedy-search kNN width vs quality & search cost
//!   B. fixed vs adaptive collapse threshold
//!   C. linking-aligned admission parameters (segment_p)
//!   D. calibration-budget sensitivity
//! These back the constants baked into the defaults (knn=48,
//! adaptive window=16, segment_min=4 / segment_p=0.25, calib≈256).
//!
//! Thin wrapper over the `ablations` scenario preset (see
//! `harness::presets`): the same scenario rows, rendered via the
//! generic harness report; per-row placement-search seconds moved to
//! the JSON-free wall-clock footer, and the full counter set lives in
//! `BENCH_ablations.json` (`ripple bench --preset ablations`).

use ripple::bench::banner;
use ripple::harness::{default_threads, preset, run_matrix};

fn main() {
    banner("Ablations", "kNN width / collapse threshold / admission / calibration (OPT-1.3B)");
    let matrix = preset("ablations").expect("ablations preset");
    let report = run_matrix(&matrix, default_threads()).expect("ablations sweep");
    print!("{}", report.to_markdown(None));
}
