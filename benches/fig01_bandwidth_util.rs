//! Figure 1: bandwidth utilization across LLMs is IOPS-constrained under
//! the structural layout; RIPPLE's co-activation linking recovers it.
//!
//! Thin wrapper over the `fig01` scenario preset (see
//! `harness::presets`): the same scenarios and metrics, rendered via
//! the generic harness report (utilization = `raw MB/s` over the
//! device's saturation bandwidth). `ripple bench --preset fig01`
//! additionally writes the `BENCH_fig01.json` artifact.

use ripple::bench::banner;
use ripple::harness::{default_threads, preset, run_matrix};

fn main() {
    banner("Figure 1", "bandwidth utilization, baseline vs RIPPLE (OnePlus 12, alpaca)");
    let matrix = preset("fig01").expect("fig01 preset");
    let report = run_matrix(&matrix, default_threads()).expect("fig01 sweep");
    print!("{}", report.to_markdown(None));
    let sat = ripple::config::devices()[0].sat_bandwidth / 1e6;
    println!("\nutilization = raw MB/s / {sat:.0} MB/s (OnePlus 12 saturation bandwidth)");
    println!("paper: baselines leave most UFS bandwidth idle; RIPPLE lifts utilization");
}
