//! Figure 1: bandwidth utilization across LLMs is IOPS-constrained under
//! the structural layout; RIPPLE's co-activation linking recovers it.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_experiment, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 1", "bandwidth utilization, baseline vs RIPPLE (OnePlus 12, alpaca)");
    let models = ["OPT-350M", "OPT-1.3B", "OPT-6.7B", "Llama2-7B", "Mistral-7B"];
    let sat = ripple::config::devices()[0].sat_bandwidth;
    let mut t = Table::new(&["model", "baseline util", "RIPPLE util", "gain"]);
    for m in models {
        let w = bench_workload(m, 0, DatasetProfile::alpaca());
        let base = run_experiment(&w, System::LlmFlash).unwrap();
        let ripple = run_experiment(&w, System::Ripple).unwrap();
        let bu = base.metrics.raw_bandwidth() / sat;
        let ru = ripple.metrics.raw_bandwidth() / sat;
        t.row(&[
            m.into(),
            format!("{:.1}%", bu * 100.0),
            format!("{:.1}%", ru * 100.0),
            format!("{:.2}x", ru / bu),
        ]);
    }
    t.print();
    println!("paper: baselines leave most UFS bandwidth idle; RIPPLE lifts utilization");
}
