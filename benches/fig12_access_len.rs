//! Figure 12: continuous-access-length distribution in RIPPLE vs
//! LLMFlash on OPT-6.7B and Llama2-7B. Paper: baseline averages 1.05 /
//! 1.10 bundles per read; RIPPLE raises the mean by 213% / 160% with
//! maxima in the hundreds.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, layouts_for, System};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn access_lengths(
    w: &ripple::bench::workloads::Workload,
    system: System,
) -> (f64, u32, Vec<u64>) {
    let calib = w.calibration_trace();
    let (layouts, _) = layouts_for(system, &calib, w.knn, w.threads);
    let eval = w.eval_trace(&w.dataset);
    let mut lens: Vec<u32> = Vec::new();
    for tok in &eval.tokens {
        for (layer, act) in tok.iter().enumerate() {
            let slots = layouts[layer].slots_for(act);
            let runs = ripple::access::plan_runs(&slots);
            lens.extend(runs.iter().map(|r| r.len));
        }
    }
    let mean = lens.iter().map(|&l| l as f64).sum::<f64>() / lens.len() as f64;
    let max = lens.iter().copied().max().unwrap_or(0);
    // histogram buckets: 1, 2-3, 4-7, 8-15, 16+
    let mut hist = vec![0u64; 5];
    for &l in &lens {
        let b = match l {
            1 => 0,
            2..=3 => 1,
            4..=7 => 2,
            8..=15 => 3,
            _ => 4,
        };
        hist[b] += 1;
    }
    (mean, max, hist)
}

fn main() {
    banner("Figure 12", "continuous access length: LLMFlash vs RIPPLE (alpaca)");
    let mut t = Table::new(&[
        "model", "system", "mean len", "max len", "=1", "2-3", "4-7", "8-15", "16+",
    ]);
    for m in ["OPT-6.7B", "Llama2-7B"] {
        let w = bench_workload(m, 0, DatasetProfile::alpaca());
        for sys in [System::LlmFlash, System::RippleOffline] {
            let (mean, max, hist) = access_lengths(&w, sys);
            let total: u64 = hist.iter().sum();
            let pct = |c: u64| format!("{:.0}%", 100.0 * c as f64 / total as f64);
            t.row(&[
                m.into(),
                sys.name().into(),
                format!("{mean:.2}"),
                max.to_string(),
                pct(hist[0]),
                pct(hist[1]),
                pct(hist[2]),
                pct(hist[3]),
                pct(hist[4]),
            ]);
        }
    }
    t.print();
    println!("paper: baseline mean 1.05-1.10; RIPPLE +213%/+160%, max up to 620/344");
}
