//! Table 4: wall-clock cost of the offline placement search per model
//! and dataset. The paper reports seconds-to-~2-minutes for full models
//! with layer-parallel search; we measure `sim_layers` representative
//! layers in parallel and report both the measured time and the
//! estimated full-model time at 8-way layer parallelism.

use ripple::bench::banner;
use ripple::bench::workloads::bench_workload;
use ripple::placement::{place_model, GreedyParams};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Table 4", "offline search cost (seconds)");
    let models = ["OPT-350M", "OPT-1.3B", "OPT-6.7B", "Llama2-7B", "Mistral-7B"];
    let mut t = Table::new(&[
        "dataset", "model", "neurons/layer", "measured (2 layers)", "est. full model",
    ]);
    for ds in DatasetProfile::all() {
        for m in models {
            let w = bench_workload(m, 0, ds.clone());
            let calib = w.calibration_trace();
            let t0 = std::time::Instant::now();
            let layouts = place_model(&calib, GreedyParams { knn: w.knn, ..Default::default() }, w.threads);
            let secs = t0.elapsed().as_secs_f64();
            assert_eq!(layouts.len(), w.sim_layers);
            let per_layer = secs / w.sim_layers as f64 * w.threads.min(w.sim_layers) as f64;
            let full = per_layer * w.model.n_layers as f64 / 8.0;
            t.row(&[
                ds.name.into(),
                m.into(),
                w.model.neurons_per_layer.to_string(),
                format!("{secs:.2}"),
                format!("{full:.1}"),
            ]);
        }
    }
    t.print();
    println!("paper: 5.3s (OPT-350M) .. 105s (Mistral-7B), one-time cost");
}
