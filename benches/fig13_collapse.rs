//! Figure 13: access-collapse ablation on OPT-6.7B and Llama2-7B —
//! transfer volume (rises slightly), commands/IOPS (drop), effective
//! bandwidth (rises ~1.21x / 1.09x in the paper). Placement and cache
//! policy are held identical on both sides; ONLY collapse toggles.

use ripple::bench::banner;
use ripple::bench::workloads::{bench_workload, run_spec, SystemSpec};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 13", "access collapse ablation (alpaca; placement+cache fixed)");
    let mut t = Table::new(&[
        "model", "collapse", "volume MB/token", "cmds/token", "eff bw MB/s", "gain",
    ]);
    for m in ["OPT-6.7B", "Llama2-7B"] {
        let w = bench_workload(m, 0, DatasetProfile::alpaca());
        let spec_off = SystemSpec {
            ripple_placement: true,
            collapse: false,
            cache_policy: "linking",
            dense: false,
            sub_reads: 1,
        };
        let spec_on = SystemSpec { collapse: true, ..spec_off };
        let off = run_spec(&w, spec_off, &w.dataset).unwrap();
        let on = run_spec(&w, spec_on, &w.dataset).unwrap();
        let vol = |r: &ripple::bench::workloads::ExperimentResult| {
            r.metrics.totals.bytes as f64 / r.metrics.tokens as f64 / 1e6 * r.layer_scale
        };
        let cmds = |r: &ripple::bench::workloads::ExperimentResult| {
            r.metrics.totals.commands as f64 / r.metrics.tokens as f64 * r.layer_scale
        };
        let gain = on.metrics.effective_bandwidth() / off.metrics.effective_bandwidth();
        t.row(&[
            m.into(),
            "off".into(),
            format!("{:.2}", vol(&off)),
            format!("{:.0}", cmds(&off)),
            format!("{:.0}", off.metrics.effective_bandwidth() / 1e6),
            String::new(),
        ]);
        t.row(&[
            m.into(),
            "on".into(),
            format!("{:.2}", vol(&on)),
            format!("{:.0}", cmds(&on)),
            format!("{:.0}", on.metrics.effective_bandwidth() / 1e6),
            format!("{gain:.2}x"),
        ]);
    }
    t.print();
    println!("paper: +1.21x (OPT-6.7B) and +1.09x (Llama2-7B) effective bandwidth");
}
