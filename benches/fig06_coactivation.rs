//! Figure 6: neuron co-activation structure across LLMs and datasets.
//! The paper shows heatmaps; we report the quantitative equivalent — the
//! contrast between a neuron's strongest partner and a random partner
//! (>> 1 means the visible block structure exists), plus the top-pair
//! co-activation probability.

use ripple::bench::banner;
use ripple::bench::workloads::bench_workload;
use ripple::coact::CoactStats;
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

fn main() {
    banner("Figure 6", "co-activation contrast (top-partner / random-pair)");
    let mut t = Table::new(&["model", "dataset", "contrast", "max P(ij)", "mean P(i)"]);
    for model in ["OPT-350M", "Llama2-7B"] {
        for ds in DatasetProfile::all() {
            let w = bench_workload(model, 0, ds.clone());
            let calib = w.calibration_trace();
            let stats = CoactStats::from_trace_layer(&calib, 0);
            let contrast = stats.contrast(128, 7);
            // strongest pair probability among a sample of hot neurons
            let mut max_pij = 0.0f64;
            for i in 0..64u32 {
                if let Some(&(j, _)) = stats.top_partners(i, 1).first() {
                    max_pij = max_pij.max(stats.p_ij(i, j));
                }
            }
            let mean_pi: f64 = (0..stats.n_neurons() as u32)
                .map(|i| stats.freq(i) as f64 / stats.n_tokens() as f64)
                .sum::<f64>()
                / stats.n_neurons() as f64;
            t.row(&[
                model.into(),
                ds.name.into(),
                format!("{contrast:.1}x"),
                format!("{max_pij:.2}"),
                format!("{mean_pi:.3}"),
            ]);
        }
    }
    t.print();
    println!("paper: bright block structure on every model x dataset (contrast >> 1)");
}
