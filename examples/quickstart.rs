//! Quickstart: the RIPPLE library in ~60 lines, no artifacts needed.
//!
//! Builds a synthetic correlated workload for one OPT-350M-shaped layer
//! stack, runs the offline placement search, and streams tokens through
//! the online pipeline against the UFS simulator — printing the
//! latency/IOPS/bandwidth gain over the structural baseline.
//!
//! Run: cargo run --release --example quickstart

use ripple::bench::workloads::{run_experiment, System, Workload};
use ripple::config::{devices, model_by_name};
use ripple::trace::DatasetProfile;

fn main() -> anyhow::Result<()> {
    // 1. Pick a model geometry (paper Table 3), device (Table 2) and
    //    calibration dataset profile.
    let model = model_by_name("OPT-350M")?;
    let device = devices()[0].clone(); // OnePlus 12
    let mut w = Workload::new(model, device, DatasetProfile::alpaca());
    w.calib_tokens = 256; // offline co-activation extraction budget
    w.eval_tokens = 100; // paper reports averages over 100 tokens

    println!(
        "model {} on {} ({} bundles/layer, {:.1}% sparsity)",
        w.model.name,
        w.device.name,
        w.model.neurons_per_layer,
        w.model.sparsity * 100.0
    );

    // 2. Run the same workload under the LLMFlash baseline and RIPPLE.
    //    run_experiment = extract co-activation -> place (Algorithm 1)
    //    -> stream eval tokens through cache/collapse/flash-sim.
    let baseline = run_experiment(&w, System::LlmFlash)?;
    let ripple = run_experiment(&w, System::Ripple)?;

    for r in [&baseline, &ripple] {
        println!(
            "  {:<12} {:>8.2} ms/token   {:>9.0} IOPS   {:>7.1} MB/s effective   \
             mean read {:.2} bundles",
            r.system.name(),
            r.latency_ms(),
            r.metrics.iops(),
            r.metrics.effective_bandwidth() / 1e6,
            r.metrics.mean_access_len(),
        );
    }
    println!(
        "speedup {:.2}x, bandwidth gain {:.2}x (offline search took {:.2}s)",
        baseline.latency_ms() / ripple.latency_ms(),
        ripple.metrics.effective_bandwidth() / baseline.metrics.effective_bandwidth(),
        ripple.placement_secs,
    );
    Ok(())
}
