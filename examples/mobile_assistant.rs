//! Mobile-assistant scenario (the paper's motivating use case):
//! a personal on-device assistant answering a multi-turn chat session.
//!
//! Simulates a session against OPT-6.7B geometry on the OnePlus 12:
//! turns arrive with think-time between them, the DRAM cache stays warm
//! across turns, and we report per-turn I/O latency — first turn (cold)
//! vs steady state (warm) — for LLMFlash vs RIPPLE.
//!
//! Run: cargo run --release --example mobile_assistant

use ripple::bench::workloads::{bench_workload, layouts_for, System, Workload};
use ripple::cache::{KeySpace, NeuronCache};
use ripple::flash::UfsSim;
use ripple::metrics::RunMetrics;
use ripple::neuron::NeuronSpace;
use ripple::pipeline::{IoPipeline, PipelineConfig};
use ripple::trace::DatasetProfile;
use ripple::util::stats::Table;

const TURNS: usize = 8;
const TOKENS_PER_TURN: usize = 24;

fn run_session(w: &Workload, system: System) -> Vec<f64> {
    let calib = w.calibration_trace();
    let (layouts, _) = layouts_for(system, &calib, w.knn, w.threads);
    let bundle_bytes = w.model.bundle_bytes(w.precision);
    let space = NeuronSpace::new(w.sim_layers, w.model.neurons_per_layer, bundle_bytes);
    let cache_policy = if system == System::Ripple { "linking" } else { "s3fifo" };
    let mut cache = NeuronCache::from_config(
        cache_policy,
        (space.total() as f64 * w.cache_ratio) as usize,
        KeySpace::of(&space),
        w.seed,
    )
    .unwrap();
    let mut pipeline = IoPipeline::new(
        PipelineConfig {
            bundle_bytes,
            collapse: system == System::Ripple,
            initial_threshold: 4,
            max_threshold: ((w.device.knee_bytes() / bundle_bytes as f64) as u32).max(1),
            window: 16,
            sub_reads_per_run: 1,
        },
        space.clone(),
        layouts,
    );
    let mut sim = UfsSim::new(w.device.clone(), space.image_bytes());

    // one long session: the trace generator provides the activation
    // stream; each turn consumes TOKENS_PER_TURN tokens
    let mut session = w.eval_trace(&w.dataset);
    while session.n_tokens() < TURNS * TOKENS_PER_TURN {
        let more = w.eval_trace(&w.dataset);
        for t in more.tokens {
            session.tokens.push(t);
        }
    }
    let mut per_turn = Vec::new();
    for turn in 0..TURNS {
        let mut m = RunMetrics::new();
        for t in 0..TOKENS_PER_TURN {
            let tok = &session.tokens[turn * TOKENS_PER_TURN + t];
            let io = pipeline.step_token(&mut cache, &mut sim, tok);
            m.record(&io, bundle_bytes);
        }
        per_turn.push(m.mean_latency_ns() * w.layer_scale() / 1e6);
    }
    per_turn
}

fn main() -> anyhow::Result<()> {
    println!("mobile assistant session: OPT-6.7B on OnePlus 12, {TURNS} turns\n");
    let w = bench_workload("OPT-6.7B", 0, DatasetProfile::alpaca());

    let flash = run_session(&w, System::LlmFlash);
    let ripple = run_session(&w, System::Ripple);

    let mut t = Table::new(&["turn", "LLMFlash ms/tok", "RIPPLE ms/tok", "speedup"]);
    for i in 0..TURNS {
        t.row(&[
            format!("{}", i + 1),
            format!("{:.1}", flash[i]),
            format!("{:.1}", ripple[i]),
            format!("{:.2}x", flash[i] / ripple[i]),
        ]);
    }
    t.print();

    let warm = |v: &[f64]| v[2..].iter().sum::<f64>() / (v.len() - 2) as f64;
    println!(
        "\ncold first turn: {:.1} -> {:.1} ms/token; warm steady state: {:.1} -> {:.1} ms/token",
        flash[0],
        ripple[0],
        warm(&flash),
        warm(&ripple)
    );
    println!("the cache warms across turns; RIPPLE keeps its continuity advantage throughout");
    Ok(())
}
