//! End-to-end serving driver (the repo's headline validation).
//!
//! Loads the real opt-micro model (trained at `make artifacts`, weights
//! living as bundles in the simulated UFS flash), then:
//!
//!   1. serves a batched request stream with the STRUCTURAL layout,
//!   2. records ground-truth activation traces, runs the offline
//!      placement search (Algorithm 1), rewrites the flash image,
//!   3. serves the same stream again with the RIPPLE layout + online
//!      stage and compares latency / IOPS / effective bandwidth,
//!   4. finally drives the full coordinator (router + dynamic batcher +
//!      engine workers) and reports serving throughput.
//!
//! Every FFN in step 1-3 executes through the PJRT `ffn_sparse`
//! artifact on bundle bytes fetched from the flash simulator — all
//! three layers of the stack are on the numerical path.
//!
//! Run: make artifacts && cargo run --release --example serve_llm

use ripple::coordinator::{Server, ServerOptions};
use ripple::engine::{Engine, EngineOptions};
use ripple::placement::{place_model, GreedyParams};
use ripple::runtime::{artifacts_available, default_artifacts_dir};

fn report(tag: &str, e: &Engine, tokens: usize, wall_s: f64) {
    println!(
        "  {tag:<12} {:>6.1} tok/s wall | sim I/O {:>7.3} ms/token | {:>7.0} IOPS | \
         {:>6.1} MB/s effective | cache hit {:>4.1}% | mean read {:.2} bundles",
        tokens as f64 / wall_s,
        e.io_metrics.mean_latency_ns() / 1e6,
        e.io_metrics.iops(),
        e.io_metrics.effective_bandwidth() / 1e6,
        100.0 * e.io_metrics.totals.cached_bundles as f64
            / e.io_metrics.totals.demanded_bundles.max(1) as f64,
        e.io_metrics.mean_access_len(),
    );
}

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    anyhow::ensure!(
        artifacts_available(&dir),
        "artifacts/ missing — run `make artifacts` first"
    );

    let prompts: Vec<Vec<u8>> = [
        "the quick brown ",
        "pack my box with ",
        "llm inference on ",
        "neuron co-activation ",
    ]
    .iter()
    .map(|s| s.as_bytes().to_vec())
    .collect();
    let tokens_per_req = 24;

    // ---- step 1: structural layout (LLMFlash-style baseline: no
    //      collapse, plain S3-FIFO — what the paper compares against) ---
    let baseline_opts = EngineOptions {
        batch: 4,
        collapse: false,
        cache_policy: "s3fifo".into(),
        ..Default::default()
    };
    let mut engine = Engine::load(&dir, baseline_opts)?;
    println!("opt-micro loaded: {} layers x {} bundles, flash image {} KB",
        engine.meta.n_layers,
        engine.meta.d_ffn,
        engine.sim.image_len() / 1024,
    );
    let t0 = std::time::Instant::now();
    let out_structural = engine.generate(&prompts, tokens_per_req, false)?;
    let wall_structural = t0.elapsed().as_secs_f64();
    let base_io_ms = engine.io_metrics.mean_latency_ns() / 1e6;
    println!("\n[1] structural placement:");
    report("structural", &engine, 4 * tokens_per_req, wall_structural);

    // ---- step 2: offline stage on REAL activation traces --------------
    println!("\n[2] offline stage: recording real ReLU traces + Algorithm 1");
    let trace = engine.calibrate(b"the quick brown fox jumps over the lazy dog. ", 48)?;
    println!(
        "  recorded {} tokens x {} layers, sparsity {:.1}%",
        trace.n_tokens(),
        trace.n_layers,
        trace.sparsity() * 100.0
    );
    let t0 = std::time::Instant::now();
    let layouts = place_model(&trace, GreedyParams::default(), 4);
    println!("  placement search: {:.2}s", t0.elapsed().as_secs_f64());

    // ---- step 3: RIPPLE layout + online stage, same workload -----------
    let ripple_opts = EngineOptions { batch: 4, ..Default::default() };
    let mut engine = Engine::load(&dir, ripple_opts)?;
    engine.set_layouts(layouts)?;
    let t0 = std::time::Instant::now();
    let out_ripple = engine.generate(&prompts, tokens_per_req, false)?;
    let wall_ripple = t0.elapsed().as_secs_f64();
    let ripple_io_ms = engine.io_metrics.mean_latency_ns() / 1e6;
    println!("\n[3] RIPPLE placement (+collapse +linking cache):");
    report("RIPPLE", &engine, 4 * tokens_per_req, wall_ripple);
    anyhow::ensure!(
        out_structural == out_ripple,
        "re-placement changed model outputs!"
    );
    println!(
        "  outputs identical under re-placement ✓ — simulated I/O speedup {:.2}x",
        base_io_ms / ripple_io_ms
    );
    for (p, o) in prompts.iter().zip(&out_ripple) {
        println!(
            "    {:?} -> {:?}",
            String::from_utf8_lossy(p),
            String::from_utf8_lossy(o)
        );
    }

    // ---- step 4: full coordinator --------------------------------------
    println!("\n[4] coordinator: router + dynamic batcher + engine worker");
    let server = Server::start(dir, ServerOptions::default())?;
    let n_requests = 12;
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| server.submit(prompts[i % prompts.len()].clone(), 12))
        .collect();
    let mut p50 = Vec::new();
    for rx in rxs {
        let r = rx.recv()?;
        p50.push(r.queue_ms + r.engine_ms);
    }
    let wall = t0.elapsed().as_secs_f64();
    p50.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = server.shutdown();
    println!(
        "  {} requests / {} tokens in {:.2}s -> {:.1} tok/s; request latency p50 {:.0} ms, p99 {:.0} ms",
        stats.requests,
        stats.tokens,
        wall,
        stats.tokens as f64 / wall,
        p50[p50.len() / 2],
        p50[p50.len() - 1],
    );
    println!("\nrecorded in EXPERIMENTS.md §End-to-end");
    Ok(())
}
