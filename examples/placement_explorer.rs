//! Placement explorer: dissects the offline stage on a chosen model and
//! dataset — candidate-pair statistics, link formation, fragment count,
//! continuity improvement, and cross-dataset transfer of the layout.
//!
//! Run: cargo run --release --example placement_explorer -- \
//!        [--model OPT-350M] [--dataset alpaca] [--knn 48]

use ripple::access::plan_runs;
use ripple::coact::CoactStats;
use ripple::config::{devices, model_by_name};
use ripple::neuron::Layout;
use ripple::placement::{baselines, search, GreedyParams};
use ripple::trace::DatasetProfile;
use ripple::bench::workloads::Workload;
use ripple::util::cli::Args;
use ripple::util::stats::Table;

fn mean_runs(layout: &Layout, sets: &[&[u32]]) -> f64 {
    let total: usize = sets
        .iter()
        .map(|s| plan_runs(&layout.slots_for(s)).len())
        .sum();
    total as f64 / sets.len() as f64
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let model = model_by_name(args.get_or("model", "OPT-350M"))?;
    let dataset = DatasetProfile::by_name(args.get_or("dataset", "alpaca"))?;
    let knn = args.get_usize("knn", 48)?;

    let mut w = Workload::new(model, devices()[0].clone(), dataset.clone());
    w.sim_layers = 1;
    let calib = w.calibration_trace();
    let stats = CoactStats::from_trace_layer(&calib, 0);

    println!(
        "{} / {}: {} neurons, {} calibration tokens, co-activation contrast {:.1}x",
        w.model.name,
        dataset.name,
        stats.n_neurons(),
        stats.n_tokens(),
        stats.contrast(128, 7)
    );

    // Algorithm 1 with search diagnostics
    let t0 = std::time::Instant::now();
    let r = search(&stats, GreedyParams { knn, ..Default::default() });
    println!(
        "Algorithm 1: {:.2}s — {} candidate pairs scanned, {} links, {} fragments",
        t0.elapsed().as_secs_f64(),
        r.pairs_scanned,
        r.links_made,
        r.fragments
    );

    // Continuity comparison on held-out tokens, across all baselines
    let eval = w.eval_trace(&dataset);
    let eval_sets: Vec<&[u32]> = eval.layer(0).collect();
    let mut t = Table::new(&["placement", "mean runs/token", "mean run len", "vs structural"]);
    let active = w.model.activated_per_layer() as f64;
    let structural_runs = mean_runs(&baselines::structural(stats.n_neurons()), &eval_sets);
    for (name, layout) in [
        ("structural", baselines::structural(stats.n_neurons())),
        ("frequency", baselines::frequency(&stats)),
        ("ripple", r.layout.clone()),
    ] {
        let runs = mean_runs(&layout, &eval_sets);
        t.row(&[
            name.into(),
            format!("{runs:.1}"),
            format!("{:.2}", active / runs),
            format!("{:.2}x fewer", structural_runs / runs),
        ]);
    }
    t.print();

    // Cross-dataset transfer: place on `dataset`, evaluate elsewhere
    println!("\ntransfer of this placement to other datasets (mean runs/token):");
    for other in DatasetProfile::all() {
        let eval = w.eval_trace(&other);
        let sets: Vec<&[u32]> = eval.layer(0).collect();
        println!("  eval on {:<12} {:.1}", other.name, mean_runs(&r.layout, &sets));
    }
    Ok(())
}
