"""AOT compile path: lower every opt-micro block to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client.  Python never runs on the request path.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Outputs (in --out-dir, default ../artifacts):
  attn_b{B}.hlo.txt         attention decode step (per batch variant)
  ffn_sparse_b{B}.hlo.txt   gathered top-K sparse FFN (L1 Pallas inside)
  ffn_dense_b{B}.hlo.txt    exact dense FFN (baseline / oracle)
  predictor_b{B}.hlo.txt    low-rank activation predictor
  head_b{B}.hlo.txt         final LN + logits head
  weights.bin               all trained parameters, flat little-endian f32
  manifest.json             tensor name -> {shape, offset_bytes, len}
  model_config.json         geometry (mirrored by rust config::opt_micro)
  golden.json               decode-step test vectors for rust integration
"""

import argparse
import json
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model as M
from compile.kernels import ref

BATCH_VARIANTS = (1, 4)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_blocks(cfg: M.ModelConfig):
    """Yield (artifact_name, lowered) for every compilation unit."""
    d, s, k, n = cfg.d_model, cfg.max_seq, cfg.top_k, cfg.d_ffn
    r, v = cfg.pred_rank, cfg.vocab
    for bsz in BATCH_VARIANTS:
        x = spec(bsz, d)
        vec = spec(d)
        mat = spec(d, d)
        kv = spec(bsz, s, d)
        pos = spec(dtype=jnp.int32)

        def attn(x, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo, kc, vc, pos):
            return M.attn_block(x, ln_g, ln_b, wq, bq, wk, bk, wv, bv,
                                wo, bo, kc, vc, pos, n_heads=cfg.n_heads)

        yield (f"attn_b{bsz}", jax.jit(attn).lower(
            x, vec, vec, mat, vec, mat, vec, mat, vec, mat, vec, kv, kv, pos))

        yield (f"ffn_sparse_b{bsz}", jax.jit(M.ffn_sparse_block).lower(
            x, vec, vec, spec(k, d), spec(k), spec(k, d), vec))

        yield (f"ffn_dense_b{bsz}", jax.jit(M.ffn_dense_block).lower(
            x, vec, vec, spec(n, d), spec(n), spec(n, d), vec))

        yield (f"predictor_b{bsz}", jax.jit(M.predictor_block).lower(
            x, vec, vec, spec(d, r), spec(r, n)))

        yield (f"head_b{bsz}", jax.jit(M.head_block).lower(
            x, vec, vec, spec(v, d)))


# --------------------------------------------------------------------------
# Weight export
# --------------------------------------------------------------------------

def flatten_params(params, preds):
    """Deterministic (name, array) ordering shared with rust loader."""
    out = [
        ("embed", params["embed"]),
        ("pos_embed", params["pos_embed"]),
        ("ln_f_g", params["ln_f_g"]),
        ("ln_f_b", params["ln_f_b"]),
    ]
    for li, lp in enumerate(params["layers"]):
        for name in ("ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv",
                     "wo", "bo", "ln2_g", "ln2_b", "u", "bu", "dn", "bd"):
            out.append((f"layer{li}.{name}", lp[name]))
        out.append((f"layer{li}.p1", preds[li]["p1"]))
        out.append((f"layer{li}.p2", preds[li]["p2"]))
    return out


def write_weights(path_bin, path_manifest, tensors):
    manifest = {}
    offset = 0
    with open(path_bin, "wb") as f:
        for name, arr in tensors:
            a = np.asarray(arr, np.float32)
            raw = a.tobytes()  # little-endian on all supported hosts
            manifest[name] = {
                "shape": list(a.shape),
                "offset_bytes": offset,
                "num_elems": int(a.size),
            }
            f.write(raw)
            offset += len(raw)
    with open(path_manifest, "w") as f:
        json.dump({"dtype": "f32", "total_bytes": offset,
                   "tensors": manifest}, f, indent=1, sort_keys=True)


# --------------------------------------------------------------------------
# Golden vectors for the rust integration test
# --------------------------------------------------------------------------

def make_golden(params, cfg, prompt=b"the quick brown", steps=8):
    """Dense greedy decode from the prompt; the rust engine (sparse path
    with K=top_k and ground-truth activations capped to top_k by |score|)
    must reproduce argmax tokens, and the dense path must match logits."""
    ids = jnp.asarray(list(prompt), jnp.int32)[None, :]  # B=1
    bsz = 1
    kc = [jnp.zeros((bsz, cfg.max_seq, cfg.d_model)) for _ in range(cfg.n_layers)]
    vc = [jnp.zeros((bsz, cfg.max_seq, cfg.d_model)) for _ in range(cfg.n_layers)]
    logits = None
    for pos in range(ids.shape[1]):
        logits, kc, vc = M.decode_step_dense(params, ids[:, pos], kc, vc, pos, cfg)
    out_tokens = []
    logits_trace = [np.asarray(logits[0], np.float32).tolist()]
    cur = int(jnp.argmax(logits[0]))
    for step in range(steps):
        out_tokens.append(cur)
        pos = ids.shape[1] + step
        logits, kc, vc = M.decode_step_dense(
            params, jnp.asarray([cur], jnp.int32), kc, vc, pos, cfg)
        logits_trace.append(np.asarray(logits[0], np.float32).tolist())
        cur = int(jnp.argmax(logits[0]))
    return {
        "prompt": list(prompt),
        "generated": out_tokens,
        "first_logits": logits_trace[0],
        "last_logits": logits_trace[-1],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    # legacy single-file interface kept for Makefile stamp compatibility
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    cfg = M.CFG
    print(f"[aot] opt-micro: {cfg}")

    print(f"[aot] training {args.train_steps} steps on the synthetic corpus")
    params = M.init_params(cfg, seed=args.seed)
    params, losses = M.train(params, cfg, steps=args.train_steps,
                             log=lambda s: print(s, flush=True))
    print(f"[aot] loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    print("[aot] fitting low-rank activation predictors (SVD)")
    preds = M.predictor_params(params, cfg)

    for name, lowered in lower_blocks(cfg):
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] wrote {path} ({len(text)} chars)")

    write_weights(os.path.join(out_dir, "weights.bin"),
                  os.path.join(out_dir, "manifest.json"),
                  flatten_params(params, preds))
    print("[aot] wrote weights.bin + manifest.json")

    with open(os.path.join(out_dir, "model_config.json"), "w") as f:
        json.dump({
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
            "d_ffn": cfg.d_ffn, "max_seq": cfg.max_seq,
            "top_k": cfg.top_k, "pred_rank": cfg.pred_rank,
            "batch_variants": list(BATCH_VARIANTS),
            "train_loss_first": losses[0], "train_loss_last": losses[-1],
        }, f, indent=1)

    golden = make_golden(params, cfg)
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"[aot] golden decode: prompt={bytes(golden['prompt'])!r} "
          f"generated={bytes(golden['generated'])!r}")

    # Makefile stamp (also keeps the legacy --out contract alive)
    stamp = args.out or os.path.join(out_dir, "model.hlo.txt")
    with open(stamp, "w") as f:
        f.write("// stamp: see per-block artifacts (attn_b*, ffn_*, ...)\n")
    print("[aot] done")


if __name__ == "__main__":
    main()
