"""L1 Pallas kernel: gathered sparse-FFN bundle matmul.

This is RIPPLE's compute hot-spot.  The L3 coordinator predicts the
activated FFN neurons for a token, fetches their *bundles* (up-projection
row, up bias, down-projection column) from flash into DRAM, gathers them
into fixed top-K slot buffers, and executes

    y = relu(x @ U_act^T + b_act) @ D_act

over the K gathered slots.  Padding slots carry zero weights and therefore
contribute exactly zero (relu(0 + 0) @ 0 == 0), so a union-of-batch
activation set can always be padded up to K without affecting numerics.

Hardware adaptation (paper targets smartphone CPU + UFS flash, see
DESIGN.md §Hardware-Adaptation): the K slot axis is the streamed axis —
each grid step keeps one (BLOCK_K x D) tile of U and D resident in VMEM
and feeds the MXU with two (B x D) @ (D x BLOCK_K)-shaped matmuls,
accumulating into the (B x D) output tile that stays in VMEM across the
whole grid.  This mirrors the paper's bundle granularity: the unit of
I/O (a neuron bundle) is also the unit of compute scheduling.

interpret=True is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO so the same
artifact runs under the rust PJRT CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile over the slot axis.  K is always padded to a multiple of
# BLOCK_K by the caller (aot.py / the L3 gather path).
DEFAULT_BLOCK_K = 64


def _kernel(x_ref, u_ref, b_ref, d_ref, o_ref):
    """One grid step: accumulate one BLOCK_K slice of slots into o_ref.

    x_ref: (B, D)        input activations (resident for every step)
    u_ref: (BLOCK_K, D)  up-projection rows for this slot tile
    b_ref: (1, BLOCK_K)  up biases for this slot tile
    d_ref: (BLOCK_K, D)  down-projection rows (transposed columns)
    o_ref: (B, D)        output accumulator (lives in VMEM across steps)
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (B, D) @ (D, BLOCK_K) -> (B, BLOCK_K): MXU-shaped contraction.
    h = jnp.dot(x_ref[...], u_ref[...].T, preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b_ref[...], 0.0)
    # (B, BLOCK_K) @ (BLOCK_K, D) -> (B, D)
    o_ref[...] += jnp.dot(h, d_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_k",))
def sparse_ffn(x, u, b, d, *, block_k=DEFAULT_BLOCK_K):
    """Gathered sparse FFN over K activated-neuron slots.

    Args:
      x: (B, D) float32 — pre-normalized token activations.
      u: (K, D) float32 — gathered up-projection rows.
      b: (K,)   float32 — gathered up biases.
      d: (K, D) float32 — gathered down-projection rows.
      block_k: tile size along the slot axis; K % block_k must be 0.

    Returns:
      (B, D) float32 — FFN output (before the residual add).
    """
    bsz, dim = x.shape
    k = u.shape[0]
    if k % block_k != 0:
        raise ValueError(f"K={k} not a multiple of block_k={block_k}")
    b2 = b.reshape(1, k)  # keep blocks 2-D: TPU tiling dislikes 1-D refs
    grid = (k // block_k,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, dim), lambda i: (0, 0)),
            pl.BlockSpec((block_k, dim), lambda i: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i: (0, i)),
            pl.BlockSpec((block_k, dim), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bsz, dim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, u, b2, d)


def vmem_footprint_bytes(bsz, dim, block_k):
    """Estimated VMEM working set of one grid step, in bytes (fp32).

    Used by DESIGN.md / EXPERIMENTS.md §Perf to pick BLOCK_K such that the
    working set fits a 16 MiB TPU VMEM with double-buffering headroom.
    """
    x_tile = bsz * dim
    u_tile = block_k * dim
    b_tile = block_k
    d_tile = block_k * dim
    o_tile = bsz * dim
    # double-buffer the streamed operands (u, b, d)
    return 4 * (x_tile + o_tile + 2 * (u_tile + b_tile + d_tile))


def mxu_utilization_estimate(bsz, dim, block_k):
    """Fraction of MXU 128x128 systolic-array lanes fed per step.

    Both matmuls have shapes (B, D, BLOCK_K): the MXU dimension coverage
    is min(dim,128)/128 * min(block_k,128)/128, with B as the streaming
    axis.  Purely structural — interpret mode gives no TPU wallclock.
    """
    return min(dim, 128) / 128.0 * min(block_k, 128) / 128.0


# ---------------------------------------------------------------------------
# int8 variant (Figure 17's precision story at the kernel level)
# ---------------------------------------------------------------------------

def _kernel_q8(x_ref, u_ref, us_ref, b_ref, d_ref, ds_ref, o_ref):
    """Like _kernel, but U and D arrive as int8 with per-slot scales.

    Dequantization happens in VMEM right before the MXU contraction —
    the HBM->VMEM stream moves 4x fewer weight bytes, which is exactly
    the paper's motivation for low-precision bundles (smaller flash
    reads), mirrored here as a smaller memory-traffic footprint.
    u_ref/d_ref: (BLOCK_K, D) int8; us_ref/ds_ref: (1, BLOCK_K) f32.
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    u = u_ref[...].astype(jnp.float32) * us_ref[...].T  # (BLOCK_K, D)
    d = d_ref[...].astype(jnp.float32) * ds_ref[...].T
    h = jnp.dot(x_ref[...], u.T, preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b_ref[...], 0.0)
    o_ref[...] += jnp.dot(h, d, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_k",))
def sparse_ffn_q8(x, u_q8, u_scale, b, d_q8, d_scale, *, block_k=DEFAULT_BLOCK_K):
    """Gathered sparse FFN over int8-quantized bundle slots.

    Args:
      x:       (B, D) float32
      u_q8:    (K, D) int8   — quantized up rows
      u_scale: (K,)   float32 — per-slot dequant scale for U
      b:       (K,)   float32 — up biases (kept fp32; negligible bytes)
      d_q8:    (K, D) int8
      d_scale: (K,)   float32
    """
    bsz, dim = x.shape
    k = u_q8.shape[0]
    if k % block_k != 0:
        raise ValueError(f"K={k} not a multiple of block_k={block_k}")
    grid = (k // block_k,)
    b2 = b.reshape(1, k)
    us2 = u_scale.reshape(1, k)
    ds2 = d_scale.reshape(1, k)
    return pl.pallas_call(
        _kernel_q8,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, dim), lambda i: (0, 0)),
            pl.BlockSpec((block_k, dim), lambda i: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i: (0, i)),
            pl.BlockSpec((1, block_k), lambda i: (0, i)),
            pl.BlockSpec((block_k, dim), lambda i: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bsz, dim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, dim), jnp.float32),
        interpret=True,
    )(x, u_q8, us2, b2, d_q8, ds2)


def quantize_rows(w):
    """Symmetric per-row int8 quantization: returns (q8, scale)."""
    amax = jnp.maximum(jnp.abs(w).max(axis=-1), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(w / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
