"""Pure-jnp oracles for every compiled computation.

These are the correctness ground truth: pytest + hypothesis compare the
Pallas kernel and the full model blocks against these, and the rust
integration tests compare PJRT execution results against values generated
from these (via golden files emitted by aot.py).
"""

import jax.numpy as jnp


def sparse_ffn_ref(x, u, b, d):
    """y = relu(x @ U_act^T + b_act) @ D_act   — see sparse_ffn.py."""
    h = jnp.maximum(x @ u.T + b[None, :], 0.0)
    return h @ d


def layer_norm_ref(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def ffn_dense_ref(x, ln_g, ln_b, u, bu, d, bd):
    """Full (pre-LN) dense FFN block with residual: the exact computation
    the sparse path approximates when K < N."""
    xn = layer_norm_ref(x, ln_g, ln_b)
    h = jnp.maximum(xn @ u.T + bu[None, :], 0.0)
    return x + h @ d + bd[None, :]


def attn_ref(x, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo,
             k_cache, v_cache, pos, n_heads):
    """Pre-LN causal self-attention decode step with an in-place KV cache.

    x:        (B, D)
    k_cache:  (B, S, D)  — rows [0, pos) are valid history
    pos:      scalar int32 — the index this token writes
    returns:  (y, k_cache', v_cache') with the residual already added.
    """
    bsz, dim = x.shape
    seq = k_cache.shape[1]
    hd = dim // n_heads
    xn = layer_norm_ref(x, ln_g, ln_b)
    q = xn @ wq + bq
    k = xn @ wk + bk
    v = xn @ wv + bv
    k_cache = k_cache.at[:, pos, :].set(k)
    v_cache = v_cache.at[:, pos, :].set(v)
    qh = q.reshape(bsz, n_heads, hd)
    kh = k_cache.reshape(bsz, seq, n_heads, hd)
    vh = v_cache.reshape(bsz, seq, n_heads, hd)
    scores = jnp.einsum("bhd,bshd->bhs", qh, kh) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.arange(seq) <= pos  # causal: history plus self
    scores = jnp.where(mask[None, None, :], scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    ctx = jnp.einsum("bhs,bshd->bhd", probs, vh).reshape(bsz, dim)
    y = x + ctx @ wo + bo
    return y, k_cache, v_cache


def predictor_ref(x, ln_g, ln_b, p1, p2):
    """Deja-Vu-style low-rank activation predictor.

    scores = ln(x) @ P1 @ P2 approximates the FFN pre-activation
    ln(x) @ U^T; score > 0 predicts the neuron activates.
    """
    xn = layer_norm_ref(x, ln_g, ln_b)
    return (xn @ p1) @ p2


def head_ref(x, ln_g, ln_b, emb):
    """Final layernorm + tied-embedding logits head."""
    xn = layer_norm_ref(x, ln_g, ln_b)
    return xn @ emb.T


def sparse_ffn_q8_ref(x, u_q8, u_scale, b, d_q8, d_scale):
    """Dequantize-then-compute oracle for the int8 kernel."""
    u = u_q8.astype(jnp.float32) * u_scale[:, None]
    d = d_q8.astype(jnp.float32) * d_scale[:, None]
    return sparse_ffn_ref(x, u, b, d)
