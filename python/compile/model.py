"""L2: the opt-micro JAX model.

An OPT-style pre-LN ReLU transformer small enough to AOT-compile and serve
on the CPU PJRT client, yet structurally identical to the Table-3 models:
every FFN neuron is a *bundle* (up-projection row, up bias, down-projection
row) that RIPPLE's L3 coordinator stores in simulated flash, predicts,
fetches and gathers.

The model is split into per-block jittable functions — one compiled PJRT
executable each — because the L3 request path interleaves I/O between
blocks (predict layer l+1 while computing layer l is future work; today the
pipeline is predict -> fetch -> compute per layer):

  * ``attn_block``   dense attention + residual (always DRAM-resident)
  * ``ffn_sparse_block``  gathered top-K sparse FFN (weights from flash),
                          calls the L1 Pallas kernel
  * ``ffn_dense_block``   exact dense FFN (baseline / oracle)
  * ``predictor_block``   low-rank activation predictor (Deja-Vu style)
  * ``head_block``        final LN + tied-embedding logits

Weights never travel inside the HLO: every executable takes them as
runtime parameters so one artifact serves all layers.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.sparse_ffn import sparse_ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """opt-micro geometry. Mirrors rust/src/config/model.rs::opt_micro()."""

    vocab: int = 256          # byte-level
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 4
    d_ffn: int = 512          # neurons (bundles) per FFN block
    max_seq: int = 128
    top_k: int = 128          # gathered sparse-FFN slots (25% of d_ffn)
    pred_rank: int = 32       # low-rank predictor bottleneck (d_model/2)

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


CFG = ModelConfig()


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig = CFG, seed: int = 0):
    """Deterministic init. Layout mirrors artifacts/weights manifest."""
    key = jax.random.PRNGKey(seed)
    ks = iter(jax.random.split(key, 8 + 16 * cfg.n_layers))

    def dense(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    d = cfg.d_model
    params = {
        "embed": dense(next(ks), (cfg.vocab, d), 0.02),
        "pos_embed": dense(next(ks), (cfg.max_seq, d), 0.02),
        "ln_f_g": jnp.ones((d,), jnp.float32),
        "ln_f_b": jnp.zeros((d,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        lp = {
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            "wq": dense(next(ks), (d, d), d ** -0.5),
            "bq": jnp.zeros((d,), jnp.float32),
            "wk": dense(next(ks), (d, d), d ** -0.5),
            "bk": jnp.zeros((d,), jnp.float32),
            "wv": dense(next(ks), (d, d), d ** -0.5),
            "bv": jnp.zeros((d,), jnp.float32),
            "wo": dense(next(ks), (d, d), d ** -0.5),
            "bo": jnp.zeros((d,), jnp.float32),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
            # FFN bundles: U rows (d_ffn, d), up bias (d_ffn), D rows (d_ffn, d)
            "u": dense(next(ks), (cfg.d_ffn, d), d ** -0.5),
            "bu": jnp.zeros((cfg.d_ffn,), jnp.float32),
            "dn": dense(next(ks), (cfg.d_ffn, d), cfg.d_ffn ** -0.5),
            "bd": jnp.zeros((d,), jnp.float32),
        }
        params["layers"].append(lp)
    return params


def predictor_params(params, cfg: ModelConfig = CFG):
    """Fit the low-rank predictor P1 @ P2 ~= U^T per layer via SVD.

    Rank-r truncated SVD of U^T gives the best rank-r approximation of the
    pre-activation map; sign(ln(x) @ P1 @ P2) then predicts activation with
    high-but-imperfect recall — matching the paper's trained predictors.
    """
    preds = []
    for lp in params["layers"]:
        ut = lp["u"].T  # (d, d_ffn)
        u_svd, s, vt = jnp.linalg.svd(ut, full_matrices=False)
        r = cfg.pred_rank
        p1 = u_svd[:, :r] * s[:r][None, :]   # (d, r)
        p2 = vt[:r, :]                        # (r, d_ffn)
        preds.append({"p1": p1, "p2": p2})
    return preds


# --------------------------------------------------------------------------
# Blocks (these are the AOT compilation units)
# --------------------------------------------------------------------------

def attn_block(x, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo,
               k_cache, v_cache, pos, *, n_heads=CFG.n_heads):
    return ref.attn_ref(x, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo,
                        k_cache, v_cache, pos, n_heads)


def ffn_sparse_block(x, ln_g, ln_b, u_act, bu_act, d_act, bd):
    """Pre-LN sparse FFN with residual. u_act/bu_act/d_act are the gathered
    top-K bundle slots (padding slots are all-zero)."""
    xn = ref.layer_norm_ref(x, ln_g, ln_b)
    y = sparse_ffn(xn, u_act, bu_act, d_act)
    return x + y + bd[None, :]


def ffn_dense_block(x, ln_g, ln_b, u, bu, d, bd):
    return ref.ffn_dense_ref(x, ln_g, ln_b, u, bu, d, bd)


def predictor_block(x, ln_g, ln_b, p1, p2):
    return ref.predictor_ref(x, ln_g, ln_b, p1, p2)


def head_block(x, ln_g, ln_b, emb):
    return ref.head_ref(x, ln_g, ln_b, emb)


# --------------------------------------------------------------------------
# Full-model reference paths (testing / training only, never compiled)
# --------------------------------------------------------------------------

def embed(params, ids, pos):
    return params["embed"][ids] + params["pos_embed"][pos]


def decode_step_dense(params, ids, k_caches, v_caches, pos,
                      cfg: ModelConfig = CFG):
    """One dense decode step over the whole model; the oracle the sparse
    engine path is compared against (with K = d_ffn they agree exactly)."""
    x = embed(params, ids, pos)
    new_k, new_v = [], []
    for li, lp in enumerate(params["layers"]):
        x, kc, vc = attn_block(
            x, lp["ln1_g"], lp["ln1_b"], lp["wq"], lp["bq"], lp["wk"],
            lp["bk"], lp["wv"], lp["bv"], lp["wo"], lp["bo"],
            k_caches[li], v_caches[li], pos, n_heads=cfg.n_heads)
        new_k.append(kc)
        new_v.append(vc)
        x = ffn_dense_block(x, lp["ln2_g"], lp["ln2_b"],
                            lp["u"], lp["bu"], lp["dn"], lp["bd"])
    logits = head_block(x, params["ln_f_g"], params["ln_f_b"], params["embed"])
    return logits, new_k, new_v


def ffn_activations(params, x, layer, cfg: ModelConfig = CFG):
    """Ground-truth activation mask for one layer: which neurons have
    positive pre-activation.  Used to record *real* co-activation traces."""
    lp = params["layers"][layer]
    xn = ref.layer_norm_ref(x, lp["ln2_g"], lp["ln2_b"])
    pre = xn @ lp["u"].T + lp["bu"][None, :]
    return pre > 0.0


# --------------------------------------------------------------------------
# Tiny training loop (build-time only) — gives opt-micro real, non-random
# weights so served generations are structured, and gives the activation
# traces realistic correlation.
# --------------------------------------------------------------------------

def synth_corpus(n_tokens=65536, seed=1):
    """Byte corpus with heavy local structure: repeated key-value-ish
    phrases from a small template set. Cheap stand-in for Alpaca-style
    calibration text (see DESIGN.md substitutions)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    templates = [
        b"the quick brown fox jumps over the lazy dog. ",
        b"pack my box with five dozen liquor jugs. ",
        b"llm inference on smartphones is bound by iops. ",
        b"neuron co-activation linking reduces io operations. ",
        b"flash reads should be as continuous as possible. ",
        b"0123456789 9876543210 0123456789. ",
    ]
    out = bytearray()
    while len(out) < n_tokens:
        out += templates[rng.integers(len(templates))]
    return jnp.asarray(list(out[:n_tokens]), jnp.int32)


def _loss_fn(params, batch, cfg: ModelConfig):
    """Teacher-forced next-byte cross-entropy over full sequences."""
    ids = batch[:, :-1]
    tgt = batch[:, 1:]
    bsz, seq = ids.shape
    x = params["embed"][ids] + params["pos_embed"][jnp.arange(seq)][None]
    hd = cfg.head_dim
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    for lp in params["layers"]:
        xn = ref.layer_norm_ref(x, lp["ln1_g"], lp["ln1_b"])
        q = xn @ lp["wq"] + lp["bq"]
        k = xn @ lp["wk"] + lp["bk"]
        v = xn @ lp["wv"] + lp["bv"]
        qh = q.reshape(bsz, seq, cfg.n_heads, hd)
        kh = k.reshape(bsz, seq, cfg.n_heads, hd)
        vh = v.reshape(bsz, seq, cfg.n_heads, hd)
        sc = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / jnp.sqrt(hd)
        sc = jnp.where(mask[None, None], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", pr, vh).reshape(bsz, seq, -1)
        x = x + ctx @ lp["wo"] + lp["bo"]
        xn = ref.layer_norm_ref(x, lp["ln2_g"], lp["ln2_b"])
        h = jnp.maximum(xn @ lp["u"].T + lp["bu"], 0.0)
        x = x + h @ lp["dn"] + lp["bd"]
    xn = ref.layer_norm_ref(x, params["ln_f_g"], params["ln_f_b"])
    logits = xn @ params["embed"].T
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1).mean()
    return nll


@functools.partial(jax.jit, static_argnames=("cfg",))
def _adam_step(params, opt_state, batch, lr, step, cfg: ModelConfig):
    """One Adam step (b1=0.9, b2=0.999) — plain SGD oscillates on this
    loss surface past a few hundred steps."""
    loss, grads = jax.value_and_grad(_loss_fn)(params, batch, cfg)
    m, v = opt_state
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    t = step + 1.0
    def upd(p, mi, vi):
        mh = mi / (1 - b1 ** t)
        vh = vi / (1 - b2 ** t)
        return p - lr * mh / (jnp.sqrt(vh) + eps)
    params = jax.tree_util.tree_map(upd, params, m, v)
    return params, (m, v), loss


def train(params, cfg: ModelConfig = CFG, steps=200, bsz=16, seq=64,
          lr=2e-3, seed=2, log=print):
    """A few hundred Adam steps on the synthetic corpus (~seconds)."""
    import numpy as np

    corpus = np.asarray(synth_corpus())
    rng = np.random.default_rng(seed)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    opt_state = (zeros, jax.tree_util.tree_map(jnp.zeros_like, params))
    losses = []
    for step in range(steps):
        starts = rng.integers(0, len(corpus) - seq - 1, size=bsz)
        batch = jnp.stack([
            jnp.asarray(corpus[s:s + seq + 1], jnp.int32) for s in starts
        ])
        params, opt_state, loss = _adam_step(
            params, opt_state, batch, jnp.float32(lr), jnp.float32(step), cfg)
        losses.append(float(loss))
        if log and (step % 50 == 0 or step == steps - 1):
            log(f"  train step {step:4d}  loss {float(loss):.4f}")
    return params, losses
