"""L1 correctness: the Pallas sparse-FFN kernel vs the pure-jnp oracle.

hypothesis sweeps shapes and block sizes; every case asserts allclose.
This is the CORE correctness signal for the compute hot-spot.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import sparse_ffn_ref
from compile.kernels.sparse_ffn import (
    sparse_ffn, vmem_footprint_bytes, mxu_utilization_estimate,
)


def _mk(seed, bsz, k, d):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((bsz, d), np.float32)
    u = rng.standard_normal((k, d), np.float32) * 0.1
    b = rng.standard_normal((k,), np.float32) * 0.1
    dn = rng.standard_normal((k, d), np.float32) * 0.1
    return map(jnp.asarray, (x, u, b, dn))


def _check(bsz, k, d, block_k, seed=0):
    x, u, b, dn = _mk(seed, bsz, k, d)
    got = sparse_ffn(x, u, b, dn, block_k=block_k)
    want = sparse_ffn_ref(x, u, b, dn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_basic():
    _check(bsz=4, k=128, d=64, block_k=64)


def test_single_block():
    _check(bsz=1, k=64, d=64, block_k=64)


def test_many_blocks():
    _check(bsz=2, k=512, d=64, block_k=64)


def test_block_k_one():
    _check(bsz=1, k=4, d=8, block_k=1)


def test_rejects_misaligned_k():
    x, u, b, dn = _mk(0, 1, 100, 16)
    with pytest.raises(ValueError):
        sparse_ffn(x, u, b, dn, block_k=64)


def test_zero_padding_slots_are_inert():
    """Core gather-path invariant: all-zero bundle slots contribute 0."""
    x, u, b, dn = _mk(3, 2, 64, 32)
    pad = 64
    u_p = jnp.concatenate([u, jnp.zeros((pad, 32))])
    b_p = jnp.concatenate([b, jnp.zeros((pad,))])
    d_p = jnp.concatenate([dn, jnp.zeros((pad, 32))])
    got = sparse_ffn(x, u_p, b_p, d_p, block_k=32)
    want = sparse_ffn_ref(x, u, b, dn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_slot_permutation_invariance():
    """Slot order never matters: the FFN sum is commutative over slots."""
    x, u, b, dn = _mk(4, 2, 128, 64)
    perm = np.random.default_rng(5).permutation(128)
    got = sparse_ffn(x, u[perm], b[perm], dn[perm], block_k=64)
    want = sparse_ffn(x, u, b, dn, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    bsz=st.integers(1, 8),
    d=st.sampled_from([8, 16, 64, 128]),
    blocks=st.integers(1, 6),
    block_k=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2 ** 16),
)
def test_hypothesis_shape_sweep(bsz, d, blocks, block_k, seed):
    _check(bsz=bsz, k=blocks * block_k, d=d, block_k=block_k, seed=seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_hypothesis_matches_dense_when_k_equals_n(seed):
    """With every neuron gathered, sparse == dense by construction."""
    _check(bsz=4, k=256, d=64, block_k=64, seed=seed)


def test_vmem_footprint_fits_budget():
    """opt-micro tile config must fit a 16MiB VMEM with wide margin, and
    the Table-3 geometries (d=4096) must still fit with block_k=64."""
    assert vmem_footprint_bytes(4, 64, 64) < 16 * 2 ** 20
    assert vmem_footprint_bytes(1, 4096, 64) < 16 * 2 ** 20


def test_mxu_estimate_monotone():
    assert mxu_utilization_estimate(1, 128, 128) == 1.0
    assert mxu_utilization_estimate(1, 64, 64) == 0.25
    assert (mxu_utilization_estimate(1, 64, 32)
            < mxu_utilization_estimate(1, 64, 64))


# ---------------------------------------------------------------------------
# int8 kernel variant
# ---------------------------------------------------------------------------

from compile.kernels.ref import sparse_ffn_q8_ref
from compile.kernels.sparse_ffn import quantize_rows, sparse_ffn_q8


def _mk_q8(seed, bsz, k, d):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((bsz, d), np.float32))
    u = jnp.asarray(rng.standard_normal((k, d), np.float32) * 0.1)
    b = jnp.asarray(rng.standard_normal((k,), np.float32) * 0.1)
    dn = jnp.asarray(rng.standard_normal((k, d), np.float32) * 0.1)
    uq, us = quantize_rows(u)
    dq, ds = quantize_rows(dn)
    return x, uq, us, b, dq, ds, u, dn


def test_q8_matches_dequant_oracle():
    x, uq, us, b, dq, ds, _, _ = _mk_q8(0, 4, 128, 64)
    got = sparse_ffn_q8(x, uq, us, b, dq, ds, block_k=64)
    want = sparse_ffn_q8_ref(x, uq, us, b, dq, ds)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_q8_close_to_fp32():
    """Quantization error is bounded: int8 output tracks fp32 output."""
    x, uq, us, b, dq, ds, u, dn = _mk_q8(1, 2, 128, 64)
    q = np.asarray(sparse_ffn_q8(x, uq, us, b, dq, ds, block_k=64))
    f = np.asarray(sparse_ffn_ref(x, u, b, dn))
    denom = np.abs(f).mean() + 1e-6
    rel = np.abs(q - f).mean() / denom
    assert rel < 0.05, f"relative error {rel:.4f}"


def test_quantize_rows_bounds():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((16, 32), np.float32))
    q, s = quantize_rows(w)
    assert q.dtype == jnp.int8
    assert np.abs(np.asarray(q)).max() <= 127
    back = np.asarray(q, np.float32) * np.asarray(s)[:, None]
    np.testing.assert_allclose(back, np.asarray(w), atol=np.asarray(s).max())


@settings(max_examples=10, deadline=None)
@given(
    bsz=st.integers(1, 4),
    blocks=st.integers(1, 4),
    block_k=st.sampled_from([16, 32]),
    seed=st.integers(0, 2 ** 16),
)
def test_hypothesis_q8_shape_sweep(bsz, blocks, block_k, seed):
    x, uq, us, b, dq, ds, _, _ = _mk_q8(seed, bsz, blocks * block_k, 32)
    got = sparse_ffn_q8(x, uq, us, b, dq, ds, block_k=block_k)
    want = sparse_ffn_q8_ref(x, uq, us, b, dq, ds)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
