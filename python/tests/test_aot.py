"""AOT emission: HLO text artifacts parse, weights round-trip, manifest
agrees with param shapes.  Uses a reduced config so the test is fast; the
full `make artifacts` path is exercised by the build."""

import json
import os
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model as M


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(s, s))
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_lower_blocks_all_emit(tmp_path):
    cfg = M.CFG
    names = []
    for name, lowered in aot.lower_blocks(cfg):
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, name
        # every block is lowered with return_tuple=True
        assert "ROOT" in text, name
        names.append(name)
    for bsz in aot.BATCH_VARIANTS:
        for kind in ("attn", "ffn_sparse", "ffn_dense", "predictor", "head"):
            assert f"{kind}_b{bsz}" in names


def test_weights_roundtrip(tmp_path):
    cfg = M.ModelConfig(vocab=32, d_model=16, n_heads=2, n_layers=2,
                        d_ffn=32, max_seq=16, top_k=16, pred_rank=4)
    params = M.init_params(cfg, seed=3)
    preds = M.predictor_params(params, cfg)
    tensors = aot.flatten_params(params, preds)
    bin_path = tmp_path / "weights.bin"
    man_path = tmp_path / "manifest.json"
    aot.write_weights(str(bin_path), str(man_path), tensors)

    man = json.loads(man_path.read_text())
    raw = np.fromfile(bin_path, np.float32)
    assert man["dtype"] == "f32"
    assert man["total_bytes"] == raw.size * 4
    for name, arr in tensors:
        meta = man["tensors"][name]
        a = np.asarray(arr, np.float32)
        assert meta["shape"] == list(a.shape)
        got = raw[meta["offset_bytes"] // 4:
                  meta["offset_bytes"] // 4 + meta["num_elems"]]
        np.testing.assert_array_equal(got, a.ravel())


def test_manifest_contains_all_layer_tensors(tmp_path):
    cfg = M.ModelConfig(vocab=32, d_model=16, n_heads=2, n_layers=3,
                        d_ffn=32, max_seq=16, top_k=16, pred_rank=4)
    params = M.init_params(cfg, seed=1)
    preds = M.predictor_params(params, cfg)
    names = [n for n, _ in aot.flatten_params(params, preds)]
    for li in range(cfg.n_layers):
        for t in ("u", "bu", "dn", "bd", "wq", "p1", "p2"):
            assert f"layer{li}.{t}" in names


def test_golden_decode_is_deterministic():
    cfg = M.ModelConfig(vocab=256, d_model=32, n_heads=4, n_layers=2,
                        d_ffn=64, max_seq=32, top_k=32, pred_rank=4)
    params = M.init_params(cfg, seed=9)
    g1 = aot.make_golden(params, cfg, prompt=b"ab", steps=4)
    g2 = aot.make_golden(params, cfg, prompt=b"ab", steps=4)
    assert g1["generated"] == g2["generated"]
    assert g1["last_logits"] == g2["last_logits"]
    assert len(g1["first_logits"]) == cfg.vocab
