"""L2 correctness: opt-micro blocks, decode path, predictor quality."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(vocab=64, d_model=32, n_heads=4, n_layers=2,
                    d_ffn=128, max_seq=32, top_k=64, pred_rank=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=7)


def test_param_shapes(params):
    assert params["embed"].shape == (CFG.vocab, CFG.d_model)
    assert len(params["layers"]) == CFG.n_layers
    lp = params["layers"][0]
    assert lp["u"].shape == (CFG.d_ffn, CFG.d_model)
    assert lp["dn"].shape == (CFG.d_ffn, CFG.d_model)


def test_sparse_block_equals_dense_when_full(params):
    """ffn_sparse_block over ALL neurons == ffn_dense_block exactly."""
    lp = params["layers"][0]
    x = jax.random.normal(jax.random.PRNGKey(0), (4, CFG.d_model))
    got = M.ffn_sparse_block(x, lp["ln2_g"], lp["ln2_b"],
                             lp["u"], lp["bu"], lp["dn"], lp["bd"])
    want = M.ffn_dense_block(x, lp["ln2_g"], lp["ln2_b"],
                             lp["u"], lp["bu"], lp["dn"], lp["bd"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sparse_block_with_true_active_set_is_exact(params):
    """Gathering exactly the ReLU-active neurons loses nothing."""
    lp = params["layers"][1]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, CFG.d_model))
    mask = np.asarray(M.ffn_activations(params, x, 1, CFG)).any(axis=0)
    idx = np.nonzero(mask)[0]
    pad = (-len(idx)) % 64
    u = jnp.concatenate([lp["u"][idx], jnp.zeros((pad, CFG.d_model))])
    bu = jnp.concatenate([lp["bu"][idx], jnp.zeros((pad,))])
    dn = jnp.concatenate([lp["dn"][idx], jnp.zeros((pad, CFG.d_model))])
    got = M.ffn_sparse_block(x, lp["ln2_g"], lp["ln2_b"], u, bu, dn, lp["bd"])
    want = M.ffn_dense_block(x, lp["ln2_g"], lp["ln2_b"],
                             lp["u"], lp["bu"], lp["dn"], lp["bd"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_attn_block_updates_cache(params):
    lp = params["layers"][0]
    bsz = 2
    x = jax.random.normal(jax.random.PRNGKey(2), (bsz, CFG.d_model))
    kc = jnp.zeros((bsz, CFG.max_seq, CFG.d_model))
    vc = jnp.zeros((bsz, CFG.max_seq, CFG.d_model))
    y, kc2, vc2 = M.attn_block(
        x, lp["ln1_g"], lp["ln1_b"], lp["wq"], lp["bq"], lp["wk"], lp["bk"],
        lp["wv"], lp["bv"], lp["wo"], lp["bo"], kc, vc, 3,
        n_heads=CFG.n_heads)
    assert y.shape == (bsz, CFG.d_model)
    assert np.abs(np.asarray(kc2[:, 3])).sum() > 0
    np.testing.assert_array_equal(np.asarray(kc2[:, 4:]), 0.0)


def test_attn_pos0_attends_only_self(params):
    """At pos=0 the context is exactly v(x): softmax over one element."""
    lp = params["layers"][0]
    x = jax.random.normal(jax.random.PRNGKey(3), (1, CFG.d_model))
    kc = vc = jnp.zeros((1, CFG.max_seq, CFG.d_model))
    y, _, vc2 = M.attn_block(
        x, lp["ln1_g"], lp["ln1_b"], lp["wq"], lp["bq"], lp["wk"], lp["bk"],
        lp["wv"], lp["bv"], lp["wo"], lp["bo"], kc, vc, 0,
        n_heads=CFG.n_heads)
    want = x + np.asarray(vc2[:, 0]) @ np.asarray(lp["wo"]) + np.asarray(lp["bo"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_decode_step_shapes(params):
    bsz = 1
    kc = [jnp.zeros((bsz, CFG.max_seq, CFG.d_model))] * CFG.n_layers
    vc = [jnp.zeros((bsz, CFG.max_seq, CFG.d_model))] * CFG.n_layers
    logits, kc, vc = M.decode_step_dense(
        params, jnp.asarray([5], jnp.int32), kc, vc, 0, CFG)
    assert logits.shape == (bsz, CFG.vocab)
    assert len(kc) == CFG.n_layers


def test_decode_deterministic(params):
    bsz = 1
    ids = jnp.asarray([1], jnp.int32)
    outs = []
    for _ in range(2):
        kc = [jnp.zeros((bsz, CFG.max_seq, CFG.d_model))] * CFG.n_layers
        vc = [jnp.zeros((bsz, CFG.max_seq, CFG.d_model))] * CFG.n_layers
        logits, _, _ = M.decode_step_dense(params, ids, kc, vc, 0, CFG)
        outs.append(np.asarray(logits))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_predictor_recall(params):
    """The SVD predictor must catch nearly all truly-active neurons when
    thresholded at 0 (high recall is what the serving path relies on)."""
    preds = M.predictor_params(params, CFG)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, CFG.d_model))
    lp = params["layers"][0]
    truth = np.asarray(M.ffn_activations(params, x, 0, CFG))
    scores = np.asarray(M.predictor_block(
        x, lp["ln2_g"], lp["ln2_b"], preds[0]["p1"], preds[0]["p2"]))
    predicted = scores > -0.1  # slack threshold, as the engine uses
    recall = (predicted & truth).sum() / max(truth.sum(), 1)
    assert recall > 0.85, f"predictor recall too low: {recall:.3f}"


def test_activation_sparsity_reasonable(params):
    """ReLU produces real sparsity (not ~0%, not ~100% active)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (32, CFG.d_model))
    act = np.asarray(M.ffn_activations(params, x, 0, CFG))
    frac = act.mean()
    assert 0.05 < frac < 0.95


def test_train_reduces_loss():
    cfg = M.ModelConfig(vocab=256, d_model=32, n_heads=4, n_layers=2,
                        d_ffn=64, max_seq=64, top_k=32, pred_rank=4)
    p = M.init_params(cfg, seed=0)
    p, losses = M.train(p, cfg, steps=30, bsz=8, seq=32, log=None)
    assert losses[-1] < losses[0]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), pos=st.integers(0, 30))
def test_hypothesis_attn_matches_ref(params, seed, pos):
    lp = params["layers"][0]
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, CFG.d_model))
    kc = jax.random.normal(jax.random.PRNGKey(seed + 1),
                           (1, CFG.max_seq, CFG.d_model)) * 0.1
    vc = jax.random.normal(jax.random.PRNGKey(seed + 2),
                           (1, CFG.max_seq, CFG.d_model)) * 0.1
    y1, k1, v1 = M.attn_block(
        x, lp["ln1_g"], lp["ln1_b"], lp["wq"], lp["bq"], lp["wk"], lp["bk"],
        lp["wv"], lp["bv"], lp["wo"], lp["bo"], kc, vc, pos,
        n_heads=CFG.n_heads)
    y2, k2, v2 = ref.attn_ref(
        x, lp["ln1_g"], lp["ln1_b"], lp["wq"], lp["bq"], lp["wk"], lp["bk"],
        lp["wv"], lp["bv"], lp["wo"], lp["bo"], kc, vc, pos, CFG.n_heads)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)
