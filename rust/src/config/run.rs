//! Run configuration: ties a model geometry, device, precision, cache,
//! pipeline and prefetch knobs together. Loadable from JSON (examples/
//! and the CLI).

use crate::prefetch::PrefetchConfig;
use crate::util::json::Json;

use super::{DeviceConfig, ModelConfig, Precision, device_by_name, model_by_name};

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub device: DeviceConfig,
    pub precision: Precision,
    /// Fraction of all FFN bundles that fit the DRAM cache (paper: 0.1).
    pub cache_ratio: f64,
    /// Access-collapse initial gap threshold in bundles (adapted online).
    pub collapse_threshold: usize,
    /// Enable RIPPLE's access collapse.
    pub collapse: bool,
    /// Cache admission policy: "linking" (RIPPLE), "s3fifo", "lru", "none".
    pub cache_policy: String,
    /// Placement policy: "ripple", "structural", "frequency", "llmflash".
    pub placement: String,
    /// Speculative next-layer prefetch on the async flash timeline.
    pub prefetch: bool,
    /// Per-layer speculative read budget, bytes.
    pub prefetch_budget_bytes: usize,
    /// Layers of lookahead for speculation (>= 1).
    pub prefetch_lookahead: usize,
    /// RNG seed for workload generation.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        let pf = PrefetchConfig::default();
        Self {
            model: model_by_name("OPT-350M").unwrap(),
            device: device_by_name("OnePlus 12").unwrap(),
            precision: Precision::Fp16,
            cache_ratio: 0.1,
            collapse_threshold: 4,
            collapse: true,
            cache_policy: "linking".to_string(),
            placement: "ripple".to_string(),
            prefetch: pf.enabled,
            prefetch_budget_bytes: pf.budget_bytes,
            prefetch_lookahead: pf.lookahead,
            seed: 42,
        }
    }
}

impl RunConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut cfg = RunConfig::default();
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            cfg.model = model_by_name(m)?;
        }
        if let Some(d) = j.get("device").and_then(Json::as_str) {
            cfg.device = device_by_name(d)?;
        }
        if let Some(p) = j.get("precision").and_then(Json::as_str) {
            cfg.precision = Precision::parse(p)?;
        }
        if let Some(v) = j.get("cache_ratio").and_then(Json::as_f64) {
            anyhow::ensure!((0.0..=1.0).contains(&v), "cache_ratio out of [0,1]");
            cfg.cache_ratio = v;
        }
        if let Some(v) = j.get("collapse_threshold").and_then(Json::as_usize) {
            cfg.collapse_threshold = v;
        }
        if let Some(Json::Bool(b)) = j.get("collapse") {
            cfg.collapse = *b;
        }
        if let Some(v) = j.get("cache_policy").and_then(Json::as_str) {
            cfg.cache_policy = v.to_string();
        }
        if let Some(v) = j.get("placement").and_then(Json::as_str) {
            cfg.placement = v.to_string();
        }
        if let Some(Json::Bool(b)) = j.get("prefetch") {
            cfg.prefetch = *b;
        }
        if let Some(v) = j.get("prefetch_budget_bytes").and_then(Json::as_usize) {
            anyhow::ensure!(
                v <= 64 << 20,
                "prefetch_budget_bytes {v} unreasonable (max 64 MiB)"
            );
            cfg.prefetch_budget_bytes = v;
        }
        if let Some(v) = j.get("prefetch_lookahead").and_then(Json::as_usize) {
            anyhow::ensure!(v >= 1, "prefetch_lookahead must be >= 1");
            cfg.prefetch_lookahead = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            cfg.seed = v as u64;
        }
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(s)?)
    }

    /// DRAM cache capacity in bundles for this model.
    pub fn cache_capacity_bundles(&self) -> usize {
        (self.model.total_neurons() as f64 * self.cache_ratio) as usize
    }

    /// The prefetch knobs as a `prefetch::PrefetchConfig`.
    pub fn prefetch_config(&self) -> PrefetchConfig {
        PrefetchConfig {
            enabled: self.prefetch,
            budget_bytes: self.prefetch_budget_bytes,
            lookahead: self.prefetch_lookahead,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = RunConfig::default();
        assert_eq!(c.model.name, "OPT-350M");
        assert!(c.collapse);
    }

    #[test]
    fn from_json_overrides() {
        let c = RunConfig::from_json_str(
            r#"{"model": "Llama2-7B", "device": "OnePlus Ace 2",
                "precision": "int8", "cache_ratio": 0.2,
                "collapse": false, "placement": "structural", "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(c.model.name, "Llama2-7B");
        assert_eq!(c.device.name, "OnePlus Ace 2");
        assert_eq!(c.precision, Precision::Int8);
        assert!(!c.collapse);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::from_json_str(r#"{"model": "nope"}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"cache_ratio": 3.0}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"prefetch_lookahead": 0}"#).is_err());
        assert!(
            RunConfig::from_json_str(r#"{"prefetch_budget_bytes": 999999999999}"#).is_err()
        );
    }

    #[test]
    fn prefetch_knobs_parse() {
        let c = RunConfig::from_json_str(
            r#"{"prefetch": true, "prefetch_budget_bytes": 65536,
                "prefetch_lookahead": 2}"#,
        )
        .unwrap();
        assert!(c.prefetch);
        assert_eq!(c.prefetch_budget_bytes, 65536);
        assert_eq!(c.prefetch_lookahead, 2);
        let pf = c.prefetch_config();
        assert!(pf.enabled);
        assert_eq!(pf.budget_slots(4096), 16);
        // default stays off: bit-compatible with the synchronous baseline
        assert!(!RunConfig::default().prefetch);
    }

    #[test]
    fn cache_capacity() {
        let mut c = RunConfig::default();
        c.cache_ratio = 0.1;
        let cap = c.cache_capacity_bundles();
        assert_eq!(cap, (c.model.total_neurons() as f64 * 0.1) as usize);
    }
}
