//! Run configuration: ties a model geometry, device, precision, cache,
//! pipeline and prefetch knobs together. Loadable from JSON (examples/
//! and the CLI).

use crate::cache::CacheParams;
use crate::prefetch::PrefetchConfig;
use crate::util::json::Json;

use super::{DeviceConfig, ModelConfig, Precision, device_by_name, model_by_name};

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub device: DeviceConfig,
    pub precision: Precision,
    /// Fraction of all FFN bundles that fit the DRAM cache (paper: 0.1).
    pub cache_ratio: f64,
    /// Access-collapse initial gap threshold in bundles (adapted online).
    pub collapse_threshold: usize,
    /// Enable RIPPLE's access collapse.
    pub collapse: bool,
    /// Cache eviction/admission policy: "linking" (RIPPLE), "s3fifo",
    /// "lru", "victim", "setassoc", "costaware", "none".
    pub cache_policy: String,
    /// Set-associativity for the "setassoc" policy (>= 1; other
    /// policies ignore it).
    pub cache_ways: usize,
    /// Linking admission: runs shorter than this many bundles always
    /// admit (they are sporadic, not linked segments).
    pub admission_segment_min: u32,
    /// Linking admission: all-or-nothing admission probability for
    /// segments of at least `admission_segment_min` bundles, in [0, 1].
    pub admission_segment_p: f64,
    /// Placement policy: "ripple", "structural", "frequency", "llmflash".
    pub placement: String,
    /// Speculative next-layer prefetch on the async flash timeline.
    pub prefetch: bool,
    /// Per-layer speculative read budget, bytes.
    pub prefetch_budget_bytes: usize,
    /// Layers of lookahead for speculation (>= 1).
    pub prefetch_lookahead: usize,
    /// RNG seed for workload generation.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        let pf = PrefetchConfig::default();
        Self {
            model: model_by_name("OPT-350M").unwrap(),
            device: device_by_name("OnePlus 12").unwrap(),
            precision: Precision::Fp16,
            cache_ratio: 0.1,
            collapse_threshold: 4,
            collapse: true,
            cache_policy: "linking".to_string(),
            cache_ways: CacheParams::default().ways,
            admission_segment_min: CacheParams::default().segment_min,
            admission_segment_p: CacheParams::default().segment_p,
            placement: "ripple".to_string(),
            prefetch: pf.enabled,
            prefetch_budget_bytes: pf.budget_bytes,
            prefetch_lookahead: pf.lookahead,
            seed: 42,
        }
    }
}

impl RunConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut cfg = RunConfig::default();
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            cfg.model = model_by_name(m)?;
        }
        if let Some(d) = j.get("device").and_then(Json::as_str) {
            cfg.device = device_by_name(d)?;
        }
        if let Some(p) = j.get("precision").and_then(Json::as_str) {
            cfg.precision = Precision::parse(p)?;
        }
        if let Some(v) = j.get("cache_ratio").and_then(Json::as_f64) {
            anyhow::ensure!((0.0..=1.0).contains(&v), "cache_ratio out of [0,1]");
            cfg.cache_ratio = v;
        }
        if let Some(v) = j.get("collapse_threshold").and_then(Json::as_usize) {
            cfg.collapse_threshold = v;
        }
        if let Some(Json::Bool(b)) = j.get("collapse") {
            cfg.collapse = *b;
        }
        if let Some(v) = j.get("cache_policy").and_then(Json::as_str) {
            // canonicalize early so a typo fails at load, not mid-run
            cfg.cache_policy = crate::cache::policy_name(v)?.to_string();
        }
        if let Some(v) = j.get("cache_ways").and_then(Json::as_usize) {
            anyhow::ensure!(v >= 1, "cache_ways must be >= 1");
            cfg.cache_ways = v;
        }
        if let Some(v) = j.get("admission_segment_min").and_then(Json::as_usize) {
            cfg.admission_segment_min = v as u32;
        }
        if let Some(v) = j.get("admission_segment_p").and_then(Json::as_f64) {
            anyhow::ensure!(
                (0.0..=1.0).contains(&v),
                "admission_segment_p out of [0,1]"
            );
            cfg.admission_segment_p = v;
        }
        if let Some(v) = j.get("placement").and_then(Json::as_str) {
            cfg.placement = v.to_string();
        }
        if let Some(Json::Bool(b)) = j.get("prefetch") {
            cfg.prefetch = *b;
        }
        if let Some(v) = j.get("prefetch_budget_bytes").and_then(Json::as_usize) {
            anyhow::ensure!(
                v <= 64 << 20,
                "prefetch_budget_bytes {v} unreasonable (max 64 MiB)"
            );
            cfg.prefetch_budget_bytes = v;
        }
        if let Some(v) = j.get("prefetch_lookahead").and_then(Json::as_usize) {
            anyhow::ensure!(v >= 1, "prefetch_lookahead must be >= 1");
            cfg.prefetch_lookahead = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            cfg.seed = v as u64;
        }
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(s)?)
    }

    /// DRAM cache capacity in bundles for this model.
    pub fn cache_capacity_bundles(&self) -> usize {
        (self.model.total_neurons() as f64 * self.cache_ratio) as usize
    }

    /// The cache tuning knobs as a `cache::CacheParams` — what
    /// `NeuronCache::from_config_with` consumes. The defaults reproduce
    /// the historically hard-coded `Admission::Linking { segment_min:
    /// 4, segment_p: 0.5 }` and `DEFAULT_WAYS` exactly.
    pub fn cache_params(&self) -> CacheParams {
        CacheParams {
            ways: self.cache_ways,
            segment_min: self.admission_segment_min,
            segment_p: self.admission_segment_p,
        }
    }

    /// The prefetch knobs as a `prefetch::PrefetchConfig`.
    pub fn prefetch_config(&self) -> PrefetchConfig {
        PrefetchConfig {
            enabled: self.prefetch,
            budget_bytes: self.prefetch_budget_bytes,
            lookahead: self.prefetch_lookahead,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = RunConfig::default();
        assert_eq!(c.model.name, "OPT-350M");
        assert!(c.collapse);
    }

    #[test]
    fn from_json_overrides() {
        let c = RunConfig::from_json_str(
            r#"{"model": "Llama2-7B", "device": "OnePlus Ace 2",
                "precision": "int8", "cache_ratio": 0.2,
                "collapse": false, "placement": "structural", "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(c.model.name, "Llama2-7B");
        assert_eq!(c.device.name, "OnePlus Ace 2");
        assert_eq!(c.precision, Precision::Int8);
        assert!(!c.collapse);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::from_json_str(r#"{"model": "nope"}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"cache_ratio": 3.0}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"prefetch_lookahead": 0}"#).is_err());
        assert!(
            RunConfig::from_json_str(r#"{"prefetch_budget_bytes": 999999999999}"#).is_err()
        );
        assert!(RunConfig::from_json_str(r#"{"cache_policy": "bogus"}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"cache_ways": 0}"#).is_err());
        assert!(
            RunConfig::from_json_str(r#"{"admission_segment_p": 1.5}"#).is_err()
        );
    }

    #[test]
    fn cache_knobs_parse_and_default_to_the_historical_values() {
        // regression pin for the once-hard-coded admission constants:
        // an empty config must still mean Linking{min 4, p 0.5}, ways 4
        let d = RunConfig::default().cache_params();
        assert_eq!(d, CacheParams::default());
        assert_eq!(d.segment_min, 4);
        assert!((d.segment_p - 0.5).abs() < 1e-12);
        assert_eq!(d.ways, 4);
        let c = RunConfig::from_json_str(
            r#"{"cache_policy": "setassoc", "cache_ways": 8,
                "admission_segment_min": 2, "admission_segment_p": 0.25}"#,
        )
        .unwrap();
        assert_eq!(c.cache_policy, "setassoc");
        let p = c.cache_params();
        assert_eq!(p.ways, 8);
        assert_eq!(p.segment_min, 2);
        assert!((p.segment_p - 0.25).abs() < 1e-12);
    }

    #[test]
    fn prefetch_knobs_parse() {
        let c = RunConfig::from_json_str(
            r#"{"prefetch": true, "prefetch_budget_bytes": 65536,
                "prefetch_lookahead": 2}"#,
        )
        .unwrap();
        assert!(c.prefetch);
        assert_eq!(c.prefetch_budget_bytes, 65536);
        assert_eq!(c.prefetch_lookahead, 2);
        let pf = c.prefetch_config();
        assert!(pf.enabled);
        assert_eq!(pf.budget_slots(4096), 16);
        // default stays off: bit-compatible with the synchronous baseline
        assert!(!RunConfig::default().prefetch);
    }

    #[test]
    fn cache_capacity() {
        let mut c = RunConfig::default();
        c.cache_ratio = 0.1;
        let cap = c.cache_capacity_bundles();
        assert_eq!(cap, (c.model.total_neurons() as f64 * 0.1) as usize);
    }
}
