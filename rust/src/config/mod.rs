//! Model geometries (paper Table 3), smartphone device models (Table 2),
//! precision settings (Figure 17) and run configuration.

mod device;
mod model;
mod run;

pub use device::{DeviceConfig, UfsGeneration, devices, device_by_name};
pub use model::{ModelConfig, models, model_by_name, opt_micro};
pub use run::RunConfig;

/// Floating-point precision of stored neurons (Figure 17 sweeps this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
}

impl Precision {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Precision::Fp32 => 4,
            Precision::Fp16 => 2,
            Precision::Int8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "fp32" | "f32" => Ok(Precision::Fp32),
            "fp16" | "f16" => Ok(Precision::Fp16),
            "int8" | "i8" => Ok(Precision::Int8),
            _ => anyhow::bail!("unknown precision `{s}` (fp32|fp16|int8)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_sizes() {
        assert_eq!(Precision::Fp16.bytes_per_elem(), 2);
        assert_eq!(Precision::parse("int8").unwrap(), Precision::Int8);
        assert!(Precision::parse("fp64").is_err());
    }
}
