//! Smartphone device models — paper Table 2.
//!
//! The UFS parameters are calibrated so that the simulated
//! bandwidth-vs-I/O-size curve reproduces the paper's Figure 4:
//! throughput is near-linear in continuous read size below ~24 KB
//! (IOPS-bound: each command costs a fixed service slot on the device)
//! and saturates at the interface's sustained rate beyond that.
//!
//! The service model (see flash::UfsSim) is
//! `t(cmd of s bytes) = cmd_latency + s / sat_bandwidth`, executed
//! serially by the device with a `queue_depth`-entry command queue that
//! pipelines host submission. The IOPS/bandwidth crossover point is
//! `cmd_latency * sat_bandwidth` ≈ 24 KB for UFS 4.0.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UfsGeneration {
    Ufs31,
    Ufs40,
}

#[derive(Clone, Debug)]
pub struct DeviceConfig {
    pub name: &'static str,
    pub soc: &'static str,
    pub dram_gb: usize,
    pub flash_gb: usize,
    pub ufs: UfsGeneration,
    /// Sustained (saturated) read bandwidth, bytes/sec.
    pub sat_bandwidth: f64,
    /// Fixed per-command device service latency, nanoseconds.
    pub cmd_latency_ns: f64,
    /// Host-side submission overhead per command, nanoseconds (pipelined
    /// across the command queue; scales inversely with SoC speed).
    pub submit_overhead_ns: f64,
    /// Synchronous (queue-depth-1) read latency, nanoseconds: the cost of
    /// an mmap page-fault style read that cannot overlap in the command
    /// queue. llama.cpp's offload path reads through mmap and pays this
    /// per fault — the paper's Table 1 / Figure 10 llama.cpp numbers are
    /// only explicable at this latency, not at queued-command cost.
    pub sync_latency_ns: f64,
    /// UFS command queue entries (the paper stresses this is only 32).
    pub queue_depth: usize,
    /// Relative SoC compute speed (OnePlus 12 = 1.0); scales compute
    /// latency estimates in Table-1-style breakdowns.
    pub soc_speed: f64,
}

impl DeviceConfig {
    /// Steady-state bandwidth for continuous reads of `io_bytes`
    /// (closed form of the flash sim; used for calibration tests).
    pub fn bandwidth_at(&self, io_bytes: usize) -> f64 {
        let t = self.cmd_latency_ns / 1e9 + io_bytes as f64 / self.sat_bandwidth;
        io_bytes as f64 / t
    }

    /// I/O size where IOPS-bound turns bandwidth-bound (Figure 4's knee).
    pub fn knee_bytes(&self) -> f64 {
        self.cmd_latency_ns / 1e9 * self.sat_bandwidth
    }

    /// Max small-read IOPS (device-serialized).
    pub fn max_iops(&self) -> f64 {
        1e9 / self.cmd_latency_ns
    }
}

/// Paper Table 2.
pub fn devices() -> Vec<DeviceConfig> {
    vec![
        DeviceConfig {
            name: "OnePlus 12",
            soc: "Snapdragon 8 Gen 3",
            dram_gb: 24,
            flash_gb: 1024,
            ufs: UfsGeneration::Ufs40,
            sat_bandwidth: 2.9e9,
            cmd_latency_ns: 8_500.0, // knee ~= 24.6 KB
            submit_overhead_ns: 1_200.0,
            sync_latency_ns: 110_000.0,
            queue_depth: 32,
            soc_speed: 1.0,
        },
        DeviceConfig {
            name: "OnePlus Ace 3",
            soc: "Snapdragon 8 Gen 2",
            dram_gb: 16,
            flash_gb: 512,
            ufs: UfsGeneration::Ufs40,
            sat_bandwidth: 2.9e9,
            cmd_latency_ns: 8_500.0,
            submit_overhead_ns: 1_450.0,
            sync_latency_ns: 118_000.0,
            queue_depth: 32,
            soc_speed: 0.88,
        },
        DeviceConfig {
            name: "OnePlus Ace 2",
            soc: "Snapdragon 8+ Gen 1",
            dram_gb: 16,
            flash_gb: 512,
            ufs: UfsGeneration::Ufs31,
            sat_bandwidth: 1.45e9, // ~half of UFS 4.0, per paper Fig 16
            cmd_latency_ns: 17_000.0,
            submit_overhead_ns: 1_700.0,
            sync_latency_ns: 160_000.0,
            queue_depth: 32,
            soc_speed: 0.78,
        },
    ]
}

pub fn device_by_name(name: &str) -> anyhow::Result<DeviceConfig> {
    devices()
        .into_iter()
        .find(|d| d.name.eq_ignore_ascii_case(name))
        .ok_or_else(|| {
            anyhow::anyhow!("unknown device `{name}` (OnePlus 12|OnePlus Ace 3|OnePlus Ace 2)")
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_devices() {
        let ds = devices();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds[0].dram_gb, 24);
        assert_eq!(ds[2].ufs, UfsGeneration::Ufs31);
        assert!(ds.iter().all(|d| d.queue_depth == 32));
    }

    #[test]
    fn figure4_knee_near_24kb() {
        let op12 = &devices()[0];
        let knee = op12.knee_bytes();
        assert!((20_000.0..30_000.0).contains(&knee), "knee={knee}");
    }

    #[test]
    fn figure4_linear_region() {
        // Below the knee, doubling I/O size ~doubles bandwidth.
        let op12 = &devices()[0];
        let b4 = op12.bandwidth_at(4 * 1024);
        let b8 = op12.bandwidth_at(8 * 1024);
        let ratio = b8 / b4;
        assert!((1.6..2.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn figure4_saturation() {
        let op12 = &devices()[0];
        let b = op12.bandwidth_at(4 * 1024 * 1024);
        assert!(b > 0.95 * op12.sat_bandwidth);
    }

    #[test]
    fn ace2_roughly_half_of_op12() {
        // Figure 16: OP Ace2 ~half the performance of OP12 on small reads.
        let ds = devices();
        let r = ds[0].bandwidth_at(8 * 1024) / ds[2].bandwidth_at(8 * 1024);
        assert!((1.7..2.4).contains(&r), "ratio={r}");
    }
}
