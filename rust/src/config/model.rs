//! Model geometries — paper Table 3 plus the AOT-served `opt-micro`.
//!
//! Only *geometry* matters for the I/O experiments (neuron count, neuron
//! dimension, layer count, FFN linear-layer count, sparsity); weight
//! values never influence read patterns. opt-micro additionally has real
//! trained weights in `artifacts/` and runs through PJRT.

use super::Precision;

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: &'static str,
    /// Total parameter count (reporting only).
    pub n_params: u64,
    pub n_layers: usize,
    /// FFN neurons (= bundles) per FFN block.
    pub neurons_per_layer: usize,
    /// Neuron (hidden) dimension.
    pub neuron_dim: usize,
    /// Linear layers bound into one neuron bundle: 2 for OPT (up+down),
    /// 3 for Llama2/Mistral (gate+up+down).
    pub ffn_linears: usize,
    /// Average fraction of neurons activated per token (Table 3).
    pub sparsity: f64,
}

impl ModelConfig {
    /// Bytes of one neuron *bundle* at the given precision:
    /// `ffn_linears` vectors of `neuron_dim` elements (+1 bias element).
    pub fn bundle_bytes(&self, prec: Precision) -> usize {
        (self.ffn_linears * self.neuron_dim + 1) * prec.bytes_per_elem()
    }

    /// Expected activated neurons per layer per token.
    pub fn activated_per_layer(&self) -> usize {
        ((self.neurons_per_layer as f64) * self.sparsity).round().max(1.0) as usize
    }

    /// Total FFN bundles across all layers.
    pub fn total_neurons(&self) -> usize {
        self.n_layers * self.neurons_per_layer
    }

    /// FFN FLOPs per token (dense): 2 * linears * neurons * dim per layer.
    pub fn ffn_flops_dense(&self) -> f64 {
        2.0 * self.ffn_linears as f64
            * self.neurons_per_layer as f64
            * self.neuron_dim as f64
            * self.n_layers as f64
    }

    /// Non-FFN (attention etc.) FLOPs per token, crude transformer
    /// estimate: 4 d² per layer projections x2 matmuls.
    pub fn attn_flops(&self) -> f64 {
        8.0 * (self.neuron_dim as f64).powi(2) * self.n_layers as f64
    }
}

/// Paper Table 3.
pub fn models() -> Vec<ModelConfig> {
    vec![
        ModelConfig {
            name: "OPT-350M",
            n_params: 350_000_000,
            n_layers: 24,
            neurons_per_layer: 8_192 / 2, // 8192 total rows+cols = 4096 bundles
            neuron_dim: 1024,
            ffn_linears: 2,
            sparsity: 0.0949,
        },
        ModelConfig {
            name: "OPT-1.3B",
            n_params: 1_300_000_000,
            n_layers: 24,
            neurons_per_layer: 16_384 / 2,
            neuron_dim: 2048,
            ffn_linears: 2,
            sparsity: 0.0409,
        },
        ModelConfig {
            name: "OPT-6.7B",
            n_params: 6_700_000_000,
            n_layers: 32,
            neurons_per_layer: 32_768 / 2,
            neuron_dim: 4096,
            ffn_linears: 2,
            sparsity: 0.0328,
        },
        ModelConfig {
            name: "Llama2-7B",
            n_params: 7_000_000_000,
            n_layers: 32,
            neurons_per_layer: 33_024 / 3,
            neuron_dim: 4096,
            ffn_linears: 3,
            sparsity: 0.1388,
        },
        ModelConfig {
            name: "Mistral-7B",
            n_params: 7_300_000_000,
            n_layers: 32,
            neurons_per_layer: 43_008 / 3,
            neuron_dim: 4096,
            ffn_linears: 3,
            sparsity: 0.6052,
        },
    ]
}

/// The PJRT-served end-to-end model (see python/compile/model.py).
pub fn opt_micro() -> ModelConfig {
    ModelConfig {
        name: "opt-micro",
        n_params: 600_000,
        n_layers: 4,
        neurons_per_layer: 512,
        neuron_dim: 64,
        ffn_linears: 2,
        sparsity: 0.25,
    }
}

pub fn model_by_name(name: &str) -> anyhow::Result<ModelConfig> {
    if name == "opt-micro" {
        return Ok(opt_micro());
    }
    models()
        .into_iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model `{name}` (OPT-350M|OPT-1.3B|OPT-6.7B|Llama2-7B|Mistral-7B|opt-micro)"
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    #[test]
    fn table3_geometries() {
        let ms = models();
        assert_eq!(ms.len(), 5);
        let opt350 = &ms[0];
        assert_eq!(opt350.n_layers, 24);
        assert_eq!(opt350.neuron_dim, 1024);
        // fp16 bundle ~ 4KB for OPT-350M (2 linears x 1024 dims x 2B)
        let b = opt350.bundle_bytes(Precision::Fp16);
        assert!((4_000..4_200).contains(&b), "bundle={b}");
    }

    #[test]
    fn activated_counts() {
        let m = model_by_name("Mistral-7B").unwrap();
        let a = m.activated_per_layer();
        assert!((8_600..8_700).contains(&a), "activated={a}");
    }

    #[test]
    fn lookup_case_insensitive() {
        assert!(model_by_name("opt-6.7b").is_ok());
        assert!(model_by_name("gpt-5").is_err());
    }

    #[test]
    fn opt_micro_matches_python_config() {
        // Mirrors python/compile/model.py::ModelConfig defaults.
        let m = opt_micro();
        assert_eq!(m.n_layers, 4);
        assert_eq!(m.neurons_per_layer, 512);
        assert_eq!(m.neuron_dim, 64);
    }
}
