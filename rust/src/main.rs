//! `ripple` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   serve      start the serving coordinator on opt-micro and run a
//!              demo request stream (alias for examples/serve_llm)
//!   generate   one-shot generation from a prompt
//!   place      run the offline placement search on a synthetic workload
//!              and report continuity statistics
//!   simulate   trace-driven I/O simulation for one (model, device,
//!              dataset, system) point
//!   bench      run a named scenario-matrix preset and write the
//!              `BENCH_<name>.json` / `.md` report (DESIGN.md
//!              §Scenario-harness)
//!   trace-check
//!              validate a `--trace-out` Chrome-trace JSON file
//!              (schema + monotone per-track timestamps)
//!   devices / models
//!              list the Table-2 / Table-3 configurations
//!
//! Examples:
//!   ripple generate --prompt "the quick" --tokens 16
//!   ripple simulate --model OPT-6.7B --system ripple --dataset wikitext
//!   ripple place --model OPT-350M --dataset alpaca
//!   ripple bench --preset fig18 --baseline report/BENCH_fig18.json

use anyhow::Result;

use ripple::bench::workloads::{self, System, SystemSpec, Workload};
use ripple::config::{device_by_name, devices, model_by_name, models};
use ripple::coordinator::{
    run_fleet_traced, run_serve_traced, ArbiterPolicy, FleetConfig, FleetScheduler,
    ServeConfig, Server, ServerOptions,
};
use ripple::engine::{Engine, EngineOptions};
use ripple::harness;
use ripple::obs::{export, TraceConfig, TraceHandle};
use ripple::runtime::default_artifacts_dir;
use ripple::trace::{ArrivalProcess, DatasetProfile};
use ripple::util::cli::Args;
use ripple::util::stats::Table;

fn main() {
    let args = Args::from_env(&[
        "dense",
        "fleet",
        "help",
        "list",
        "no-collapse",
        "prefetch",
        "private-cache",
    ]);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "serve" => serve(&args),
        "generate" => generate(&args),
        "place" => place(&args),
        "simulate" => simulate(&args),
        "bench" => bench(&args),
        "trace-check" => trace_check(&args),
        "devices" => list_devices(),
        "models" => list_models(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "ripple — correlation-aware neuron management (paper reproduction)\n\n\
         usage: ripple <serve|generate|place|simulate|bench|trace-check|devices|models> \
         [options]\n\n\
         generate: --prompt <str> --tokens <n> [--dense]\n\
         serve:    --requests <n> --tokens <n> --workers <n> [--prefetch]\n\
                   --prefetch: workers speculatively read each next layer's\n\
                   predicted bundles on the overlapped (async) flash timeline\n\
                   so transfers hide under compute\n\
         place:    --model <name> --dataset <alpaca|openwebtext|wikitext> [--knn <m>]\n\
         simulate: --model <name> --device <name> --dataset <name>\n\
                   --system <llamacpp|llmflash|ripple-offline|ripple>\n\
                   [--config <runconfig.json>] [--cache-ratio <f>] [--tokens <n>]\n\
                   [--cache <linking|s3fifo|lru|victim|setassoc|costaware|none>]\n\
                   [--ways <n>] (associativity for --cache setassoc)\n\
                   [--no-collapse] [--prefetch] [--prefetch-budget <bytes>]\n\
                   [--prefetch-lookahead <n>]\n\
                   --prefetch: overlap flash reads with modeled compute via\n\
                   speculative next-layer prefetch (default: synchronous\n\
                   timeline, bit-identical to the pre-overlap baseline)\n\
                   [--sessions <n>] [--max-concurrent <slots>]\n\
                   [--session-arrival-ms <gap>] [--private-cache]\n\
                   --sessions: multi-session serving simulation — N\n\
                   continuous-batched decode streams through ONE shared\n\
                   DRAM cache and ONE flash timeline (per-session p50/p95/\n\
                   p99 latency, queueing delay, fairness, cross-session\n\
                   cache reuse); --private-cache splits the same total\n\
                   DRAM into per-session partitions for comparison\n\
                   --sessions with --prefetch runs each stream on the\n\
                   overlapped flash timeline; a per-round arbiter splits\n\
                   one global speculative byte budget across sessions:\n\
                   [--arbiter <fair|deadline>] [--deadline-target-ms <f>]\n\
                   [--prefetch-global-budget-kb <n>] (default global\n\
                   budget: per-session budget x sessions)\n\
                   --fleet: event-driven open-loop fleet simulation —\n\
                   sessions arrive by a stochastic process instead of\n\
                   all at once; an admission bound may reject them and\n\
                   a scheduler orders each decode round:\n\
                   [--fleet] [--sessions <n>] [--max-concurrent <slots>]\n\
                   [--arrival <fixed|poisson|bursty|diurnal>]\n\
                   [--arrival-rate <per-s>] [--arrival-spacing-ms <gap>]\n\
                   [--burst <n>] [--period-s <f>] [--depth <f>]\n\
                   [--scheduler <fifo|srt>] [--admission-bound <n>]\n\
                   [--slo-ms <f>]; with --prefetch the fleet decodes on\n\
                   the overlapped timeline under fair-share arbitration\n\
                   [--decode-threads <n>] (serving and fleet paths):\n\
                   plan each round's session I/O on an n-thread pool\n\
                   before the serial commit phase — results are\n\
                   bit-identical for every n, only wall-clock changes\n\
                   [--trace-out <trace.json>] [--trace-tail <k>]\n\
                   --trace-out: attach the flight recorder (observation-\n\
                   only, timeline stays bit-identical) and export a\n\
                   Chrome trace-event / Perfetto JSON file with one\n\
                   track per session plus device and arbiter tracks;\n\
                   --trace-tail keeps the K slowest token chains\n\
                   (default 32); works on all three simulate paths\n\
         bench:    --preset <name> [--threads <n>] [--decode-threads <n>]\n\
                   [--baseline <BENCH_x.json>] [--out <dir>] | --list\n\
                   runs a scenario matrix, prints the Markdown report and\n\
                   writes BENCH_<name>.json + .md under --out (default report/)\n\
                   --threads is the TOTAL budget shared between sweep\n\
                   workers and per-row decode pools; --decode-threads\n\
                   forces every row's pool width after expansion (names\n\
                   and JSON stay byte-identical across widths)\n\
                   --preset perf: decode-throughput proof — long eval\n\
                   streams whose wall-clock simulated-tokens/sec lands in\n\
                   the Markdown report only (JSON stays deterministic)\n\
                   --preset trace: flight-recorder demo — every row runs\n\
                   traced and the report carries per-phase attribution\n\
         trace-check: <trace.json> — validate a --trace-out file\n\
                   (parses, checks required keys, finite values and\n\
                   monotone per-track timestamps; exits non-zero on\n\
                   malformed traces)"
    );
}

fn generate(args: &Args) -> Result<()> {
    let prompt = args.get_or("prompt", "the quick brown ").as_bytes().to_vec();
    let tokens = args.get_usize("tokens", 16)?;
    let mut engine = Engine::load(default_artifacts_dir(), EngineOptions::default())?;
    let t0 = std::time::Instant::now();
    let out = engine.generate(&[prompt.clone()], tokens, args.flag("dense"))?;
    let dt = t0.elapsed().as_secs_f64();
    println!("prompt:    {:?}", String::from_utf8_lossy(&prompt));
    println!("generated: {:?}", String::from_utf8_lossy(&out[0]));
    println!(
        "{} tokens in {:.2}s wall ({:.1} tok/s), simulated I/O {:.2} ms/token, \
         {:.0} IOPS, effective bw {:.1} MB/s, cache hit {:.1}%",
        out[0].len(),
        dt,
        out[0].len() as f64 / dt,
        engine.io_metrics.mean_latency_ns() / 1e6,
        engine.io_metrics.iops(),
        engine.io_metrics.effective_bandwidth() / 1e6,
        100.0 * engine.io_metrics.totals.cached_bundles as f64
            / engine.io_metrics.totals.demanded_bundles.max(1) as f64,
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let n_requests = args.get_usize("requests", 8)?;
    let tokens = args.get_usize("tokens", 8)?;
    let workers = args.get_usize("workers", 1)?;
    let mut opts = ServerOptions { n_workers: workers, ..Default::default() };
    // workers self-calibrate a speculative predictor at startup
    opts.engine.prefetch.enabled = args.flag("prefetch");
    let server = Server::start(default_artifacts_dir(), opts)?;
    println!("serving {n_requests} requests x {tokens} tokens on {workers} worker(s)");
    let prompts = [
        "the quick brown ",
        "pack my box with ",
        "llm inference on ",
        "neuron co-activation ",
    ];
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| server.submit(prompts[i % prompts.len()].into(), tokens))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv()?;
        println!(
            "  req {i}: {:?} (worker {}, batch {}, queue {:.1} ms, engine {:.1} ms, \
             sim I/O {:.2} ms, overlap {:.0}%, pf hit/waste {}/{})",
            String::from_utf8_lossy(&r.generated),
            r.worker,
            r.batch_size,
            r.queue_ms,
            r.engine_ms,
            r.sim_io_ms,
            r.overlap_ratio * 100.0,
            r.prefetch_hit_bundles,
            r.prefetch_wasted_bundles,
        );
    }
    let stats = server.shutdown();
    println!(
        "served {} requests / {} tokens in {:.2}s -> {:.1} tok/s",
        stats.requests,
        stats.tokens,
        stats.wall_s,
        stats.tokens_per_sec()
    );
    Ok(())
}

fn place(args: &Args) -> Result<()> {
    let model = model_by_name(args.get_or("model", "OPT-350M"))?;
    let dataset = DatasetProfile::by_name(args.get_or("dataset", "alpaca"))?;
    let mut w = Workload::new(model, devices()[0].clone(), dataset);
    w.knn = args.get_usize("knn", w.knn)?;
    let calib = w.calibration_trace();
    let t0 = std::time::Instant::now();
    let stats = ripple::coact::CoactStats::from_trace_layer(&calib, 0);
    let r = ripple::placement::search(&stats, ripple::placement::GreedyParams { knn: w.knn, ..Default::default() });
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "model {} layer 0: {} neurons, search {:.2}s, {} links, {} fragments",
        w.model.name,
        r.layout.len(),
        secs,
        r.links_made,
        r.fragments
    );
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    if args.flag("list") {
        println!("available presets:");
        for p in harness::preset_names() {
            println!("  {p}");
        }
        return Ok(());
    }
    let matrix = harness::preset(args.get_or("preset", "smoke"))?;
    let threads = args.get_usize("threads", harness::default_threads())?;
    let baseline = match args.get("baseline") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading baseline `{path}`: {e}"))?;
            Some(harness::Baseline::parse(&text)?)
        }
        None => None,
    };
    // --decode-threads N re-runs the identical matrix with every row's
    // plan-phase pool forced to N (applied after expansion, so row
    // names and the JSON bytes never change — CI byte-cmp's the
    // reports across pool widths)
    let decode_override = match args.get("decode-threads") {
        None => None,
        Some(v) => {
            let n: usize = v.parse().map_err(|_| {
                anyhow::anyhow!("--decode-threads expects a positive integer")
            })?;
            anyhow::ensure!(n >= 1, "--decode-threads must be >= 1");
            Some(n)
        }
    };
    let out_dir = args.get_or("out", "report");
    let report = harness::run_matrix_with(&matrix, threads, decode_override)?;
    let md = report.to_markdown(baseline.as_ref());
    print!("{md}");
    std::fs::create_dir_all(out_dir)
        .map_err(|e| anyhow::anyhow!("creating `{out_dir}`: {e}"))?;
    let json_path = format!("{out_dir}/BENCH_{}.json", report.name);
    let md_path = format!("{out_dir}/BENCH_{}.md", report.name);
    std::fs::write(&json_path, report.json_string())
        .map_err(|e| anyhow::anyhow!("writing `{json_path}`: {e}"))?;
    std::fs::write(&md_path, &md)
        .map_err(|e| anyhow::anyhow!("writing `{md_path}`: {e}"))?;
    println!("\nwrote {json_path} and {md_path}");
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let dataset = DatasetProfile::by_name(args.get_or("dataset", "alpaca"))?;
    let system = System::by_key(args.get_or("system", "ripple"))?;
    // --config <file.json> loads a RunConfig (model/device/precision/
    // cache-ratio/seed + prefetch/cache knobs); explicit flags still
    // override.
    let mut cache_params = ripple::cache::CacheParams::default();
    let mut w = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config `{path}`: {e}"))?;
        let cfg = ripple::config::RunConfig::from_json_str(&text)?;
        cache_params = cfg.cache_params();
        Workload::from_run(&cfg, dataset)
    } else {
        let model = model_by_name(args.get_or("model", "OPT-350M"))?;
        let device = device_by_name(args.get_or("device", "OnePlus 12"))?;
        Workload::new(model, device, dataset)
    };
    w.cache_ratio = args.get_f64("cache-ratio", w.cache_ratio)?;
    w.eval_tokens = args.get_usize("tokens", w.eval_tokens)?;
    w.prefetch.enabled = w.prefetch.enabled || args.flag("prefetch");
    w.prefetch.budget_bytes =
        args.get_usize("prefetch-budget", w.prefetch.budget_bytes)?;
    w.prefetch.lookahead = args.get_usize("prefetch-lookahead", w.prefetch.lookahead)?;
    // same bounds the JSON config path enforces
    anyhow::ensure!(
        w.prefetch.lookahead >= 1,
        "--prefetch-lookahead must be >= 1"
    );
    anyhow::ensure!(
        w.prefetch.budget_bytes <= 64 << 20,
        "--prefetch-budget {} unreasonable (max 64 MiB)",
        w.prefetch.budget_bytes
    );
    anyhow::ensure!(
        !args.flag("sessions"),
        "--sessions needs a value (e.g. --sessions 4)"
    );
    // --cache / --ways select the DRAM eviction policy (cache-lab,
    // DESIGN.md §Cache-lab) on top of the system preset; every
    // simulate path (single-stream, --sessions, --fleet) honours them
    let mut sspec = SystemSpec::of(system, w.model.ffn_linears);
    sspec.cache_params = cache_params;
    if let Some(pol) = args.get("cache") {
        sspec.cache_policy = ripple::cache::policy_name(pol)?;
    }
    let ways = args.get_usize("ways", sspec.cache_params.ways)?;
    anyhow::ensure!(ways >= 1, "--ways must be >= 1");
    sspec.cache_params.ways = ways;
    if args.flag("fleet") {
        return simulate_fleet(args, &w, system, sspec);
    }
    if args.get("sessions").is_some() {
        return simulate_serve(args, &w, system, sspec);
    }
    let trace = trace_handle_from(args)?;
    let eval = w.dataset.clone();
    let r = workloads::run_spec_traced(&w, sspec, &eval, trace.as_ref())?;
    let mut t = Table::new(&[
        "system", "io ms/token", "e2e ms/token", "overlap", "IOPS", "eff bw MB/s",
        "mean access len", "place s",
    ]);
    t.row(&[
        r.system.name().into(),
        format!("{:.2}", r.latency_ms()),
        format!("{:.2}", r.e2e_ms()),
        format!("{:.0}%", r.overlap_ratio() * 100.0),
        format!("{:.0}", r.metrics.iops()),
        format!("{:.1}", r.metrics.effective_bandwidth() / 1e6),
        format!("{:.2}", r.metrics.mean_access_len()),
        format!("{:.2}", r.placement_secs),
    ]);
    t.print();
    finish_trace(args, trace.as_ref(), w.layer_scale())
}

/// Parse the `--trace-out` / `--trace-tail` knobs into an optional
/// flight-recorder handle. `None` (the default) leaves every simulate
/// path exactly as it was before tracing existed.
fn trace_handle_from(args: &Args) -> Result<Option<TraceHandle>> {
    if args.get("trace-out").is_none() {
        anyhow::ensure!(
            args.get("trace-tail").is_none(),
            "--trace-tail needs --trace-out"
        );
        return Ok(None);
    }
    let cfg = TraceConfig {
        tail_k: args.get_usize("trace-tail", TraceConfig::default().tail_k)?,
        ..TraceConfig::default()
    };
    Ok(Some(TraceHandle::new(cfg)))
}

/// Print the recorder's closure summary and export the Chrome-trace
/// JSON to the `--trace-out` path. No-op without a recorder.
fn finish_trace(args: &Args, trace: Option<&TraceHandle>, layer_scale: f64) -> Result<()> {
    let Some(t) = trace else { return Ok(()) };
    let at = t.with(|rec| rec.attribution(layer_scale));
    println!(
        "\ntrace: {} tokens, {} spans ({} dropped), accounted {:.2} ms vs \
         latency {:.2} ms (closure error {:.4} ms, {}/{} exact)",
        at.tokens,
        at.spans_recorded,
        at.spans_dropped,
        at.accounted_ms,
        at.latency_ms,
        at.closure_error_ms,
        at.exact_closures,
        at.tokens,
    );
    let path = args.get("trace-out").expect("finish_trace requires --trace-out");
    let json = t.with(|rec| export::chrome_trace_json(rec));
    std::fs::write(path, &json)
        .map_err(|e| anyhow::anyhow!("writing trace `{path}`: {e}"))?;
    println!("wrote {path} ({} bytes)", json.len());
    Ok(())
}

/// `trace-check <file>`: validate a `--trace-out` JSON file.
fn trace_check(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .map(String::as_str)
        .or_else(|| args.get("file"))
        .ok_or_else(|| anyhow::anyhow!("usage: ripple trace-check <trace.json>"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading trace `{path}`: {e}"))?;
    let check = export::validate_chrome_trace(&text)
        .map_err(|e| anyhow::anyhow!("trace `{path}` invalid: {e:#}"))?;
    println!("{path}: OK ({} events across {} tracks)", check.events, check.tracks);
    Ok(())
}

/// `simulate --sessions N`: the multi-session serving simulation —
/// N continuous-batched decode streams through one shared DRAM cache
/// and one shared flash timeline (DESIGN.md §Serving). With
/// `--prefetch` each stream decodes on the overlapped timeline and a
/// per-round arbiter divides one global speculative byte budget.
fn simulate_serve(
    args: &Args,
    w: &Workload,
    system: System,
    sspec: SystemSpec,
) -> Result<()> {
    let arbiter = match args.get("arbiter") {
        None => None,
        Some("fair") => Some(ArbiterPolicy::FairShare),
        Some("deadline") => Some(ArbiterPolicy::DeadlineAware {
            target_ns: args.get_f64("deadline-target-ms", 2.0)? * 1e6,
        }),
        Some(other) => anyhow::bail!("--arbiter expects fair|deadline, got `{other}`"),
    };
    anyhow::ensure!(
        w.prefetch.enabled
            || (arbiter.is_none() && args.get("prefetch-global-budget-kb").is_none()),
        "--arbiter/--prefetch-global-budget-kb need --prefetch"
    );
    if let Some(ArbiterPolicy::DeadlineAware { target_ns }) = arbiter {
        anyhow::ensure!(
            target_ns.is_finite() && target_ns > 0.0,
            "--deadline-target-ms must be positive"
        );
    }
    let decode_threads = args.get_usize("decode-threads", 1)?;
    anyhow::ensure!(decode_threads >= 1, "--decode-threads must be >= 1");
    let mut cfg = ServeConfig {
        sessions: args.get_usize("sessions", 4)?,
        max_concurrent: args.get_usize("max-concurrent", 4)?,
        arrival_spacing_ns: args.get_f64("session-arrival-ms", 0.0)? * 1e6,
        shared_cache: !args.flag("private-cache"),
        decode_threads,
        ..ServeConfig::default()
    };
    if let Some(policy) = arbiter {
        cfg.arbiter = policy;
    }
    if let Some(kb) = args.get("prefetch-global-budget-kb") {
        let kb: usize = kb
            .parse()
            .map_err(|_| anyhow::anyhow!("--prefetch-global-budget-kb expects an integer"))?;
        cfg.prefetch_global_budget = Some(kb * 1024);
    }
    let trace = trace_handle_from(args)?;
    let out = run_serve_traced(w, system, sspec, &cfg, trace.as_ref())?;
    let scale = w.layer_scale();
    let ms = |ns: f64| ns * scale / 1e6;
    let mut t = Table::new(&[
        "session", "arrival ms", "queue ms", "tokens", "mean ms/tok", "p95 ms/tok",
        "finished ms",
    ]);
    let mut sessions = out.serve.sessions.clone();
    for s in &mut sessions {
        t.row(&[
            s.id.to_string(),
            format!("{:.1}", ms(s.arrival_ns)),
            format!("{:.2}", ms(s.queue_delay_ns)),
            s.tokens.to_string(),
            format!("{:.2}", ms(s.mean_latency_ns())),
            format!("{:.2}", ms(s.latency_ns.percentile(95.0))),
            format!("{:.1}", ms(s.finished_ns)),
        ]);
    }
    t.print();
    let sv = &out.summary;
    println!(
        "\n{} sessions x {} tokens ({} cache, {} slots, peak {} active): \
         p50/p95/p99 {:.2}/{:.2}/{:.2} ms/token, mean queue {:.2} ms, \
         fairness {:.3}, agg cache hit {:.1}% (cross-session {:.1}%), \
         makespan {:.1} ms",
        sv.sessions,
        sv.tokens,
        if sv.shared_cache { "shared" } else { "private" },
        sv.max_concurrent,
        sv.peak_active,
        sv.p50_ms,
        sv.p95_ms,
        sv.p99_ms,
        sv.mean_queue_delay_ms,
        sv.fairness,
        sv.cache_hit_ratio * 100.0,
        sv.cross_session_hit_ratio * 100.0,
        sv.makespan_ms,
    );
    if !sv.session_prefetch.is_empty() {
        let mut pt = Table::new(&[
            "session", "pf hit", "pf wasted", "overlap", "service ms/tok",
            "round queue ms/tok",
        ]);
        for p in &sv.session_prefetch {
            pt.row(&[
                p.id.to_string(),
                p.prefetch_hit_bundles.to_string(),
                p.prefetch_wasted_bundles.to_string(),
                format!("{:.0}%", p.overlap_ratio * 100.0),
                format!("{:.2}", p.mean_service_ms),
                format!("{:.2}", p.mean_round_queue_ms),
            ]);
        }
        println!(
            "\nspeculative prefetch: {} hit / {} wasted bundles across sessions",
            sv.prefetch_hit_bundles, sv.prefetch_wasted_bundles
        );
        pt.print();
    }
    finish_trace(args, trace.as_ref(), scale)
}

/// `simulate --fleet`: the event-driven open-loop fleet simulation
/// (DESIGN.md §Fleet) — sessions arrive by a stochastic process, an
/// admission bound may reject them, and a scheduler orders each decode
/// round over one shared DRAM cache and one flash timeline.
fn simulate_fleet(
    args: &Args,
    w: &Workload,
    system: System,
    sspec: SystemSpec,
) -> Result<()> {
    let rate = args.get_f64("arrival-rate", 1000.0)?;
    let arrival = match args.get_or("arrival", "poisson") {
        "fixed" => ArrivalProcess::Fixed {
            spacing_ns: args.get_f64("arrival-spacing-ms", 0.0)? * 1e6,
        },
        "poisson" => ArrivalProcess::Poisson { rate_per_s: rate },
        "bursty" => {
            ArrivalProcess::Bursty { rate_per_s: rate, burst: args.get_usize("burst", 4)? }
        }
        "diurnal" => ArrivalProcess::Diurnal {
            rate_per_s: rate,
            period_s: args.get_f64("period-s", 0.1)?,
            depth: args.get_f64("depth", 0.5)?,
        },
        other => {
            anyhow::bail!("--arrival expects fixed|poisson|bursty|diurnal, got `{other}`")
        }
    };
    let scheduler = match args.get_or("scheduler", "fifo") {
        "fifo" => FleetScheduler::Fifo,
        "srt" => FleetScheduler::ShortestRemaining,
        other => anyhow::bail!("--scheduler expects fifo|srt, got `{other}`"),
    };
    let scale = w.layer_scale();
    let decode_threads = args.get_usize("decode-threads", 1)?;
    anyhow::ensure!(decode_threads >= 1, "--decode-threads must be >= 1");
    let mut cfg = FleetConfig {
        sessions: args.get_usize("sessions", 16)?,
        max_concurrent: args.get_usize("max-concurrent", 4)?,
        arrival,
        arrival_seed: w.seed,
        scheduler,
        decode_threads,
        ..FleetConfig::default()
    };
    if let Some(b) = args.get("admission-bound") {
        let b: usize = b
            .parse()
            .map_err(|_| anyhow::anyhow!("--admission-bound expects an integer"))?;
        cfg.admission_bound = Some(b);
    }
    if let Some(ms) = args.get("slo-ms") {
        let ms: f64 =
            ms.parse().map_err(|_| anyhow::anyhow!("--slo-ms expects a number"))?;
        anyhow::ensure!(ms.is_finite() && ms > 0.0, "--slo-ms must be positive");
        // the SLO is given in full-model ms; the simulator compares
        // raw per-representative-layer ns
        cfg.slo_ns = ms * 1e6 / scale;
    }
    if let Some(kb) = args.get("prefetch-global-budget-kb") {
        anyhow::ensure!(
            w.prefetch.enabled,
            "--prefetch-global-budget-kb needs --prefetch"
        );
        let kb: usize = kb.parse().map_err(|_| {
            anyhow::anyhow!("--prefetch-global-budget-kb expects an integer")
        })?;
        cfg.prefetch_global_budget = Some(kb * 1024);
    }
    let trace = trace_handle_from(args)?;
    let out = run_fleet_traced(w, system, sspec, &cfg, trace.as_ref())?;
    let fs = &out.fleet;
    let sv = &out.summary;
    println!(
        "offered {} sessions / {} tokens ({} slots, {} scheduler, peak {} active): \
         admitted {}, rejected {} ({:.1}%), completed {} sessions / {} tokens",
        fs.offered_sessions,
        fs.offered_tokens,
        sv.max_concurrent,
        cfg.scheduler.key(),
        sv.peak_active,
        fs.admitted_sessions,
        fs.rejected_sessions,
        fs.rejection_rate * 100.0,
        fs.completed_sessions,
        fs.completed_tokens,
    );
    println!(
        "goodput {:.0} tok/s, p50/p95/p99/p99.9 {:.2}/{:.2}/{:.2}/{:.2} ms/token, \
         mean queue {:.2} ms, agg cache hit {:.1}% (cross-session {:.1}%), \
         makespan {:.1} ms",
        fs.goodput_tokens_per_s,
        sv.p50_ms,
        sv.p95_ms,
        sv.p99_ms,
        sv.p999_ms,
        sv.mean_queue_delay_ms,
        sv.cache_hit_ratio * 100.0,
        sv.cross_session_hit_ratio * 100.0,
        sv.makespan_ms,
    );
    if fs.slo_ms > 0.0 {
        println!(
            "SLO {:.1} ms/token: {} violations ({:.2}% of completed tokens)",
            fs.slo_ms,
            fs.slo_violations,
            fs.slo_violation_rate * 100.0,
        );
    }
    println!(
        "event heap retired {} arrivals + {} token completions + {} flash tickets",
        fs.arrival_events, fs.token_events, fs.ticket_events,
    );
    finish_trace(args, trace.as_ref(), scale)
}

fn list_devices() -> Result<()> {
    let mut t = Table::new(&["device", "soc", "dram", "flash", "ufs", "sat bw", "max iops"]);
    for d in devices() {
        t.row(&[
            d.name.into(),
            d.soc.into(),
            format!("{}GB", d.dram_gb),
            format!("{}GB", d.flash_gb),
            format!("{:?}", d.ufs),
            format!("{:.1}GB/s", d.sat_bandwidth / 1e9),
            format!("{:.0}k", d.max_iops() / 1e3),
        ]);
    }
    t.print();
    Ok(())
}

fn list_models() -> Result<()> {
    let mut t = Table::new(&[
        "model", "params", "layers", "bundles/layer", "dim", "linears", "sparsity",
    ]);
    for m in models().into_iter().chain([ripple::config::opt_micro()]) {
        t.row(&[
            m.name.into(),
            format!("{:.1}M", m.n_params as f64 / 1e6),
            m.n_layers.to_string(),
            m.neurons_per_layer.to_string(),
            m.neuron_dim.to_string(),
            m.ffn_linears.to_string(),
            format!("{:.1}%", m.sparsity * 100.0),
        ]);
    }
    t.print();
    Ok(())
}
