//! Speculative next-layer prefetch (tentpole of the overlapped pipeline).
//!
//! While layer *L* computes, the pipeline predicts which bundles layer
//! *L+1* will activate and issues their flash reads speculatively on the
//! async device timeline (flash::submit_batch), so the transfer overlaps
//! compute instead of serializing behind it — the PowerInfer-2 /
//! LLM-in-a-flash observation applied to RIPPLE's bundle layout.
//!
//! The predictor is built offline from the same calibration trace the
//! placement search uses. Per layer it keeps:
//!
//! * a kNN co-activation adjacency (each bundle's `max_partners`
//!   strongest partners by co-count, from [`CoactStats`]), and
//! * the activation-frequency ranking (the Zipf-hot head of the layer).
//!
//! A prediction for layer `l` scores candidates by summed co-counts with
//! the *seed* sets — the current token's activations in already-computed
//! layers plus the previous token's activations in layer `l` itself —
//! and back-fills the byte budget with the frequency-hot head so a cold
//! seed still produces useful speculation. Everything is integer
//! arithmetic over a deterministic trace: predictions are bit-stable,
//! which is what keeps the overlapped flash timeline replayable.

use crate::coact::CoactStats;
use crate::neuron::BundleId;
use crate::trace::Trace;

/// Dense-score sentinel: the bundle has not been touched this call.
/// Real scores are bounded by `(max_freq * 2 + 1) * seeds`, far below it.
const UNSCORED: u64 = u64::MAX;

/// Reusable scoring buffers for [`Prefetcher::predict_into`] (§Perf):
/// a direct-indexed per-bundle score array plus a touched list, reset
/// in O(touched) after every call — the hot path never hashes and,
/// after warmup, never allocates.
#[derive(Clone, Debug, Default)]
pub struct PredictScratch {
    /// `bundle -> accumulated score` (`UNSCORED` = untouched).
    score: Vec<u64>,
    /// Bundles scored this call, in first-touch order.
    touched: Vec<BundleId>,
    /// Scored candidates, sorted (score desc, id asc) then truncated.
    ranked: Vec<(BundleId, u64)>,
}

/// Runtime knobs for speculative prefetch (see `RunConfig`).
#[derive(Clone, Debug)]
pub struct PrefetchConfig {
    /// Master switch; when off the pipeline is byte-identical to the
    /// synchronous baseline.
    pub enabled: bool,
    /// Per-layer speculative read budget in bytes (caps predicted slots
    /// at `budget_bytes / bundle_bytes`).
    pub budget_bytes: usize,
    /// How many layers ahead to speculate (1 = classic next-layer).
    pub lookahead: usize,
    /// kNN width of the co-activation adjacency kept per bundle.
    pub max_partners: usize,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        Self { enabled: false, budget_bytes: 256 * 1024, lookahead: 1, max_partners: 12 }
    }
}

impl PrefetchConfig {
    /// Budget expressed in bundles for a given bundle size. A zero
    /// bundle size has no valid slot to speculate on, so the budget is
    /// zero — not `budget_bytes` whole slots.
    pub fn budget_slots(&self, bundle_bytes: usize) -> usize {
        if bundle_bytes == 0 {
            return 0;
        }
        self.budget_bytes / bundle_bytes
    }
}

/// Per-layer co-activation predictor for speculative reads. Cloning is
/// cheap relative to construction (no trace rescan) and gives every
/// serving session its own predictor over the shared calibration scan.
#[derive(Clone)]
pub struct Prefetcher {
    cfg: PrefetchConfig,
    per_layer: usize,
    /// `[layer][bundle]` -> strongest partners `(partner, co_count)`.
    partners: Vec<Vec<Vec<(BundleId, u32)>>>,
    /// `[layer][bundle]` -> activation count over the calibration trace.
    freq: Vec<Vec<u32>>,
    /// `[layer]` -> bundles ordered by frequency descending (ties by id).
    hot: Vec<Vec<BundleId>>,
}

impl Prefetcher {
    /// Build from a calibration trace (same input as the placement
    /// search). `threads` shards the per-layer co-count scans.
    pub fn from_trace(trace: &Trace, cfg: PrefetchConfig, threads: usize) -> Self {
        let knn = cfg.max_partners.max(1);
        let mut stats = Vec::with_capacity(trace.n_layers);
        let mut pairs = Vec::with_capacity(trace.n_layers);
        for layer in 0..trace.n_layers {
            let s = CoactStats::from_trace_layer(trace, layer);
            pairs.push(s.candidate_pairs_parallel(knn, threads.max(1)));
            stats.push(s);
        }
        Self::from_layer_pairs(&stats, &pairs, cfg)
    }

    /// Build from precomputed per-layer stats + candidate pair lists —
    /// typically the placement search's own scan, so the dominant O(n²)
    /// co-count pass runs once for both consumers. `pairs[l]` must be
    /// `CoactStats::candidate_pairs*` output for layer `l`; a kNN width
    /// below `cfg.max_partners` just yields a narrower adjacency.
    pub fn from_layer_pairs(
        stats: &[CoactStats],
        pairs: &[Vec<(BundleId, BundleId, u32)>],
        cfg: PrefetchConfig,
    ) -> Self {
        assert_eq!(stats.len(), pairs.len(), "stats/pairs layer count mismatch");
        assert!(!stats.is_empty(), "need at least one layer");
        let n = stats[0].n_neurons();
        let knn = cfg.max_partners.max(1);
        let mut partners = Vec::with_capacity(stats.len());
        let mut freq = Vec::with_capacity(stats.len());
        let mut hot = Vec::with_capacity(stats.len());
        for (s, layer_pairs) in stats.iter().zip(pairs) {
            assert_eq!(s.n_neurons(), n, "layer width mismatch");
            let mut adj: Vec<Vec<(BundleId, u32)>> = vec![Vec::new(); n];
            for &(a, b, c) in layer_pairs {
                adj[a as usize].push((b, c));
                adj[b as usize].push((a, c));
            }
            for l in &mut adj {
                l.sort_unstable_by(|x, y| y.1.cmp(&x.1).then(x.0.cmp(&y.0)));
                l.truncate(knn);
            }
            let f: Vec<u32> = (0..n as u32).map(|i| s.freq(i)).collect();
            let mut by_freq: Vec<BundleId> = (0..n as u32).collect();
            by_freq.sort_unstable_by(|&a, &b| {
                f[b as usize].cmp(&f[a as usize]).then(a.cmp(&b))
            });
            partners.push(adj);
            freq.push(f);
            hot.push(by_freq);
        }
        Self { cfg, per_layer: n, partners, freq, hot }
    }

    pub fn config(&self) -> &PrefetchConfig {
        &self.cfg
    }

    pub fn n_layers(&self) -> usize {
        self.partners.len()
    }

    pub fn per_layer(&self) -> usize {
        self.per_layer
    }

    /// Allocate scoring scratch sized for this predictor's layer width.
    pub fn scratch(&self) -> PredictScratch {
        PredictScratch {
            score: vec![UNSCORED; self.per_layer],
            touched: Vec::with_capacity(self.per_layer.min(1 << 16)),
            ranked: Vec::with_capacity(self.per_layer.min(1 << 16)),
        }
    }

    /// Predict up to `max_out` bundles likely active in `layer`, scored
    /// from the given seed activation sets; `out` receives sorted unique
    /// ids. Scores accumulate in a dense array indexed by bundle id and
    /// reset via the touched list, so repeated calls neither hash nor
    /// (after warmup) allocate — bit-identical to the historical
    /// hash-map scorer, which the replayable flash timeline depends on.
    pub fn predict_into(
        &self,
        layer: usize,
        seeds: &[&[BundleId]],
        max_out: usize,
        scratch: &mut PredictScratch,
        out: &mut Vec<BundleId>,
    ) {
        out.clear();
        if max_out == 0 || layer >= self.partners.len() {
            return;
        }
        if scratch.score.len() < self.per_layer {
            scratch.score.resize(self.per_layer, UNSCORED);
        }
        debug_assert!(scratch.touched.is_empty(), "scratch not reset");
        let freq = &self.freq[layer];
        let adj = &self.partners[layer];
        // Seed bonus exceeding any popularity-floor score: a bundle that
        // just fired (this token, adjacent layer; or last token, this
        // layer) is stronger evidence than base popularity, so seeds must
        // never be crowded out of the budget by the hot head.
        let top_freq = self.hot[layer]
            .first()
            .map(|&h| freq[h as usize] as u64)
            .unwrap_or(0);
        let score = &mut scratch.score;
        let touched = &mut scratch.touched;
        for seed in seeds {
            for &s in *seed {
                if (s as usize) >= self.per_layer {
                    continue;
                }
                let e = &mut score[s as usize];
                if *e == UNSCORED {
                    *e = 0;
                    touched.push(s);
                }
                *e += freq[s as usize] as u64 + top_freq + 1;
                for &(p, w) in &adj[s as usize] {
                    let e = &mut score[p as usize];
                    if *e == UNSCORED {
                        *e = 0;
                        touched.push(p);
                    }
                    *e += w as u64;
                }
            }
        }
        // popularity floor: back-fill the budget with the hot head so a
        // cold seed (first token, unseen pattern) still speculates well
        for &h in self.hot[layer].iter().take(max_out) {
            let pop = (freq[h as usize] as u64).div_ceil(2);
            if pop > 0 {
                let e = &mut score[h as usize];
                if *e == UNSCORED {
                    *e = pop;
                    touched.push(h);
                }
            }
        }
        let ranked = &mut scratch.ranked;
        ranked.clear();
        ranked.extend(touched.iter().map(|&b| (b, score[b as usize])));
        // total order (unique ids), so the result never depends on the
        // accumulation order — same contract the hash map had
        ranked.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(max_out);
        out.extend(ranked.iter().map(|&(b, _)| b));
        out.sort_unstable();
        // O(touched) reset: ready for the next call
        for &b in touched.iter() {
            score[b as usize] = UNSCORED;
        }
        touched.clear();
    }

    /// Allocating convenience wrapper over [`Prefetcher::predict_into`].
    pub fn predict(&self, layer: usize, seeds: &[&[BundleId]], max_out: usize) -> Vec<BundleId> {
        let mut scratch = self.scratch();
        let mut out = Vec::new();
        self.predict_into(layer, seeds, max_out, &mut scratch, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{DatasetProfile, TraceGen};

    fn calib(n_layers: usize, n: usize) -> Trace {
        let mut tg =
            TraceGen::new(n_layers, n, n / 10, &DatasetProfile::alpaca(), 11, 5);
        tg.generate(128)
    }

    #[test]
    fn predictions_sorted_unique_bounded() {
        let tr = calib(2, 256);
        let pf = Prefetcher::from_trace(&tr, PrefetchConfig::default(), 2);
        let seed = tr.tokens[0][0].clone();
        for layer in 0..2 {
            let p = pf.predict(layer, &[&seed], 32);
            assert!(p.len() <= 32);
            assert!(!p.is_empty());
            assert!(p.windows(2).all(|w| w[0] < w[1]));
            assert!(p.iter().all(|&b| (b as usize) < 256));
        }
    }

    #[test]
    fn deterministic_predictions() {
        let tr = calib(1, 200);
        let a = Prefetcher::from_trace(&tr, PrefetchConfig::default(), 1);
        let b = Prefetcher::from_trace(&tr, PrefetchConfig::default(), 3);
        let seed: Vec<u32> = vec![3, 17, 42, 80];
        assert_eq!(a.predict(0, &[&seed], 24), b.predict(0, &[&seed], 24));
    }

    #[test]
    fn cold_seed_falls_back_to_hot_head() {
        let tr = calib(1, 256);
        let pf = Prefetcher::from_trace(&tr, PrefetchConfig::default(), 1);
        let p = pf.predict(0, &[], 16);
        assert_eq!(p.len(), 16);
        // every predicted bundle must be among the 16 most frequent
        let head: std::collections::HashSet<u32> =
            pf.hot[0].iter().take(16).copied().collect();
        assert!(p.iter().all(|b| head.contains(b)));
    }

    #[test]
    fn seed_partners_outrank_random() {
        // seeding with a real activation set must beat the cold hot-head
        // fallback at predicting the *next* token of the same stream
        let mut tg = TraceGen::new(1, 512, 50, &DatasetProfile::alpaca(), 11, 5);
        let tr = tg.generate(200);
        let pf = Prefetcher::from_trace(&tr, PrefetchConfig::default(), 2);
        let mut eval = TraceGen::new(1, 512, 50, &DatasetProfile::alpaca(), 11, 99);
        let stream = eval.generate(60);
        let mut hits_seeded = 0usize;
        let mut total = 0usize;
        for w in stream.tokens.windows(2) {
            let seed = &w[0][0];
            let truth = &w[1][0];
            let pred = pf.predict(0, &[seed.as_slice()], 64);
            hits_seeded += pred.iter().filter(|b| truth.binary_search(b).is_ok()).count();
            total += truth.len();
        }
        // correlated communities make the predictor far better than the
        // 64/512 = 12.5% random baseline
        let ratio = hits_seeded as f64 / total as f64;
        assert!(ratio > 0.2, "seeded hit ratio {ratio}");
    }

    #[test]
    fn predict_into_matches_predict_across_reused_scratch() {
        // the dense-scored path must be bit-identical to the allocating
        // wrapper, including when one scratch serves many calls
        let tr = calib(2, 256);
        let pf = Prefetcher::from_trace(&tr, PrefetchConfig::default(), 2);
        let mut scratch = pf.scratch();
        let mut out = Vec::new();
        for t in 0..8 {
            let seed = tr.tokens[t][0].clone();
            for layer in 0..2 {
                pf.predict_into(layer, &[&seed], 24, &mut scratch, &mut out);
                assert_eq!(out, pf.predict(layer, &[&seed], 24), "t={t} layer={layer}");
            }
        }
        // cold-seed and empty calls reset cleanly too
        pf.predict_into(0, &[], 16, &mut scratch, &mut out);
        assert_eq!(out, pf.predict(0, &[], 16));
        pf.predict_into(0, &[], 0, &mut scratch, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn budget_slots_math() {
        let c = PrefetchConfig { budget_bytes: 10_000, ..Default::default() };
        assert_eq!(c.budget_slots(1000), 10);
        // degenerate bundle size: nothing valid to speculate on
        assert_eq!(c.budget_slots(0), 0);
    }
}
