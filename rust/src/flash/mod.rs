//! UFS flash simulator.
//!
//! Substitute for the phones' physical UFS 3.1/4.0 storage (see DESIGN.md
//! §Substitutions). It holds a *real* backing image (the engine stores
//! actual neuron-bundle bytes in it and computes on what it reads back)
//! and charges simulated time per command batch:
//!
//!   t(batch) = submit_overhead            (first-command queue fill)
//!            + Σ_cmd (cmd_latency + len / sat_bandwidth)
//!
//! The device executes queued commands serially — this is exactly what
//! makes small scattered reads IOPS-bound on a 32-entry queue: per-command
//! cost dominates until reads are ~knee_bytes long (Figure 4). Host
//! submission (1–2 µs/cmd) is always faster than device service
//! (8–17 µs/cmd), so with a 32-deep queue the host never starves the
//! device and the serial-service model is exact; `queue_depth` still
//! bounds how many commands one submission window may carry (the sim
//! charges one extra `submit_overhead` per window refill).
//!
//! Determinism: no wall clock anywhere; the simulated clock advances only
//! through `read_batch`, so every experiment replays bit-identically.

use crate::config::DeviceConfig;

/// One read command: a contiguous byte extent in the flash image.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReadCmd {
    pub offset: u64,
    pub len: usize,
}

/// Timing + volume outcome of one submitted batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchResult {
    pub elapsed_ns: f64,
    pub commands: usize,
    pub bytes: usize,
}

/// Cumulative flash statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlashStats {
    pub total_commands: u64,
    pub total_bytes: u64,
    pub total_busy_ns: f64,
    pub total_batches: u64,
}

impl FlashStats {
    /// Achieved bandwidth over all traffic so far (bytes/sec).
    pub fn bandwidth(&self) -> f64 {
        if self.total_busy_ns == 0.0 {
            0.0
        } else {
            self.total_bytes as f64 / (self.total_busy_ns / 1e9)
        }
    }

    /// Achieved IOPS over all traffic so far.
    pub fn iops(&self) -> f64 {
        if self.total_busy_ns == 0.0 {
            0.0
        } else {
            self.total_commands as f64 / (self.total_busy_ns / 1e9)
        }
    }
}

pub struct UfsSim {
    dev: DeviceConfig,
    image: Vec<u8>,
    clock_ns: f64,
    stats: FlashStats,
    /// Synchronous (mmap page-fault) mode: each command pays the full
    /// QD-1 round-trip latency and nothing overlaps. Models llama.cpp's
    /// mmap offload path; async (queued) mode models a proper io
    /// submission path (LLMFlash, RIPPLE).
    sync: bool,
}

impl UfsSim {
    /// Create with a zeroed image of `image_bytes`.
    pub fn new(dev: DeviceConfig, image_bytes: u64) -> Self {
        Self::with_image(dev, vec![0u8; image_bytes as usize])
    }

    /// Create around an existing flash image (real model weights).
    pub fn with_image(dev: DeviceConfig, image: Vec<u8>) -> Self {
        Self { dev, image, clock_ns: 0.0, stats: FlashStats::default(), sync: false }
    }

    /// Switch to synchronous (queue-depth-1, mmap-fault) timing.
    pub fn set_sync(&mut self, sync: bool) {
        self.sync = sync;
    }

    pub fn is_sync(&self) -> bool {
        self.sync
    }

    pub fn device(&self) -> &DeviceConfig {
        &self.dev
    }

    pub fn image_len(&self) -> u64 {
        self.image.len() as u64
    }

    /// Setup-time write (placement tool / engine load). Free of charge:
    /// the paper's offline stage rewrites flash once, off the request path.
    pub fn write_image(&mut self, offset: u64, bytes: &[u8]) {
        let o = offset as usize;
        self.image[o..o + bytes.len()].copy_from_slice(bytes);
    }

    /// Pure timing model for a batch (no data movement). Used by the
    /// trace-driven benches where bundle *contents* are irrelevant.
    pub fn time_batch(&self, cmds: &[ReadCmd]) -> BatchResult {
        if cmds.is_empty() {
            return BatchResult::default();
        }
        let per_cmd = if self.sync {
            self.dev.sync_latency_ns
        } else {
            self.dev.cmd_latency_ns
        };
        let mut ns = if self.sync {
            0.0 // no submission pipelining to account for
        } else {
            cmds.len().div_ceil(self.dev.queue_depth) as f64 * self.dev.submit_overhead_ns
        };
        let mut bytes = 0usize;
        for c in cmds {
            ns += per_cmd + c.len as f64 / self.dev.sat_bandwidth * 1e9;
            bytes += c.len;
        }
        BatchResult { elapsed_ns: ns, commands: cmds.len(), bytes }
    }

    /// Submit a batch: advances the simulated clock, updates statistics,
    /// and copies each command's bytes into `out` (appended back-to-back
    /// in command order). Returns the batch timing.
    pub fn read_batch(&mut self, cmds: &[ReadCmd], out: &mut Vec<u8>) -> BatchResult {
        for c in cmds {
            let o = c.offset as usize;
            assert!(
                o + c.len <= self.image.len(),
                "read past end of flash image: off={o} len={} image={}",
                c.len,
                self.image.len()
            );
            out.extend_from_slice(&self.image[o..o + c.len]);
        }
        self.charge(cmds)
    }

    /// Advance the clock for a batch without copying data (metrics-only
    /// callers). Identical accounting to `read_batch`.
    pub fn charge(&mut self, cmds: &[ReadCmd]) -> BatchResult {
        let r = self.time_batch(cmds);
        self.clock_ns += r.elapsed_ns;
        self.stats.total_commands += r.commands as u64;
        self.stats.total_bytes += r.bytes as u64;
        self.stats.total_busy_ns += r.elapsed_ns;
        self.stats.total_batches += 1;
        r
    }

    pub fn clock_ns(&self) -> f64 {
        self.clock_ns
    }

    pub fn stats(&self) -> FlashStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = FlashStats::default();
        self.clock_ns = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::devices;

    fn op12() -> DeviceConfig {
        devices()[0].clone()
    }

    #[test]
    fn reads_return_written_bytes() {
        let mut sim = UfsSim::new(op12(), 1024);
        sim.write_image(100, &[1, 2, 3, 4]);
        let mut out = Vec::new();
        let r = sim.read_batch(&[ReadCmd { offset: 100, len: 4 }], &mut out);
        assert_eq!(out, vec![1, 2, 3, 4]);
        assert_eq!(r.commands, 1);
        assert_eq!(r.bytes, 4);
        assert!(r.elapsed_ns > 0.0);
    }

    #[test]
    fn one_big_read_beats_many_small() {
        // The paper's core premise: same bytes, fewer commands -> faster.
        let sim = UfsSim::new(op12(), 1 << 20);
        let small: Vec<ReadCmd> = (0..64)
            .map(|i| ReadCmd { offset: i * 2048, len: 2048 })
            .collect();
        let big = [ReadCmd { offset: 0, len: 64 * 2048 }];
        let t_small = sim.time_batch(&small).elapsed_ns;
        let t_big = sim.time_batch(&big).elapsed_ns;
        assert!(
            t_big < t_small / 10.0,
            "big={t_big} small={t_small}: continuity should dominate"
        );
    }

    #[test]
    fn figure4_bandwidth_curve_matches_closed_form() {
        let dev = op12();
        let sim = UfsSim::new(dev.clone(), 16 << 20);
        for &sz in &[4096usize, 8192, 24576, 262_144, 1 << 20] {
            let n = (4 << 20) / sz;
            let cmds: Vec<ReadCmd> = (0..n)
                .map(|i| ReadCmd { offset: (i * sz) as u64, len: sz })
                .collect();
            let r = sim.time_batch(&cmds);
            let bw = r.bytes as f64 / (r.elapsed_ns / 1e9);
            let want = dev.bandwidth_at(sz);
            let err = (bw - want).abs() / want;
            assert!(err < 0.05, "size={sz} bw={bw:.3e} want={want:.3e}");
        }
    }

    #[test]
    fn clock_and_stats_accumulate() {
        let mut sim = UfsSim::new(op12(), 4096);
        let mut out = Vec::new();
        sim.read_batch(&[ReadCmd { offset: 0, len: 512 }], &mut out);
        sim.read_batch(
            &[ReadCmd { offset: 512, len: 512 }, ReadCmd { offset: 2048, len: 128 }],
            &mut out,
        );
        let s = sim.stats();
        assert_eq!(s.total_commands, 3);
        assert_eq!(s.total_bytes, 1152);
        assert_eq!(s.total_batches, 2);
        assert!((sim.clock_ns() - s.total_busy_ns).abs() < 1e-9);
        assert!(s.iops() > 0.0 && s.bandwidth() > 0.0);
    }

    #[test]
    fn queue_window_refills_charged() {
        let dev = op12();
        let sim = UfsSim::new(dev.clone(), 1 << 20);
        let c33: Vec<ReadCmd> =
            (0..33).map(|i| ReadCmd { offset: i * 64, len: 64 }).collect();
        let c32: Vec<ReadCmd> =
            (0..32).map(|i| ReadCmd { offset: i * 64, len: 64 }).collect();
        let t33 = sim.time_batch(&c33).elapsed_ns;
        let t32 = sim.time_batch(&c32).elapsed_ns;
        let per_cmd = dev.cmd_latency_ns + 64.0 / dev.sat_bandwidth * 1e9;
        // 33rd command costs one service slot plus one extra window refill
        let extra = t33 - t32;
        assert!((extra - per_cmd - dev.submit_overhead_ns).abs() < 1.0);
    }

    #[test]
    #[should_panic(expected = "read past end")]
    fn oob_read_panics() {
        let mut sim = UfsSim::new(op12(), 128);
        let mut out = Vec::new();
        sim.read_batch(&[ReadCmd { offset: 100, len: 64 }], &mut out);
    }

    #[test]
    fn sync_mode_is_much_slower_per_command() {
        let mut sim = UfsSim::new(op12(), 1 << 20);
        let cmds: Vec<ReadCmd> =
            (0..16).map(|i| ReadCmd { offset: i * 4096, len: 4096 }).collect();
        let fast = sim.time_batch(&cmds).elapsed_ns;
        sim.set_sync(true);
        let slow = sim.time_batch(&cmds).elapsed_ns;
        assert!(slow > 8.0 * fast, "sync={slow} async={fast}");
    }

    #[test]
    fn empty_batch_is_free() {
        let mut sim = UfsSim::new(op12(), 128);
        let r = sim.charge(&[]);
        assert_eq!(r.elapsed_ns, 0.0);
        assert_eq!(sim.stats().total_commands, 0);
    }
}
