//! UFS flash simulator with an asynchronous command timeline.
//!
//! Substitute for the phones' physical UFS 3.1/4.0 storage (see DESIGN.md
//! §Substitutions). It holds a *real* backing image (the engine stores
//! actual neuron-bundle bytes in it and computes on what it reads back)
//! and charges simulated time per command batch:
//!
//!   t(batch) = submit_overhead            (first-command queue fill)
//!            + Σ_cmd (cmd_latency + len / sat_bandwidth)
//!
//! The device executes queued commands serially — this is exactly what
//! makes small scattered reads IOPS-bound on a 32-entry queue: per-command
//! cost dominates until reads are ~knee_bytes long (Figure 4). Host
//! submission (1–2 µs/cmd) is always faster than device service
//! (8–17 µs/cmd), so with a 32-deep queue the host never starves the
//! device and the serial-service model is exact; `queue_depth` still
//! bounds how many commands one submission window may carry (the sim
//! charges one extra `submit_overhead` per window refill).
//!
//! # Two timelines (DESIGN.md §Async-flash-timeline)
//!
//! The sim tracks a *host* clock (`clock_ns`) and a *device* frontier
//! (`device_free_ns`). `submit_batch` enqueues work on the device
//! timeline (the device starts it when free, never before the host
//! submits) and returns a [`Ticket`]; `wait` advances the host clock only
//! for the *uncovered remainder* — if compute (`advance_compute`) already
//! pushed the host clock past the batch's completion, the wait is free
//! and the flash busy time was fully hidden. The legacy synchronous API
//! (`charge` / `read_batch`) is submit-then-wait on an idle device and is
//! arithmetically identical to the historical `clock += elapsed` model,
//! so existing experiments replay bit-for-bit.
//!
//! Determinism: no wall clock anywhere; both timelines advance only
//! through deterministic f64 arithmetic on submitted batches and
//! explicit `advance_compute` calls, so every experiment — including
//! ones with speculative prefetch in flight — replays bit-identically.

use crate::config::DeviceConfig;
use crate::obs::{MarkKind, Phase, TraceHandle, Track};

/// One read command: a contiguous byte extent in the flash image.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReadCmd {
    pub offset: u64,
    pub len: usize,
}

/// Timing + volume outcome of one submitted batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchResult {
    pub elapsed_ns: f64,
    pub commands: usize,
    pub bytes: usize,
}

/// Outcome of waiting on an in-flight batch: its device-time result plus
/// how long the host actually stalled (0 when fully overlapped).
#[derive(Clone, Copy, Debug, Default)]
pub struct WaitOutcome {
    pub batch: BatchResult,
    pub stall_ns: f64,
}

/// Handle to an in-flight submitted batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ticket(u64);

struct InFlight {
    id: u64,
    /// Absolute device-timeline completion.
    completion_ns: f64,
    result: BatchResult,
}

/// Cumulative flash statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlashStats {
    pub total_commands: u64,
    pub total_bytes: u64,
    /// Device busy time (service time of all batches).
    pub total_busy_ns: f64,
    pub total_batches: u64,
    /// Host time actually blocked in `wait` (== busy time when every
    /// batch is waited synchronously).
    pub total_stall_ns: f64,
    /// Busy time hidden under compute (`busy - stall` per wait, clamped
    /// at zero — queueing delay can make a stall exceed its own batch's
    /// service time).
    pub total_hidden_ns: f64,
}

impl FlashStats {
    /// Achieved bandwidth over all traffic so far (bytes/sec).
    pub fn bandwidth(&self) -> f64 {
        if self.total_busy_ns == 0.0 {
            0.0
        } else {
            self.total_bytes as f64 / (self.total_busy_ns / 1e9)
        }
    }

    /// Achieved IOPS over all traffic so far.
    pub fn iops(&self) -> f64 {
        if self.total_busy_ns == 0.0 {
            0.0
        } else {
            self.total_commands as f64 / (self.total_busy_ns / 1e9)
        }
    }

    /// Fraction of device busy time hidden under compute, in [0, 1].
    pub fn overlap_ratio(&self) -> f64 {
        if self.total_busy_ns == 0.0 {
            0.0
        } else {
            self.total_hidden_ns / self.total_busy_ns
        }
    }
}

pub struct UfsSim {
    dev: DeviceConfig,
    image: Vec<u8>,
    clock_ns: f64,
    stats: FlashStats,
    /// Device timeline frontier: when the device finishes everything
    /// submitted so far.
    device_free_ns: f64,
    /// Host time spent in `advance_compute` (not flash time).
    compute_ns: f64,
    inflight: Vec<InFlight>,
    next_ticket: u64,
    /// Synchronous (mmap page-fault) mode: each command pays the full
    /// QD-1 round-trip latency and nothing overlaps. Models llama.cpp's
    /// mmap offload path; async (queued) mode models a proper io
    /// submission path (LLMFlash, RIPPLE).
    sync: bool,
    /// Optional flight recorder: device-track service spans + ticket
    /// lifecycle marks. `None` (the default) records nothing and leaves
    /// every timing/accounting path byte-identical.
    trace: Option<TraceHandle>,
}

impl UfsSim {
    /// Create with a zeroed image of `image_bytes`.
    pub fn new(dev: DeviceConfig, image_bytes: u64) -> Self {
        Self::with_image(dev, vec![0u8; image_bytes as usize])
    }

    /// Create around an existing flash image (real model weights).
    pub fn with_image(dev: DeviceConfig, image: Vec<u8>) -> Self {
        Self {
            dev,
            image,
            clock_ns: 0.0,
            stats: FlashStats::default(),
            device_free_ns: 0.0,
            compute_ns: 0.0,
            // a handful of batches at most are ever in flight (demand +
            // per-layer speculation); reserving keeps submit_batch off
            // the allocator on the decode hot path (§Perf)
            inflight: Vec::with_capacity(8),
            next_ticket: 0,
            sync: false,
            trace: None,
        }
    }

    /// Attach (or detach) a flight recorder. Tracing records device-track
    /// flash-service spans and ticket lifecycle marks; it never changes
    /// timing or statistics.
    pub fn set_trace(&mut self, trace: Option<TraceHandle>) {
        self.trace = trace;
    }

    /// Switch to synchronous (queue-depth-1, mmap-fault) timing.
    pub fn set_sync(&mut self, sync: bool) {
        self.sync = sync;
    }

    pub fn is_sync(&self) -> bool {
        self.sync
    }

    pub fn device(&self) -> &DeviceConfig {
        &self.dev
    }

    pub fn image_len(&self) -> u64 {
        self.image.len() as u64
    }

    /// Setup-time write (placement tool / engine load). Free of charge:
    /// the paper's offline stage rewrites flash once, off the request path.
    pub fn write_image(&mut self, offset: u64, bytes: &[u8]) {
        let o = offset as usize;
        self.image[o..o + bytes.len()].copy_from_slice(bytes);
    }

    /// Pure timing model for a batch (no data movement). Used by the
    /// trace-driven benches where bundle *contents* are irrelevant.
    pub fn time_batch(&self, cmds: &[ReadCmd]) -> BatchResult {
        if cmds.is_empty() {
            return BatchResult::default();
        }
        let per_cmd = if self.sync {
            self.dev.sync_latency_ns
        } else {
            self.dev.cmd_latency_ns
        };
        let mut ns = if self.sync {
            0.0 // no submission pipelining to account for
        } else {
            cmds.len().div_ceil(self.dev.queue_depth) as f64 * self.dev.submit_overhead_ns
        };
        let mut bytes = 0usize;
        for c in cmds {
            ns += per_cmd + c.len as f64 / self.dev.sat_bandwidth * 1e9;
            bytes += c.len;
        }
        BatchResult { elapsed_ns: ns, commands: cmds.len(), bytes }
    }

    // -----------------------------------------------------------------------
    // Asynchronous timeline
    // -----------------------------------------------------------------------

    /// Enqueue a batch on the device timeline without blocking the host.
    /// Stats (commands/bytes/busy) are charged at submission — the device
    /// will do this work regardless of whether anyone waits. Returns a
    /// ticket to `wait` on (or `drop_ticket` for abandoned speculation).
    pub fn submit_batch(&mut self, cmds: &[ReadCmd]) -> Ticket {
        let r = self.time_batch(cmds);
        // The device starts this batch when it has drained everything
        // already queued, but never before the host submits it (now).
        // An empty batch is zero work: it completes immediately at the
        // host clock instead of queueing behind in-flight speculation.
        let completion = if r.commands == 0 {
            self.clock_ns
        } else {
            let start = if self.device_free_ns > self.clock_ns {
                self.device_free_ns
            } else {
                self.clock_ns
            };
            let c = start + r.elapsed_ns;
            self.device_free_ns = c;
            if let Some(trace) = &self.trace {
                let submit_ns = self.clock_ns;
                trace.with(|rec| {
                    rec.span(Track::Device, Phase::FlashService, start, r.elapsed_ns);
                    rec.mark(
                        Track::Device,
                        MarkKind::FlashSubmit,
                        submit_ns,
                        r.commands as f64,
                        r.bytes as f64,
                    );
                });
            }
            c
        };
        self.stats.total_commands += r.commands as u64;
        self.stats.total_bytes += r.bytes as u64;
        self.stats.total_busy_ns += r.elapsed_ns;
        self.stats.total_batches += 1;
        let id = self.next_ticket;
        self.next_ticket += 1;
        self.inflight.push(InFlight { id, completion_ns: completion, result: r });
        Ticket(id)
    }

    /// Like `submit_batch` but also copies each command's bytes into
    /// `out` (appended back-to-back in command order). The data is
    /// deterministic, so it is materialized at submit time; only *timing*
    /// resolves at `wait`.
    pub fn submit_read_batch(&mut self, cmds: &[ReadCmd], out: &mut Vec<u8>) -> Ticket {
        self.copy_out(cmds, out);
        self.submit_batch(cmds)
    }

    /// Block the host until the batch completes: advances the host clock
    /// only for the uncovered remainder of the batch's completion time.
    ///
    /// Panics on an unknown (already waited / dropped) ticket.
    pub fn wait(&mut self, t: Ticket) -> WaitOutcome {
        let idx = self
            .inflight
            .iter()
            .position(|f| f.id == t.0)
            .expect("wait on unknown or already-completed flash ticket");
        let inf = self.inflight.swap_remove(idx);
        let stall = if inf.completion_ns > self.clock_ns {
            inf.completion_ns - self.clock_ns
        } else {
            0.0
        };
        if inf.completion_ns > self.clock_ns {
            self.clock_ns = inf.completion_ns;
        }
        self.stats.total_stall_ns += stall;
        self.stats.total_hidden_ns += (inf.result.elapsed_ns - stall).max(0.0);
        if let Some(trace) = &self.trace {
            let now = self.clock_ns;
            trace.with(|rec| {
                rec.mark(
                    Track::Device,
                    MarkKind::FlashComplete,
                    now,
                    stall,
                    inf.result.commands as f64,
                );
            });
        }
        WaitOutcome { batch: inf.result, stall_ns: stall }
    }

    /// Abandon an in-flight batch without blocking (wholly wasted
    /// speculation: the device still did the work — busy time stays
    /// charged — but the host never needs the data). The batch's busy
    /// time counts as hidden, since the host never stalled for it.
    pub fn drop_ticket(&mut self, t: Ticket) {
        if let Some(idx) = self.inflight.iter().position(|f| f.id == t.0) {
            let inf = self.inflight.swap_remove(idx);
            self.stats.total_hidden_ns += inf.result.elapsed_ns;
            if let Some(trace) = &self.trace {
                let now = self.clock_ns;
                trace.with(|rec| {
                    rec.mark(
                        Track::Device,
                        MarkKind::FlashDrop,
                        now,
                        inf.result.commands as f64,
                        inf.result.bytes as f64,
                    );
                });
            }
        }
    }

    /// Device service time of an in-flight batch (None once waited or
    /// dropped). Lets tracing producers attribute a prefetch window
    /// without re-running the timing model.
    pub fn ticket_elapsed_ns(&self, t: Ticket) -> Option<f64> {
        self.inflight.iter().find(|f| f.id == t.0).map(|f| f.result.elapsed_ns)
    }

    /// Advance the host clock by `ns` of (simulated) compute. In-flight
    /// batches keep executing on the device timeline underneath.
    pub fn advance_compute(&mut self, ns: f64) {
        debug_assert!(ns >= 0.0);
        self.clock_ns += ns;
        self.compute_ns += ns;
    }

    /// Jump the host clock forward to an absolute time, if later than
    /// now. Used by the serving loop when every session has drained and
    /// the next arrival is in the future: the gap is idle wall time, not
    /// compute, so hidden/overlap accounting is untouched. In-flight
    /// batches (there are none across serve rounds — speculation is
    /// reconciled within its own token) would keep completing on the
    /// device timeline underneath.
    pub fn advance_to(&mut self, ns: f64) {
        if ns > self.clock_ns {
            self.clock_ns = ns;
        }
    }

    /// Number of batches submitted but not yet waited/dropped.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// Absolute device-timeline completion of everything submitted.
    pub fn device_free_ns(&self) -> f64 {
        self.device_free_ns
    }

    /// Total host time spent in `advance_compute`.
    pub fn compute_ns(&self) -> f64 {
        self.compute_ns
    }

    // -----------------------------------------------------------------------
    // Synchronous (legacy) API — submit + wait on the spot
    // -----------------------------------------------------------------------

    /// Submit a batch synchronously: advances the simulated clock, updates
    /// statistics, and copies each command's bytes into `out` (appended
    /// back-to-back in command order). Returns the batch timing.
    pub fn read_batch(&mut self, cmds: &[ReadCmd], out: &mut Vec<u8>) -> BatchResult {
        self.copy_out(cmds, out);
        self.charge(cmds)
    }

    fn copy_out(&self, cmds: &[ReadCmd], out: &mut Vec<u8>) {
        for c in cmds {
            let o = c.offset as usize;
            assert!(
                o + c.len <= self.image.len(),
                "read past end of flash image: off={o} len={} image={}",
                c.len,
                self.image.len()
            );
            out.extend_from_slice(&self.image[o..o + c.len]);
        }
    }

    /// Advance the clock for a batch without copying data (metrics-only
    /// callers). Identical accounting to `read_batch`: submit-then-wait
    /// on the spot, which on an idle device reduces to the historical
    /// `clock += elapsed` arithmetic bit-for-bit.
    pub fn charge(&mut self, cmds: &[ReadCmd]) -> BatchResult {
        let t = self.submit_batch(cmds);
        self.wait(t).batch
    }

    pub fn clock_ns(&self) -> f64 {
        self.clock_ns
    }

    pub fn stats(&self) -> FlashStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = FlashStats::default();
        self.clock_ns = 0.0;
        self.device_free_ns = 0.0;
        self.compute_ns = 0.0;
        self.inflight.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::devices;

    fn op12() -> DeviceConfig {
        devices()[0].clone()
    }

    #[test]
    fn reads_return_written_bytes() {
        let mut sim = UfsSim::new(op12(), 1024);
        sim.write_image(100, &[1, 2, 3, 4]);
        let mut out = Vec::new();
        let r = sim.read_batch(&[ReadCmd { offset: 100, len: 4 }], &mut out);
        assert_eq!(out, vec![1, 2, 3, 4]);
        assert_eq!(r.commands, 1);
        assert_eq!(r.bytes, 4);
        assert!(r.elapsed_ns > 0.0);
    }

    #[test]
    fn one_big_read_beats_many_small() {
        // The paper's core premise: same bytes, fewer commands -> faster.
        let sim = UfsSim::new(op12(), 1 << 20);
        let small: Vec<ReadCmd> = (0..64)
            .map(|i| ReadCmd { offset: i * 2048, len: 2048 })
            .collect();
        let big = [ReadCmd { offset: 0, len: 64 * 2048 }];
        let t_small = sim.time_batch(&small).elapsed_ns;
        let t_big = sim.time_batch(&big).elapsed_ns;
        assert!(
            t_big < t_small / 10.0,
            "big={t_big} small={t_small}: continuity should dominate"
        );
    }

    #[test]
    fn figure4_bandwidth_curve_matches_closed_form() {
        let dev = op12();
        let sim = UfsSim::new(dev.clone(), 16 << 20);
        for &sz in &[4096usize, 8192, 24576, 262_144, 1 << 20] {
            let n = (4 << 20) / sz;
            let cmds: Vec<ReadCmd> = (0..n)
                .map(|i| ReadCmd { offset: (i * sz) as u64, len: sz })
                .collect();
            let r = sim.time_batch(&cmds);
            let bw = r.bytes as f64 / (r.elapsed_ns / 1e9);
            let want = dev.bandwidth_at(sz);
            let err = (bw - want).abs() / want;
            assert!(err < 0.05, "size={sz} bw={bw:.3e} want={want:.3e}");
        }
    }

    #[test]
    fn clock_and_stats_accumulate() {
        let mut sim = UfsSim::new(op12(), 4096);
        let mut out = Vec::new();
        sim.read_batch(&[ReadCmd { offset: 0, len: 512 }], &mut out);
        sim.read_batch(
            &[ReadCmd { offset: 512, len: 512 }, ReadCmd { offset: 2048, len: 128 }],
            &mut out,
        );
        let s = sim.stats();
        assert_eq!(s.total_commands, 3);
        assert_eq!(s.total_bytes, 1152);
        assert_eq!(s.total_batches, 2);
        assert!((sim.clock_ns() - s.total_busy_ns).abs() < 1e-9);
        assert!(s.iops() > 0.0 && s.bandwidth() > 0.0);
        // fully synchronous -> every busy ns was a stall, nothing hidden
        assert!((s.total_stall_ns - s.total_busy_ns).abs() < 1e-6);
        assert!(s.total_hidden_ns.abs() < 1e-6);
        assert!(s.overlap_ratio().abs() < 1e-9);
    }

    #[test]
    fn queue_window_refills_charged() {
        let dev = op12();
        let sim = UfsSim::new(dev.clone(), 1 << 20);
        let c33: Vec<ReadCmd> =
            (0..33).map(|i| ReadCmd { offset: i * 64, len: 64 }).collect();
        let c32: Vec<ReadCmd> =
            (0..32).map(|i| ReadCmd { offset: i * 64, len: 64 }).collect();
        let t33 = sim.time_batch(&c33).elapsed_ns;
        let t32 = sim.time_batch(&c32).elapsed_ns;
        let per_cmd = dev.cmd_latency_ns + 64.0 / dev.sat_bandwidth * 1e9;
        // 33rd command costs one service slot plus one extra window refill
        let extra = t33 - t32;
        assert!((extra - per_cmd - dev.submit_overhead_ns).abs() < 1.0);
    }

    #[test]
    #[should_panic(expected = "read past end")]
    fn oob_read_panics() {
        let mut sim = UfsSim::new(op12(), 128);
        let mut out = Vec::new();
        sim.read_batch(&[ReadCmd { offset: 100, len: 64 }], &mut out);
    }

    #[test]
    fn sync_mode_is_much_slower_per_command() {
        let mut sim = UfsSim::new(op12(), 1 << 20);
        let cmds: Vec<ReadCmd> =
            (0..16).map(|i| ReadCmd { offset: i * 4096, len: 4096 }).collect();
        let fast = sim.time_batch(&cmds).elapsed_ns;
        sim.set_sync(true);
        let slow = sim.time_batch(&cmds).elapsed_ns;
        assert!(slow > 8.0 * fast, "sync={slow} async={fast}");
    }

    #[test]
    fn empty_batch_is_free() {
        let mut sim = UfsSim::new(op12(), 128);
        let r = sim.charge(&[]);
        assert_eq!(r.elapsed_ns, 0.0);
        assert_eq!(sim.stats().total_commands, 0);
    }

    #[test]
    fn charge_is_bit_identical_to_submit_wait() {
        // the legacy synchronous path and the async path must produce
        // bit-identical timelines for the same command stream
        let batches: Vec<Vec<ReadCmd>> = (0..10u64)
            .map(|i| {
                (0..(i % 4) + 1)
                    .map(|j| ReadCmd {
                        offset: (i * 131 + j * 17) * 64,
                        len: 64 * (j as usize + 1),
                    })
                    .collect()
            })
            .collect();
        let mut a = UfsSim::new(op12(), 1 << 20);
        let mut b = UfsSim::new(op12(), 1 << 20);
        for cmds in &batches {
            a.charge(cmds);
            let t = b.submit_batch(cmds);
            b.wait(t);
        }
        assert_eq!(a.clock_ns().to_bits(), b.clock_ns().to_bits());
        assert_eq!(a.stats().total_busy_ns.to_bits(), b.stats().total_busy_ns.to_bits());
        assert_eq!(a.stats().total_commands, b.stats().total_commands);
        assert_eq!(a.stats().total_bytes, b.stats().total_bytes);
        assert_eq!(a.stats().total_batches, b.stats().total_batches);
    }

    #[test]
    fn compute_hides_inflight_batch() {
        let mut sim = UfsSim::new(op12(), 1 << 20);
        let cmds = [ReadCmd { offset: 0, len: 4096 }];
        let service = sim.time_batch(&cmds).elapsed_ns;
        let t = sim.submit_batch(&cmds);
        // compute for twice the service time: the wait must be free
        sim.advance_compute(2.0 * service);
        let w = sim.wait(t);
        assert_eq!(w.stall_ns, 0.0);
        assert_eq!(w.batch.elapsed_ns.to_bits(), service.to_bits());
        let s = sim.stats();
        assert_eq!(s.total_stall_ns, 0.0);
        assert_eq!(s.total_hidden_ns.to_bits(), service.to_bits());
        assert!((s.overlap_ratio() - 1.0).abs() < 1e-12);
        // host clock advanced by compute only
        assert_eq!(sim.clock_ns().to_bits(), (2.0 * service).to_bits());
    }

    #[test]
    fn partial_overlap_charges_remainder() {
        let mut sim = UfsSim::new(op12(), 1 << 20);
        let cmds = [ReadCmd { offset: 0, len: 65536 }];
        let service = sim.time_batch(&cmds).elapsed_ns;
        let t = sim.submit_batch(&cmds);
        sim.advance_compute(service / 4.0);
        let w = sim.wait(t);
        assert!(w.stall_ns > 0.0 && w.stall_ns < service);
        assert!((w.stall_ns + service / 4.0 - service).abs() < 1e-6);
        // clock ends exactly at the batch completion
        assert_eq!(sim.clock_ns().to_bits(), service.to_bits());
    }

    #[test]
    fn serial_device_queues_batches() {
        // two batches submitted back-to-back: the second starts when the
        // first completes, so waiting the second costs both service times
        let mut sim = UfsSim::new(op12(), 1 << 20);
        let cmds = [ReadCmd { offset: 0, len: 4096 }];
        let service = sim.time_batch(&cmds).elapsed_ns;
        let t1 = sim.submit_batch(&cmds);
        let t2 = sim.submit_batch(&cmds);
        let w2 = sim.wait(t2);
        assert!((w2.stall_ns - 2.0 * service).abs() < 1e-6);
        // the first is long done: free wait
        let w1 = sim.wait(t1);
        assert_eq!(w1.stall_ns, 0.0);
    }

    #[test]
    fn drop_ticket_counts_hidden_not_stall() {
        let mut sim = UfsSim::new(op12(), 1 << 20);
        let cmds = [ReadCmd { offset: 0, len: 4096 }];
        let service = sim.time_batch(&cmds).elapsed_ns;
        let t = sim.submit_batch(&cmds);
        assert_eq!(sim.in_flight(), 1);
        sim.drop_ticket(t);
        assert_eq!(sim.in_flight(), 0);
        let s = sim.stats();
        assert_eq!(s.total_busy_ns.to_bits(), service.to_bits());
        assert_eq!(s.total_stall_ns, 0.0);
        assert_eq!(s.total_hidden_ns.to_bits(), service.to_bits());
        // host clock untouched
        assert_eq!(sim.clock_ns(), 0.0);
    }

    #[test]
    #[should_panic(expected = "unknown or already-completed")]
    fn double_wait_panics() {
        let mut sim = UfsSim::new(op12(), 1 << 20);
        let t = sim.submit_batch(&[ReadCmd { offset: 0, len: 64 }]);
        sim.wait(t);
        sim.wait(t);
    }

    #[test]
    fn submit_read_batch_returns_data_at_submit() {
        let mut sim = UfsSim::new(op12(), 1024);
        sim.write_image(64, &[9, 8, 7]);
        let mut out = Vec::new();
        let t = sim.submit_read_batch(&[ReadCmd { offset: 64, len: 3 }], &mut out);
        assert_eq!(out, vec![9, 8, 7]);
        let w = sim.wait(t);
        assert_eq!(w.batch.bytes, 3);
    }

    #[test]
    fn advance_to_only_moves_forward() {
        let mut sim = UfsSim::new(op12(), 1 << 20);
        sim.advance_to(500.0);
        assert_eq!(sim.clock_ns().to_bits(), 500.0f64.to_bits());
        sim.advance_to(100.0);
        assert_eq!(sim.clock_ns().to_bits(), 500.0f64.to_bits());
        // idle time is neither compute nor stall
        assert_eq!(sim.compute_ns(), 0.0);
        assert_eq!(sim.stats().total_stall_ns, 0.0);
    }

    #[test]
    fn reset_clears_timelines() {
        let mut sim = UfsSim::new(op12(), 1 << 20);
        let _ = sim.submit_batch(&[ReadCmd { offset: 0, len: 64 }]);
        sim.advance_compute(100.0);
        sim.reset_stats();
        assert_eq!(sim.clock_ns(), 0.0);
        assert_eq!(sim.device_free_ns(), 0.0);
        assert_eq!(sim.compute_ns(), 0.0);
        assert_eq!(sim.in_flight(), 0);
        assert_eq!(sim.stats().total_batches, 0);
    }
}
