//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 via the PJRT C API).
//! Artifacts are HLO *text* (see python/compile/aot.py for why), parsed
//! with `HloModuleProto::from_text_file`, compiled once per process and
//! cached. Python never runs here — the request path is pure rust+PJRT.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; flattens the single tuple output the
    /// AOT path always emits (`return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact `{}`", self.name))?;
        let first = outs
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("artifact `{}` returned no buffers", self.name))?;
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// PJRT client + executable cache over an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

impl Runtime {
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        anyhow::ensure!(
            dir.is_dir(),
            "artifacts directory `{}` not found — run `make artifacts` first",
            dir.display()
        );
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load (or fetch from cache) an artifact by stem, e.g. "attn_b4".
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        anyhow::ensure!(path.is_file(), "artifact `{}` missing", path.display());
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text `{}`", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact `{name}`"))?;
        let e = std::rc::Rc::new(Executable { exe, name: name.to_string() });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape from a flat slice.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar i32 literal (e.g. the decode position).
pub fn lit_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// True when the AOT artifacts have been built (tests use this to skip
/// gracefully instead of failing on a fresh checkout).
pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("manifest.json").is_file()
}

/// The default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Runtime {
        Runtime::cpu(default_artifacts_dir()).unwrap()
    }

    #[test]
    fn lit_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit_f32(&[1.0], &[2]).is_err());
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Runtime::cpu("/nonexistent/artifacts").err().expect("must fail");
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn head_artifact_runs() {
        if !artifacts_available(default_artifacts_dir()) {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut rt = rt();
        let head = rt.load("head_b1").unwrap();
        // head(x[1,64], ln_g[64], ln_b[64], emb[256,64]) -> logits[1,256]
        let x = lit_f32(&vec![0.1; 64], &[1, 64]).unwrap();
        let g = lit_f32(&vec![1.0; 64], &[64]).unwrap();
        let b = lit_f32(&vec![0.0; 64], &[64]).unwrap();
        let emb = lit_f32(&vec![0.01; 256 * 64], &[256, 64]).unwrap();
        let out = head.run(&[x, g, b, emb]).unwrap();
        assert_eq!(out.len(), 1);
        let logits = to_vec_f32(&out[0]).unwrap();
        assert_eq!(logits.len(), 256);
        // x is constant across dims -> ln(x)=0 -> logits all 0
        assert!(logits.iter().all(|v| v.abs() < 1e-4));
    }

    #[test]
    fn executable_cache_reuses() {
        if !artifacts_available(default_artifacts_dir()) {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut rt = rt();
        let a = rt.load("head_b1").unwrap();
        let b = rt.load("head_b1").unwrap();
        assert!(std::rc::Rc::ptr_eq(&a, &b));
    }
}
