//! LRU with a victim buffer: evicted-but-recently-hot entries park in a
//! small FIFO side table and promote back on re-reference BEFORE any
//! flash read is charged (the classic victim-cache trick, applied to the
//! DRAM neuron cache).
//!
//! Geometry: of the requested capacity `C`, a small fixed slice
//! (`C / 8`, clamped to `[1, 64]`, zero when `C < 2`) becomes the FIFO
//! side table and the rest backs a plain [`Lru`] main table. The two
//! are disjoint, so `len = main.len + fifo.len <= C` and the reported
//! capacity is exactly the requested one.
//!
//! Promotion swaps rather than cascades: a re-referenced victim moves to
//! the main table's MRU position and the key the main table demotes (if
//! any) takes its place in the FIFO — net occupancy is unchanged and no
//! eviction escapes unreported through `touch`'s bool-only interface.
//!
//! §Perf: the main table is the dense slot-indexed [`Lru`]; the FIFO is
//! a pre-reserved ring of at most 64 keys scanned linearly (cheaper than
//! any index at that size). Steady state allocates nothing.

use std::collections::VecDeque;

use super::lru::Lru;

/// Largest victim FIFO regardless of capacity: a side table is a
/// recency backstop, not a second cache, and linear scans must stay
/// cheap.
const MAX_VICTIMS: usize = 64;

#[derive(Debug)]
pub struct Victim {
    main: Lru,
    fifo: VecDeque<u64>,
    victim_cap: usize,
    capacity: usize,
}

impl Victim {
    pub fn new(capacity: usize) -> Self {
        Self::bounded(capacity, 0)
    }

    /// Capacity-aware construction (§Perf): pre-sizes the main table's
    /// slot index for `key_bound` dense keys and reserves the FIFO ring
    /// up front, so steady-state operation never allocates.
    pub fn bounded(capacity: usize, key_bound: usize) -> Self {
        let victim_cap =
            if capacity >= 2 { (capacity / 8).clamp(1, MAX_VICTIMS) } else { 0 };
        Self {
            main: Lru::bounded(capacity - victim_cap, key_bound),
            fifo: VecDeque::with_capacity(victim_cap + 1),
            victim_cap,
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.main.len() + self.fifo.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn fifo_position(&self, key: u64) -> Option<usize> {
        self.fifo.iter().position(|&k| k == key)
    }

    /// Move a key out of the FIFO into the main table's MRU slot; the
    /// key the main table demotes backfills the freed FIFO slot.
    fn promote(&mut self, pos: usize, key: u64) {
        self.fifo.remove(pos);
        if let Some(demoted) = self.main.insert(key) {
            self.fifo.push_back(demoted);
        }
    }

    pub fn touch(&mut self, key: u64) -> bool {
        if self.main.touch(key) {
            return true;
        }
        match self.fifo_position(key) {
            Some(pos) => {
                self.promote(pos, key);
                true
            }
            None => false,
        }
    }

    pub fn contains_untouched(&self, key: u64) -> bool {
        self.main.contains_untouched(key) || self.fifo_position(key).is_some()
    }

    /// Insert a key; a cold insert under pressure demotes the main
    /// table's LRU entry into the FIFO, and the FIFO's oldest victim is
    /// what actually leaves the cache. Returns the evicted key, if any.
    pub fn insert(&mut self, key: u64) -> Option<u64> {
        if self.capacity == 0 {
            return None;
        }
        if self.main.touch(key) {
            return None;
        }
        if let Some(pos) = self.fifo_position(key) {
            self.promote(pos, key);
            return None;
        }
        let demoted = self.main.insert(key);
        let Some(demoted) = demoted else { return None };
        if self.victim_cap == 0 {
            return Some(demoted);
        }
        self.fifo.push_back(demoted);
        if self.fifo.len() > self.victim_cap {
            self.fifo.pop_front()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_parks_and_promotes() {
        // capacity 9 -> main 8, fifo 1
        let mut c = Victim::new(9);
        for k in 0..8u64 {
            assert_eq!(c.insert(k), None);
        }
        // key 0 is the main LRU; a cold insert demotes it into the FIFO
        assert_eq!(c.insert(100), None);
        assert_eq!(c.len(), 9);
        assert!(c.contains_untouched(0), "victim must still be resident");
        // re-referencing the victim promotes it back without an eviction
        assert!(c.touch(0));
        assert_eq!(c.len(), 9);
        assert!(c.contains_untouched(0));
    }

    #[test]
    fn fifo_overflow_is_the_real_eviction() {
        let mut c = Victim::new(9); // main 8, fifo 1
        for k in 0..8u64 {
            c.insert(k);
        }
        assert_eq!(c.insert(100), None); // demotes 0 into the fifo
        assert_eq!(c.insert(101), Some(0)); // demotes 1; fifo overflow drops 0
        assert!(!c.contains_untouched(0));
        assert!(c.contains_untouched(1));
        assert_eq!(c.len(), 9);
    }

    #[test]
    fn tiny_capacities_degrade_to_plain_lru() {
        let mut c = Victim::new(1); // victim slice is 0 below capacity 2
        assert_eq!(c.insert(1), None);
        assert_eq!(c.insert(2), Some(1));
        assert!(c.touch(2) && !c.touch(1));
        let mut z = Victim::new(0);
        assert_eq!(z.insert(1), None);
        assert!(!z.touch(1));
        assert_eq!(z.len(), 0);
    }

    #[test]
    fn promotion_swaps_instead_of_cascading() {
        // full cache: promoting a victim must not change occupancy or
        // silently drop a key
        let mut c = Victim::new(9);
        for k in 0..9u64 {
            c.insert(k);
        }
        for k in 100..104u64 {
            c.insert(k);
        }
        let len = c.len();
        // some key now sits in the FIFO; touching it swaps, not evicts
        let victim = (0..200u64)
            .find(|&k| !Lru::contains_untouched(&c.main, k) && c.contains_untouched(k))
            .expect("a parked victim");
        assert!(c.touch(victim));
        assert_eq!(c.len(), len);
        assert!(c.main.contains_untouched(victim));
    }
}
