//! S3-FIFO (Yang et al., SOSP'23) — "FIFO queues are all you need".
//!
//! The paper integrates S3-FIFO into every baseline and into RIPPLE
//! itself (§6.1); RIPPLE only changes the *admission* layer on top
//! (cache/mod.rs). Structure:
//!
//! * small FIFO (~10% of capacity) absorbs new keys,
//! * main FIFO (~90%) holds promoted keys,
//! * ghost FIFO remembers keys recently evicted from small.
//!
//! Eviction from small promotes keys that were re-referenced
//! (freq > 0) to main, otherwise demotes them to ghost. Eviction from
//! main lazily reinserts keys with freq > 0 (decremented). A miss whose
//! key sits in ghost is inserted directly into main ("quick demotion
//! was wrong" signal). Frequencies are capped at 3 as in the paper.

use std::collections::{HashMap, VecDeque};

#[derive(Debug)]
pub struct S3Fifo {
    capacity: usize,
    small_cap: usize,
    small: VecDeque<u64>,
    main: VecDeque<u64>,
    ghost: VecDeque<u64>,
    ghost_cap: usize,
    /// key -> (freq, where): where: 0=small, 1=main, 2=ghost
    table: HashMap<u64, (u8, u8)>,
}

const IN_SMALL: u8 = 0;
const IN_MAIN: u8 = 1;
const IN_GHOST: u8 = 2;
const FREQ_CAP: u8 = 3;

impl S3Fifo {
    pub fn new(capacity: usize) -> Self {
        let small_cap = (capacity / 10).max(1).min(capacity);
        Self {
            capacity,
            small_cap,
            small: VecDeque::new(),
            main: VecDeque::new(),
            ghost: VecDeque::new(),
            ghost_cap: capacity, // ghost remembers ~1x capacity of keys
            table: HashMap::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Resident entries (small + main, not ghost).
    pub fn len(&self) -> usize {
        self.small.len() + self.main.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookup; a hit bumps the frequency counter.
    pub fn touch(&mut self, key: u64) -> bool {
        match self.table.get_mut(&key) {
            Some((freq, loc)) if *loc != IN_GHOST => {
                *freq = (*freq + 1).min(FREQ_CAP);
                true
            }
            _ => false,
        }
    }

    pub fn contains_untouched(&self, key: u64) -> bool {
        matches!(self.table.get(&key), Some((_, loc)) if *loc != IN_GHOST)
    }

    /// Insert after a miss (no-op if already resident).
    pub fn insert(&mut self, key: u64) {
        if self.capacity == 0 {
            return;
        }
        match self.table.get(&key) {
            Some((_, loc)) if *loc != IN_GHOST => return, // already resident
            Some((_, _ghost)) => {
                // ghost hit: admit straight to main
                self.remove_from_ghost(key);
                self.ensure_room();
                self.main.push_back(key);
                self.table.insert(key, (0, IN_MAIN));
            }
            None => {
                self.ensure_room();
                self.small.push_back(key);
                self.table.insert(key, (0, IN_SMALL));
            }
        }
    }

    fn remove_from_ghost(&mut self, key: u64) {
        // lazy: mark removed in table; ghost queue entries are validated
        // against the table when they rotate out.
        self.table.remove(&key);
    }

    fn ensure_room(&mut self) {
        while self.len() >= self.capacity {
            if self.small.len() >= self.small_cap || self.main.is_empty() {
                self.evict_small();
            } else {
                self.evict_main();
            }
        }
    }

    fn evict_small(&mut self) {
        while let Some(key) = self.small.pop_front() {
            let Some(&(freq, loc)) = self.table.get(&key) else { continue };
            if loc != IN_SMALL {
                continue; // stale queue entry
            }
            if freq > 0 {
                // re-referenced while in small: promote to main
                self.table.insert(key, (0, IN_MAIN));
                self.main.push_back(key);
                if self.len() < self.capacity {
                    return;
                }
                continue;
            }
            // demote to ghost
            self.table.insert(key, (0, IN_GHOST));
            self.ghost.push_back(key);
            self.trim_ghost();
            return;
        }
    }

    fn evict_main(&mut self) {
        while let Some(key) = self.main.pop_front() {
            let Some(&(freq, loc)) = self.table.get(&key) else { continue };
            if loc != IN_MAIN {
                continue;
            }
            if freq > 0 {
                // lazy promotion: second chance with decayed freq
                self.table.insert(key, (freq - 1, IN_MAIN));
                self.main.push_back(key);
                continue;
            }
            self.table.remove(&key);
            return;
        }
    }

    fn trim_ghost(&mut self) {
        while self.ghost.len() > self.ghost_cap {
            if let Some(old) = self.ghost.pop_front() {
                if matches!(self.table.get(&old), Some((_, loc)) if *loc == IN_GHOST) {
                    self.table.remove(&old);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hit_miss() {
        let mut c = S3Fifo::new(10);
        assert!(!c.touch(1));
        c.insert(1);
        assert!(c.touch(1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn one_hit_wonders_dont_pollute_main() {
        // Scan 100 cold keys through a small cache while key 7 is hot:
        // 7 must survive (the signature S3-FIFO property).
        let mut c = S3Fifo::new(10);
        c.insert(7);
        c.touch(7);
        for i in 100..200u64 {
            c.insert(i);
            c.touch(7); // keep 7 hot
        }
        assert!(c.touch(7), "hot key evicted by scan");
        assert!(c.len() <= 10);
    }

    #[test]
    fn ghost_hit_promotes_to_main() {
        let mut c = S3Fifo::new(10);
        c.insert(42); // into small
        // push it out of small with cold keys (42 never re-referenced)
        for i in 0..10u64 {
            c.insert(i);
        }
        assert!(!c.touch(42), "42 should be ghosted");
        c.insert(42); // ghost hit -> main
        assert!(c.touch(42));
    }

    #[test]
    fn capacity_respected_under_churn() {
        let mut c = S3Fifo::new(32);
        for i in 0..10_000u64 {
            c.insert(i % 97);
            if i % 3 == 0 {
                c.touch(i % 7);
            }
            assert!(c.len() <= 32, "len={} at i={i}", c.len());
        }
    }

    #[test]
    fn zero_capacity() {
        let mut c = S3Fifo::new(0);
        c.insert(1);
        assert!(!c.touch(1));
    }

    #[test]
    fn skewed_workload_beats_fifo_pollution() {
        // hit ratio on a Zipf-ish loop should be decent: hot 8 keys fit.
        let mut c = S3Fifo::new(16);
        let mut hits = 0;
        let mut total = 0;
        for round in 0..400u64 {
            for hot in 0..8u64 {
                total += 1;
                if c.touch(hot) {
                    hits += 1;
                } else {
                    c.insert(hot);
                }
            }
            // occasional cold scan
            let cold = 1000 + round;
            if !c.touch(cold) {
                c.insert(cold);
            }
        }
        let ratio = hits as f64 / total as f64;
        assert!(ratio > 0.9, "hit ratio {ratio}");
    }
}
