//! S3-FIFO (Yang et al., SOSP'23) — "FIFO queues are all you need".
//!
//! The paper integrates S3-FIFO into every baseline and into RIPPLE
//! itself (§6.1); RIPPLE only changes the *admission* layer on top
//! (cache/mod.rs). Structure:
//!
//! * small FIFO (~10% of capacity) absorbs new keys,
//! * main FIFO (~90%) holds promoted keys,
//! * ghost FIFO remembers keys recently evicted from small.
//!
//! Eviction from small promotes keys that were re-referenced
//! (freq > 0) to main, otherwise demotes them to ghost. Eviction from
//! main lazily reinserts keys with freq > 0 (decremented). A miss whose
//! key sits in ghost is inserted directly into main ("quick demotion
//! was wrong" signal). Frequencies are capped at 3 as in the paper.
//!
//! §Perf: the per-key (freq, loc) record lives in a direct-indexed
//! dense byte table (`Vec<u8>`), not a hash map — keys are
//! `layer * slots_per_layer + slot` (see [`crate::cache::KeySpace`]),
//! so the universe is small and known up front. [`S3Fifo::bounded`]
//! pre-sizes the table and the three queues so steady-state operation
//! never touches the allocator; [`S3Fifo::new`] grows the table on
//! demand for callers with unknown key bounds.

use std::collections::VecDeque;

#[derive(Debug)]
pub struct S3Fifo {
    capacity: usize,
    small_cap: usize,
    small: VecDeque<u64>,
    main: VecDeque<u64>,
    ghost: VecDeque<u64>,
    ghost_cap: usize,
    /// key -> packed (freq, loc) record (dense; `ABSENT` = untracked).
    /// loc: 0=small, 1=main, 2=ghost; freq capped at `FREQ_CAP`.
    table: Vec<u8>,
}

const IN_SMALL: u8 = 0;
const IN_MAIN: u8 = 1;
const IN_GHOST: u8 = 2;
const FREQ_CAP: u8 = 3;
/// Dense-table sentinel for "key not tracked" (no packed record ever
/// reaches it: max is `(IN_GHOST << 2) | FREQ_CAP`).
const ABSENT: u8 = u8::MAX;

#[inline]
fn pack(freq: u8, loc: u8) -> u8 {
    (loc << 2) | freq
}

#[inline]
fn unpack(b: u8) -> (u8, u8) {
    (b & 0b11, b >> 2)
}

impl S3Fifo {
    pub fn new(capacity: usize) -> Self {
        Self::bounded(capacity, 0)
    }

    /// Capacity-aware construction: all keys are `< key_bound`, so the
    /// record table and the queue rings can be sized once, up front.
    /// With a real bound the rings reserve their FULL worst case — the
    /// zero-alloc invariant (§Perf) must hold at any cache size; only
    /// the unknown-bound [`S3Fifo::new`] path caps its speculative
    /// reservation.
    pub fn bounded(capacity: usize, key_bound: usize) -> Self {
        let small_cap = (capacity / 10).max(1).min(capacity);
        let ghost_cap = capacity; // ghost remembers ~1x capacity of keys
        let cap_guard = if key_bound > 0 { usize::MAX } else { 1 << 20 };
        let reserve = |n: usize| VecDeque::with_capacity((n + 2).min(cap_guard));
        Self {
            capacity,
            small_cap,
            // small can fill the whole cache before the first eviction,
            // so both resident queues reserve full capacity
            small: reserve(capacity),
            main: reserve(capacity),
            ghost: reserve(ghost_cap),
            ghost_cap,
            table: vec![ABSENT; key_bound],
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Resident entries (small + main, not ghost).
    pub fn len(&self) -> usize {
        self.small.len() + self.main.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    fn get(&self, key: u64) -> Option<(u8, u8)> {
        match self.table.get(key as usize) {
            Some(&b) if b != ABSENT => Some(unpack(b)),
            _ => None,
        }
    }

    /// Write the (freq, loc) record for `key`, growing the table when
    /// the key exceeds the construction-time bound (never on the
    /// bounded path).
    #[inline]
    fn set(&mut self, key: u64, freq: u8, loc: u8) {
        let k = key as usize;
        if k >= self.table.len() {
            self.table.resize(k + 1, ABSENT);
        }
        self.table[k] = pack(freq, loc);
    }

    #[inline]
    fn remove_record(&mut self, key: u64) {
        if let Some(b) = self.table.get_mut(key as usize) {
            *b = ABSENT;
        }
    }

    /// Lookup; a hit bumps the frequency counter.
    pub fn touch(&mut self, key: u64) -> bool {
        match self.get(key) {
            Some((freq, loc)) if loc != IN_GHOST => {
                self.set(key, (freq + 1).min(FREQ_CAP), loc);
                true
            }
            _ => false,
        }
    }

    pub fn contains_untouched(&self, key: u64) -> bool {
        matches!(self.get(key), Some((_, loc)) if loc != IN_GHOST)
    }

    /// Insert after a miss (no-op if already resident).
    /// Returns the resident key evicted to make room, if any.
    pub fn insert(&mut self, key: u64) -> Option<u64> {
        if self.capacity == 0 {
            return None;
        }
        match self.get(key) {
            Some((_, loc)) if loc != IN_GHOST => None, // already resident
            Some(_) => {
                // ghost hit: admit straight to main. Lazy removal: the
                // ghost queue entry is validated against the table when
                // it rotates out.
                self.remove_record(key);
                let evicted = self.ensure_room();
                self.main.push_back(key);
                self.set(key, 0, IN_MAIN);
                evicted
            }
            None => {
                let evicted = self.ensure_room();
                self.small.push_back(key);
                self.set(key, 0, IN_SMALL);
                evicted
            }
        }
    }

    fn ensure_room(&mut self) -> Option<u64> {
        let mut evicted = None;
        while self.len() >= self.capacity {
            let e = if self.small.len() >= self.small_cap || self.main.is_empty() {
                self.evict_small()
            } else {
                self.evict_main()
            };
            debug_assert!(
                evicted.is_none() || e.is_none(),
                "one insert evicts at most one resident key"
            );
            evicted = evicted.or(e);
        }
        evicted
    }

    fn evict_small(&mut self) -> Option<u64> {
        while let Some(key) = self.small.pop_front() {
            let Some((freq, loc)) = self.get(key) else { continue };
            if loc != IN_SMALL {
                continue; // stale queue entry
            }
            if freq > 0 {
                // re-referenced while in small: promote to main
                self.set(key, 0, IN_MAIN);
                self.main.push_back(key);
                if self.len() < self.capacity {
                    return None;
                }
                continue;
            }
            // demote to ghost: the key leaves the resident set
            self.set(key, 0, IN_GHOST);
            self.ghost.push_back(key);
            self.trim_ghost();
            return Some(key);
        }
        None
    }

    fn evict_main(&mut self) -> Option<u64> {
        while let Some(key) = self.main.pop_front() {
            let Some((freq, loc)) = self.get(key) else { continue };
            if loc != IN_MAIN {
                continue;
            }
            if freq > 0 {
                // lazy promotion: second chance with decayed freq
                self.set(key, freq - 1, IN_MAIN);
                self.main.push_back(key);
                continue;
            }
            self.remove_record(key);
            return Some(key);
        }
        None
    }

    fn trim_ghost(&mut self) {
        while self.ghost.len() > self.ghost_cap {
            if let Some(old) = self.ghost.pop_front() {
                if matches!(self.get(old), Some((_, loc)) if loc == IN_GHOST) {
                    self.remove_record(old);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hit_miss() {
        let mut c = S3Fifo::new(10);
        assert!(!c.touch(1));
        c.insert(1);
        assert!(c.touch(1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn one_hit_wonders_dont_pollute_main() {
        // Scan 100 cold keys through a small cache while key 7 is hot:
        // 7 must survive (the signature S3-FIFO property).
        let mut c = S3Fifo::new(10);
        c.insert(7);
        c.touch(7);
        for i in 100..200u64 {
            c.insert(i);
            c.touch(7); // keep 7 hot
        }
        assert!(c.touch(7), "hot key evicted by scan");
        assert!(c.len() <= 10);
    }

    #[test]
    fn ghost_hit_promotes_to_main() {
        let mut c = S3Fifo::new(10);
        c.insert(42); // into small
        // push it out of small with cold keys (42 never re-referenced)
        for i in 0..10u64 {
            c.insert(i);
        }
        assert!(!c.touch(42), "42 should be ghosted");
        c.insert(42); // ghost hit -> main
        assert!(c.touch(42));
    }

    #[test]
    fn capacity_respected_under_churn() {
        let mut c = S3Fifo::new(32);
        for i in 0..10_000u64 {
            c.insert(i % 97);
            if i % 3 == 0 {
                c.touch(i % 7);
            }
            assert!(c.len() <= 32, "len={} at i={i}", c.len());
        }
    }

    #[test]
    fn zero_capacity() {
        let mut c = S3Fifo::new(0);
        c.insert(1);
        assert!(!c.touch(1));
    }

    #[test]
    fn evictions_reported_once_per_insert() {
        let mut c = S3Fifo::new(8);
        let mut resident = std::collections::HashSet::new();
        for i in 0..2_000u64 {
            let k = (i * 13) % 41;
            if c.touch(k) {
                continue;
            }
            let evicted = c.insert(k);
            resident.insert(k);
            if let Some(e) = evicted {
                assert!(resident.remove(&e), "evicted {e} was not resident");
                assert!(!c.contains_untouched(e), "evicted {e} still resident");
            }
            assert_eq!(resident.len(), c.len(), "resident set diverged at {i}");
        }
    }

    #[test]
    fn bounded_behaves_like_unbounded() {
        let mut a = S3Fifo::new(16);
        let mut b = S3Fifo::bounded(16, 97);
        for i in 0..5_000u64 {
            let k = (i * 31) % 97;
            assert_eq!(a.touch(k), b.touch(k), "touch diverged at {i}");
            if i % 2 == 0 {
                assert_eq!(a.insert(k), b.insert(k), "insert diverged at {i}");
            }
            assert_eq!(a.len(), b.len());
        }
        assert_eq!(b.table.len(), 97);
    }

    #[test]
    fn skewed_workload_beats_fifo_pollution() {
        // hit ratio on a Zipf-ish loop should be decent: hot 8 keys fit.
        let mut c = S3Fifo::new(16);
        let mut hits = 0;
        let mut total = 0;
        for round in 0..400u64 {
            for hot in 0..8u64 {
                total += 1;
                if c.touch(hot) {
                    hits += 1;
                } else {
                    c.insert(hot);
                }
            }
            // occasional cold scan
            let cold = 1000 + round;
            if !c.touch(cold) {
                c.insert(cold);
            }
        }
        let ratio = hits as f64 / total as f64;
        assert!(ratio > 0.9, "hit ratio {ratio}");
    }
}
