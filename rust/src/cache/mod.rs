//! DRAM neuron cache: policy trait, S3-FIFO and LRU implementations, and
//! RIPPLE's linking-aligned admission layer (paper §5.2).
//!
//! §Perf (DESIGN.md): cache keys are **dense** — `(layer, slot)` maps to
//! `layer * slots_per_layer + slot` via [`KeySpace`], so the whole key
//! universe is `[0, n_layers * slots_per_layer)` and every policy can
//! index a flat slot table instead of hashing. Construct through
//! [`NeuronCache::from_config`] (or [`CachePolicy::bounded`]) with the
//! real key bound and the steady-state decode path never touches the
//! allocator or a hash function.

mod lru;
mod s3fifo;

pub use lru::Lru;
pub use s3fifo::S3Fifo;

use crate::access::SlotRun;
use crate::neuron::{NeuronSpace, Slot};
use crate::util::rng::Rng;

/// Dense key geometry shared by the cache policies and the owner table:
/// a `(layer, slot)` pair maps to `layer * slots_per_layer + slot`, so
/// every key lies in `[0, bound())` and direct indexing replaces
/// hashing on the per-token hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KeySpace {
    /// Layers covered by the key universe.
    pub n_layers: usize,
    /// Slots per layer (the key stride).
    pub slots_per_layer: usize,
}

impl KeySpace {
    /// A key space for `n_layers` layers of `slots_per_layer` slots.
    pub fn new(n_layers: usize, slots_per_layer: usize) -> Self {
        Self { n_layers, slots_per_layer }
    }

    /// The key space of a [`NeuronSpace`] (the usual construction).
    pub fn of(space: &NeuronSpace) -> Self {
        Self::new(space.n_layers, space.per_layer)
    }

    /// Exclusive upper bound of every key in this space.
    pub fn bound(&self) -> usize {
        self.n_layers * self.slots_per_layer
    }

    /// The dense key of `(layer, slot)`.
    #[inline]
    pub fn key(&self, layer: usize, slot: Slot) -> u64 {
        debug_assert!(layer < self.n_layers, "layer {layer} out of key space");
        debug_assert!(
            (slot as usize) < self.slots_per_layer,
            "slot {slot} out of key space stride {}",
            self.slots_per_layer
        );
        layer as u64 * self.slots_per_layer as u64 + slot as u64
    }
}

/// Uniform policy interface over dense `(layer, slot)` keys.
pub trait CachePolicy: Send {
    /// Lookup; a hit refreshes the entry's standing.
    fn touch(&mut self, key: u64) -> bool;
    /// Insert after a miss (may evict). Returns the key evicted from
    /// the resident set, if any — [`NeuronCache`] resets the evicted
    /// key's owner record on it.
    fn insert(&mut self, key: u64) -> Option<u64>;
    /// Residency test with NO side effects (no recency/frequency bump) —
    /// used by speculative prefetch filtering, which must not distort
    /// the policy's view of real demand.
    fn contains(&self, key: u64) -> bool;
    fn len(&self) -> usize;
    fn capacity(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Capacity-aware construction (§Perf): every key the policy will
    /// ever see is `< key_bound`, so the dense slot table and the
    /// internal queues/slabs are sized once — steady-state operation
    /// never allocates.
    fn bounded(capacity: usize, key_bound: usize) -> Self
    where
        Self: Sized;
}

impl CachePolicy for Lru {
    fn touch(&mut self, key: u64) -> bool {
        Lru::touch(self, key)
    }
    fn insert(&mut self, key: u64) -> Option<u64> {
        Lru::insert(self, key)
    }
    fn contains(&self, key: u64) -> bool {
        Lru::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        Lru::len(self)
    }
    fn capacity(&self) -> usize {
        Lru::capacity(self)
    }
    fn bounded(capacity: usize, key_bound: usize) -> Self {
        Lru::bounded(capacity, key_bound)
    }
}

impl CachePolicy for S3Fifo {
    fn touch(&mut self, key: u64) -> bool {
        S3Fifo::touch(self, key)
    }
    fn insert(&mut self, key: u64) -> Option<u64> {
        S3Fifo::insert(self, key)
    }
    fn contains(&self, key: u64) -> bool {
        S3Fifo::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        S3Fifo::len(self)
    }
    fn capacity(&self) -> usize {
        S3Fifo::capacity(self)
    }
    fn bounded(capacity: usize, key_bound: usize) -> Self {
        S3Fifo::bounded(capacity, key_bound)
    }
}

/// No-op cache (cache_ratio = 0 configurations).
pub struct NullCache;

impl CachePolicy for NullCache {
    fn touch(&mut self, _key: u64) -> bool {
        false
    }
    fn insert(&mut self, _key: u64) -> Option<u64> {
        None
    }
    fn contains(&self, _key: u64) -> bool {
        false
    }
    fn len(&self) -> usize {
        0
    }
    fn capacity(&self) -> usize {
        0
    }
    fn bounded(_capacity: usize, _key_bound: usize) -> Self {
        NullCache
    }
}

/// How insertions are admitted (paper §5.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    /// Admit everything (plain S3-FIFO / LRU baselines).
    All,
    /// RIPPLE linking-aligned: *sporadic* slots (read runs shorter than
    /// `segment_min`) admit as usual; *continuous segments* admit
    /// all-or-nothing with probability `segment_p` — caching a partial
    /// segment would fragment an optimized flash extent into
    /// discontinuous residue reads while burning DRAM on it.
    Linking { segment_min: u32, segment_p: f64 },
}

/// Owner-table sentinel: no session admitted this key.
const NO_OWNER: u32 = u32::MAX;

/// The neuron cache used by the pipeline: a policy + admission layer.
///
/// Multi-tenant serving (DESIGN.md §Serving) shares ONE `NeuronCache`
/// across sessions: call [`NeuronCache::set_session`] before each
/// session's accesses and the cache additionally attributes every hit
/// to the session that admitted the entry, counting *cross-session*
/// hits — the co-activation reuse a shared cache buys over private
/// partitions. Without a session tag the counters and behavior are
/// bit-identical to the historical single-tenant cache.
pub struct NeuronCache {
    policy: Box<dyn CachePolicy>,
    admission: Admission,
    rng: Rng,
    /// statistics
    pub hits: u64,
    pub misses: u64,
    /// Hits on entries admitted by a *different* session (only counted
    /// once `set_session` has been called).
    pub cross_hits: u64,
    /// Current session tag; `None` = single-tenant (no attribution).
    session: Option<u32>,
    /// Dense key geometry (`layer * slots_per_layer + slot`).
    keys: KeySpace,
    /// key -> session that last admitted it (dense; `NO_OWNER` = none).
    /// Reset whenever the policy evicts a key, so a later re-admission
    /// through an untagged path can never inherit a stale owner (the
    /// old map-backed table let that miscount `cross_hits`).
    owners: Vec<u32>,
}

impl NeuronCache {
    pub fn new(
        policy: Box<dyn CachePolicy>,
        admission: Admission,
        seed: u64,
        keys: KeySpace,
    ) -> Self {
        Self {
            policy,
            admission,
            rng: Rng::new(seed),
            hits: 0,
            misses: 0,
            cross_hits: 0,
            session: None,
            keys,
            owners: vec![NO_OWNER; keys.bound()],
        }
    }

    /// Tag subsequent accesses with a session id (multi-tenant serving).
    /// Enables cross-session hit attribution; policy behavior, hit/miss
    /// counts and admission decisions are unaffected.
    pub fn set_session(&mut self, session: u32) {
        self.session = Some(session);
    }

    /// Return to untagged single-tenant mode: subsequent admissions
    /// record no owner and hits are never attributed across sessions.
    pub fn clear_session(&mut self) {
        self.session = None;
    }

    /// The fraction of hits served by an entry another session admitted
    /// (0.0 while single-tenant or before any hit).
    pub fn cross_hit_ratio(&self) -> f64 {
        if self.hits == 0 { 0.0 } else { self.cross_hits as f64 / self.hits as f64 }
    }

    /// Build from a RunConfig policy name. `keys` is the dense key
    /// geometry of the workload (usually `KeySpace::of(&space)`); the
    /// policy pre-sizes its slot tables from it so the steady-state
    /// decode path never allocates.
    pub fn from_config(
        policy: &str,
        capacity: usize,
        keys: KeySpace,
        seed: u64,
    ) -> anyhow::Result<Self> {
        // segment_p tuned by benches/ablations.rs (Ablation C)
        let linking = Admission::Linking { segment_min: 4, segment_p: 0.5 };
        let bound = keys.bound();
        Ok(match policy {
            "linking" => {
                Self::new(Box::new(S3Fifo::bounded(capacity, bound)), linking, seed, keys)
            }
            "s3fifo" => Self::new(
                Box::new(S3Fifo::bounded(capacity, bound)),
                Admission::All,
                seed,
                keys,
            ),
            "lru" => Self::new(
                Box::new(Lru::bounded(capacity, bound)),
                Admission::All,
                seed,
                keys,
            ),
            "none" => Self::new(Box::new(NullCache), Admission::All, seed, keys),
            _ => anyhow::bail!("unknown cache policy `{policy}` (linking|s3fifo|lru|none)"),
        })
    }

    pub fn len(&self) -> usize {
        self.policy.len()
    }

    pub fn capacity(&self) -> usize {
        self.policy.capacity()
    }

    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 { 0.0 } else { self.hits as f64 / total as f64 }
    }

    /// Side-effect-free residency test (prefetch planning).
    pub fn contains(&self, layer: usize, slot: Slot) -> bool {
        self.policy.contains(self.keys.key(layer, slot))
    }

    /// Partition activated slots into (cached, must-read), reusing the
    /// caller's buffers (§Perf: the per-token hot path allocates
    /// nothing). Slots must be sorted; both outputs preserve order.
    pub fn filter_into(
        &mut self,
        layer: usize,
        slots: &[Slot],
        hit: &mut Vec<Slot>,
        miss: &mut Vec<Slot>,
    ) {
        hit.clear();
        miss.clear();
        for &s in slots {
            let k = self.keys.key(layer, s);
            if self.policy.touch(k) {
                self.hits += 1;
                if let Some(me) = self.session {
                    let owner = self.owners.get(k as usize).copied().unwrap_or(NO_OWNER);
                    if owner != NO_OWNER && owner != me {
                        self.cross_hits += 1;
                    }
                }
                hit.push(s);
            } else {
                self.misses += 1;
                miss.push(s);
            }
        }
    }

    /// Allocating convenience wrapper over [`NeuronCache::filter_into`].
    pub fn filter(&mut self, layer: usize, slots: &[Slot]) -> (Vec<Slot>, Vec<Slot>) {
        let mut hit = Vec::new();
        let mut miss = Vec::with_capacity(slots.len());
        self.filter_into(layer, slots, &mut hit, &mut miss);
        (hit, miss)
    }

    #[inline]
    fn set_owner(&mut self, k: u64, owner: u32) {
        let i = k as usize;
        if i >= self.owners.len() {
            if owner == NO_OWNER {
                return;
            }
            // only reachable when a key exceeds the construction-time
            // bound (tests with unknown geometry); never on the hot path
            self.owners.resize(i + 1, NO_OWNER);
        }
        self.owners[i] = owner;
    }

    #[inline]
    fn insert_key(&mut self, k: u64) {
        if let Some(evicted) = self.policy.insert(k) {
            self.set_owner(evicted, NO_OWNER);
        }
        if let Some(me) = self.session {
            self.set_owner(k, me);
        }
    }

    /// Admit freshly-read runs according to the admission policy.
    /// `runs` are the *demanded* read runs (post-collapse is fine: the
    /// speculative gap slots arrived in DRAM too and are admitted with
    /// their segment).
    pub fn admit(&mut self, layer: usize, runs: &[SlotRun]) {
        let keys = self.keys;
        for r in runs {
            match self.admission {
                Admission::All => {
                    for s in r.start..r.end() {
                        self.insert_key(keys.key(layer, s));
                    }
                }
                Admission::Linking { segment_min, segment_p } => {
                    if r.len < segment_min {
                        for s in r.start..r.end() {
                            self.insert_key(keys.key(layer, s));
                        }
                    } else if self.rng.chance(segment_p) {
                        // all-or-nothing segment admission
                        for s in r.start..r.end() {
                            self.insert_key(keys.key(layer, s));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::access::plan_runs;

    fn runs(slots: &[Slot]) -> Vec<SlotRun> {
        plan_runs(slots)
    }

    fn keys() -> KeySpace {
        KeySpace::new(2, 64)
    }

    #[test]
    fn filter_partitions() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1, keys());
        c.admit(0, &runs(&[1, 2, 3]));
        let (hit, miss) = c.filter(0, &[1, 2, 5]);
        assert_eq!(hit, vec![1, 2]);
        assert_eq!(miss, vec![5]);
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn filter_into_reuses_buffers() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1, keys());
        c.admit(0, &runs(&[1, 2, 3]));
        let mut hit = vec![99, 98]; // stale content must be cleared
        let mut miss = vec![97];
        c.filter_into(0, &[1, 2, 5], &mut hit, &mut miss);
        assert_eq!(hit, vec![1, 2]);
        assert_eq!(miss, vec![5]);
        c.filter_into(0, &[3, 9], &mut hit, &mut miss);
        assert_eq!(hit, vec![3]);
        assert_eq!(miss, vec![9]);
    }

    #[test]
    fn layers_are_disjoint() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1, keys());
        c.admit(0, &runs(&[1]));
        let (hit, _) = c.filter(1, &[1]);
        assert!(hit.is_empty());
    }

    #[test]
    fn key_space_is_dense() {
        let ks = KeySpace::new(3, 100);
        assert_eq!(ks.bound(), 300);
        assert_eq!(ks.key(0, 0), 0);
        assert_eq!(ks.key(0, 99), 99);
        assert_eq!(ks.key(1, 0), 100);
        assert_eq!(ks.key(2, 99), 299);
    }

    #[test]
    fn linking_admits_sporadic_always() {
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 0.0 },
            3,
            keys(),
        );
        c.admit(0, &runs(&[10, 20, 30])); // three 1-runs: sporadic
        let (hit, _) = c.filter(0, &[10, 20, 30]);
        assert_eq!(hit.len(), 3);
    }

    #[test]
    fn linking_segment_all_or_nothing() {
        // segment_p = 0 -> long runs never admitted
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 0.0 },
            3,
            keys(),
        );
        c.admit(0, &runs(&[0, 1, 2, 3, 4]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4]);
        assert!(hit.is_empty());

        // segment_p = 1 -> whole segment admitted
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 1.0 },
            3,
            keys(),
        );
        c.admit(0, &runs(&[0, 1, 2, 3, 4]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4]);
        assert_eq!(hit.len(), 5);
    }

    #[test]
    fn from_config_names() {
        for p in ["linking", "s3fifo", "lru", "none"] {
            assert!(NeuronCache::from_config(p, 16, keys(), 0).is_ok(), "{p}");
        }
        assert!(NeuronCache::from_config("arc", 16, keys(), 0).is_err());
    }

    #[test]
    fn null_cache_never_hits() {
        let mut c = NeuronCache::from_config("none", 0, keys(), 0).unwrap();
        c.admit(0, &runs(&[1, 2, 3]));
        let (hit, miss) = c.filter(0, &[1, 2, 3]);
        assert!(hit.is_empty());
        assert_eq!(miss.len(), 3);
    }

    #[test]
    fn cross_session_hits_attributed() {
        let mut c = NeuronCache::new(Box::new(Lru::new(16)), Admission::All, 1, keys());
        c.set_session(0);
        c.admit(0, &runs(&[1, 2]));
        // a session hitting its own entries: no cross hits
        c.filter(0, &[1, 2]);
        assert_eq!(c.hits, 2);
        assert_eq!(c.cross_hits, 0);
        // another session reusing them: cross hits
        c.set_session(1);
        let (hit, _) = c.filter(0, &[1, 2]);
        assert_eq!(hit.len(), 2);
        assert_eq!(c.cross_hits, 2);
        assert!((c.cross_hit_ratio() - 0.5).abs() < 1e-12);
        // ownership follows the most recent admitter
        c.admit(0, &runs(&[9]));
        c.set_session(0);
        c.filter(0, &[9]);
        assert_eq!(c.cross_hits, 3);
    }

    #[test]
    fn untagged_cache_never_counts_cross_hits() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1, keys());
        c.admit(0, &runs(&[1]));
        c.filter(0, &[1]);
        assert!(c.hits == 1 && c.cross_hits == 0);
        assert_eq!(c.cross_hit_ratio(), 0.0);
    }

    #[test]
    fn eviction_resets_owner_for_untagged_readmission() {
        // Regression (the old HashMap owner table kept stale records):
        // session 0 admits a key, the key is evicted, an UNTAGGED path
        // re-admits it — a later hit by session 1 must NOT be counted as
        // a cross-session hit, because no session owns the live entry.
        let mut c = NeuronCache::new(Box::new(Lru::new(1)), Admission::All, 1, keys());
        c.set_session(0);
        c.admit(0, &runs(&[5])); // owner(5) = 0
        c.clear_session();
        c.admit(0, &runs(&[6])); // evicts 5 -> owner(5) resets
        c.admit(0, &runs(&[5])); // untagged re-admission: no owner
        c.set_session(1);
        let (hit, _) = c.filter(0, &[5]);
        assert_eq!(hit, vec![5]);
        assert_eq!(c.cross_hits, 0, "stale owner record miscounted a cross hit");
    }

    #[test]
    fn eviction_then_tagged_readmission_attributes_to_new_owner() {
        // evict -> re-admit by another session: attribution follows the
        // live entry, exactly as before the dense-owner refactor.
        let mut c = NeuronCache::new(Box::new(Lru::new(1)), Admission::All, 1, keys());
        c.set_session(0);
        c.admit(0, &runs(&[5]));
        c.set_session(1);
        c.admit(0, &runs(&[6])); // evicts 5
        c.admit(0, &runs(&[5])); // evicts 6; owner(5) = 1
        c.set_session(0);
        let (hit, _) = c.filter(0, &[5]);
        assert_eq!(hit, vec![5]);
        assert_eq!(c.cross_hits, 1);
        // and session 1 hitting its own re-admission stays clean
        c.set_session(1);
        c.filter(0, &[5]);
        assert_eq!(c.cross_hits, 1);
    }

    #[test]
    fn hit_ratio_tracks() {
        let mut c = NeuronCache::from_config("s3fifo", 16, keys(), 0).unwrap();
        c.admit(0, &runs(&[1]));
        c.filter(0, &[1]);
        c.filter(0, &[2]);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-12);
    }
}
