//! DRAM neuron cache: policy trait, S3-FIFO and LRU implementations, and
//! RIPPLE's linking-aligned admission layer (paper §5.2).

mod lru;
mod s3fifo;

pub use lru::Lru;
pub use s3fifo::S3Fifo;

use crate::access::SlotRun;
use crate::neuron::Slot;
use crate::util::rng::Rng;

/// Uniform policy interface over (layer, slot) keys.
pub trait CachePolicy: Send {
    /// Lookup; a hit refreshes the entry's standing.
    fn touch(&mut self, key: u64) -> bool;
    /// Insert after a miss (may evict).
    fn insert(&mut self, key: u64);
    /// Residency test with NO side effects (no recency/frequency bump) —
    /// used by speculative prefetch filtering, which must not distort
    /// the policy's view of real demand.
    fn contains(&self, key: u64) -> bool;
    fn len(&self) -> usize;
    fn capacity(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl CachePolicy for Lru {
    fn touch(&mut self, key: u64) -> bool {
        Lru::touch(self, key)
    }
    fn insert(&mut self, key: u64) {
        Lru::insert(self, key);
    }
    fn contains(&self, key: u64) -> bool {
        Lru::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        Lru::len(self)
    }
    fn capacity(&self) -> usize {
        Lru::capacity(self)
    }
}

impl CachePolicy for S3Fifo {
    fn touch(&mut self, key: u64) -> bool {
        S3Fifo::touch(self, key)
    }
    fn insert(&mut self, key: u64) {
        S3Fifo::insert(self, key);
    }
    fn contains(&self, key: u64) -> bool {
        S3Fifo::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        S3Fifo::len(self)
    }
    fn capacity(&self) -> usize {
        S3Fifo::capacity(self)
    }
}

/// No-op cache (cache_ratio = 0 configurations).
pub struct NullCache;

impl CachePolicy for NullCache {
    fn touch(&mut self, _key: u64) -> bool {
        false
    }
    fn insert(&mut self, _key: u64) {}
    fn contains(&self, _key: u64) -> bool {
        false
    }
    fn len(&self) -> usize {
        0
    }
    fn capacity(&self) -> usize {
        0
    }
}

#[inline]
pub fn key(layer: usize, slot: Slot) -> u64 {
    ((layer as u64) << 32) | slot as u64
}

/// How insertions are admitted (paper §5.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    /// Admit everything (plain S3-FIFO / LRU baselines).
    All,
    /// RIPPLE linking-aligned: *sporadic* slots (read runs shorter than
    /// `segment_min`) admit as usual; *continuous segments* admit
    /// all-or-nothing with probability `segment_p` — caching a partial
    /// segment would fragment an optimized flash extent into
    /// discontinuous residue reads while burning DRAM on it.
    Linking { segment_min: u32, segment_p: f64 },
}

/// The neuron cache used by the pipeline: a policy + admission layer.
///
/// Multi-tenant serving (DESIGN.md §Serving) shares ONE `NeuronCache`
/// across sessions: call [`NeuronCache::set_session`] before each
/// session's accesses and the cache additionally attributes every hit
/// to the session that admitted the entry, counting *cross-session*
/// hits — the co-activation reuse a shared cache buys over private
/// partitions. Without a session tag the counters and behavior are
/// bit-identical to the historical single-tenant cache.
pub struct NeuronCache {
    policy: Box<dyn CachePolicy>,
    admission: Admission,
    rng: Rng,
    /// statistics
    pub hits: u64,
    pub misses: u64,
    /// Hits on entries admitted by a *different* session (only counted
    /// once `set_session` has been called).
    pub cross_hits: u64,
    /// Current session tag; `None` = single-tenant (no attribution).
    session: Option<u32>,
    /// key -> session that last admitted it. Entries for evicted keys
    /// may linger (they are only consulted for resident keys, so stale
    /// owners never miscount); the map is bounded by the slot universe.
    owners: std::collections::HashMap<u64, u32>,
}

impl NeuronCache {
    pub fn new(policy: Box<dyn CachePolicy>, admission: Admission, seed: u64) -> Self {
        Self {
            policy,
            admission,
            rng: Rng::new(seed),
            hits: 0,
            misses: 0,
            cross_hits: 0,
            session: None,
            owners: std::collections::HashMap::new(),
        }
    }

    /// Tag subsequent accesses with a session id (multi-tenant serving).
    /// Enables cross-session hit attribution; policy behavior, hit/miss
    /// counts and admission decisions are unaffected.
    pub fn set_session(&mut self, session: u32) {
        self.session = Some(session);
    }

    /// The fraction of hits served by an entry another session admitted
    /// (0.0 while single-tenant or before any hit).
    pub fn cross_hit_ratio(&self) -> f64 {
        if self.hits == 0 { 0.0 } else { self.cross_hits as f64 / self.hits as f64 }
    }

    /// Build from a RunConfig policy name.
    pub fn from_config(
        policy: &str,
        capacity: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        // segment_p tuned by benches/ablations.rs (Ablation C)
        let linking = Admission::Linking { segment_min: 4, segment_p: 0.5 };
        Ok(match policy {
            "linking" => Self::new(Box::new(S3Fifo::new(capacity)), linking, seed),
            "s3fifo" => Self::new(Box::new(S3Fifo::new(capacity)), Admission::All, seed),
            "lru" => Self::new(Box::new(Lru::new(capacity)), Admission::All, seed),
            "none" => Self::new(Box::new(NullCache), Admission::All, seed),
            _ => anyhow::bail!("unknown cache policy `{policy}` (linking|s3fifo|lru|none)"),
        })
    }

    pub fn len(&self) -> usize {
        self.policy.len()
    }

    pub fn capacity(&self) -> usize {
        self.policy.capacity()
    }

    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 { 0.0 } else { self.hits as f64 / total as f64 }
    }

    /// Side-effect-free residency test (prefetch planning).
    pub fn contains(&self, layer: usize, slot: Slot) -> bool {
        self.policy.contains(key(layer, slot))
    }

    /// Partition activated slots into (cached, must-read). Slots must be
    /// sorted; the returned vectors preserve order.
    pub fn filter(&mut self, layer: usize, slots: &[Slot]) -> (Vec<Slot>, Vec<Slot>) {
        let mut hit = Vec::new();
        let mut miss = Vec::with_capacity(slots.len());
        for &s in slots {
            let k = key(layer, s);
            if self.policy.touch(k) {
                self.hits += 1;
                if let Some(me) = self.session {
                    if self.owners.get(&k).is_some_and(|&owner| owner != me) {
                        self.cross_hits += 1;
                    }
                }
                hit.push(s);
            } else {
                self.misses += 1;
                miss.push(s);
            }
        }
        (hit, miss)
    }

    #[inline]
    fn insert_key(&mut self, k: u64) {
        self.policy.insert(k);
        if let Some(me) = self.session {
            self.owners.insert(k, me);
        }
    }

    /// Admit freshly-read runs according to the admission policy.
    /// `runs` are the *demanded* read runs (post-collapse is fine: the
    /// speculative gap slots arrived in DRAM too and are admitted with
    /// their segment).
    pub fn admit(&mut self, layer: usize, runs: &[SlotRun]) {
        for r in runs {
            match self.admission {
                Admission::All => {
                    for s in r.start..r.end() {
                        self.insert_key(key(layer, s));
                    }
                }
                Admission::Linking { segment_min, segment_p } => {
                    if r.len < segment_min {
                        for s in r.start..r.end() {
                            self.insert_key(key(layer, s));
                        }
                    } else if self.rng.chance(segment_p) {
                        // all-or-nothing segment admission
                        for s in r.start..r.end() {
                            self.insert_key(key(layer, s));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::access::plan_runs;

    fn runs(slots: &[Slot]) -> Vec<SlotRun> {
        plan_runs(slots)
    }

    #[test]
    fn filter_partitions() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1);
        c.admit(0, &runs(&[1, 2, 3]));
        let (hit, miss) = c.filter(0, &[1, 2, 5]);
        assert_eq!(hit, vec![1, 2]);
        assert_eq!(miss, vec![5]);
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn layers_are_disjoint() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1);
        c.admit(0, &runs(&[1]));
        let (hit, _) = c.filter(1, &[1]);
        assert!(hit.is_empty());
    }

    #[test]
    fn linking_admits_sporadic_always() {
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 0.0 },
            3,
        );
        c.admit(0, &runs(&[10, 20, 30])); // three 1-runs: sporadic
        let (hit, _) = c.filter(0, &[10, 20, 30]);
        assert_eq!(hit.len(), 3);
    }

    #[test]
    fn linking_segment_all_or_nothing() {
        // segment_p = 0 -> long runs never admitted
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 0.0 },
            3,
        );
        c.admit(0, &runs(&[0, 1, 2, 3, 4]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4]);
        assert!(hit.is_empty());

        // segment_p = 1 -> whole segment admitted
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 1.0 },
            3,
        );
        c.admit(0, &runs(&[0, 1, 2, 3, 4]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4]);
        assert_eq!(hit.len(), 5);
    }

    #[test]
    fn from_config_names() {
        for p in ["linking", "s3fifo", "lru", "none"] {
            assert!(NeuronCache::from_config(p, 16, 0).is_ok(), "{p}");
        }
        assert!(NeuronCache::from_config("arc", 16, 0).is_err());
    }

    #[test]
    fn null_cache_never_hits() {
        let mut c = NeuronCache::from_config("none", 0, 0).unwrap();
        c.admit(0, &runs(&[1, 2, 3]));
        let (hit, miss) = c.filter(0, &[1, 2, 3]);
        assert!(hit.is_empty());
        assert_eq!(miss.len(), 3);
    }

    #[test]
    fn cross_session_hits_attributed() {
        let mut c = NeuronCache::new(Box::new(Lru::new(16)), Admission::All, 1);
        c.set_session(0);
        c.admit(0, &runs(&[1, 2]));
        // a session hitting its own entries: no cross hits
        c.filter(0, &[1, 2]);
        assert_eq!(c.hits, 2);
        assert_eq!(c.cross_hits, 0);
        // another session reusing them: cross hits
        c.set_session(1);
        let (hit, _) = c.filter(0, &[1, 2]);
        assert_eq!(hit.len(), 2);
        assert_eq!(c.cross_hits, 2);
        assert!((c.cross_hit_ratio() - 0.5).abs() < 1e-12);
        // ownership follows the most recent admitter
        c.admit(0, &runs(&[9]));
        c.set_session(0);
        c.filter(0, &[9]);
        assert_eq!(c.cross_hits, 3);
    }

    #[test]
    fn untagged_cache_never_counts_cross_hits() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1);
        c.admit(0, &runs(&[1]));
        c.filter(0, &[1]);
        assert!(c.hits == 1 && c.cross_hits == 0);
        assert_eq!(c.cross_hit_ratio(), 0.0);
    }

    #[test]
    fn hit_ratio_tracks() {
        let mut c = NeuronCache::from_config("s3fifo", 16, 0).unwrap();
        c.admit(0, &runs(&[1]));
        c.filter(0, &[1]);
        c.filter(0, &[2]);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-12);
    }
}
