//! DRAM neuron cache: policy trait, the policy implementations (S3-FIFO,
//! LRU, and the cache-lab trio — victim-buffered LRU, set-associative,
//! flash-cost-aware; DESIGN.md §Cache-lab), and RIPPLE's linking-aligned
//! admission layer (paper §5.2).
//!
//! §Perf (DESIGN.md): cache keys are **dense** — `(layer, slot)` maps to
//! `layer * slots_per_layer + slot` via [`KeySpace`], so the whole key
//! universe is `[0, n_layers * slots_per_layer)` and every policy can
//! index a flat slot table instead of hashing. Construct through
//! [`NeuronCache::from_config`] (or [`CachePolicy::bounded`]) with the
//! real key bound and the steady-state decode path never touches the
//! allocator or a hash function.

mod costaware;
mod lru;
mod s3fifo;
mod setassoc;
mod victim;

pub use costaware::{CostAware, DEFAULT_COST};
pub use lru::Lru;
pub use s3fifo::S3Fifo;
pub use setassoc::{SetAssoc, DEFAULT_WAYS};
pub use victim::Victim;

use crate::access::SlotRun;
use crate::neuron::{NeuronSpace, Slot};
use crate::util::rng::Rng;

/// Dense key geometry shared by the cache policies and the owner table:
/// a `(layer, slot)` pair maps to `layer * slots_per_layer + slot`, so
/// every key lies in `[0, bound())` and direct indexing replaces
/// hashing on the per-token hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KeySpace {
    /// Layers covered by the key universe.
    pub n_layers: usize,
    /// Slots per layer (the key stride).
    pub slots_per_layer: usize,
}

impl KeySpace {
    /// A key space for `n_layers` layers of `slots_per_layer` slots.
    pub fn new(n_layers: usize, slots_per_layer: usize) -> Self {
        Self { n_layers, slots_per_layer }
    }

    /// The key space of a [`NeuronSpace`] (the usual construction).
    pub fn of(space: &NeuronSpace) -> Self {
        Self::new(space.n_layers, space.per_layer)
    }

    /// Exclusive upper bound of every key in this space.
    pub fn bound(&self) -> usize {
        self.n_layers * self.slots_per_layer
    }

    /// The dense key of `(layer, slot)`.
    #[inline]
    pub fn key(&self, layer: usize, slot: Slot) -> u64 {
        debug_assert!(layer < self.n_layers, "layer {layer} out of key space");
        debug_assert!(
            (slot as usize) < self.slots_per_layer,
            "slot {slot} out of key space stride {}",
            self.slots_per_layer
        );
        layer as u64 * self.slots_per_layer as u64 + slot as u64
    }
}

/// Uniform policy interface over dense `(layer, slot)` keys.
///
/// `Sync` rides along with `Send` so a `NeuronCache` behind `&` can be
/// probed from the parallel plan phase's scoped workers; the only
/// shared-access entry point is [`contains`](Self::contains), which is
/// side-effect free by contract.
pub trait CachePolicy: Send + Sync {
    /// Lookup; a hit refreshes the entry's standing.
    fn touch(&mut self, key: u64) -> bool;
    /// Insert after a miss (may evict). Returns the key evicted from
    /// the resident set, if any — [`NeuronCache`] resets the evicted
    /// key's owner record on it.
    fn insert(&mut self, key: u64) -> Option<u64>;
    /// Insert after a miss, carrying the caller's estimate of how
    /// expensive this key would be to re-read from flash (higher =
    /// costlier; [`NeuronCache::admit`] derives it from the read-run
    /// length). Cost-oblivious policies ignore it — the default
    /// delegates to [`CachePolicy::insert`], so existing policies and
    /// their reports are bit-identical — while [`CostAware`] uses it to
    /// evict cheap-to-refetch linked runs before expensive singletons.
    fn insert_with_cost(&mut self, key: u64, _cost: u32) -> Option<u64> {
        self.insert(key)
    }
    /// Residency test with NO side effects (no recency/frequency bump) —
    /// used by speculative prefetch filtering, which must not distort
    /// the policy's view of real demand.
    fn contains(&self, key: u64) -> bool;
    fn len(&self) -> usize;
    fn capacity(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Capacity-aware construction (§Perf): every key the policy will
    /// ever see is `< key_bound`, so the dense slot table and the
    /// internal queues/slabs are sized once — steady-state operation
    /// never allocates.
    fn bounded(capacity: usize, key_bound: usize) -> Self
    where
        Self: Sized;
}

impl CachePolicy for Lru {
    fn touch(&mut self, key: u64) -> bool {
        Lru::touch(self, key)
    }
    fn insert(&mut self, key: u64) -> Option<u64> {
        Lru::insert(self, key)
    }
    fn contains(&self, key: u64) -> bool {
        Lru::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        Lru::len(self)
    }
    fn capacity(&self) -> usize {
        Lru::capacity(self)
    }
    fn bounded(capacity: usize, key_bound: usize) -> Self {
        Lru::bounded(capacity, key_bound)
    }
}

impl CachePolicy for S3Fifo {
    fn touch(&mut self, key: u64) -> bool {
        S3Fifo::touch(self, key)
    }
    fn insert(&mut self, key: u64) -> Option<u64> {
        S3Fifo::insert(self, key)
    }
    fn contains(&self, key: u64) -> bool {
        S3Fifo::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        S3Fifo::len(self)
    }
    fn capacity(&self) -> usize {
        S3Fifo::capacity(self)
    }
    fn bounded(capacity: usize, key_bound: usize) -> Self {
        S3Fifo::bounded(capacity, key_bound)
    }
}

impl CachePolicy for Victim {
    fn touch(&mut self, key: u64) -> bool {
        Victim::touch(self, key)
    }
    fn insert(&mut self, key: u64) -> Option<u64> {
        Victim::insert(self, key)
    }
    fn contains(&self, key: u64) -> bool {
        Victim::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        Victim::len(self)
    }
    fn capacity(&self) -> usize {
        Victim::capacity(self)
    }
    fn bounded(capacity: usize, key_bound: usize) -> Self {
        Victim::bounded(capacity, key_bound)
    }
}

impl CachePolicy for SetAssoc {
    fn touch(&mut self, key: u64) -> bool {
        SetAssoc::touch(self, key)
    }
    fn insert(&mut self, key: u64) -> Option<u64> {
        SetAssoc::insert(self, key)
    }
    fn contains(&self, key: u64) -> bool {
        SetAssoc::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        SetAssoc::len(self)
    }
    fn capacity(&self) -> usize {
        SetAssoc::capacity(self)
    }
    fn bounded(capacity: usize, key_bound: usize) -> Self {
        SetAssoc::bounded(capacity, key_bound)
    }
}

impl CachePolicy for CostAware {
    fn touch(&mut self, key: u64) -> bool {
        CostAware::touch(self, key)
    }
    fn insert(&mut self, key: u64) -> Option<u64> {
        CostAware::insert(self, key)
    }
    fn insert_with_cost(&mut self, key: u64, cost: u32) -> Option<u64> {
        CostAware::insert_with_cost(self, key, cost)
    }
    fn contains(&self, key: u64) -> bool {
        CostAware::contains_untouched(self, key)
    }
    fn len(&self) -> usize {
        CostAware::len(self)
    }
    fn capacity(&self) -> usize {
        CostAware::capacity(self)
    }
    fn bounded(capacity: usize, key_bound: usize) -> Self {
        CostAware::bounded(capacity, key_bound)
    }
}

/// No-op cache (cache_ratio = 0 configurations).
pub struct NullCache;

impl CachePolicy for NullCache {
    fn touch(&mut self, _key: u64) -> bool {
        false
    }
    fn insert(&mut self, _key: u64) -> Option<u64> {
        None
    }
    fn contains(&self, _key: u64) -> bool {
        false
    }
    fn len(&self) -> usize {
        0
    }
    fn capacity(&self) -> usize {
        0
    }
    fn bounded(_capacity: usize, _key_bound: usize) -> Self {
        NullCache
    }
}

/// How insertions are admitted (paper §5.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    /// Admit everything (plain S3-FIFO / LRU baselines).
    All,
    /// RIPPLE linking-aligned: *sporadic* slots (read runs shorter than
    /// `segment_min`) admit as usual; *continuous segments* admit
    /// all-or-nothing with probability `segment_p` — caching a partial
    /// segment would fragment an optimized flash extent into
    /// discontinuous residue reads while burning DRAM on it.
    Linking { segment_min: u32, segment_p: f64 },
}

/// Policy-construction knobs beyond the policy name and capacity
/// (threaded from `RunConfig` / the harness / the CLI). Defaults
/// reproduce the historical hard-coded values bit-for-bit: `ways = 4`
/// for the set-associative table, `segment_min = 4` / `segment_p = 0.5`
/// for linking admission (tuned by benches/ablations.rs, Ablation C).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheParams {
    /// Associativity of the `setassoc` policy (clamped to capacity).
    pub ways: usize,
    /// Linking admission: runs shorter than this always admit.
    pub segment_min: u32,
    /// Linking admission: all-or-nothing segment admission probability.
    pub segment_p: f64,
}

impl Default for CacheParams {
    fn default() -> Self {
        Self { ways: DEFAULT_WAYS, segment_min: 4, segment_p: 0.5 }
    }
}

/// Canonicalize a cache-policy name to the `&'static str` the
/// [`NeuronCache::from_config`] family accepts — the single list every
/// front end (CLI `--cache`, harness policy axis, `RunConfig`) checks
/// against, so an unknown name fails loudly at parse time.
pub fn policy_name(s: &str) -> anyhow::Result<&'static str> {
    Ok(match s {
        "linking" => "linking",
        "s3fifo" => "s3fifo",
        "lru" => "lru",
        "victim" => "victim",
        "setassoc" => "setassoc",
        "costaware" => "costaware",
        "none" => "none",
        _ => anyhow::bail!(
            "unknown cache policy `{s}` \
             (linking|s3fifo|lru|victim|setassoc|costaware|none)"
        ),
    })
}

/// Owner-table sentinel: no session admitted this key.
const NO_OWNER: u32 = u32::MAX;

/// The neuron cache used by the pipeline: a policy + admission layer.
///
/// Multi-tenant serving (DESIGN.md §Serving) shares ONE `NeuronCache`
/// across sessions: call [`NeuronCache::set_session`] before each
/// session's accesses and the cache additionally attributes every hit
/// to the session that admitted the entry, counting *cross-session*
/// hits — the co-activation reuse a shared cache buys over private
/// partitions. Without a session tag the counters and behavior are
/// bit-identical to the historical single-tenant cache.
pub struct NeuronCache {
    policy: Box<dyn CachePolicy>,
    admission: Admission,
    rng: Rng,
    /// statistics
    pub hits: u64,
    pub misses: u64,
    /// Hits on entries admitted by a *different* session (only counted
    /// once `set_session` has been called).
    pub cross_hits: u64,
    /// Current session tag; `None` = single-tenant (no attribution).
    session: Option<u32>,
    /// Dense key geometry (`layer * slots_per_layer + slot`).
    keys: KeySpace,
    /// key -> session that last admitted it (dense; `NO_OWNER` = none).
    /// Reset whenever the policy evicts a key, so a later re-admission
    /// through an untagged path can never inherit a stale owner (the
    /// old map-backed table let that miscount `cross_hits`).
    owners: Vec<u32>,
}

impl NeuronCache {
    pub fn new(
        policy: Box<dyn CachePolicy>,
        admission: Admission,
        seed: u64,
        keys: KeySpace,
    ) -> Self {
        Self {
            policy,
            admission,
            rng: Rng::new(seed),
            hits: 0,
            misses: 0,
            cross_hits: 0,
            session: None,
            keys,
            owners: vec![NO_OWNER; keys.bound()],
        }
    }

    /// Tag subsequent accesses with a session id (multi-tenant serving).
    /// Enables cross-session hit attribution; policy behavior, hit/miss
    /// counts and admission decisions are unaffected.
    pub fn set_session(&mut self, session: u32) {
        self.session = Some(session);
    }

    /// Return to untagged single-tenant mode: subsequent admissions
    /// record no owner and hits are never attributed across sessions.
    pub fn clear_session(&mut self) {
        self.session = None;
    }

    /// The fraction of hits served by an entry another session admitted
    /// (0.0 while single-tenant or before any hit).
    pub fn cross_hit_ratio(&self) -> f64 {
        if self.hits == 0 { 0.0 } else { self.cross_hits as f64 / self.hits as f64 }
    }

    /// Build from a RunConfig policy name with default [`CacheParams`]
    /// (bit-identical to the historical hard-coded construction). `keys`
    /// is the dense key geometry of the workload (usually
    /// `KeySpace::of(&space)`); the policy pre-sizes its slot tables
    /// from it so the steady-state decode path never allocates.
    pub fn from_config(
        policy: &str,
        capacity: usize,
        keys: KeySpace,
        seed: u64,
    ) -> anyhow::Result<Self> {
        Self::from_config_with(policy, capacity, keys, seed, CacheParams::default())
    }

    /// [`NeuronCache::from_config`] with explicit construction knobs:
    /// linking's admission segment parameters and the set-associative
    /// table's associativity come from `params` instead of being
    /// hard-coded (ISSUE 9 bugfix).
    pub fn from_config_with(
        policy: &str,
        capacity: usize,
        keys: KeySpace,
        seed: u64,
        params: CacheParams,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&params.segment_p),
            "admission segment_p {} out of [0,1]",
            params.segment_p
        );
        anyhow::ensure!(params.ways >= 1, "cache ways must be >= 1");
        let linking = Admission::Linking {
            segment_min: params.segment_min,
            segment_p: params.segment_p,
        };
        let bound = keys.bound();
        Ok(match policy_name(policy)? {
            "linking" => {
                Self::new(Box::new(S3Fifo::bounded(capacity, bound)), linking, seed, keys)
            }
            "s3fifo" => Self::new(
                Box::new(S3Fifo::bounded(capacity, bound)),
                Admission::All,
                seed,
                keys,
            ),
            "lru" => Self::new(
                Box::new(Lru::bounded(capacity, bound)),
                Admission::All,
                seed,
                keys,
            ),
            // the three lab policies run admission-free on purpose:
            // they are EVICTION comparisons against lru at equal DRAM,
            // and an admission filter would confound the axis
            "victim" => Self::new(
                Box::new(Victim::bounded(capacity, bound)),
                Admission::All,
                seed,
                keys,
            ),
            "setassoc" => Self::new(
                Box::new(SetAssoc::with_ways(capacity, params.ways)),
                Admission::All,
                seed,
                keys,
            ),
            "costaware" => Self::new(
                Box::new(CostAware::bounded(capacity, bound)),
                Admission::All,
                seed,
                keys,
            ),
            _ => Self::new(Box::new(NullCache), Admission::All, seed, keys), // "none"
        })
    }

    /// Override the admission layer (the harness's ablation axis: vary
    /// `segment_min`/`segment_p` — or disable linking — over ANY base
    /// policy). Policy state, RNG stream and statistics are untouched.
    pub fn set_admission(&mut self, admission: Admission) {
        self.admission = admission;
    }

    /// Zero the hit/miss/cross-hit counters (cache contents stay warm).
    /// Call when a warm cache is reused across measurement windows —
    /// e.g. the serving engine's post-calibration reset — so one row's
    /// `cache_hit_ratio` never carries another row's counts.
    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.cross_hits = 0;
    }

    pub fn len(&self) -> usize {
        self.policy.len()
    }

    pub fn capacity(&self) -> usize {
        self.policy.capacity()
    }

    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 { 0.0 } else { self.hits as f64 / total as f64 }
    }

    /// Side-effect-free residency test (prefetch planning).
    pub fn contains(&self, layer: usize, slot: Slot) -> bool {
        self.policy.contains(self.keys.key(layer, slot))
    }

    /// Partition activated slots into (cached, must-read), reusing the
    /// caller's buffers (§Perf: the per-token hot path allocates
    /// nothing). Slots must be sorted; both outputs preserve order.
    pub fn filter_into(
        &mut self,
        layer: usize,
        slots: &[Slot],
        hit: &mut Vec<Slot>,
        miss: &mut Vec<Slot>,
    ) {
        hit.clear();
        miss.clear();
        for &s in slots {
            let k = self.keys.key(layer, s);
            if self.policy.touch(k) {
                self.hits += 1;
                if let Some(me) = self.session {
                    let owner = self.owners.get(k as usize).copied().unwrap_or(NO_OWNER);
                    if owner != NO_OWNER && owner != me {
                        self.cross_hits += 1;
                    }
                }
                hit.push(s);
            } else {
                self.misses += 1;
                miss.push(s);
            }
        }
    }

    /// Allocating convenience wrapper over [`NeuronCache::filter_into`].
    pub fn filter(&mut self, layer: usize, slots: &[Slot]) -> (Vec<Slot>, Vec<Slot>) {
        let mut hit = Vec::new();
        let mut miss = Vec::with_capacity(slots.len());
        self.filter_into(layer, slots, &mut hit, &mut miss);
        (hit, miss)
    }

    #[inline]
    fn set_owner(&mut self, k: u64, owner: u32) {
        let i = k as usize;
        if i >= self.owners.len() {
            if owner == NO_OWNER {
                return;
            }
            // only reachable when a key exceeds the construction-time
            // bound (tests with unknown geometry); never on the hot path
            self.owners.resize(i + 1, NO_OWNER);
        }
        self.owners[i] = owner;
    }

    #[inline]
    fn insert_key(&mut self, k: u64, cost: u32) {
        if let Some(evicted) = self.policy.insert_with_cost(k, cost) {
            self.set_owner(evicted, NO_OWNER);
        }
        if let Some(me) = self.session {
            self.set_owner(k, me);
        }
    }

    /// Estimated flash re-read cost of one bundle of an `len`-bundle
    /// read run. UFS latency is command-dominated (DESIGN.md
    /// §Async-flash-timeline): re-reading a linked L-run costs one
    /// command amortized over L bundles, while L singletons cost L
    /// commands — so cost decays hyperbolically from [`DEFAULT_COST`]
    /// (a singleton) toward 1 (a >=256-bundle run). Cost-oblivious
    /// policies never see the value (their `insert_with_cost` drops it).
    #[inline]
    pub fn run_cost(len: u32) -> u32 {
        (DEFAULT_COST / len.max(1)).max(1)
    }

    /// Admit freshly-read runs according to the admission policy.
    /// `runs` are the *demanded* read runs (post-collapse is fine: the
    /// speculative gap slots arrived in DRAM too and are admitted with
    /// their segment). Every slot of a run is admitted with the run's
    /// re-read cost ([`NeuronCache::run_cost`]), so a cost-aware policy
    /// sees linked runs as cheap and singletons as expensive.
    pub fn admit(&mut self, layer: usize, runs: &[SlotRun]) {
        let keys = self.keys;
        for r in runs {
            let cost = Self::run_cost(r.len);
            match self.admission {
                Admission::All => {
                    for s in r.start..r.end() {
                        self.insert_key(keys.key(layer, s), cost);
                    }
                }
                Admission::Linking { segment_min, segment_p } => {
                    if r.len < segment_min {
                        for s in r.start..r.end() {
                            self.insert_key(keys.key(layer, s), cost);
                        }
                    } else if self.rng.chance(segment_p) {
                        // all-or-nothing segment admission
                        for s in r.start..r.end() {
                            self.insert_key(keys.key(layer, s), cost);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::access::plan_runs;

    fn runs(slots: &[Slot]) -> Vec<SlotRun> {
        plan_runs(slots)
    }

    fn keys() -> KeySpace {
        KeySpace::new(2, 64)
    }

    #[test]
    fn filter_partitions() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1, keys());
        c.admit(0, &runs(&[1, 2, 3]));
        let (hit, miss) = c.filter(0, &[1, 2, 5]);
        assert_eq!(hit, vec![1, 2]);
        assert_eq!(miss, vec![5]);
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn filter_into_reuses_buffers() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1, keys());
        c.admit(0, &runs(&[1, 2, 3]));
        let mut hit = vec![99, 98]; // stale content must be cleared
        let mut miss = vec![97];
        c.filter_into(0, &[1, 2, 5], &mut hit, &mut miss);
        assert_eq!(hit, vec![1, 2]);
        assert_eq!(miss, vec![5]);
        c.filter_into(0, &[3, 9], &mut hit, &mut miss);
        assert_eq!(hit, vec![3]);
        assert_eq!(miss, vec![9]);
    }

    #[test]
    fn layers_are_disjoint() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1, keys());
        c.admit(0, &runs(&[1]));
        let (hit, _) = c.filter(1, &[1]);
        assert!(hit.is_empty());
    }

    #[test]
    fn key_space_is_dense() {
        let ks = KeySpace::new(3, 100);
        assert_eq!(ks.bound(), 300);
        assert_eq!(ks.key(0, 0), 0);
        assert_eq!(ks.key(0, 99), 99);
        assert_eq!(ks.key(1, 0), 100);
        assert_eq!(ks.key(2, 99), 299);
    }

    #[test]
    fn linking_admits_sporadic_always() {
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 0.0 },
            3,
            keys(),
        );
        c.admit(0, &runs(&[10, 20, 30])); // three 1-runs: sporadic
        let (hit, _) = c.filter(0, &[10, 20, 30]);
        assert_eq!(hit.len(), 3);
    }

    #[test]
    fn linking_segment_all_or_nothing() {
        // segment_p = 0 -> long runs never admitted
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 0.0 },
            3,
            keys(),
        );
        c.admit(0, &runs(&[0, 1, 2, 3, 4]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4]);
        assert!(hit.is_empty());

        // segment_p = 1 -> whole segment admitted
        let mut c = NeuronCache::new(
            Box::new(Lru::new(64)),
            Admission::Linking { segment_min: 4, segment_p: 1.0 },
            3,
            keys(),
        );
        c.admit(0, &runs(&[0, 1, 2, 3, 4]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4]);
        assert_eq!(hit.len(), 5);
    }

    #[test]
    fn from_config_names() {
        for p in ["linking", "s3fifo", "lru", "victim", "setassoc", "costaware", "none"] {
            assert!(NeuronCache::from_config(p, 16, keys(), 0).is_ok(), "{p}");
            assert_eq!(policy_name(p).unwrap(), p);
        }
        assert!(NeuronCache::from_config("arc", 16, keys(), 0).is_err());
        assert!(policy_name("arc").is_err());
    }

    #[test]
    fn from_config_with_validates_params() {
        let bad_p = CacheParams { segment_p: 1.5, ..CacheParams::default() };
        assert!(NeuronCache::from_config_with("linking", 16, keys(), 0, bad_p).is_err());
        let bad_w = CacheParams { ways: 0, ..CacheParams::default() };
        assert!(NeuronCache::from_config_with("setassoc", 16, keys(), 0, bad_w).is_err());
    }

    #[test]
    fn from_config_params_reach_the_admission_layer() {
        // segment_p = 0 through CacheParams: long segments never admit
        // (the hard-coded default 0.5 would admit about half of them)
        let p0 = CacheParams { segment_p: 0.0, ..CacheParams::default() };
        let mut c = NeuronCache::from_config_with("linking", 64, keys(), 3, p0).unwrap();
        c.admit(0, &runs(&[0, 1, 2, 3, 4]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4]);
        assert!(hit.is_empty());
        // segment_min above the run length: the same run is "sporadic"
        let pmin = CacheParams { segment_min: 16, ..CacheParams::default() };
        let mut c =
            NeuronCache::from_config_with("linking", 64, keys(), 3, pmin).unwrap();
        c.admit(0, &runs(&[0, 1, 2, 3, 4]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4]);
        assert_eq!(hit.len(), 5);
    }

    #[test]
    fn set_admission_overrides_only_admission() {
        let mut c = NeuronCache::from_config("linking", 64, keys(), 3).unwrap();
        c.set_admission(Admission::All);
        c.admit(0, &runs(&[0, 1, 2, 3, 4, 5, 6, 7]));
        let (hit, _) = c.filter(0, &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(hit.len(), 8, "Admission::All admits whole segments");
    }

    #[test]
    fn reset_stats_zeroes_counters_but_keeps_contents() {
        let mut c = NeuronCache::from_config("lru", 16, keys(), 0).unwrap();
        c.set_session(0);
        c.admit(0, &runs(&[1, 2, 3]));
        c.set_session(1);
        c.filter(0, &[1, 2, 9]);
        assert!(c.hits == 2 && c.misses == 1 && c.cross_hits == 2);
        c.reset_stats();
        assert!(c.hits == 0 && c.misses == 0 && c.cross_hits == 0);
        assert_eq!(c.hit_ratio(), 0.0);
        // the cache itself stays warm: contents and ownership survive
        let (hit, _) = c.filter(0, &[1, 2, 3]);
        assert_eq!(hit.len(), 3);
        assert_eq!(c.cross_hits, 3, "ownership survived the stats reset");
    }

    #[test]
    fn run_cost_decays_with_run_length() {
        assert_eq!(NeuronCache::run_cost(0), DEFAULT_COST); // defensive
        assert_eq!(NeuronCache::run_cost(1), DEFAULT_COST);
        assert_eq!(NeuronCache::run_cost(4), 64);
        assert_eq!(NeuronCache::run_cost(256), 1);
        assert_eq!(NeuronCache::run_cost(10_000), 1);
    }

    #[test]
    fn costaware_cache_evicts_linked_runs_before_singletons() {
        // capacity 8: admit 4 singletons, then an 8-run under pressure —
        // the run's bundles (cheap to re-read) churn among themselves
        // while every expensive singleton stays resident
        let mut c = NeuronCache::from_config("costaware", 8, keys(), 0).unwrap();
        c.admit(0, &runs(&[10, 20, 30, 40]));
        c.admit(0, &runs(&[50, 51, 52, 53, 54, 55, 56, 57]));
        let (hit, _) = c.filter(0, &[10, 20, 30, 40]);
        assert_eq!(hit.len(), 4, "singletons must outlive the cheap run");
        // ...whereas plain lru at the same capacity keeps only the run
        let mut l = NeuronCache::from_config("lru", 8, keys(), 0).unwrap();
        l.admit(0, &runs(&[10, 20, 30, 40]));
        l.admit(0, &runs(&[50, 51, 52, 53, 54, 55, 56, 57]));
        let (hit, _) = l.filter(0, &[10, 20, 30, 40]);
        assert!(hit.is_empty(), "lru recency evicts the singletons");
    }

    #[test]
    fn null_cache_never_hits() {
        let mut c = NeuronCache::from_config("none", 0, keys(), 0).unwrap();
        c.admit(0, &runs(&[1, 2, 3]));
        let (hit, miss) = c.filter(0, &[1, 2, 3]);
        assert!(hit.is_empty());
        assert_eq!(miss.len(), 3);
    }

    #[test]
    fn cross_session_hits_attributed() {
        let mut c = NeuronCache::new(Box::new(Lru::new(16)), Admission::All, 1, keys());
        c.set_session(0);
        c.admit(0, &runs(&[1, 2]));
        // a session hitting its own entries: no cross hits
        c.filter(0, &[1, 2]);
        assert_eq!(c.hits, 2);
        assert_eq!(c.cross_hits, 0);
        // another session reusing them: cross hits
        c.set_session(1);
        let (hit, _) = c.filter(0, &[1, 2]);
        assert_eq!(hit.len(), 2);
        assert_eq!(c.cross_hits, 2);
        assert!((c.cross_hit_ratio() - 0.5).abs() < 1e-12);
        // ownership follows the most recent admitter
        c.admit(0, &runs(&[9]));
        c.set_session(0);
        c.filter(0, &[9]);
        assert_eq!(c.cross_hits, 3);
    }

    #[test]
    fn untagged_cache_never_counts_cross_hits() {
        let mut c = NeuronCache::new(Box::new(Lru::new(8)), Admission::All, 1, keys());
        c.admit(0, &runs(&[1]));
        c.filter(0, &[1]);
        assert!(c.hits == 1 && c.cross_hits == 0);
        assert_eq!(c.cross_hit_ratio(), 0.0);
    }

    #[test]
    fn eviction_resets_owner_for_untagged_readmission() {
        // Regression (the old HashMap owner table kept stale records):
        // session 0 admits a key, the key is evicted, an UNTAGGED path
        // re-admits it — a later hit by session 1 must NOT be counted as
        // a cross-session hit, because no session owns the live entry.
        let mut c = NeuronCache::new(Box::new(Lru::new(1)), Admission::All, 1, keys());
        c.set_session(0);
        c.admit(0, &runs(&[5])); // owner(5) = 0
        c.clear_session();
        c.admit(0, &runs(&[6])); // evicts 5 -> owner(5) resets
        c.admit(0, &runs(&[5])); // untagged re-admission: no owner
        c.set_session(1);
        let (hit, _) = c.filter(0, &[5]);
        assert_eq!(hit, vec![5]);
        assert_eq!(c.cross_hits, 0, "stale owner record miscounted a cross hit");
    }

    #[test]
    fn eviction_then_tagged_readmission_attributes_to_new_owner() {
        // evict -> re-admit by another session: attribution follows the
        // live entry, exactly as before the dense-owner refactor.
        let mut c = NeuronCache::new(Box::new(Lru::new(1)), Admission::All, 1, keys());
        c.set_session(0);
        c.admit(0, &runs(&[5]));
        c.set_session(1);
        c.admit(0, &runs(&[6])); // evicts 5
        c.admit(0, &runs(&[5])); // evicts 6; owner(5) = 1
        c.set_session(0);
        let (hit, _) = c.filter(0, &[5]);
        assert_eq!(hit, vec![5]);
        assert_eq!(c.cross_hits, 1);
        // and session 1 hitting its own re-admission stays clean
        c.set_session(1);
        c.filter(0, &[5]);
        assert_eq!(c.cross_hits, 1);
    }

    #[test]
    fn hit_ratio_tracks() {
        let mut c = NeuronCache::from_config("s3fifo", 16, keys(), 0).unwrap();
        c.admit(0, &runs(&[1]));
        c.filter(0, &[1]);
        c.filter(0, &[2]);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-12);
    }
}
