//! Classic LRU cache over u64 keys (baseline policy + building block).
//! Intrusive doubly-linked list over a slab, O(1) touch/insert/evict.
//!
//! §Perf: the key index is a direct-indexed dense slot table
//! (`Vec<u32>`), not a hash map — cache keys are
//! `layer * slots_per_layer + slot` (see [`crate::cache::KeySpace`]), so
//! the key universe is small, dense, and known at construction.
//! [`Lru::bounded`] pre-sizes every table so steady-state operation
//! never touches the allocator; [`Lru::new`] starts with an empty index
//! and grows it on demand (tests and callers with unknown bounds).

const NIL: u32 = u32::MAX;

#[derive(Clone, Debug)]
struct Node {
    key: u64,
    prev: u32,
    next: u32,
}

#[derive(Debug)]
pub struct Lru {
    /// key -> node index (dense slot table; `NIL` = absent).
    index: Vec<u32>,
    nodes: Vec<Node>,
    free: Vec<u32>,
    head: u32, // most recent
    tail: u32, // least recent
    len: usize,
    capacity: usize,
}

impl Lru {
    pub fn new(capacity: usize) -> Self {
        Self::bounded(capacity, 0)
    }

    /// Capacity-aware construction: all keys are `< key_bound`, so the
    /// slot table (and the node slab) can be sized once, up front. With
    /// a real bound the slab reserves the FULL capacity — at most
    /// `key_bound` entries can ever be resident, and the zero-alloc
    /// invariant (§Perf) must hold at any cache size; only the
    /// unknown-bound [`Lru::new`] path caps its speculative reservation.
    pub fn bounded(capacity: usize, key_bound: usize) -> Self {
        let slab = if key_bound > 0 {
            capacity.min(key_bound)
        } else {
            capacity.min(1 << 20)
        };
        Self {
            index: vec![NIL; key_bound],
            nodes: Vec::with_capacity(slab),
            free: Vec::with_capacity(slab),
            head: NIL,
            tail: NIL,
            len: 0,
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn slot(&self, key: u64) -> u32 {
        self.index.get(key as usize).copied().unwrap_or(NIL)
    }

    /// Write the slot entry for `key`, growing the table when the key
    /// exceeds the construction-time bound (never on the bounded path).
    #[inline]
    fn set_slot(&mut self, key: u64, idx: u32) {
        let k = key as usize;
        if k >= self.index.len() {
            if idx == NIL {
                return;
            }
            self.index.resize(k + 1, NIL);
        }
        self.index[k] = idx;
    }

    fn unlink(&mut self, idx: u32) {
        let (p, n) = {
            let node = &self.nodes[idx as usize];
            (node.prev, node.next)
        };
        if p != NIL {
            self.nodes[p as usize].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.nodes[n as usize].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, idx: u32) {
        self.nodes[idx as usize].prev = NIL;
        self.nodes[idx as usize].next = self.head;
        if self.head != NIL {
            self.nodes[self.head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Lookup; a hit refreshes recency.
    pub fn touch(&mut self, key: u64) -> bool {
        let idx = self.slot(key);
        if idx == NIL {
            return false;
        }
        self.unlink(idx);
        self.push_front(idx);
        true
    }

    pub fn contains_untouched(&self, key: u64) -> bool {
        self.slot(key) != NIL
    }

    /// Insert a key, evicting the LRU entry if full.
    /// Returns the evicted key, if any.
    pub fn insert(&mut self, key: u64) -> Option<u64> {
        if self.capacity == 0 {
            return None;
        }
        if self.touch(key) {
            return None;
        }
        let mut evicted = None;
        if self.len >= self.capacity {
            let tail = self.tail;
            debug_assert_ne!(tail, NIL);
            let old_key = self.nodes[tail as usize].key;
            self.unlink(tail);
            self.set_slot(old_key, NIL);
            self.free.push(tail);
            self.len -= 1;
            evicted = Some(old_key);
        }
        let idx = if let Some(i) = self.free.pop() {
            self.nodes[i as usize] = Node { key, prev: NIL, next: NIL };
            i
        } else {
            self.nodes.push(Node { key, prev: NIL, next: NIL });
            (self.nodes.len() - 1) as u32
        };
        self.push_front(idx);
        self.set_slot(key, idx);
        self.len += 1;
        evicted
    }

    pub fn remove(&mut self, key: u64) -> bool {
        let idx = self.slot(key);
        if idx == NIL {
            return false;
        }
        self.unlink(idx);
        self.set_slot(key, NIL);
        self.free.push(idx);
        self.len -= 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_touch_evict() {
        let mut c = Lru::new(2);
        assert_eq!(c.insert(1), None);
        assert_eq!(c.insert(2), None);
        assert!(c.touch(1)); // 1 now MRU; 2 is LRU
        assert_eq!(c.insert(3), Some(2));
        assert!(c.touch(1));
        assert!(!c.touch(2));
        assert!(c.touch(3));
    }

    #[test]
    fn reinsert_is_touch() {
        let mut c = Lru::new(2);
        c.insert(1);
        c.insert(2);
        assert_eq!(c.insert(1), None); // refresh, no eviction
        assert_eq!(c.insert(3), Some(2));
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut c = Lru::new(0);
        assert_eq!(c.insert(1), None);
        assert!(!c.touch(1));
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn remove_frees_slot() {
        let mut c = Lru::new(2);
        c.insert(1);
        c.insert(2);
        assert!(c.remove(1));
        assert!(!c.remove(1));
        c.insert(3);
        assert_eq!(c.len(), 2);
        assert!(c.touch(2) && c.touch(3));
    }

    #[test]
    fn capacity_never_exceeded_under_churn() {
        let mut c = Lru::new(16);
        for i in 0..1000u64 {
            c.insert(i % 37);
            assert!(c.len() <= 16);
        }
    }

    #[test]
    fn bounded_behaves_like_unbounded() {
        // same op stream, identical outcomes, and the bounded slot table
        // never grows past its construction size
        let mut a = Lru::new(4);
        let mut b = Lru::bounded(4, 37);
        for i in 0..500u64 {
            let k = (i * 7) % 37;
            assert_eq!(a.touch(k), b.touch(k), "touch diverged at {i}");
            if i % 3 != 0 {
                assert_eq!(a.insert(k), b.insert(k), "insert diverged at {i}");
            }
            assert_eq!(a.len(), b.len());
        }
        assert_eq!(b.index.len(), 37);
    }
}
