//! Classic LRU cache over u64 keys (baseline policy + building block).
//! Intrusive doubly-linked list over a slab, O(1) touch/insert/evict.

use std::collections::HashMap;

const NIL: u32 = u32::MAX;

#[derive(Clone, Debug)]
struct Node {
    key: u64,
    prev: u32,
    next: u32,
}

#[derive(Debug)]
pub struct Lru {
    map: HashMap<u64, u32>,
    nodes: Vec<Node>,
    free: Vec<u32>,
    head: u32, // most recent
    tail: u32, // least recent
    capacity: usize,
}

impl Lru {
    pub fn new(capacity: usize) -> Self {
        Self {
            map: HashMap::with_capacity(capacity.min(1 << 20)),
            nodes: Vec::with_capacity(capacity.min(1 << 20)),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn unlink(&mut self, idx: u32) {
        let (p, n) = {
            let node = &self.nodes[idx as usize];
            (node.prev, node.next)
        };
        if p != NIL {
            self.nodes[p as usize].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.nodes[n as usize].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, idx: u32) {
        self.nodes[idx as usize].prev = NIL;
        self.nodes[idx as usize].next = self.head;
        if self.head != NIL {
            self.nodes[self.head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Lookup; a hit refreshes recency.
    pub fn touch(&mut self, key: u64) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            self.unlink(idx);
            self.push_front(idx);
            true
        } else {
            false
        }
    }

    pub fn contains_untouched(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    /// Insert a key, evicting the LRU entry if full.
    /// Returns the evicted key, if any.
    pub fn insert(&mut self, key: u64) -> Option<u64> {
        if self.capacity == 0 {
            return None;
        }
        if self.touch(key) {
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.capacity {
            let tail = self.tail;
            debug_assert_ne!(tail, NIL);
            let old_key = self.nodes[tail as usize].key;
            self.unlink(tail);
            self.map.remove(&old_key);
            self.free.push(tail);
            evicted = Some(old_key);
        }
        let idx = if let Some(i) = self.free.pop() {
            self.nodes[i as usize] = Node { key, prev: NIL, next: NIL };
            i
        } else {
            self.nodes.push(Node { key, prev: NIL, next: NIL });
            (self.nodes.len() - 1) as u32
        };
        self.push_front(idx);
        self.map.insert(key, idx);
        evicted
    }

    pub fn remove(&mut self, key: u64) -> bool {
        if let Some(idx) = self.map.remove(&key) {
            self.unlink(idx);
            self.free.push(idx);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_touch_evict() {
        let mut c = Lru::new(2);
        assert_eq!(c.insert(1), None);
        assert_eq!(c.insert(2), None);
        assert!(c.touch(1)); // 1 now MRU; 2 is LRU
        assert_eq!(c.insert(3), Some(2));
        assert!(c.touch(1));
        assert!(!c.touch(2));
        assert!(c.touch(3));
    }

    #[test]
    fn reinsert_is_touch() {
        let mut c = Lru::new(2);
        c.insert(1);
        c.insert(2);
        assert_eq!(c.insert(1), None); // refresh, no eviction
        assert_eq!(c.insert(3), Some(2));
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut c = Lru::new(0);
        assert_eq!(c.insert(1), None);
        assert!(!c.touch(1));
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn remove_frees_slot() {
        let mut c = Lru::new(2);
        c.insert(1);
        c.insert(2);
        assert!(c.remove(1));
        assert!(!c.remove(1));
        c.insert(3);
        assert_eq!(c.len(), 2);
        assert!(c.touch(2) && c.touch(3));
    }

    #[test]
    fn capacity_never_exceeded_under_churn() {
        let mut c = Lru::new(16);
        for i in 0..1000u64 {
            c.insert(i % 37);
            assert!(c.len() <= 16);
        }
    }
}
