//! Set-associative slot table: a hardware-style N-way cache index as a
//! cheaper-than-LRU policy for fleet-scale sweeps.
//!
//! The requested capacity is carved into `capacity / ways` sets of
//! `ways` slots each (rounding the remainder down — the reported
//! capacity stays the requested one, occupancy just never reaches the
//! round-off). A key maps to set `key % sets`; within a set, slot 0 is
//! the MRU way and eviction drops the last way — LRU order, but only
//! across `ways` entries, so every operation is a bounded scan of one
//! tiny slice.
//!
//! Modulo striping is deliberate: dense cache keys are
//! `layer * slots_per_layer + slot` ([`crate::cache::KeySpace`]), so the
//! contiguous co-activation runs the linking stage builds stripe
//! perfectly across sets instead of colliding in one.
//!
//! §Perf: storage is a single flat `Vec<u64>` of `sets * ways` slots
//! sized at construction — no per-key index at all, which is the selling
//! point over [`super::Lru`]: memory is O(sets x ways), not O(key
//! universe), and there is nothing to grow. `bounded` therefore ignores
//! its `key_bound` and is identical to `new`.

/// Empty-slot sentinel (dense keys are `< n_layers * slots_per_layer`,
/// far below it).
const EMPTY: u64 = u64::MAX;

/// Associativity used when the policy is built through the plain
/// [`crate::cache::CachePolicy::bounded`] constructor (the harness
/// default; `--ways` overrides it via [`SetAssoc::with_ways`]).
pub const DEFAULT_WAYS: usize = 4;

#[derive(Debug)]
pub struct SetAssoc {
    /// `sets * ways` slots; set `s` owns `slots[s*ways .. (s+1)*ways]`
    /// with way 0 = MRU and empty ways packed at the tail.
    slots: Vec<u64>,
    sets: usize,
    ways: usize,
    len: usize,
    capacity: usize,
}

impl SetAssoc {
    pub fn new(capacity: usize) -> Self {
        Self::with_ways(capacity, DEFAULT_WAYS)
    }

    /// Identical to [`SetAssoc::new`]: there is no key-indexed table to
    /// pre-size (see module docs), the constructor exists to satisfy the
    /// uniform [`crate::cache::CachePolicy::bounded`] construction.
    pub fn bounded(capacity: usize, _key_bound: usize) -> Self {
        Self::new(capacity)
    }

    /// Construct with an explicit associativity. `ways` is clamped to
    /// `[1, capacity]`; a zero capacity stores nothing.
    pub fn with_ways(capacity: usize, ways: usize) -> Self {
        let ways = ways.max(1).min(capacity.max(1));
        let sets = capacity / ways;
        Self { slots: vec![EMPTY; sets * ways], sets, ways, len: 0, capacity }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Associativity actually in effect (after clamping).
    pub fn ways(&self) -> usize {
        self.ways
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn set_range(&self, key: u64) -> std::ops::Range<usize> {
        let set = (key % self.sets as u64) as usize * self.ways;
        set..set + self.ways
    }

    pub fn touch(&mut self, key: u64) -> bool {
        if self.sets == 0 {
            return false;
        }
        let range = self.set_range(key);
        let set = &mut self.slots[range];
        match set.iter().position(|&k| k == key) {
            Some(pos) => {
                set[..=pos].rotate_right(1);
                true
            }
            None => false,
        }
    }

    pub fn contains_untouched(&self, key: u64) -> bool {
        if self.sets == 0 {
            return false;
        }
        self.slots[self.set_range(key)].contains(&key)
    }

    /// Insert a key, evicting its set's last (least-recent) way when the
    /// set is full. Returns the evicted key, if any.
    pub fn insert(&mut self, key: u64) -> Option<u64> {
        if self.sets == 0 {
            return None;
        }
        if self.touch(key) {
            return None;
        }
        let range = self.set_range(key);
        let set = &mut self.slots[range];
        // empty ways are packed at the tail, so the first EMPTY (if any)
        // is where the set stops being full
        match set.iter().position(|&k| k == EMPTY) {
            Some(first_empty) => {
                set[..=first_empty].rotate_right(1);
                set[0] = key;
                self.len += 1;
                None
            }
            None => {
                let evicted = set[self.ways - 1];
                set.rotate_right(1);
                set[0] = key;
                Some(evicted)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conflict_eviction_is_per_set() {
        // capacity 8, 4 ways -> 2 sets; even keys collide in set 0
        let mut c = SetAssoc::with_ways(8, 4);
        for k in [0u64, 2, 4, 6] {
            assert_eq!(c.insert(k), None);
        }
        // a fifth even key evicts the set-0 LRU (key 0)...
        assert_eq!(c.insert(8), Some(0));
        // ...while set 1 is untouched
        assert_eq!(c.insert(1), None);
        assert!(c.contains_untouched(1));
        assert_eq!(c.len(), 5);
    }

    #[test]
    fn touch_is_mru_within_the_set() {
        let mut c = SetAssoc::with_ways(4, 4); // one set
        for k in [10u64, 20, 30, 40] {
            c.insert(k);
        }
        assert!(c.touch(10)); // refresh the would-be victim
        assert_eq!(c.insert(50), Some(20));
        assert!(c.contains_untouched(10));
    }

    #[test]
    fn ways_clamp_and_round_down() {
        let c = SetAssoc::with_ways(10, 4);
        assert_eq!(c.capacity(), 10);
        assert_eq!(c.ways(), 4);
        assert_eq!(c.sets, 2); // 8 usable slots, capacity reported as 10
        let d = SetAssoc::with_ways(2, 64);
        assert_eq!(d.ways(), 2); // ways clamped to capacity
        assert_eq!(d.sets, 1);
    }

    #[test]
    fn zero_capacity_stores_nothing() {
        let mut c = SetAssoc::new(0);
        assert_eq!(c.insert(1), None);
        assert!(!c.touch(1));
        assert!(!c.contains_untouched(1));
        assert_eq!(c.len(), 0);
        assert_eq!(c.capacity(), 0);
    }

    #[test]
    fn direct_mapped_single_way() {
        let mut c = SetAssoc::with_ways(4, 1); // 4 sets, 1 way each
        assert_eq!(c.insert(0), None);
        assert_eq!(c.insert(4), Some(0)); // same set, immediate conflict
        assert_eq!(c.insert(1), None);
        assert_eq!(c.len(), 2);
    }
}
