//! Flash-cost-aware eviction: a bucketed LRU whose victim choice weighs
//! how expensive each entry is to re-read from flash.
//!
//! The Neuralink-specific observation (ISSUE 9, paper §5): eviction cost
//! is NOT uniform. A bundle that belongs to a long linked run re-reads
//! for one amortized flash command (the run comes back as a single
//! sequential extent), while a singleton neuron costs a whole
//! command-latency round trip by itself. The admission path therefore
//! tags every insert with a re-read cost ([`crate::cache::NeuronCache`]
//! derives it from the run length) and eviction drains the CHEAPEST
//! cost class first, least-recent first within the class — cheap linked
//! runs leave before expensive singletons, and keys of one run share a
//! class so runs evict coherently.
//!
//! With uniform costs every entry lands in one class and the policy
//! degenerates to exact LRU — which is what the generic conformance
//! battery (and the cost-oblivious default [`crate::cache::CachePolicy::
//! insert`], pinned to [`DEFAULT_COST`]) exercises.
//!
//! §Perf: same intrusive-list-over-slab construction as [`super::Lru`]
//! — a dense key index, a node slab with a free list, and fixed arrays
//! of per-class list heads/tails. The eviction scan is at most
//! [`N_CLASSES`] probes; steady state allocates nothing and hashes
//! nothing.

const NIL: u32 = u32::MAX;

/// Cost classes: entries bucket by `floor(log2(cost))`, so 32 classes
/// cover the whole `u32` cost range.
pub const N_CLASSES: usize = 32;

/// Cost assumed by the cost-oblivious [`crate::cache::CachePolicy::insert`]
/// path: the most expensive (singleton) class, so un-costed inserts are
/// protected exactly like LRU protects everything.
pub const DEFAULT_COST: u32 = 256;

#[inline]
fn class_of(cost: u32) -> u8 {
    (cost.max(1).ilog2() as u8).min(N_CLASSES as u8 - 1)
}

#[derive(Clone, Debug)]
struct Node {
    key: u64,
    prev: u32,
    next: u32,
    class: u8,
}

#[derive(Debug)]
pub struct CostAware {
    /// key -> node index (dense slot table; `NIL` = absent).
    index: Vec<u32>,
    nodes: Vec<Node>,
    free: Vec<u32>,
    /// Per-class MRU / LRU list ends.
    heads: [u32; N_CLASSES],
    tails: [u32; N_CLASSES],
    len: usize,
    capacity: usize,
}

impl CostAware {
    pub fn new(capacity: usize) -> Self {
        Self::bounded(capacity, 0)
    }

    /// Capacity-aware construction (§Perf): sizing mirrors
    /// [`super::Lru::bounded`] — with a real `key_bound` the dense index
    /// and the slab are allocated once, up front.
    pub fn bounded(capacity: usize, key_bound: usize) -> Self {
        let slab = if key_bound > 0 {
            capacity.min(key_bound)
        } else {
            capacity.min(1 << 20)
        };
        Self {
            index: vec![NIL; key_bound],
            nodes: Vec::with_capacity(slab),
            free: Vec::with_capacity(slab),
            heads: [NIL; N_CLASSES],
            tails: [NIL; N_CLASSES],
            len: 0,
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn slot(&self, key: u64) -> u32 {
        self.index.get(key as usize).copied().unwrap_or(NIL)
    }

    #[inline]
    fn set_slot(&mut self, key: u64, idx: u32) {
        let k = key as usize;
        if k >= self.index.len() {
            if idx == NIL {
                return;
            }
            // only keys past the construction-time bound grow the table
            // (tests with unknown geometry); never on the bounded path
            self.index.resize(k + 1, NIL);
        }
        self.index[k] = idx;
    }

    fn unlink(&mut self, idx: u32) {
        let (p, n, c) = {
            let node = &self.nodes[idx as usize];
            (node.prev, node.next, node.class as usize)
        };
        if p != NIL {
            self.nodes[p as usize].next = n;
        } else {
            self.heads[c] = n;
        }
        if n != NIL {
            self.nodes[n as usize].prev = p;
        } else {
            self.tails[c] = p;
        }
    }

    fn push_front(&mut self, idx: u32, class: u8) {
        let c = class as usize;
        self.nodes[idx as usize].class = class;
        self.nodes[idx as usize].prev = NIL;
        self.nodes[idx as usize].next = self.heads[c];
        if self.heads[c] != NIL {
            self.nodes[self.heads[c] as usize].prev = idx;
        }
        self.heads[c] = idx;
        if self.tails[c] == NIL {
            self.tails[c] = idx;
        }
    }

    /// Lookup; a hit refreshes recency within the entry's cost class.
    pub fn touch(&mut self, key: u64) -> bool {
        let idx = self.slot(key);
        if idx == NIL {
            return false;
        }
        let class = self.nodes[idx as usize].class;
        self.unlink(idx);
        self.push_front(idx, class);
        true
    }

    pub fn contains_untouched(&self, key: u64) -> bool {
        self.slot(key) != NIL
    }

    /// Evict the least-recent entry of the cheapest non-empty cost
    /// class (the entry whose flash re-read we charge the least for).
    fn evict(&mut self) -> u64 {
        let c = (0..N_CLASSES)
            .find(|&c| self.tails[c] != NIL)
            .expect("evict called on an empty cache");
        let idx = self.tails[c];
        let key = self.nodes[idx as usize].key;
        self.unlink(idx);
        self.set_slot(key, NIL);
        self.free.push(idx);
        self.len -= 1;
        key
    }

    /// Insert a key with its estimated flash re-read cost; a resident
    /// key is re-classed to the new cost and refreshed instead. Returns
    /// the evicted key, if any.
    pub fn insert_with_cost(&mut self, key: u64, cost: u32) -> Option<u64> {
        if self.capacity == 0 {
            return None;
        }
        let class = class_of(cost);
        let idx = self.slot(key);
        if idx != NIL {
            self.unlink(idx);
            self.push_front(idx, class);
            return None;
        }
        let evicted = (self.len >= self.capacity).then(|| self.evict());
        let idx = if let Some(i) = self.free.pop() {
            self.nodes[i as usize] = Node { key, prev: NIL, next: NIL, class };
            i
        } else {
            self.nodes.push(Node { key, prev: NIL, next: NIL, class });
            (self.nodes.len() - 1) as u32
        };
        self.push_front(idx, class);
        self.set_slot(key, idx);
        self.len += 1;
        evicted
    }

    /// Cost-oblivious insert: everything lands in the [`DEFAULT_COST`]
    /// (most-protected) class, which makes the policy exact LRU.
    pub fn insert(&mut self, key: u64) -> Option<u64> {
        self.insert_with_cost(key, DEFAULT_COST)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cheap_entries_evict_before_expensive_ones() {
        let mut c = CostAware::new(2);
        assert_eq!(c.insert_with_cost(1, 256), None); // expensive singleton
        assert_eq!(c.insert_with_cost(2, 1), None); // cheap linked-run key
        // 1 is older, but 2 is cheaper to re-read: 2 goes first
        assert_eq!(c.insert_with_cost(3, 256), Some(2));
        assert!(c.contains_untouched(1));
        assert!(!c.contains_untouched(2));
    }

    #[test]
    fn uniform_cost_is_exact_lru() {
        let mut c = CostAware::new(2);
        c.insert(1);
        c.insert(2);
        assert!(c.touch(1)); // 2 becomes LRU
        assert_eq!(c.insert(3), Some(2));
        assert!(c.touch(1) && c.touch(3) && !c.touch(2));
    }

    #[test]
    fn within_class_eviction_is_lru_order() {
        let mut c = CostAware::new(3);
        c.insert_with_cost(1, 4);
        c.insert_with_cost(2, 4);
        c.insert_with_cost(3, 4);
        assert!(c.touch(1));
        assert_eq!(c.insert_with_cost(4, 4), Some(2), "least-recent of the class");
    }

    #[test]
    fn reinsert_reclasses_without_eviction() {
        let mut c = CostAware::new(2);
        c.insert_with_cost(1, 1); // cheap
        c.insert_with_cost(2, 256);
        assert_eq!(c.insert_with_cost(1, 256), None, "re-class is not an eviction");
        // both now expensive; 2 is least recent of the shared class
        assert_eq!(c.insert_with_cost(3, 256), Some(2));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn cost_classes_are_log_bucketed() {
        assert_eq!(class_of(0), 0);
        assert_eq!(class_of(1), 0);
        assert_eq!(class_of(2), 1);
        assert_eq!(class_of(3), 1);
        assert_eq!(class_of(256), 8);
        assert_eq!(class_of(u32::MAX), 31);
    }

    #[test]
    fn zero_capacity_stores_nothing() {
        let mut c = CostAware::new(0);
        assert_eq!(c.insert_with_cost(1, 1), None);
        assert!(!c.touch(1));
        assert_eq!(c.len(), 0);
    }
}
