//! L3 serving coordinator: request types, dynamic batcher, replica
//! router, the threaded serving loop, the deterministic multi-session
//! serving simulation ([`session`]), and the event-driven fleet-scale
//! simulator with open-loop traffic ([`fleet`]).
//!
//! Topology: a single dispatcher thread runs the `Batcher` and `Router`;
//! each worker thread owns one `Engine` (PJRT handles are not `Send`, so
//! engines are constructed inside their threads). Requests enter through
//! `Server::submit`, which returns a oneshot-style receiver for the
//! response. Channels are std `mpsc` — the offline environment has no
//! tokio, and the serving loop is CPU-bound on PJRT compute anyway.
//!
//! Batching note: batched sequences share the decode position (the AOT
//! attention artifact takes one `pos` per batch), so shorter prompts are
//! right-padded with spaces during the longer prompts' prefill. Padding
//! only feeds a slot's *own* sequence; slots never attend to each other.

pub mod arbiter;
mod batcher;
pub mod fleet;
pub mod parallel;
mod router;
pub mod session;
pub mod tcp;

pub use arbiter::{ArbiterPolicy, PrefetchArbiter, SessionDemand};
pub use batcher::{Batcher, BatcherConfig};
pub use parallel::{with_decode_pool, DecodePool, DisjointSlice};
pub use fleet::{
    run_fleet, run_fleet_traced, EventHeap, FleetConfig, FleetEvent, FleetManager, FleetOutcome,
    FleetScheduler, FleetStats,
};
pub use router::Router;
pub use session::{run_serve, run_serve_traced, ServeConfig, ServeOutcome, SessionManager};
pub use tcp::{TcpClient, TcpFrontend};

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{Engine, EngineOptions};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub generated: Vec<u8>,
    /// Wall-clock time inside the engine (compute; CPU-PJRT).
    pub engine_ms: f64,
    /// Queueing delay before the batch started.
    pub queue_ms: f64,
    /// Simulated flash I/O (device busy) time attributed to this batch, ms.
    pub sim_io_ms: f64,
    /// Speculative prefetch hits attributed to this batch, bundles.
    pub prefetch_hit_bundles: u64,
    /// Speculatively read bundles this batch never demanded.
    pub prefetch_wasted_bundles: u64,
    /// Fraction of this batch's flash busy time hidden under compute
    /// (0.0 when the worker runs the synchronous schedule).
    pub overlap_ratio: f64,
    /// Which worker served it.
    pub worker: usize,
    /// Batch size it was served in.
    pub batch_size: usize,
}

#[derive(Clone, Debug)]
pub struct ServerOptions {
    pub engine: EngineOptions,
    pub batcher: BatcherConfig,
    pub n_workers: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        let engine = EngineOptions { batch: 4, ..Default::default() };
        Self { engine, batcher: BatcherConfig::default(), n_workers: 1 }
    }
}

struct Pending {
    req: Request,
    enqueued: Instant,
    reply: mpsc::Sender<Response>,
}

enum Ctl {
    Submit(Pending),
    Shutdown,
}

struct WorkerMsg {
    batch: Vec<Pending>,
}

/// Aggregate serving statistics (filled at shutdown).
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub tokens: u64,
    pub wall_s: f64,
}

impl ServerStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s == 0.0 { 0.0 } else { self.tokens as f64 / self.wall_s }
    }
}

pub struct Server {
    ctl: mpsc::Sender<Ctl>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    started: Instant,
    counters: std::sync::Arc<Counters>,
}

#[derive(Default)]
struct Counters {
    requests: std::sync::atomic::AtomicU64,
    tokens: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the dispatcher + `n_workers` engine workers. Fails fast if
    /// any worker cannot load the artifacts.
    pub fn start(artifacts_dir: std::path::PathBuf, opts: ServerOptions) -> Result<Self> {
        anyhow::ensure!(opts.n_workers > 0, "need at least one worker");
        let (ctl_tx, ctl_rx) = mpsc::channel::<Ctl>();
        let counters = std::sync::Arc::new(Counters::default());

        // spawn workers; each confirms engine load via a ready channel
        let mut worker_txs = Vec::new();
        let mut readies = Vec::new();
        for wid in 0..opts.n_workers {
            let (wtx, wrx) = mpsc::channel::<WorkerMsg>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let dir = artifacts_dir.clone();
            let eopts = opts.engine.clone();
            let ctrs = counters.clone();
            std::thread::Builder::new()
                .name(format!("ripple-worker-{wid}"))
                .spawn(move || worker_loop(wid, dir, eopts, wrx, ready_tx, ctrs))
                .context("spawning worker")?;
            readies.push(ready_rx);
            worker_txs.push(wtx);
        }
        for (wid, r) in readies.into_iter().enumerate() {
            r.recv()
                .with_context(|| format!("worker {wid} died during startup"))??;
        }

        // dispatcher thread: batcher + router
        let bcfg = opts.batcher.clone();
        let dispatcher = std::thread::Builder::new()
            .name("ripple-dispatch".into())
            .spawn(move || dispatcher_loop(ctl_rx, worker_txs, bcfg))
            .context("spawning dispatcher")?;

        Ok(Self {
            ctl: ctl_tx,
            dispatcher: Some(dispatcher),
            next_id: std::sync::atomic::AtomicU64::new(1),
            started: Instant::now(),
            counters,
        })
    }

    /// Submit a prompt; returns a receiver that yields the Response.
    pub fn submit(&self, prompt: Vec<u8>, max_new: usize) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let pending = Pending {
            req: Request { id, prompt, max_new },
            enqueued: Instant::now(),
            reply: tx,
        };
        // If the dispatcher is gone the receiver will simply see EOF.
        let _ = self.ctl.send(Ctl::Submit(pending));
        rx
    }

    /// Stop accepting work, flush the queue, join all threads.
    pub fn shutdown(mut self) -> ServerStats {
        let _ = self.ctl.send(Ctl::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        ServerStats {
            requests: self
                .counters
                .requests
                .load(std::sync::atomic::Ordering::Relaxed),
            tokens: self.counters.tokens.load(std::sync::atomic::Ordering::Relaxed),
            wall_s: self.started.elapsed().as_secs_f64(),
        }
    }
}

fn dispatcher_loop(
    ctl: mpsc::Receiver<Ctl>,
    workers: Vec<mpsc::Sender<WorkerMsg>>,
    bcfg: BatcherConfig,
) {
    let max_batch = bcfg.max_batch;
    let mut batcher: Batcher<Pending> = Batcher::new(bcfg);
    let mut router = Router::new(workers.len());
    loop {
        // Sleep until either new work or the oldest request's deadline.
        let timeout = batcher
            .next_deadline_in(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match ctl.recv_timeout(timeout) {
            Ok(Ctl::Submit(p)) => batcher.push(p, Instant::now()),
            Ok(Ctl::Shutdown) => break,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        while let Some(batch) = batcher.pop_ready(Instant::now()) {
            let w = router.dispatch();
            if workers[w].send(WorkerMsg { batch }).is_err() {
                // worker died; drop its requests (receivers see EOF)
            }
            router.complete(w); // synchronous send: account immediately
        }
    }
    // flush remaining queue on shutdown
    let mut rest = batcher.drain_all();
    while !rest.is_empty() {
        let take = rest.len().min(max_batch);
        let batch: Vec<Pending> = rest.drain(..take).collect();
        let w = router.dispatch();
        let _ = workers[w].send(WorkerMsg { batch });
        router.complete(w);
    }
    // dropping worker_txs closes the workers
}

fn worker_loop(
    wid: usize,
    dir: std::path::PathBuf,
    opts: EngineOptions,
    rx: mpsc::Receiver<WorkerMsg>,
    ready: mpsc::Sender<Result<()>>,
    counters: std::sync::Arc<Counters>,
) {
    let want_prefetch = opts.prefetch.enabled;
    let mut engine = match Engine::load(&dir, opts) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    if want_prefetch {
        // Learn the speculative predictor from a short self-calibration
        // before taking traffic, then reset serving state so request
        // metrics start clean (the DRAM cache stays warm on purpose).
        match engine.calibrate(b"the quick brown fox jumps over the lazy dog. ", 32) {
            Ok(tr) => {
                if let Err(e) = engine.enable_prefetch(&tr) {
                    log::error!("worker {wid}: prefetch setup failed: {e:#}");
                }
            }
            Err(e) => log::error!("worker {wid}: prefetch calibration failed: {e:#}"),
        }
        if let Err(e) = engine.reset_sequence() {
            log::error!("worker {wid}: reset after calibration failed: {e:#}");
        }
        // all three stat families (run metrics, flash counters, cache
        // hit/miss counters) — previously the cache counters leaked the
        // calibration traffic into the serving-window hit ratio
        engine.reset_io_stats();
    }
    while let Ok(WorkerMsg { batch }) = rx.recv() {
        let started = Instant::now();
        let max_new = batch.iter().map(|p| p.req.max_new).max().unwrap_or(0);
        let prompts: Vec<Vec<u8>> = batch.iter().map(|p| p.req.prompt.clone()).collect();
        let flash_before = engine.sim.stats();
        let pf_before = (
            engine.io_metrics.totals.prefetch_hit_bundles,
            engine.io_metrics.totals.prefetch_wasted_bundles,
        );
        let result = engine.generate(&prompts, max_new, false);
        let engine_ms = started.elapsed().as_secs_f64() * 1e3;
        let flash_after = engine.sim.stats();
        let busy_d = flash_after.total_busy_ns - flash_before.total_busy_ns;
        let hidden_d = flash_after.total_hidden_ns - flash_before.total_hidden_ns;
        let sim_io_ms = busy_d / 1e6;
        // the sim's canonical definition (hidden/busy), as a delta
        let overlap_ratio =
            if busy_d > 0.0 { (hidden_d / busy_d).clamp(0.0, 1.0) } else { 0.0 };
        let prefetch_hit_bundles =
            engine.io_metrics.totals.prefetch_hit_bundles - pf_before.0;
        let prefetch_wasted_bundles =
            engine.io_metrics.totals.prefetch_wasted_bundles - pf_before.1;
        match result {
            Ok(outs) => {
                for (p, out) in batch.into_iter().zip(outs) {
                    let mut generated = out;
                    generated.truncate(p.req.max_new);
                    counters
                        .requests
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    counters
                        .tokens
                        .fetch_add(generated.len() as u64, std::sync::atomic::Ordering::Relaxed);
                    let _ = p.reply.send(Response {
                        id: p.req.id,
                        generated,
                        engine_ms,
                        queue_ms: started.duration_since(p.enqueued).as_secs_f64() * 1e3,
                        sim_io_ms,
                        prefetch_hit_bundles,
                        prefetch_wasted_bundles,
                        overlap_ratio,
                        worker: wid,
                        batch_size: prompts.len(),
                    });
                }
            }
            Err(err) => {
                log::error!("worker {wid}: generation failed: {err:#}");
                // receivers see EOF
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    #[test]
    fn serves_concurrent_requests() {
        let dir = default_artifacts_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let server = Server::start(dir, ServerOptions::default()).unwrap();
        let rxs: Vec<_> = (0..6)
            .map(|i| server.submit(format!("req {i} the quick").into_bytes(), 4))
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert_eq!(resp.generated.len(), 4);
            assert!(resp.engine_ms > 0.0);
            assert!(resp.sim_io_ms >= 0.0);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 6);
        assert_eq!(stats.tokens, 24);
        assert!(stats.tokens_per_sec() > 0.0);
    }

    #[test]
    fn startup_fails_without_artifacts() {
        let err = Server::start("/nonexistent".into(), ServerOptions::default());
        assert!(err.is_err());
    }
}
