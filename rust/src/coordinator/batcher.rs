//! Dynamic batcher: groups queued requests into engine-sized batches,
//! dispatching when the batch fills or the oldest request has waited the
//! deadline (vLLM-style size-or-timeout policy).
//!
//! Two dispatch disciplines share the same FIFO queue:
//!
//! * **lockstep** (`pop_ready`) — the historical size-or-timeout batch,
//!   used by the request/response `Server`;
//! * **continuous** (`pop_upto`) — iteration-level scheduling: whenever
//!   decode slots free up *between tokens*, the scheduler immediately
//!   admits the oldest waiting requests to fill them, so sessions join
//!   and leave a running batch instead of waiting for a full batch to
//!   retire (the multi-session serving simulation, DESIGN.md §Serving).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Engine batch capacity.
    pub max_batch: usize,
    /// Max time the oldest request may wait before dispatch.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 4, max_wait: Duration::from_millis(20) }
    }
}

#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<(T, Instant)>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch > 0);
        Self { cfg, queue: VecDeque::new() }
    }

    pub fn push(&mut self, item: T, now: Instant) {
        self.queue.push_back((item, now));
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Time the worker may sleep before a deadline dispatch is due.
    pub fn next_deadline_in(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|(_, t)| {
            let deadline = *t + self.cfg.max_wait;
            deadline.saturating_duration_since(now)
        })
    }

    /// Dispatch a batch if full or if the oldest request timed out.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Vec<T>> {
        let full = self.queue.len() >= self.cfg.max_batch;
        let due = self
            .queue
            .front()
            .is_some_and(|(_, t)| now.duration_since(*t) >= self.cfg.max_wait);
        if !full && !due {
            return None;
        }
        let n = self.queue.len().min(self.cfg.max_batch);
        Some(self.queue.drain(..n).map(|(x, _)| x).collect())
    }

    /// Drain everything (shutdown).
    pub fn drain_all(&mut self) -> Vec<T> {
        self.queue.drain(..).map(|(x, _)| x).collect()
    }

    /// Continuous-batching admission: immediately pop up to `n` queued
    /// requests in FIFO order, regardless of batch-fill or deadline
    /// state. Called with the number of free decode slots each time a
    /// session finishes a token (or leaves), so waiting requests join
    /// the running batch at the next token boundary.
    pub fn pop_upto(&mut self, n: usize) -> Vec<T> {
        let take = self.queue.len().min(n);
        self.queue.drain(..take).map(|(x, _)| x).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, ms: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait: Duration::from_millis(ms) }
    }

    #[test]
    fn dispatches_when_full() {
        let now = Instant::now();
        let mut b = Batcher::new(cfg(2, 1000));
        b.push(1, now);
        assert!(b.pop_ready(now).is_none());
        b.push(2, now);
        assert_eq!(b.pop_ready(now).unwrap(), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn dispatches_on_deadline() {
        let now = Instant::now();
        let mut b = Batcher::new(cfg(4, 10));
        b.push(7, now);
        assert!(b.pop_ready(now).is_none());
        let later = now + Duration::from_millis(11);
        assert_eq!(b.pop_ready(later).unwrap(), vec![7]);
    }

    #[test]
    fn batch_caps_at_max() {
        let now = Instant::now();
        let mut b = Batcher::new(cfg(2, 0));
        for i in 0..5 {
            b.push(i, now);
        }
        assert_eq!(b.pop_ready(now).unwrap(), vec![0, 1]);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn deadline_hint() {
        let now = Instant::now();
        let mut b: Batcher<u32> = Batcher::new(cfg(4, 10));
        assert!(b.next_deadline_in(now).is_none());
        b.push(1, now);
        let d = b.next_deadline_in(now + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn pop_upto_is_fifo_prefix() {
        let now = Instant::now();
        let mut b = Batcher::new(cfg(4, 1000));
        for i in 0..5 {
            b.push(i, now);
        }
        assert_eq!(b.pop_upto(0), Vec::<i32>::new());
        assert_eq!(b.pop_upto(2), vec![0, 1]);
        assert_eq!(b.len(), 3);
        // asking for more than queued drains what exists
        assert_eq!(b.pop_upto(10), vec![2, 3, 4]);
        assert!(b.is_empty());
    }

    #[test]
    fn drain_all_empties() {
        let now = Instant::now();
        let mut b = Batcher::new(cfg(8, 1000));
        b.push(1, now);
        b.push(2, now);
        assert_eq!(b.drain_all(), vec![1, 2]);
        assert!(b.is_empty());
    }
}
