//! Replica router: least-loaded dispatch across engine workers, with
//! round-robin tie-breaking. Each worker owns one Engine (PJRT handles
//! are not Send, so engines live inside their worker threads).

/// Tracks outstanding batches per worker and picks the next target.
#[derive(Clone, Debug)]
pub struct Router {
    inflight: Vec<usize>,
    rr: usize,
}

impl Router {
    pub fn new(n_workers: usize) -> Self {
        assert!(n_workers > 0);
        Self { inflight: vec![0; n_workers], rr: 0 }
    }

    pub fn n_workers(&self) -> usize {
        self.inflight.len()
    }

    /// Pick the least-loaded worker (round-robin among ties) and account
    /// one in-flight batch against it.
    pub fn dispatch(&mut self) -> usize {
        let n = self.inflight.len();
        let mut best = self.rr % n;
        for k in 0..n {
            let i = (self.rr + k) % n;
            if self.inflight[i] < self.inflight[best] {
                best = i;
            }
        }
        self.rr = (best + 1) % n;
        self.inflight[best] += 1;
        best
    }

    /// Mark one batch done on `worker`.
    pub fn complete(&mut self, worker: usize) {
        assert!(self.inflight[worker] > 0, "completion without dispatch");
        self.inflight[worker] -= 1;
    }

    pub fn inflight(&self, worker: usize) -> usize {
        self.inflight[worker]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robins_when_balanced() {
        let mut r = Router::new(3);
        let picks: Vec<usize> = (0..3).map(|_| r.dispatch()).collect();
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2]);
    }

    #[test]
    fn prefers_least_loaded() {
        let mut r = Router::new(2);
        let a = r.dispatch();
        let _b = r.dispatch();
        r.complete(a); // a now has 0 in flight, other has 1
        assert_eq!(r.dispatch(), a);
    }

    #[test]
    fn inflight_accounting() {
        let mut r = Router::new(2);
        let w = r.dispatch();
        assert_eq!(r.inflight(w), 1);
        r.complete(w);
        assert_eq!(r.inflight(w), 0);
    }

    #[test]
    #[should_panic(expected = "completion without dispatch")]
    fn complete_requires_dispatch() {
        let mut r = Router::new(1);
        r.complete(0);
    }
}
