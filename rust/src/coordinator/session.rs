//! Multi-session serving simulation (DESIGN.md §Serving).
//!
//! The paper's online stage models ONE decode stream. The serving
//! regime the ROADMAP targets is N interleaved streams contending for
//! one DRAM neuron cache and one flash command queue — the regime
//! PowerInfer-2 (2406.06282) and "LLM in a flash" (2312.11514) show is
//! dominated by cache sharing and I/O scheduling. [`SessionManager`]
//! drives that regime deterministically:
//!
//! * every session owns only its *planner* state (an [`IoPipeline`]
//!   with its own adaptive-collapse controller) and its activation
//!   stream; the [`NeuronCache`] and [`UfsSim`] are borrowed shared
//!   state, exactly one of each per device;
//! * scheduling is **continuous batching**: up to `max_concurrent`
//!   sessions hold decode slots; whenever a session finishes its last
//!   token it leaves and the oldest waiting session joins at the next
//!   token boundary (`Batcher::pop_upto`), rather than lockstep
//!   batches that retire whole;
//! * each decode round serves one token per active session, serially
//!   on the shared (serial-service) flash device, with the start slot
//!   rotated round-robin so no session is systematically last;
//! * time is virtual: a token costs its flash stall plus the modeled
//!   compute window, queueing delay is admission minus arrival, and no
//!   wall clock feeds any metric — serve reports replay bit-for-bit.
//!
//! With `sessions == 1` and a shared cache the manager reduces exactly
//! to the historical single-stream experiment: same trace, same cache
//! and pipeline construction, same flash arithmetic, bit-for-bit
//! (pinned by `rust/tests/harness_golden.rs`).

use std::time::{Duration, Instant};

use crate::bench::workloads::{
    self, cache_capacity, layouts_for, neuron_space, System, SystemSpec, Workload,
};
use crate::cache::{KeySpace, NeuronCache};
use crate::flash::UfsSim;
use crate::metrics::{RunMetrics, ServeMetrics, ServeSummary, SessionStats};
use crate::pipeline::IoPipeline;
use crate::trace::Trace;

use super::{Batcher, BatcherConfig};

/// Knobs of one serving simulation.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Number of decode sessions (users).
    pub sessions: usize,
    /// Decode slots: how many sessions may be mid-decode at once.
    pub max_concurrent: usize,
    /// Virtual gap between consecutive session arrivals, ns (0 = all
    /// arrive together, the maximum-contention case).
    pub arrival_spacing_ns: f64,
    /// One shared DRAM cache (true) vs per-session private partitions
    /// of the same *total* capacity (false).
    pub shared_cache: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            sessions: 4,
            max_concurrent: 4,
            arrival_spacing_ns: 0.0,
            shared_cache: true,
        }
    }
}

/// Everything a serve run produces.
pub struct ServeOutcome {
    /// Aggregate I/O metrics over every token of every session —
    /// directly comparable with single-stream `RunMetrics`.
    pub metrics: RunMetrics,
    /// Per-session and tail statistics.
    pub serve: ServeMetrics,
    /// Flat full-model-scaled summary (what reports serialize).
    pub summary: ServeSummary,
    /// Offline placement wall-clock, seconds (Markdown-only).
    pub placement_secs: f64,
    /// Wall-clock of the multi-session decode loop, seconds
    /// (Markdown-only, like `placement_secs`; see §Perf).
    pub decode_wall_secs: f64,
    /// Bundle size used by every session.
    pub bundle_bytes: usize,
}

/// One decode session's live state inside the manager.
struct Session {
    trace: Trace,
    pipeline: IoPipeline,
    next_token: usize,
    stats: SessionStats,
}

/// Drives N sessions through one shared cache + flash timeline with
/// continuous batching. Construct via [`run_serve`] for the standard
/// workload wiring, or assemble manually for custom experiments.
pub struct SessionManager {
    cfg: ServeConfig,
    sessions: Vec<Session>,
    /// One entry in shared mode; one per session in private mode.
    caches: Vec<NeuronCache>,
    compute_ns_per_token: f64,
    bundle_bytes: usize,
}

impl SessionManager {
    /// Build a manager from per-session pipelines/traces and the cache
    /// set (1 shared or `sessions` private). Panics on arity mismatch.
    pub fn new(
        cfg: ServeConfig,
        streams: Vec<(IoPipeline, Trace)>,
        caches: Vec<NeuronCache>,
        compute_ns_per_token: f64,
        bundle_bytes: usize,
    ) -> Self {
        assert_eq!(streams.len(), cfg.sessions, "one (pipeline, trace) per session");
        let expected = if cfg.shared_cache { 1 } else { cfg.sessions };
        assert_eq!(caches.len(), expected, "cache count must match sharing mode");
        assert!(cfg.max_concurrent > 0, "need at least one decode slot");
        let sessions = streams
            .into_iter()
            .enumerate()
            .map(|(id, (pipeline, trace))| {
                assert!(trace.n_tokens() > 0, "session {id} has an empty trace");
                Session {
                    trace,
                    pipeline,
                    next_token: 0,
                    stats: SessionStats::new(id, id as f64 * cfg.arrival_spacing_ns),
                }
            })
            .collect();
        Self { cfg, sessions, caches, compute_ns_per_token, bundle_bytes }
    }

    /// Run every session to completion against the shared flash
    /// timeline; returns (aggregate run metrics, serve metrics).
    pub fn run(mut self, sim: &mut UfsSim) -> (RunMetrics, ServeMetrics) {
        let n = self.cfg.sessions;
        let mut agg = RunMetrics::new();
        let mut serve = ServeMetrics {
            max_concurrent: self.cfg.max_concurrent,
            shared_cache: self.cfg.shared_cache,
            ..Default::default()
        };
        // The Batcher keeps the admission queue FIFO; continuous-batching
        // admission (`pop_upto`) never reads timestamps or deadlines, so
        // every push carries one inert anchor Instant — arrival times
        // live on the virtual clock (`SessionStats::arrival_ns`), and no
        // wall-clock value ever reaches a metric.
        let anchor = Instant::now();
        let mut waiting: Batcher<usize> = Batcher::new(BatcherConfig {
            max_batch: self.cfg.max_concurrent,
            max_wait: Duration::from_secs(3600),
        });
        let mut clock_ns = 0.0f64;
        let mut next_arrival = 0usize; // sessions not yet queued
        let mut active: Vec<usize> = Vec::new(); // slot order
        let mut done = 0usize;
        let mut round = 0usize;
        while done < n {
            // arrivals due by now enter the admission queue
            while next_arrival < n
                && self.sessions[next_arrival].stats.arrival_ns <= clock_ns
            {
                waiting.push(next_arrival, anchor);
                next_arrival += 1;
            }
            // continuous batching: free slots admit the oldest waiters
            let free = self.cfg.max_concurrent - active.len();
            for sid in waiting.pop_upto(free) {
                self.sessions[sid].stats.queue_delay_ns =
                    clock_ns - self.sessions[sid].stats.arrival_ns;
                active.push(sid);
            }
            serve.peak_active = serve.peak_active.max(active.len());
            if active.is_empty() {
                // idle server: jump to the next arrival
                assert!(next_arrival < n, "no active, no waiting, not done");
                clock_ns = clock_ns.max(self.sessions[next_arrival].stats.arrival_ns);
                continue;
            }
            // one decode round: one token per active session, serially on
            // the shared device; rotate the start slot so no session is
            // systematically last in the round.
            let round_start = clock_ns;
            let k = active.len();
            let rot = round % k;
            let mut leaving: Vec<usize> = Vec::new();
            for i in 0..k {
                let sid = active[(rot + i) % k];
                let cache_idx = if self.cfg.shared_cache { 0 } else { sid };
                let cache = &mut self.caches[cache_idx];
                if self.cfg.shared_cache {
                    cache.set_session(sid as u32);
                }
                let sess = &mut self.sessions[sid];
                let tok = &sess.trace.tokens[sess.next_token];
                let io = sess.pipeline.step_token(cache, sim, tok);
                clock_ns += io.stall_ns + self.compute_ns_per_token;
                let latency = clock_ns - round_start;
                sess.stats.record_token(&io, latency);
                serve.all_latency_ns.add(latency);
                agg.record(&io, self.bundle_bytes);
                agg.record_compute(self.compute_ns_per_token);
                sess.next_token += 1;
                if sess.next_token == sess.trace.n_tokens() {
                    sess.stats.finished_ns = clock_ns;
                    leaving.push(sid);
                }
            }
            // sessions leave between tokens; their slots refill next round
            active.retain(|sid| !leaving.contains(sid));
            done += leaving.len();
            round += 1;
        }
        serve.makespan_ns = clock_ns;
        for c in &self.caches {
            serve.cache_hits += c.hits;
            serve.cache_cross_hits += c.cross_hits;
        }
        serve.sessions = self.sessions.into_iter().map(|s| s.stats).collect();
        (agg, serve)
    }
}

/// Run a full serving simulation for a workload: placement once (one
/// model in flash serves everyone), one pipeline + trace per session,
/// one shared `UfsSim`, and a shared cache or equal-total private
/// partitions. Synchronous flash timeline only — speculative prefetch
/// under contention is future work (ROADMAP).
pub fn run_serve(
    w: &Workload,
    system: System,
    spec: SystemSpec,
    cfg: &ServeConfig,
) -> anyhow::Result<ServeOutcome> {
    anyhow::ensure!(cfg.sessions > 0, "serve needs at least one session");
    anyhow::ensure!(cfg.max_concurrent > 0, "serve needs at least one decode slot");
    anyhow::ensure!(
        !spec.dense,
        "dense streaming (llamacpp) has no per-session sparsity to share; \
         run it single-stream"
    );
    anyhow::ensure!(
        !w.prefetch.enabled,
        "the serving simulation runs the synchronous flash timeline; \
         disable prefetch"
    );
    let calib = w.calibration_trace();
    let (layouts, placement_secs) = layouts_for(system, &calib, w.knn, w.threads);
    let space = neuron_space(w);
    let bundle_bytes = space.bundle_bytes;
    let pcfg = workloads::pipeline_config(spec, w, None);
    let cap_total = cache_capacity(w);
    let n_caches = if cfg.shared_cache { 1 } else { cfg.sessions };
    // private partitions must sum to EXACTLY the shared capacity or the
    // shared-vs-private comparison is biased: spread the remainder of
    // the floor division over the first caches.
    let cap_of = |idx: usize| {
        if cfg.shared_cache {
            cap_total
        } else {
            cap_total / cfg.sessions + usize::from(idx < cap_total % cfg.sessions)
        }
    };
    let keys = KeySpace::of(&space);
    let caches: Vec<NeuronCache> = (0..n_caches)
        .map(|idx| NeuronCache::from_config(spec.cache_policy, cap_of(idx), keys, w.seed))
        .collect::<anyhow::Result<_>>()?;
    let streams: Vec<(IoPipeline, Trace)> = (0..cfg.sessions)
        .map(|sid| {
            (
                IoPipeline::new(pcfg.clone(), space.clone(), layouts.clone()),
                w.session_eval_trace(&w.dataset, sid),
            )
        })
        .collect();
    let compute_ns_per_token = w.compute_ns_per_layer * w.sim_layers as f64;
    let mut sim = UfsSim::new(w.device.clone(), space.image_bytes());
    let manager =
        SessionManager::new(cfg.clone(), streams, caches, compute_ns_per_token, bundle_bytes);
    let t_decode = Instant::now();
    let (metrics, mut serve) = manager.run(&mut sim);
    let decode_wall_secs = t_decode.elapsed().as_secs_f64();
    let summary = serve.summary(w.layer_scale(), metrics.cache_hit_ratio());
    Ok(ServeOutcome {
        metrics,
        serve,
        summary,
        placement_secs,
        decode_wall_secs,
        bundle_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::tiny_workload;

    fn tiny_serve(cfg: ServeConfig) -> ServeOutcome {
        let mut w = tiny_workload();
        w.eval_tokens = 12;
        let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
        run_serve(&w, System::Ripple, spec, &cfg).unwrap()
    }

    #[test]
    fn all_sessions_complete_all_tokens() {
        let out = tiny_serve(ServeConfig { sessions: 3, ..Default::default() });
        assert_eq!(out.serve.sessions.len(), 3);
        for s in &out.serve.sessions {
            assert_eq!(s.tokens, 12);
            assert!(s.finished_ns > 0.0);
        }
        assert_eq!(out.metrics.tokens, 36);
        assert_eq!(out.summary.tokens, 36);
        assert!(out.summary.p99_ms >= out.summary.p50_ms);
        assert!(out.summary.makespan_ms > 0.0);
    }

    #[test]
    fn slots_bound_concurrency_and_queue_delay_appears() {
        let out = tiny_serve(ServeConfig {
            sessions: 5,
            max_concurrent: 2,
            ..Default::default()
        });
        assert!(out.serve.peak_active <= 2);
        // the first two sessions get slots at arrival; later ones wait
        assert_eq!(out.serve.sessions[0].queue_delay_ns, 0.0);
        assert_eq!(out.serve.sessions[1].queue_delay_ns, 0.0);
        assert!(out.serve.sessions[4].queue_delay_ns > 0.0);
        assert!(out.summary.mean_queue_delay_ms > 0.0);
    }

    #[test]
    fn staggered_arrivals_reduce_contention() {
        let packed = tiny_serve(ServeConfig {
            sessions: 4,
            max_concurrent: 4,
            arrival_spacing_ns: 0.0,
            shared_cache: true,
        });
        let spread = tiny_serve(ServeConfig {
            sessions: 4,
            max_concurrent: 4,
            // huge spacing: sessions run essentially alone
            arrival_spacing_ns: 1e12,
            shared_cache: true,
        });
        assert!(
            spread.summary.p95_ms <= packed.summary.p95_ms,
            "serial sessions must not see worse tails than packed ones: \
             {} vs {}",
            spread.summary.p95_ms,
            packed.summary.p95_ms
        );
        assert!(spread.summary.makespan_ms > packed.summary.makespan_ms);
    }

    #[test]
    fn serve_run_is_deterministic() {
        let cfg = ServeConfig { sessions: 4, max_concurrent: 3, ..Default::default() };
        let a = tiny_serve(cfg.clone());
        let b = tiny_serve(cfg);
        assert_eq!(
            a.metrics.totals.elapsed_ns.to_bits(),
            b.metrics.totals.elapsed_ns.to_bits()
        );
        assert_eq!(a.metrics.totals.commands, b.metrics.totals.commands);
        assert_eq!(a.summary.p99_ms.to_bits(), b.summary.p99_ms.to_bits());
        assert_eq!(a.summary.makespan_ms.to_bits(), b.summary.makespan_ms.to_bits());
        assert_eq!(a.summary.fairness.to_bits(), b.summary.fairness.to_bits());
    }

    #[test]
    fn serve_rejects_dense_and_prefetch() {
        let mut w = tiny_workload();
        w.eval_tokens = 4;
        let dense = SystemSpec::of(System::LlamaCpp, w.model.ffn_linears);
        assert!(run_serve(&w, System::LlamaCpp, dense, &ServeConfig::default()).is_err());
        let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
        w.prefetch.enabled = true;
        assert!(run_serve(&w, System::Ripple, spec, &ServeConfig::default()).is_err());
    }

    #[test]
    fn private_caches_never_cross_hit() {
        let out = tiny_serve(ServeConfig {
            sessions: 3,
            shared_cache: false,
            ..Default::default()
        });
        assert_eq!(out.serve.cache_cross_hits, 0);
        assert_eq!(out.summary.cross_session_hit_ratio, 0.0);
        assert!(!out.summary.shared_cache);
    }
}
