//! Multi-session serving simulation (DESIGN.md §Serving).
//!
//! The paper's online stage models ONE decode stream. The serving
//! regime the ROADMAP targets is N interleaved streams contending for
//! one DRAM neuron cache and one flash command queue — the regime
//! PowerInfer-2 (2406.06282) and "LLM in a flash" (2312.11514) show is
//! dominated by cache sharing and I/O scheduling. [`SessionManager`]
//! drives that regime deterministically:
//!
//! * every session owns only its *planner* state (an [`IoPipeline`]
//!   with its own adaptive-collapse controller) and its activation
//!   stream; the [`NeuronCache`] and [`UfsSim`] are borrowed shared
//!   state, exactly one of each per device;
//! * scheduling is **continuous batching**: up to `max_concurrent`
//!   sessions hold decode slots; whenever a session finishes its last
//!   token it leaves and the oldest waiting session joins at the next
//!   token boundary (`Batcher::pop_upto`), rather than lockstep
//!   batches that retire whole;
//! * each decode round serves one token per active session, serially
//!   on the shared (serial-service) flash device, with the start slot
//!   rotated round-robin so no session is systematically last;
//! * time is virtual: a token costs its flash stall plus the modeled
//!   compute window, queueing delay is admission minus arrival, and no
//!   wall clock feeds any metric — serve reports replay bit-for-bit;
//! * with prefetch enabled, every session runs the overlapped pipeline
//!   against the shared device frontier, and a
//!   [`PrefetchArbiter`](super::arbiter::PrefetchArbiter) divides the
//!   global speculative byte budget across the round's active sessions
//!   before any token is served (fair-share or deadline-aware).
//!
//! With `sessions == 1` and a shared cache the manager reduces exactly
//! to the historical single-stream experiment: same trace, same cache
//! and pipeline construction, same flash arithmetic, bit-for-bit
//! (pinned by `rust/tests/harness_golden.rs`).

use std::time::{Duration, Instant};

use crate::bench::workloads::{
    self, cache_capacity, layouts_for, neuron_space, System, SystemSpec, Workload,
};
use crate::cache::{KeySpace, NeuronCache};
use crate::flash::UfsSim;
use crate::metrics::{RunMetrics, ServeMetrics, ServeSummary, SessionStats};
use crate::obs::{MarkKind, Phase, TraceHandle, Track};
use crate::pipeline::{IoPipeline, TokenPrep};
use crate::prefetch::Prefetcher;
use crate::trace::Trace;

use super::arbiter::{ArbiterPolicy, PrefetchArbiter, SessionDemand};
use super::parallel::{with_decode_pool, DecodePool, DisjointSlice};
use super::{Batcher, BatcherConfig};

/// Knobs of one serving simulation.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Number of decode sessions (users).
    pub sessions: usize,
    /// Decode slots: how many sessions may be mid-decode at once.
    pub max_concurrent: usize,
    /// Virtual gap between consecutive session arrivals, ns (0 = all
    /// arrive together, the maximum-contention case).
    pub arrival_spacing_ns: f64,
    /// One shared DRAM cache (true) vs per-session private partitions
    /// of the same *total* capacity (false).
    pub shared_cache: bool,
    /// Policy dividing the global speculative byte budget across the
    /// round's active sessions (prefetch-enabled workloads only).
    pub arbiter: ArbiterPolicy,
    /// Global speculative byte budget per decode round, across ALL
    /// sessions. `None` defaults to the per-session configured budget
    /// times `sessions`, so a single session keeps its full budget and
    /// the run reduces bit-for-bit to the single-stream overlapped
    /// experiment.
    pub prefetch_global_budget: Option<usize>,
    /// Threads for the parallel plan phase of each decode round
    /// (DESIGN.md §Parallel-decode). Results are decode-thread-count
    /// invariant — the commit phase replays the round in canonical
    /// session order — so this knob only changes wall-clock. 1 (the
    /// default) runs the historical fully-serial loop.
    pub decode_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            sessions: 4,
            max_concurrent: 4,
            arrival_spacing_ns: 0.0,
            shared_cache: true,
            arbiter: ArbiterPolicy::FairShare,
            prefetch_global_budget: None,
            decode_threads: 1,
        }
    }
}

/// Everything a serve run produces.
pub struct ServeOutcome {
    /// Aggregate I/O metrics over every token of every session —
    /// directly comparable with single-stream `RunMetrics`.
    pub metrics: RunMetrics,
    /// Per-session and tail statistics.
    pub serve: ServeMetrics,
    /// Flat full-model-scaled summary (what reports serialize).
    pub summary: ServeSummary,
    /// Offline placement wall-clock, seconds (Markdown-only).
    pub placement_secs: f64,
    /// Wall-clock of the multi-session decode loop, seconds
    /// (Markdown-only, like `placement_secs`; see §Perf).
    pub decode_wall_secs: f64,
    /// Bundle size used by every session.
    pub bundle_bytes: usize,
}

/// One decode session's live state inside the manager.
///
/// The parallel plan phase hands each active session (and its
/// [`TokenPrep`]) to exactly one pool job, so a `Session` is only ever
/// touched by one thread at a time.
struct Session {
    trace: Trace,
    pipeline: IoPipeline,
    next_token: usize,
    stats: SessionStats,
}

/// Drives N sessions through one shared cache + flash timeline with
/// continuous batching. Construct via [`run_serve`] for the standard
/// workload wiring, or assemble manually for custom experiments.
///
/// All loop state lives on the manager (hoisted buffers, pre-sized
/// recorders), so a steady-state [`step_round`](Self::step_round)
/// touches the allocator not at all — pinned by
/// `rust/tests/zero_alloc_decode.rs`.
pub struct SessionManager {
    cfg: ServeConfig,
    sessions: Vec<Session>,
    /// Phase-1 plan output, one per session (indexed by sid; kept off
    /// `Session` so the plan phase can view sessions and preps as two
    /// independently disjoint slices).
    preps: Vec<TokenPrep>,
    /// One entry in shared mode; one per session in private mode.
    caches: Vec<NeuronCache>,
    compute_ns_per_token: f64,
    bundle_bytes: usize,
    /// Overlapped (prefetch-capable) serve path, enabled by
    /// [`enable_prefetch`](Self::enable_prefetch).
    overlapped: bool,
    compute_ns_per_layer: f64,
    arbiter: PrefetchArbiter,
    // ---- run state, hoisted so the steady-state round is alloc-free
    agg: RunMetrics,
    serve: ServeMetrics,
    waiting: Batcher<usize>,
    anchor: Instant,
    clock_ns: f64,
    next_arrival: usize,
    active: Vec<usize>,
    demands: Vec<SessionDemand>,
    done: usize,
    round: usize,
    /// Optional flight recorder: per-token phase spans, admission spans,
    /// and arbiter grant marks. `None` records nothing.
    trace: Option<TraceHandle>,
}

impl SessionManager {
    /// Build a manager from per-session pipelines/traces and the cache
    /// set (1 shared or `sessions` private). Panics on arity mismatch.
    pub fn new(
        cfg: ServeConfig,
        streams: Vec<(IoPipeline, Trace)>,
        caches: Vec<NeuronCache>,
        compute_ns_per_token: f64,
        bundle_bytes: usize,
    ) -> Self {
        assert_eq!(streams.len(), cfg.sessions, "one (pipeline, trace) per session");
        let expected = if cfg.shared_cache { 1 } else { cfg.sessions };
        assert_eq!(caches.len(), expected, "cache count must match sharing mode");
        assert!(cfg.max_concurrent > 0, "need at least one decode slot");
        let mut sessions: Vec<Session> = streams
            .into_iter()
            .enumerate()
            .map(|(id, (pipeline, trace))| {
                assert!(trace.n_tokens() > 0, "session {id} has an empty trace");
                Session {
                    trace,
                    pipeline,
                    next_token: 0,
                    stats: SessionStats::new(id, id as f64 * cfg.arrival_spacing_ns),
                }
            })
            .collect();
        // pre-size every recorder the round loop feeds, so recording
        // stays off the allocator
        let total_tokens: usize = sessions.iter().map(|s| s.trace.n_tokens()).sum();
        for s in &mut sessions {
            let n = s.trace.n_tokens();
            s.stats.latency_ns.reserve(n);
        }
        let mut agg = RunMetrics::new();
        agg.latency_ns.reserve(total_tokens);
        let mut serve = ServeMetrics {
            max_concurrent: cfg.max_concurrent,
            shared_cache: cfg.shared_cache,
            ..Default::default()
        };
        serve.all_latency_ns.reserve(total_tokens);
        let mut arbiter = PrefetchArbiter::new(cfg.arbiter, 0);
        arbiter.reserve(cfg.sessions);
        // The Batcher keeps the admission queue FIFO; continuous-batching
        // admission (`pop_upto`) never reads timestamps or deadlines, so
        // every push carries one inert anchor Instant — arrival times
        // live on the virtual clock (`SessionStats::arrival_ns`), and no
        // wall-clock value ever reaches a metric.
        let waiting = Batcher::new(BatcherConfig {
            max_batch: cfg.max_concurrent,
            max_wait: Duration::from_secs(3600),
        });
        let active = Vec::with_capacity(cfg.sessions);
        let demands = Vec::with_capacity(cfg.sessions);
        let preps = (0..cfg.sessions).map(|_| TokenPrep::default()).collect();
        Self {
            cfg,
            sessions,
            preps,
            caches,
            compute_ns_per_token,
            bundle_bytes,
            overlapped: false,
            compute_ns_per_layer: 0.0,
            arbiter,
            agg,
            serve,
            waiting,
            anchor: Instant::now(),
            clock_ns: 0.0,
            next_arrival: 0,
            active,
            demands,
            done: 0,
            round: 0,
            trace: None,
        }
    }

    /// Attach (or detach) a flight recorder, propagating it to every
    /// session's pipeline (each attributed to its own session track).
    /// Tracing never changes scheduling, timing, or metrics.
    pub fn set_trace(&mut self, trace: Option<TraceHandle>) {
        for (sid, s) in self.sessions.iter_mut().enumerate() {
            s.pipeline.set_trace(trace.clone(), sid as u32);
        }
        self.trace = trace;
    }

    /// Switch rounds to the overlapped (prefetch-capable) pipeline:
    /// tokens step through `step_token_overlapped` with this per-layer
    /// compute window, and a [`PrefetchArbiter`] divides
    /// `global_budget_bytes` of speculation across the round's active
    /// sessions before any token is served.
    pub fn enable_prefetch(&mut self, compute_ns_per_layer: f64, global_budget_bytes: usize) {
        self.overlapped = true;
        self.compute_ns_per_layer = compute_ns_per_layer;
        self.arbiter = PrefetchArbiter::new(self.cfg.arbiter, global_budget_bytes);
        self.arbiter.reserve(self.cfg.sessions);
    }

    /// True once every session has decoded its last token.
    pub fn is_done(&self) -> bool {
        self.done == self.cfg.sessions
    }

    /// Divide the global speculative budget across this round's active
    /// sessions and install the grants before any token is served. A
    /// session's demand is its configured per-submission budget; its
    /// urgency (deadline policy) is its observed mean serve latency.
    fn arbitrate_round(&mut self) {
        self.demands.clear();
        for &sid in &self.active {
            let s = &self.sessions[sid];
            self.demands.push(SessionDemand {
                demand_bytes: s.pipeline.prefetch_budget_bytes(),
                mean_latency_ns: s.stats.mean_latency_ns(),
            });
        }
        let grants = self.arbiter.arbitrate(&self.demands);
        if let Some(trace) = &self.trace {
            let now = self.clock_ns;
            trace.with(|rec| {
                for (i, &sid) in self.active.iter().enumerate() {
                    rec.mark(
                        Track::Arbiter,
                        MarkKind::Grant,
                        now,
                        grants[i] as f64,
                        sid as f64,
                    );
                }
            });
        }
        for (i, &sid) in self.active.iter().enumerate() {
            self.sessions[sid].pipeline.set_prefetch_grant(Some(grants[i]));
        }
    }

    /// Phase 1 of a decode round (DESIGN.md §Parallel-decode): every
    /// active session computes its pure session-local plan — sorted
    /// slot lists and, in overlapped mode, speculative predictions —
    /// into its own [`TokenPrep`], concurrently on the pool. Touches
    /// no shared state (no cache, no flash sim, no stats), so result
    /// bytes cannot depend on scheduling. Skipped entirely on an
    /// inline pool: the serial commit then computes everything in
    /// place, which is the identical historical code path.
    fn plan_round(&mut self, pool: &mut DecodePool<'_>) {
        if pool.threads() <= 1 {
            return;
        }
        let overlapped = self.overlapped;
        let active = &self.active;
        let sessions = DisjointSlice::new(&mut self.sessions);
        let preps = DisjointSlice::new(&mut self.preps);
        pool.run(active.len(), |i| {
            let sid = active[i];
            // Safety: `active` holds unique session ids and the pool
            // runs each index exactly once, so this job is the sole
            // accessor of session `sid` and its prep.
            unsafe {
                let sess = &mut *sessions.get(sid);
                let prep = &mut *preps.get(sid);
                let tok = &sess.trace.tokens[sess.next_token];
                sess.pipeline.prepare_token(tok, overlapped, prep);
            }
        });
    }

    /// Advance the simulation by one scheduler iteration: admit due
    /// arrivals, then either serve one decode round (one token per
    /// active session, serially on the shared device, start slot
    /// rotated round-robin) or jump the clock to the next arrival.
    /// Returns false once every session has finished.
    pub fn step_round(&mut self, sim: &mut UfsSim) -> bool {
        self.step_round_pooled(sim, &mut DecodePool::inline())
    }

    /// [`step_round`](Self::step_round) with a plan-phase pool: the
    /// round's session-local planning fans out over `pool`, then the
    /// serial commit phase below replays the round **in the same fixed
    /// session order as ever**, consuming prepared values only where
    /// they provably match the inline computation — so hit/miss
    /// outcomes, flash timelines, and every metric are bit-identical
    /// across decode-thread counts (pinned by
    /// `rust/tests/parallel_props.rs`).
    pub fn step_round_pooled(&mut self, sim: &mut UfsSim, pool: &mut DecodePool<'_>) -> bool {
        let n = self.cfg.sessions;
        if self.done == n {
            return false;
        }
        // arrivals due by now enter the admission queue
        while self.next_arrival < n
            && self.sessions[self.next_arrival].stats.arrival_ns <= self.clock_ns
        {
            self.waiting.push(self.next_arrival, self.anchor);
            self.next_arrival += 1;
        }
        // continuous batching: free slots admit the oldest waiters
        let free = self.cfg.max_concurrent - self.active.len();
        for sid in self.waiting.pop_upto(free) {
            self.sessions[sid].stats.queue_delay_ns =
                self.clock_ns - self.sessions[sid].stats.arrival_ns;
            if let Some(trace) = &self.trace {
                let arrival = self.sessions[sid].stats.arrival_ns;
                let delay = self.sessions[sid].stats.queue_delay_ns;
                let now = self.clock_ns;
                trace.with(|rec| {
                    rec.span(Track::Session(sid as u32), Phase::AdmissionQueue, arrival, delay);
                    rec.mark(Track::Session(sid as u32), MarkKind::Admit, now, delay, 0.0);
                });
            }
            self.active.push(sid);
        }
        self.serve.peak_active = self.serve.peak_active.max(self.active.len());
        if self.active.is_empty() {
            // idle server: jump to the next arrival
            assert!(self.next_arrival < n, "no active, no waiting, not done");
            self.clock_ns =
                self.clock_ns.max(self.sessions[self.next_arrival].stats.arrival_ns);
            if self.overlapped {
                // the device frontier idles through the same gap — an
                // overlapped submit after the jump must not hide work
                // under time nobody computed through
                sim.advance_to(self.clock_ns);
            }
            return true;
        }
        if self.overlapped {
            self.arbitrate_round();
        }
        // phase 1: parallel session-local planning (after the arbiter,
        // so prepared predictions see their final grants)
        self.plan_round(pool);
        // phase 2: serial canonical commit, fixed session order
        let round_start = self.clock_ns;
        let k = self.active.len();
        let rot = self.round % k;
        for i in 0..k {
            let sid = self.active[(rot + i) % k];
            let cache_idx = if self.cfg.shared_cache { 0 } else { sid };
            let cache = &mut self.caches[cache_idx];
            if self.cfg.shared_cache {
                cache.set_session(sid as u32);
            }
            let sess = &mut self.sessions[sid];
            let prep = &mut self.preps[sid];
            let tok = &sess.trace.tokens[sess.next_token];
            // the i-th session's token starts only after its round
            // predecessors finish on the shared device
            let served_at = self.clock_ns;
            let io = if self.overlapped {
                sess.pipeline.step_token_overlapped_prepared(
                    cache,
                    sim,
                    tok,
                    self.compute_ns_per_layer,
                    prep,
                )
            } else {
                sess.pipeline.step_token_prepared(cache, sim, tok, prep)
            };
            self.clock_ns += io.stall_ns + self.compute_ns_per_token;
            let latency = self.clock_ns - round_start;
            sess.stats.record_token(&io, latency);
            sess.stats.record_service_split(
                io.stall_ns + self.compute_ns_per_token,
                served_at - round_start,
            );
            if let Some(trace) = &self.trace {
                let queue_ns = served_at - round_start;
                let compute = self.compute_ns_per_token;
                let t_sid = sid as u32;
                trace.with(|rec| {
                    rec.token(t_sid, round_start, queue_ns, io.stall_ns, compute, latency)
                });
            }
            self.serve.all_latency_ns.add(latency);
            self.agg.record(&io, self.bundle_bytes);
            self.agg.record_compute(self.compute_ns_per_token);
            sess.next_token += 1;
            if sess.next_token == sess.trace.n_tokens() {
                sess.stats.finished_ns = self.clock_ns;
                self.done += 1;
            }
        }
        // sessions leave between tokens; their slots refill next round.
        // Linear scan (no per-round scratch list, no quadratic
        // `contains` probe): a session stays active iff it has tokens
        // left.
        let sessions = &self.sessions;
        self.active
            .retain(|&sid| sessions[sid].next_token < sessions[sid].trace.n_tokens());
        self.round += 1;
        self.done < n
    }

    /// Seal the run: makespan, cache totals, per-session stats.
    pub fn finish(self) -> (RunMetrics, ServeMetrics) {
        let SessionManager { sessions, caches, clock_ns, agg, mut serve, .. } = self;
        serve.makespan_ns = clock_ns;
        for c in &caches {
            serve.cache_hits += c.hits;
            serve.cache_cross_hits += c.cross_hits;
        }
        serve.sessions = sessions.into_iter().map(|s| s.stats).collect();
        (agg, serve)
    }

    /// Run every session to completion against the shared flash
    /// timeline; returns (aggregate run metrics, serve metrics).
    pub fn run(self, sim: &mut UfsSim) -> (RunMetrics, ServeMetrics) {
        self.run_pooled(sim, &mut DecodePool::inline())
    }

    /// [`run`](Self::run) with a plan-phase pool (see
    /// [`step_round_pooled`](Self::step_round_pooled)); results are
    /// identical for every pool size.
    pub fn run_pooled(
        mut self,
        sim: &mut UfsSim,
        pool: &mut DecodePool<'_>,
    ) -> (RunMetrics, ServeMetrics) {
        while self.step_round_pooled(sim, pool) {}
        self.finish()
    }
}

/// Run a full serving simulation for a workload: placement once (one
/// model in flash serves everyone), one pipeline + trace per session,
/// one shared `UfsSim`, and a shared cache or equal-total private
/// partitions. With `w.prefetch.enabled` every session runs the
/// overlapped pipeline — speculation and demand from all sessions
/// contend through the shared device frontier — and a
/// [`PrefetchArbiter`] divides the global speculative byte budget
/// across the round's active sessions (`cfg.arbiter`,
/// `cfg.prefetch_global_budget`).
pub fn run_serve(
    w: &Workload,
    system: System,
    spec: SystemSpec,
    cfg: &ServeConfig,
) -> anyhow::Result<ServeOutcome> {
    run_serve_traced(w, system, spec, cfg, None)
}

/// [`run_serve`] with an optional flight recorder attached to the shared
/// flash sim and every session pipeline. `None` is exactly `run_serve`;
/// `Some` records spans/marks without changing any metric (the recorder
/// only observes virtual-time values the run already computes).
pub fn run_serve_traced(
    w: &Workload,
    system: System,
    spec: SystemSpec,
    cfg: &ServeConfig,
    trace: Option<&TraceHandle>,
) -> anyhow::Result<ServeOutcome> {
    anyhow::ensure!(cfg.sessions > 0, "serve needs at least one session");
    anyhow::ensure!(cfg.max_concurrent > 0, "serve needs at least one decode slot");
    anyhow::ensure!(
        !spec.dense,
        "dense streaming (llamacpp) has no per-session sparsity to share; \
         run it single-stream"
    );
    let calib = w.calibration_trace();
    let overlapped = w.prefetch.enabled;
    // prefetch-enabled ripple runs reuse the single-stream shared-scan
    // construction, so `sessions == 1` replays the single-stream
    // overlapped experiment bit-for-bit (pinned by harness_golden)
    let mut prefetcher: Option<Prefetcher> = None;
    let (layouts, placement_secs) = if overlapped && spec.ripple_placement {
        let t0 = Instant::now();
        let (layouts, pf) = workloads::ripple_overlapped_artifacts(w, &calib);
        prefetcher = Some(pf);
        (layouts, t0.elapsed().as_secs_f64())
    } else {
        layouts_for(system, &calib, w.knn, w.threads)
    };
    if overlapped && prefetcher.is_none() {
        // non-ripple placement: no shared scan to reuse
        prefetcher = Some(Prefetcher::from_trace(&calib, w.prefetch.clone(), w.threads));
    }
    let space = neuron_space(w);
    let bundle_bytes = space.bundle_bytes;
    let pcfg = workloads::pipeline_config(spec, w, None);
    let cap_total = cache_capacity(w);
    let n_caches = if cfg.shared_cache { 1 } else { cfg.sessions };
    // private partitions must sum to EXACTLY the shared capacity or the
    // shared-vs-private comparison is biased: spread the remainder of
    // the floor division over the first caches.
    let cap_of = |idx: usize| {
        if cfg.shared_cache {
            cap_total
        } else {
            cap_total / cfg.sessions + usize::from(idx < cap_total % cfg.sessions)
        }
    };
    let keys = KeySpace::of(&space);
    let caches: Vec<NeuronCache> = (0..n_caches)
        .map(|idx| {
            NeuronCache::from_config_with(
                spec.cache_policy,
                cap_of(idx),
                keys,
                w.seed,
                spec.cache_params,
            )
        })
        .collect::<anyhow::Result<_>>()?;
    let streams: Vec<(IoPipeline, Trace)> = (0..cfg.sessions)
        .map(|sid| {
            let mut pipeline = IoPipeline::new(pcfg.clone(), space.clone(), layouts.clone());
            if let Some(pf) = &prefetcher {
                pipeline.set_prefetcher(Some(pf.clone()));
            }
            (pipeline, w.session_eval_trace(&w.dataset, sid))
        })
        .collect();
    let compute_ns_per_token = w.compute_ns_per_layer * w.sim_layers as f64;
    let mut sim = UfsSim::new(w.device.clone(), space.image_bytes());
    let mut manager =
        SessionManager::new(cfg.clone(), streams, caches, compute_ns_per_token, bundle_bytes);
    if overlapped {
        let global = cfg
            .prefetch_global_budget
            .unwrap_or_else(|| w.prefetch.budget_bytes.saturating_mul(cfg.sessions));
        manager.enable_prefetch(w.compute_ns_per_layer, global);
    }
    if let Some(t) = trace {
        sim.set_trace(Some(t.clone()));
        manager.set_trace(Some(t.clone()));
    }
    let t_decode = Instant::now();
    let (metrics, mut serve) = with_decode_pool(cfg.decode_threads, |pool| {
        manager.run_pooled(&mut sim, pool)
    });
    let decode_wall_secs = t_decode.elapsed().as_secs_f64();
    let mut summary = serve.summary(w.layer_scale(), metrics.cache_hit_ratio());
    if overlapped {
        summary.prefetch_hit_bundles = metrics.totals.prefetch_hit_bundles;
        summary.prefetch_wasted_bundles = metrics.totals.prefetch_wasted_bundles;
        summary.session_prefetch = serve.prefetch_attribution(w.layer_scale(), bundle_bytes);
    }
    Ok(ServeOutcome {
        metrics,
        serve,
        summary,
        placement_secs,
        decode_wall_secs,
        bundle_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::tiny_workload;

    fn tiny_serve(cfg: ServeConfig) -> ServeOutcome {
        let mut w = tiny_workload();
        w.eval_tokens = 12;
        let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
        run_serve(&w, System::Ripple, spec, &cfg).unwrap()
    }

    #[test]
    fn all_sessions_complete_all_tokens() {
        let out = tiny_serve(ServeConfig { sessions: 3, ..Default::default() });
        assert_eq!(out.serve.sessions.len(), 3);
        for s in &out.serve.sessions {
            assert_eq!(s.tokens, 12);
            assert!(s.finished_ns > 0.0);
        }
        assert_eq!(out.metrics.tokens, 36);
        assert_eq!(out.summary.tokens, 36);
        assert!(out.summary.p99_ms >= out.summary.p50_ms);
        assert!(out.summary.makespan_ms > 0.0);
    }

    #[test]
    fn slots_bound_concurrency_and_queue_delay_appears() {
        let out = tiny_serve(ServeConfig {
            sessions: 5,
            max_concurrent: 2,
            ..Default::default()
        });
        assert!(out.serve.peak_active <= 2);
        // the first two sessions get slots at arrival; later ones wait
        assert_eq!(out.serve.sessions[0].queue_delay_ns, 0.0);
        assert_eq!(out.serve.sessions[1].queue_delay_ns, 0.0);
        assert!(out.serve.sessions[4].queue_delay_ns > 0.0);
        assert!(out.summary.mean_queue_delay_ms > 0.0);
    }

    #[test]
    fn staggered_arrivals_reduce_contention() {
        let packed = tiny_serve(ServeConfig {
            sessions: 4,
            max_concurrent: 4,
            arrival_spacing_ns: 0.0,
            shared_cache: true,
            ..Default::default()
        });
        let spread = tiny_serve(ServeConfig {
            sessions: 4,
            max_concurrent: 4,
            // huge spacing: sessions run essentially alone
            arrival_spacing_ns: 1e12,
            shared_cache: true,
            ..Default::default()
        });
        assert!(
            spread.summary.p95_ms <= packed.summary.p95_ms,
            "serial sessions must not see worse tails than packed ones: \
             {} vs {}",
            spread.summary.p95_ms,
            packed.summary.p95_ms
        );
        assert!(spread.summary.makespan_ms > packed.summary.makespan_ms);
    }

    #[test]
    fn serve_run_is_deterministic() {
        let cfg = ServeConfig { sessions: 4, max_concurrent: 3, ..Default::default() };
        let a = tiny_serve(cfg.clone());
        let b = tiny_serve(cfg);
        assert_eq!(
            a.metrics.totals.elapsed_ns.to_bits(),
            b.metrics.totals.elapsed_ns.to_bits()
        );
        assert_eq!(a.metrics.totals.commands, b.metrics.totals.commands);
        assert_eq!(a.summary.p99_ms.to_bits(), b.summary.p99_ms.to_bits());
        assert_eq!(a.summary.makespan_ms.to_bits(), b.summary.makespan_ms.to_bits());
        assert_eq!(a.summary.fairness.to_bits(), b.summary.fairness.to_bits());
    }

    #[test]
    fn serve_rejects_dense() {
        let mut w = tiny_workload();
        w.eval_tokens = 4;
        let dense = SystemSpec::of(System::LlamaCpp, w.model.ffn_linears);
        assert!(run_serve(&w, System::LlamaCpp, dense, &ServeConfig::default()).is_err());
    }

    fn tiny_prefetch_serve(cfg: ServeConfig) -> ServeOutcome {
        let mut w = tiny_workload();
        w.eval_tokens = 12;
        w.prefetch.enabled = true;
        let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
        run_serve(&w, System::Ripple, spec, &cfg).unwrap()
    }

    #[test]
    fn prefetch_serve_attributes_speculation_per_session() {
        let out = tiny_prefetch_serve(ServeConfig { sessions: 3, ..Default::default() });
        assert_eq!(out.summary.session_prefetch.len(), 3);
        // per-session attribution must sum to the aggregate totals
        let hits: u64 =
            out.summary.session_prefetch.iter().map(|r| r.prefetch_hit_bundles).sum();
        let waste: u64 =
            out.summary.session_prefetch.iter().map(|r| r.prefetch_wasted_bundles).sum();
        assert_eq!(hits, out.metrics.totals.prefetch_hit_bundles);
        assert_eq!(waste, out.metrics.totals.prefetch_wasted_bundles);
        assert_eq!(out.summary.prefetch_hit_bundles, hits);
        assert_eq!(out.summary.prefetch_wasted_bundles, waste);
        // the latency split reconstructs each session's mean latency
        for s in &out.serve.sessions {
            let split = s.mean_service_ns() + s.mean_round_queue_ns();
            assert!(
                (split - s.mean_latency_ns()).abs() < 1e-6 * s.mean_latency_ns().max(1.0),
                "split {split} vs latency {}",
                s.mean_latency_ns()
            );
        }
    }

    #[test]
    fn prefetch_off_summary_carries_no_attribution() {
        let out = tiny_serve(ServeConfig { sessions: 2, ..Default::default() });
        assert!(out.summary.session_prefetch.is_empty());
        assert_eq!(out.summary.prefetch_hit_bundles, 0);
        assert_eq!(out.summary.prefetch_wasted_bundles, 0);
    }

    #[test]
    fn deadline_arbiter_serve_is_deterministic() {
        let cfg = ServeConfig {
            sessions: 3,
            arbiter: ArbiterPolicy::DeadlineAware { target_ns: 5e5 },
            prefetch_global_budget: Some(64 * 1024),
            ..Default::default()
        };
        let a = tiny_prefetch_serve(cfg.clone());
        let b = tiny_prefetch_serve(cfg);
        assert_eq!(
            a.metrics.totals.elapsed_ns.to_bits(),
            b.metrics.totals.elapsed_ns.to_bits()
        );
        assert_eq!(a.metrics.totals.bytes, b.metrics.totals.bytes);
        assert_eq!(a.summary.p99_ms.to_bits(), b.summary.p99_ms.to_bits());
        assert_eq!(
            a.summary.prefetch_hit_bundles + a.summary.prefetch_wasted_bundles,
            b.summary.prefetch_hit_bundles + b.summary.prefetch_wasted_bundles
        );
    }

    #[test]
    fn pooled_serve_matches_serial_bit_for_bit() {
        let base = ServeConfig { sessions: 5, max_concurrent: 3, ..Default::default() };
        let a = tiny_serve(base.clone());
        let b = tiny_serve(ServeConfig { decode_threads: 4, ..base });
        assert_eq!(
            a.metrics.totals.elapsed_ns.to_bits(),
            b.metrics.totals.elapsed_ns.to_bits()
        );
        assert_eq!(a.metrics.totals.commands, b.metrics.totals.commands);
        assert_eq!(a.metrics.totals.bytes, b.metrics.totals.bytes);
        assert_eq!(a.summary.p99_ms.to_bits(), b.summary.p99_ms.to_bits());
        assert_eq!(a.summary.makespan_ms.to_bits(), b.summary.makespan_ms.to_bits());
        assert_eq!(a.summary.fairness.to_bits(), b.summary.fairness.to_bits());
    }

    #[test]
    fn pooled_prefetch_serve_matches_serial_bit_for_bit() {
        let base = ServeConfig {
            sessions: 3,
            prefetch_global_budget: Some(64 * 1024),
            ..Default::default()
        };
        let a = tiny_prefetch_serve(base.clone());
        let b = tiny_prefetch_serve(ServeConfig { decode_threads: 8, ..base });
        assert_eq!(
            a.metrics.totals.elapsed_ns.to_bits(),
            b.metrics.totals.elapsed_ns.to_bits()
        );
        assert_eq!(a.metrics.totals.bytes, b.metrics.totals.bytes);
        assert_eq!(a.summary.prefetch_hit_bundles, b.summary.prefetch_hit_bundles);
        assert_eq!(a.summary.prefetch_wasted_bundles, b.summary.prefetch_wasted_bundles);
        assert_eq!(a.summary.p99_ms.to_bits(), b.summary.p99_ms.to_bits());
    }

    #[test]
    fn private_caches_never_cross_hit() {
        let out = tiny_serve(ServeConfig {
            sessions: 3,
            shared_cache: false,
            ..Default::default()
        });
        assert_eq!(out.serve.cache_cross_hits, 0);
        assert_eq!(out.summary.cross_session_hit_ratio, 0.0);
        assert!(!out.summary.shared_cache);
    }
}
