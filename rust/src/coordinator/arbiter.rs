//! Per-round arbitration of the global speculative-prefetch byte budget
//! across serving sessions (DESIGN.md §Serving).
//!
//! Every active session would happily speculate up to its configured
//! per-submission budget, but the sessions share ONE serial flash
//! device: unchecked speculation from k sessions multiplies the wasted
//! device busy time k-fold and queues everyone's demand reads behind
//! it. The arbiter divides a *global* byte budget across the round's
//! active sessions before any token is served; each session's grant
//! caps its speculative submissions for that round
//! ([`crate::pipeline::IoPipeline::set_prefetch_grant`]).
//!
//! Both policies are work-conserving — share a session cannot use
//! (its demand is below its fair cut, or it has nothing left to
//! speculate on) flows to sessions that can — and deterministic: ties
//! break by session index, and all arithmetic is integer bytes, so the
//! serving timeline stays bit-replayable.

/// Budget-division policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArbiterPolicy {
    /// Iterative water-fill: every open session receives an equal cut
    /// of the remainder until demands are met or the budget drains.
    /// Identical sessions receive equal grants (up to one byte of
    /// integer remainder).
    FairShare,
    /// Sessions closest to (or past) the per-token latency target are
    /// filled first, each up to its full demand, until the budget
    /// drains. Urgency is the session's mean per-token latency relative
    /// to `target_ns`.
    DeadlineAware {
        /// Per-token latency target in nanoseconds.
        target_ns: f64,
    },
}

/// One session's standing in the round, as seen by the arbiter.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionDemand {
    /// Bytes of speculation the session could use this round (its
    /// configured per-submission budget; 0 when it cannot speculate).
    pub demand_bytes: usize,
    /// Mean per-token latency observed so far, ns (0 before the first
    /// served token). Only the deadline-aware policy reads this.
    pub mean_latency_ns: f64,
}

/// Divides a global speculative byte budget across sessions each round.
/// The grant buffers are reused call-to-call, so arbitration in the
/// steady-state serve loop is allocation-free.
#[derive(Clone, Debug)]
pub struct PrefetchArbiter {
    policy: ArbiterPolicy,
    global_budget_bytes: usize,
    grants: Vec<usize>,
    order: Vec<usize>,
}

impl PrefetchArbiter {
    pub fn new(policy: ArbiterPolicy, global_budget_bytes: usize) -> Self {
        Self { policy, global_budget_bytes, grants: Vec::new(), order: Vec::new() }
    }

    /// Pre-size the reusable buffers for up to `n` concurrent sessions.
    pub fn reserve(&mut self, n: usize) {
        self.grants.reserve(n);
        self.order.reserve(n);
    }

    pub fn policy(&self) -> ArbiterPolicy {
        self.policy
    }

    pub fn global_budget_bytes(&self) -> usize {
        self.global_budget_bytes
    }

    /// Divide the global budget across `demands`. Returns one grant per
    /// session, in bytes; `grants[i] <= demands[i].demand_bytes` and
    /// the grants sum to `min(global_budget, Σ demand)`.
    pub fn arbitrate(&mut self, demands: &[SessionDemand]) -> &[usize] {
        self.grants.clear();
        self.grants.resize(demands.len(), 0);
        if !demands.is_empty() && self.global_budget_bytes > 0 {
            match self.policy {
                ArbiterPolicy::FairShare => self.fair_share(demands),
                ArbiterPolicy::DeadlineAware { target_ns } => {
                    self.deadline_aware(demands, target_ns)
                }
            }
        }
        &self.grants
    }

    fn fair_share(&mut self, demands: &[SessionDemand]) {
        let mut remaining = self.global_budget_bytes;
        loop {
            let open = demands
                .iter()
                .zip(&self.grants)
                .filter(|(d, g)| d.demand_bytes > **g)
                .count();
            if open == 0 || remaining == 0 {
                return;
            }
            let share = remaining / open;
            if share == 0 {
                // fewer bytes left than open sessions: hand the integer
                // remainder out a byte at a time, in session order
                for (i, d) in demands.iter().enumerate() {
                    if remaining == 0 {
                        return;
                    }
                    if d.demand_bytes > self.grants[i] {
                        self.grants[i] += 1;
                        remaining -= 1;
                    }
                }
                return;
            }
            for (i, d) in demands.iter().enumerate() {
                let headroom = d.demand_bytes - self.grants[i].min(d.demand_bytes);
                let take = headroom.min(share);
                self.grants[i] += take;
                remaining -= take;
            }
        }
    }

    fn deadline_aware(&mut self, demands: &[SessionDemand], target_ns: f64) {
        self.order.clear();
        self.order.extend(0..demands.len());
        let target = target_ns.max(1.0);
        self.order.sort_unstable_by(|&a, &b| {
            let ua = demands[a].mean_latency_ns / target;
            let ub = demands[b].mean_latency_ns / target;
            // most urgent first; session index breaks ties so the
            // schedule is deterministic
            ub.partial_cmp(&ua).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        let mut remaining = self.global_budget_bytes;
        for &i in &self.order {
            let take = demands[i].demand_bytes.min(remaining);
            self.grants[i] = take;
            remaining -= take;
            if remaining == 0 {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demand(bytes: usize) -> SessionDemand {
        SessionDemand { demand_bytes: bytes, mean_latency_ns: 0.0 }
    }

    #[test]
    fn fair_share_splits_equally_and_caps_at_demand() {
        let mut a = PrefetchArbiter::new(ArbiterPolicy::FairShare, 900);
        let g = a.arbitrate(&[demand(400), demand(400), demand(400)]);
        assert_eq!(g, &[300, 300, 300]);
        // demand below the fair cut frees share for the others
        let g = a.arbitrate(&[demand(100), demand(400), demand(400)]);
        assert_eq!(g, &[100, 400, 400]);
    }

    #[test]
    fn fair_share_integer_remainder_stays_within_one_byte() {
        let mut a = PrefetchArbiter::new(ArbiterPolicy::FairShare, 1000);
        let g = a.arbitrate(&[demand(500), demand(500), demand(500)]);
        assert_eq!(g.iter().sum::<usize>(), 1000);
        let (lo, hi) = (*g.iter().min().unwrap(), *g.iter().max().unwrap());
        assert!(hi - lo <= 1, "grants {g:?}");
    }

    #[test]
    fn single_session_gets_min_of_budget_and_demand() {
        let mut a = PrefetchArbiter::new(ArbiterPolicy::FairShare, 256 * 1024);
        assert_eq!(a.arbitrate(&[demand(256 * 1024)]), &[256 * 1024]);
        assert_eq!(a.arbitrate(&[demand(64)]), &[64]);
        let mut d = PrefetchArbiter::new(
            ArbiterPolicy::DeadlineAware { target_ns: 1e6 },
            256 * 1024,
        );
        assert_eq!(d.arbitrate(&[demand(256 * 1024)]), &[256 * 1024]);
    }

    #[test]
    fn deadline_aware_fills_most_urgent_first() {
        let mut a =
            PrefetchArbiter::new(ArbiterPolicy::DeadlineAware { target_ns: 1e6 }, 500);
        let g = a.arbitrate(&[
            SessionDemand { demand_bytes: 400, mean_latency_ns: 5e5 },
            SessionDemand { demand_bytes: 400, mean_latency_ns: 2e6 },
            SessionDemand { demand_bytes: 400, mean_latency_ns: 9e5 },
        ]);
        // session 1 is past the deadline: full demand; session 2 is
        // next-closest and takes the remainder; session 0 starves
        assert_eq!(g, &[0, 400, 100]);
    }

    #[test]
    fn deadline_aware_ties_break_by_session_index() {
        let mut a =
            PrefetchArbiter::new(ArbiterPolicy::DeadlineAware { target_ns: 1e6 }, 300);
        let g = a.arbitrate(&[
            SessionDemand { demand_bytes: 200, mean_latency_ns: 1e6 },
            SessionDemand { demand_bytes: 200, mean_latency_ns: 1e6 },
        ]);
        assert_eq!(g, &[200, 100]);
    }

    #[test]
    fn empty_and_zero_budget_rounds_grant_nothing() {
        let mut a = PrefetchArbiter::new(ArbiterPolicy::FairShare, 0);
        assert_eq!(a.arbitrate(&[demand(100)]), &[0]);
        let mut b = PrefetchArbiter::new(ArbiterPolicy::FairShare, 100);
        assert!(b.arbitrate(&[]).is_empty());
        assert_eq!(b.arbitrate(&[demand(0), demand(0)]), &[0, 0]);
    }
}
