//! Persistent scoped worker pool for the parallel plan phase of a
//! decode round (DESIGN.md §Parallel-decode).
//!
//! The serving and fleet simulators split every decode round into a
//! **parallel plan phase** — each active session computes its own
//! sorted slot lists and speculative predictions into per-session
//! scratch, touching no shared state — and a **serial commit phase**
//! that replays the round in canonical session order against the
//! shared cache and flash timeline. The pool below runs phase 1; it is
//! deliberately tiny and dependency-free:
//!
//! * [`with_decode_pool`] parks `threads - 1` workers inside a
//!   `std::thread::scope`, so worker threads may borrow the caller's
//!   stack (the session vectors live on it) and are always joined
//!   before the scope returns — even on panic.
//! * [`DecodePool::run`] publishes one round of `n` index jobs. The
//!   publishing thread claims jobs too, so `threads == 1` with a pool
//!   attached degenerates to the plain serial loop.
//! * Rounds are claimed from a single packed atomic word
//!   `(epoch << 32) | next_index`. The epoch tag makes a stale worker
//!   (one that raced past the end of a previous round) fail its CAS
//!   and go back to sleep instead of claiming an index of a round it
//!   never saw.
//! * The round handshake uses one mutex + two condvars (futex-backed
//!   on Linux), so the steady state allocates nothing — the
//!   zero-allocation decode gate runs a full pooled round under the
//!   counting allocator.
//!
//! Determinism note: the pool only ever executes *pure per-index*
//! work. Nothing about scheduling order can leak into results; the
//! commit phase is the only writer of shared state and runs in fixed
//! session order on the coordinator thread.

use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

/// One published round: a type-erased `Fn(usize)` plus the number of
/// index jobs. The closure is erased through a data pointer and a
/// monomorphized trampoline rather than a `dyn` fat pointer so the
/// word fits in a `Copy` struct the workers can lift out of the mutex.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    n: usize,
}

// Safety: `data` points at an `F: Sync` owned by the publishing
// thread, which blocks until every index job finished; workers only
// ever form `&F` from it (see `trampoline`).
unsafe impl Send for Job {}

unsafe fn trampoline<F: Fn(usize) + Sync>(data: *const (), idx: usize) {
    let f = unsafe { &*(data as *const F) };
    f(idx);
}

struct PoolState {
    job: Option<Job>,
    /// Round counter; bumped by the publisher before workers wake.
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers park here between rounds.
    wake: Condvar,
    /// The publisher parks here until `finished == n`.
    done: Condvar,
    /// Packed `(epoch & 0xFFFF_FFFF) << 32 | next_index` claim word.
    claim: AtomicU64,
    finished: AtomicUsize,
    panicked: AtomicBool,
}

fn lock(m: &Mutex<PoolState>) -> MutexGuard<'_, PoolState> {
    // a worker panic already poisons nothing we rely on (all round
    // state is atomics); keep going so the publisher can re-raise
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Shared {
    fn new() -> Self {
        Shared {
            state: Mutex::new(PoolState { job: None, epoch: 0, shutdown: false }),
            wake: Condvar::new(),
            done: Condvar::new(),
            claim: AtomicU64::new(0),
            finished: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        }
    }
}

/// Claim and execute index jobs of `epoch`'s round until the round is
/// exhausted (or superseded). Runs on workers *and* the publisher.
fn run_jobs(shared: &Shared, job: &Job, epoch: u64) {
    let tag = (epoch & 0xFFFF_FFFF) << 32;
    loop {
        let cur = shared.claim.load(Ordering::Acquire);
        if cur & !0xFFFF_FFFF != tag {
            // a newer round was published; this thread is late — the
            // epoch check means it can never claim into a round whose
            // closure it did not lift out of the mutex itself
            return;
        }
        let idx = (cur & 0xFFFF_FFFF) as usize;
        if idx >= job.n {
            return;
        }
        if shared
            .claim
            .compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            continue;
        }
        // keep draining the round even if one index panics: the
        // finished count must still reach n for the handshake to
        // complete; the publisher re-raises afterwards
        if catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.data, idx) })).is_err() {
            shared.panicked.store(true, Ordering::Release);
        }
        let done = shared.finished.fetch_add(1, Ordering::AcqRel) + 1;
        if done == job.n {
            // lock-then-notify: the publisher checks `finished` while
            // holding the state lock, so acquiring it here cannot
            // interleave between its check and its wait — no lost
            // wakeup
            drop(lock(&shared.state));
            shared.done.notify_all();
        }
    }
}

fn worker(shared: &Shared) {
    let mut seen: u64 = 0;
    loop {
        let (job, epoch) = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                match st.job {
                    // `job` can be None with a fresh epoch when this
                    // worker slept through an entire round (the
                    // publisher clears it at round end) — keep waiting
                    Some(j) if st.epoch != seen => break (j, st.epoch),
                    _ => st = shared.wake.wait(st).unwrap_or_else(|e| e.into_inner()),
                }
            }
        };
        seen = epoch;
        run_jobs(shared, &job, epoch);
    }
}

/// Ensure workers are released even if the pool user panics: dropped
/// inside the `thread::scope`, before the scope joins.
struct Shutdown<'a>(&'a Shared);

impl Drop for Shutdown<'_> {
    fn drop(&mut self) {
        let mut st = lock(&self.0.state);
        st.shutdown = true;
        drop(st);
        self.0.wake.notify_all();
    }
}

/// Handle to the plan-phase worker pool (or the inline no-pool stand-in).
///
/// Obtained from [`with_decode_pool`]; the coordinators thread it
/// through their round loops and call [`run`](Self::run) once per
/// parallel plan phase.
pub struct DecodePool<'scope> {
    shared: Option<&'scope Shared>,
    threads: usize,
}

impl DecodePool<'_> {
    /// A pool-less handle: [`run`](Self::run) executes jobs inline, in
    /// index order, on the calling thread. This is the stand-in the
    /// serial entry points (`step_round`, `run`) use, so the
    /// single-threaded code path is *literally* the historical one.
    pub fn inline() -> Self {
        DecodePool { shared: None, threads: 1 }
    }

    /// Worker count this handle fans out to (1 for [`inline`](Self::inline)).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(0..n)` with every index running exactly once, then
    /// return. `f` must be safe to call concurrently for *distinct*
    /// indices (the coordinators guarantee index-disjoint data via
    /// [`DisjointSlice`]). No result ordering exists — `f` must write
    /// only to its own index's slot.
    pub fn run<F: Fn(usize) + Sync>(&mut self, n: usize, f: F) {
        let Some(shared) = self.shared else {
            for i in 0..n {
                f(i);
            }
            return;
        };
        if n == 0 {
            return;
        }
        assert!(n < u32::MAX as usize, "round too large for the packed claim word");
        let job = Job { data: (&f as *const F).cast::<()>(), call: trampoline::<F>, n };
        let epoch;
        {
            let mut st = lock(&shared.state);
            st.epoch = st.epoch.wrapping_add(1);
            epoch = st.epoch;
            st.job = Some(job);
            shared.finished.store(0, Ordering::Release);
            // publish the claim word last-ish (still under the lock):
            // stale workers CAS against the old tag and fail
            shared
                .claim
                .store((epoch & 0xFFFF_FFFF) << 32, Ordering::Release);
        }
        shared.wake.notify_all();
        // the publishing thread is worker #0 of the round
        run_jobs(shared, &job, epoch);
        let mut st = lock(&shared.state);
        while shared.finished.load(Ordering::Acquire) < n {
            st = shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        drop(st);
        if shared.panicked.swap(false, Ordering::AcqRel) {
            panic!("decode pool worker panicked");
        }
    }
}

/// Run `f` with a decode pool of `threads` total threads (the calling
/// thread plus `threads - 1` scoped workers). `threads <= 1` skips
/// thread creation entirely and hands `f` an inline pool, so callers
/// can pass the configured `decode_threads` straight through.
pub fn with_decode_pool<R>(threads: usize, f: impl FnOnce(&mut DecodePool<'_>) -> R) -> R {
    if threads <= 1 {
        return f(&mut DecodePool::inline());
    }
    let shared = Shared::new();
    std::thread::scope(|scope| {
        for _ in 0..threads - 1 {
            scope.spawn(|| worker(&shared));
        }
        let _release = Shutdown(&shared);
        let mut pool = DecodePool { shared: Some(&shared), threads };
        f(&mut pool)
    })
}

/// Shared view over a `&mut [T]` whose elements are written by at most
/// one concurrent index job each.
///
/// The plan phase hands every session's `Session` + `TokenPrep` to
/// exactly one pool job (sessions appear at most once in the active
/// list — they are session *ids*), so per-index access is exclusive
/// even though the jobs share one slice. `get` returns a raw pointer
/// rather than `&mut T` so the aliasing obligation sits visibly on the
/// caller's `unsafe` block.
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// Safety: access is index-disjoint by the caller's contract on `get`;
// moving/sharing the view across the scoped workers is then no more
// than sharing `&mut [T]` split element-wise.
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// Pointer to element `idx` (bounds-checked).
    ///
    /// # Safety
    /// The caller must guarantee no two concurrent users dereference
    /// the same `idx`, and that dereferences do not outlive `'a`.
    pub unsafe fn get(&self, idx: usize) -> *mut T {
        assert!(idx < self.len, "DisjointSlice index out of bounds");
        unsafe { self.ptr.add(idx) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_pool_runs_every_job_in_order() {
        let mut pool = DecodePool::inline();
        assert_eq!(pool.threads(), 1);
        let log = Mutex::new(Vec::new());
        pool.run(5, |i| log.lock().unwrap().push(i));
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn scoped_pool_runs_each_index_exactly_once_across_rounds() {
        for threads in [2, 3, 8] {
            with_decode_pool(threads, |pool| {
                assert_eq!(pool.threads(), threads);
                let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
                // epoch reuse: many rounds through one pool
                for _round in 0..50 {
                    for h in &hits {
                        h.store(0, Ordering::Relaxed);
                    }
                    pool.run(hits.len(), |i| {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    });
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} not exactly-once");
                    }
                }
            });
        }
    }

    #[test]
    fn pool_handles_more_threads_than_jobs_and_empty_rounds() {
        with_decode_pool(8, |pool| {
            let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            pool.run(0, |_| unreachable!("empty round must not invoke jobs"));
            for h in &hits {
                assert_eq!(h.load(Ordering::Relaxed), 1);
            }
        });
    }

    #[test]
    fn disjoint_slice_parallel_writes_all_land() {
        let mut data = vec![0usize; 256];
        with_decode_pool(4, |pool| {
            let view = DisjointSlice::new(&mut data);
            // Safety: each index is claimed exactly once per round.
            pool.run(256, |i| unsafe { *view.get(i) = i * 3 });
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i * 3);
        }
    }

    #[test]
    fn worker_panic_propagates_to_publisher() {
        let caught = catch_unwind(|| {
            with_decode_pool(2, |pool| {
                pool.run(4, |i| {
                    if i == 2 {
                        panic!("boom");
                    }
                });
            });
        });
        assert!(caught.is_err(), "pool must re-raise worker panics");
    }
}
