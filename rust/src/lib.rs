//! RIPPLE: correlation-aware neuron management for LLM inference on
//! smartphones — a full reproduction of the paper's system.
//!
//! Three-layer architecture (see DESIGN.md):
//! - L3 (this crate): coordinator — flash simulator, neuron placement,
//!   access collapse, linking-aligned caching, batching/serving.
//! - L2: JAX model blocks AOT-lowered to HLO text (python/compile).
//! - L1: Pallas sparse-FFN kernel inside those artifacts.

pub mod access;
pub mod bench;
pub mod cache;
pub mod coact;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod flash;
pub mod harness;
pub mod metrics;
pub mod neuron;
pub mod obs;
pub mod persist;
pub mod pipeline;
pub mod placement;
pub mod prefetch;
pub mod runtime;
pub mod trace;
pub mod util;
