//! The per-token I/O pipeline (paper Figure 7, online half):
//!
//!   activated bundles -> layout (bundle->slot) -> cache filter
//!     -> prefetch reconciliation -> run planning -> access collapse
//!     -> flash batch -> cache admission -> adaptive-controller feedback
//!
//! The same pipeline object serves both the trace-driven paper benches
//! (timing-only `step_token`) and the real PJRT engine (`plan_layer` +
//! `commit_layer`, which also return the byte-level commands so the
//! engine can read actual weights).
//!
//! # Overlapped mode (DESIGN.md §Async-flash-timeline)
//!
//! With a [`Prefetcher`] attached, the pipeline splits each layer's
//! commit into `submit_layer` / `complete_layer` and, between them,
//! issues speculative reads for upcoming layers (`prefetch_layer`) on
//! the simulator's async device timeline. `plan_layer` treats demanded
//! slots covered by an in-flight speculative batch as *prefetched* —
//! they are excluded from the demand batch; `complete_layer` then waits
//! the speculative ticket (charging only the time compute did not hide),
//! admits the speculative runs into the DRAM cache, and reconciles
//! hit/waste counters. With no prefetcher attached every code path is
//! bit-identical to the historical synchronous pipeline.
//!
//! # Shared-state ownership (DESIGN.md §Serving)
//!
//! The pipeline owns only *per-stream* planner state (layouts, the
//! adaptive collapse controller, speculation bookkeeping). The DRAM
//! neuron cache and the flash timeline are **borrowed** per call —
//! multi-session serving drives N pipelines through one shared
//! [`NeuronCache`] and one shared [`UfsSim`], which is exactly the
//! contention the paper's single-stream model cannot express. A
//! single-tenant caller simply keeps one cache + sim next to its one
//! pipeline; every code path is bit-identical to the historical
//! cache-owning pipeline.

use crate::access::{collapse_runs_into, plan_runs_into, plan_volume, AdaptiveCollapse, SlotRun};
use crate::cache::NeuronCache;
use crate::config::RunConfig;
use crate::flash::{ReadCmd, Ticket, UfsSim};
use crate::metrics::TokenIo;
use crate::neuron::{BundleId, Layout, NeuronSpace, Slot};
use crate::obs::{MarkKind, Phase, TraceHandle, Track};
use crate::prefetch::{PredictScratch, Prefetcher};

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub bundle_bytes: usize,
    /// Access collapse enabled (RIPPLE online stage).
    pub collapse: bool,
    pub initial_threshold: u32,
    /// Cap on the gap threshold, in bundles. Defaults to the device
    /// knee size / bundle size: beyond that the gap fill costs more
    /// than the command it saves even in the fully IOPS-bound regime.
    pub max_threshold: u32,
    /// Adaptive-controller window, tokens.
    pub window: usize,
    /// Commands issued per planned run: 1 when neurons are stored as
    /// bundles (LLMFlash, RIPPLE); `ffn_linears` for the Llama.cpp
    /// baseline, whose up/down(/gate) rows live in separate matrix
    /// regions and need separate reads.
    pub sub_reads_per_run: usize,
}

impl PipelineConfig {
    pub fn from_run(cfg: &RunConfig) -> Self {
        let bundle_bytes = cfg.model.bundle_bytes(cfg.precision);
        let knee = cfg.device.knee_bytes();
        let max_threshold = ((knee / bundle_bytes as f64) as u32).max(1);
        Self {
            bundle_bytes,
            collapse: cfg.collapse,
            initial_threshold: cfg.collapse_threshold as u32,
            max_threshold,
            window: 16,
            sub_reads_per_run: 1,
        }
    }
}

/// One layer's planned I/O. The buffers are reusable: the pipeline's
/// step loops keep ONE plan alive and refill it per layer
/// ([`IoPipeline::plan_layer_into`]), so the steady-state decode path
/// allocates nothing (§Perf).
#[derive(Clone, Debug, Default)]
pub struct LayerPlan {
    pub layer: usize,
    /// Demanded slots served by DRAM cache.
    pub cached: Vec<Slot>,
    /// Demanded slots covered by an in-flight speculative prefetch
    /// (empty unless a prefetcher is attached and speculation is live).
    pub prefetched: Vec<Slot>,
    /// Demanded slots that must be read.
    pub missed: Vec<Slot>,
    /// Post-collapse read runs covering all missed slots.
    pub runs: Vec<SlotRun>,
    /// Byte-level commands for the flash sim (sub_reads applied).
    pub commands: Vec<ReadCmd>,
}

impl LayerPlan {
    /// Retarget the plan at `layer`, keeping every buffer's capacity.
    fn reset(&mut self, layer: usize) {
        self.layer = layer;
        self.cached.clear();
        self.prefetched.clear();
        self.missed.clear();
        self.runs.clear();
        self.commands.clear();
    }
}

/// A speculative batch in flight for one upcoming layer.
struct OutstandingPrefetch {
    runs: Vec<SlotRun>,
    ticket: Ticket,
}

impl OutstandingPrefetch {
    fn covers(&self, slot: Slot) -> bool {
        // runs are sorted and disjoint
        self.runs
            .binary_search_by(|r| {
                if slot < r.start {
                    std::cmp::Ordering::Greater
                } else if slot >= r.end() {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Equal
                }
            })
            .is_ok()
    }
}

/// One lookahead prediction computed ahead of the serial commit,
/// tagged with the inputs it was computed from so consumption can
/// prove it equals what the inline path would compute.
#[derive(Default)]
struct PreparedPrediction {
    /// Layer whose activations seeded the prediction (`cur_actives`).
    issuer: usize,
    /// Slot budget the prediction was capped at.
    budget: usize,
    /// Predicted bundles for the target layer.
    predicted: Vec<BundleId>,
    /// True until consumed (or never computed this token).
    valid: bool,
}

/// Phase-1 planning work for one token (DESIGN.md §Parallel-decode):
/// everything computable from per-stream state alone — the sorted
/// demanded slot list per layer and the predictor's lookahead
/// predictions — without touching the shared cache or flash timeline.
///
/// A prep is filled by [`IoPipeline::prepare_token`] (on a plan worker)
/// and consumed by the `*_prepared` step variants during the serial
/// commit. Consumption is validated: an entry whose inputs cannot be
/// proven identical to what the inline path would use is recomputed
/// inline, so stepping with a prep NEVER changes results — it only
/// moves work off the commit thread. Buffers keep their capacity
/// across tokens, so steady-state preparation is allocation-free.
#[derive(Default)]
pub struct TokenPrep {
    /// Sorted demanded slots per layer (`slots_for_into` output).
    slots: Vec<Vec<Slot>>,
    /// Per-layer validity of `slots`.
    slots_valid: Vec<bool>,
    /// Prepared predictions, indexed by target layer.
    preds: Vec<PreparedPrediction>,
}

impl TokenPrep {
    /// Retarget at `n_layers`, keeping every buffer's capacity.
    fn reset(&mut self, n_layers: usize) {
        if self.slots.len() < n_layers {
            self.slots.resize_with(n_layers, Vec::new);
            self.slots_valid.resize(n_layers, false);
            self.preds.resize_with(n_layers, PreparedPrediction::default);
        }
        for v in &mut self.slots_valid {
            *v = false;
        }
        for p in &mut self.preds {
            p.valid = false;
        }
    }

    /// Swap the prepared slot list for `layer` into `dst`, if present.
    /// The slot list is a pure function of the layout and the token's
    /// activations, so the substitution is always exact.
    fn take_slots(&mut self, layer: usize, dst: &mut Vec<Slot>) -> bool {
        match self.slots_valid.get_mut(layer) {
            Some(v) if *v => {
                *v = false;
                std::mem::swap(dst, &mut self.slots[layer]);
                true
            }
            _ => false,
        }
    }

    /// Swap the prepared prediction for `target` into `dst` — only when
    /// its (issuer, budget) tag proves it was computed from the same
    /// seeds and cap the inline path would use right now.
    fn take_prediction(
        &mut self,
        issuer: usize,
        target: usize,
        budget: usize,
        dst: &mut Vec<BundleId>,
    ) -> bool {
        match self.preds.get_mut(target) {
            Some(p) if p.valid && p.issuer == issuer && p.budget == budget => {
                p.valid = false;
                std::mem::swap(dst, &mut p.predicted);
                true
            }
            _ => false,
        }
    }
}

/// Reusable per-token buffers (§Perf): every intermediate vector of the
/// decode hot path lives here and is cleared between uses, never
/// dropped — after warmup a token costs zero heap allocations
/// (pinned by `rust/tests/zero_alloc_decode.rs`).
#[derive(Default)]
struct StepScratch {
    /// The step loops' reusable per-layer plan.
    plan: LayerPlan,
    /// Demanded slots after layout mapping (sorted).
    slots: Vec<Slot>,
    /// Cache-filter miss output, before the speculation peel.
    missed_all: Vec<Slot>,
    /// Pre-collapse runs of the demand path.
    base_runs: Vec<SlotRun>,
    /// Prefetch path: predicted bundles for one target layer.
    predicted: Vec<BundleId>,
    /// Prefetch path: non-resident predicted slots (sorted).
    pf_slots: Vec<Slot>,
    /// Prefetch path: pre-collapse speculative runs.
    pf_base_runs: Vec<SlotRun>,
    /// Prefetch path: lowered speculative commands.
    pf_cmds: Vec<ReadCmd>,
    /// Dense scoring buffers for the predictor.
    predict: PredictScratch,
    /// Free pool of run buffers cycling through in-flight speculation.
    run_pool: Vec<Vec<SlotRun>>,
}

pub struct IoPipeline {
    cfg: PipelineConfig,
    space: NeuronSpace,
    layouts: Vec<Layout>,
    adaptive: AdaptiveCollapse,
    prefetcher: Option<Prefetcher>,
    /// Per-round byte grant from a serving arbiter: caps each
    /// speculative submission below the configured budget. `None`
    /// (single-tenant) leaves the configured budget untouched.
    prefetch_grant: Option<usize>,
    /// Speculative batches in flight, indexed by target layer.
    outstanding: Vec<Option<OutstandingPrefetch>>,
    /// Previous token's activation set per layer — predictor seed.
    /// Buffers are cleared and refilled in place, never cloned.
    last_actives: Vec<Vec<BundleId>>,
    /// Reusable per-token buffers (§Perf).
    scratch: StepScratch,
    /// Optional flight recorder: speculation spans and plan/commit marks
    /// on this stream's session track. `None` records nothing.
    trace: Option<TraceHandle>,
    /// Session id this pipeline's trace events are attributed to.
    trace_sid: u32,
}

/// Lower planned runs to byte-level commands (sub_reads applied) into a
/// reusable buffer. Free function so callers can hold disjoint borrows
/// of the pipeline's other fields.
fn lower_runs_into(
    cfg: &PipelineConfig,
    space: &NeuronSpace,
    layer: usize,
    runs: &[SlotRun],
    cmds: &mut Vec<ReadCmd>,
) {
    cmds.clear();
    let bb = cfg.bundle_bytes;
    let sub = cfg.sub_reads_per_run.max(1);
    for r in runs {
        let (offset, _) = space.slot_range(layer, r.start);
        let total = r.len as usize * bb;
        // sub_reads > 1 models unbundled storage: the run's bytes are
        // split across `sub` matrix regions read separately.
        let part = total / sub;
        for i in 0..sub {
            let len = if i + 1 == sub { total - part * (sub - 1) } else { part };
            if len > 0 {
                cmds.push(ReadCmd { offset: offset + (i * part) as u64, len });
            }
        }
    }
}

impl IoPipeline {
    pub fn new(cfg: PipelineConfig, space: NeuronSpace, layouts: Vec<Layout>) -> Self {
        assert_eq!(layouts.len(), space.n_layers);
        for l in &layouts {
            assert_eq!(l.len(), space.per_layer);
        }
        let adaptive =
            AdaptiveCollapse::new(cfg.initial_threshold, cfg.max_threshold, cfg.window);
        let last_actives = vec![Vec::new(); space.n_layers];
        let outstanding = (0..space.n_layers).map(|_| None).collect();
        // §Perf: reserve every per-token buffer at its hard bound (a
        // layer can demand at most `per_layer` slots), so the decode hot
        // path never allocates — not even on the very first token.
        let n = space.per_layer;
        let sub = cfg.sub_reads_per_run.max(1);
        let mut scratch = StepScratch::default();
        scratch.plan.cached.reserve(n);
        scratch.plan.prefetched.reserve(n);
        scratch.plan.missed.reserve(n);
        scratch.plan.runs.reserve(n);
        scratch.plan.commands.reserve(n * sub);
        scratch.slots.reserve(n);
        scratch.missed_all.reserve(n);
        scratch.base_runs.reserve(n);
        Self {
            cfg,
            space,
            layouts,
            adaptive,
            prefetcher: None,
            prefetch_grant: None,
            outstanding,
            last_actives,
            scratch,
            trace: None,
            trace_sid: 0,
        }
    }

    /// Attach (or detach) a flight recorder, attributing this stream's
    /// events to session `sid`'s track. Tracing never changes planning,
    /// timing, or cache behaviour.
    pub fn set_trace(&mut self, trace: Option<TraceHandle>, sid: u32) {
        self.trace = trace;
        self.trace_sid = sid;
    }

    fn trace_mark(&self, kind: MarkKind, t_ns: f64, value: f64, aux: f64) {
        if let Some(trace) = &self.trace {
            let sid = self.trace_sid;
            trace.with(|rec| rec.mark(Track::Session(sid), kind, t_ns, value, aux));
        }
    }

    fn trace_span(&self, phase: Phase, t_ns: f64, dur_ns: f64) {
        if let Some(trace) = &self.trace {
            let sid = self.trace_sid;
            trace.with(|rec| rec.span(Track::Session(sid), phase, t_ns, dur_ns));
        }
    }

    pub fn layouts(&self) -> &[Layout] {
        &self.layouts
    }

    pub fn space(&self) -> &NeuronSpace {
        &self.space
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Attach (or detach) the speculative prefetcher. The predictor's
    /// layer geometry must match the pipeline's.
    pub fn set_prefetcher(&mut self, pf: Option<Prefetcher>) {
        if let Some(p) = &pf {
            assert_eq!(p.n_layers(), self.space.n_layers, "prefetcher layer mismatch");
            assert_eq!(p.per_layer(), self.space.per_layer, "prefetcher width mismatch");
            // pre-size the dense scoring buffers and the speculation
            // scratch at their hard bounds so even the first prediction
            // is allocation-free (§Perf)
            self.scratch.predict = p.scratch();
            let budget = p
                .config()
                .budget_slots(self.cfg.bundle_bytes)
                .min(self.space.per_layer);
            let sub = self.cfg.sub_reads_per_run.max(1);
            self.scratch.predicted.reserve(budget);
            self.scratch.pf_slots.reserve(budget);
            self.scratch.pf_base_runs.reserve(budget);
            self.scratch.pf_cmds.reserve(budget * sub);
            // one pooled run buffer per layer covers the deepest
            // possible speculation fan-out
            while self.scratch.run_pool.len() < self.space.n_layers {
                self.scratch.run_pool.push(Vec::with_capacity(budget));
            }
            for la in &mut self.last_actives {
                la.reserve(self.space.per_layer);
            }
        }
        self.prefetcher = pf;
    }

    pub fn take_prefetcher(&mut self) -> Option<Prefetcher> {
        self.prefetcher.take()
    }

    pub fn has_prefetcher(&self) -> bool {
        self.prefetcher.is_some()
    }

    /// The configured per-submission speculative budget in bytes (the
    /// arbiter's notion of this stream's demand); 0 with no prefetcher.
    pub fn prefetch_budget_bytes(&self) -> usize {
        self.prefetcher.as_ref().map_or(0, |p| p.config().budget_bytes)
    }

    /// Cap speculative submissions at `grant` bytes until the next call
    /// (a serving arbiter's per-round share of the global budget). The
    /// cap only ever shrinks the configured budget; a grant at or above
    /// `prefetch_budget_bytes` leaves behaviour bit-identical to the
    /// un-arbitrated pipeline. `None` removes the cap.
    pub fn set_prefetch_grant(&mut self, grant: Option<usize>) {
        self.prefetch_grant = grant;
    }

    /// Speculative batches currently in flight.
    pub fn outstanding_prefetches(&self) -> usize {
        self.outstanding.iter().filter(|o| o.is_some()).count()
    }

    pub fn threshold(&self) -> u32 {
        if self.cfg.collapse { self.adaptive.threshold() } else { 0 }
    }

    /// Plan one layer into a reusable `plan` (§Perf: zero allocations in
    /// steady state): map to slots, filter through the (borrowed,
    /// possibly shared) cache, peel off slots covered by in-flight
    /// speculation, plan + collapse runs, lower to byte commands.
    pub fn plan_layer_into(
        &mut self,
        cache: &mut NeuronCache,
        layer: usize,
        actives: &[BundleId],
        plan: &mut LayerPlan,
    ) {
        self.plan_layer_from(cache, layer, actives, plan, None);
    }

    /// [`plan_layer_into`](Self::plan_layer_into) with an optional
    /// phase-1 prep: a valid prepared slot list replaces the in-commit
    /// `slots_for_into` (a pure function of the layout and `actives`,
    /// so the substitution is exact). Everything that touches the
    /// shared cache — the residency filter, the speculation peel, the
    /// admission downstream — stays in this serial call.
    fn plan_layer_from(
        &mut self,
        cache: &mut NeuronCache,
        layer: usize,
        actives: &[BundleId],
        plan: &mut LayerPlan,
        prep: Option<&mut TokenPrep>,
    ) {
        let threshold = self.threshold();
        plan.reset(layer);
        let prepared = match prep {
            Some(p) => p.take_slots(layer, &mut self.scratch.slots),
            None => false,
        };
        if !prepared {
            self.layouts[layer].slots_for_into(actives, &mut self.scratch.slots);
        }
        cache.filter_into(
            layer,
            &self.scratch.slots,
            &mut plan.cached,
            &mut self.scratch.missed_all,
        );
        match &self.outstanding[layer] {
            Some(out) => {
                for &s in &self.scratch.missed_all {
                    if out.covers(s) {
                        plan.prefetched.push(s);
                    } else {
                        plan.missed.push(s);
                    }
                }
            }
            None => plan.missed.extend_from_slice(&self.scratch.missed_all),
        }
        plan_runs_into(&plan.missed, &mut self.scratch.base_runs);
        collapse_runs_into(&self.scratch.base_runs, threshold, &mut plan.runs);
        lower_runs_into(&self.cfg, &self.space, layer, &plan.runs, &mut plan.commands);
        if self.prefetcher.is_some() {
            // predictor seed for the next token: refill the layer's
            // buffer in place (no clone; skipped entirely on the
            // synchronous path)
            let last = &mut self.last_actives[layer];
            last.clear();
            last.extend_from_slice(actives);
        }
    }

    /// Allocating convenience wrapper over
    /// [`IoPipeline::plan_layer_into`] for callers that keep plans.
    pub fn plan_layer(
        &mut self,
        cache: &mut NeuronCache,
        layer: usize,
        actives: &[BundleId],
    ) -> LayerPlan {
        let mut plan = LayerPlan::default();
        self.plan_layer_into(cache, layer, actives, &mut plan);
        plan
    }

    // -----------------------------------------------------------------------
    // Speculative prefetch
    // -----------------------------------------------------------------------

    /// While the current layer computes, issue speculative reads for the
    /// next `lookahead` layers starting at `next_layer`, seeded by the
    /// current token's activations (`cur_actives`) and each target
    /// layer's previous-token activations. No-op without a prefetcher.
    pub fn prefetch_layer(
        &mut self,
        cache: &NeuronCache,
        sim: &mut UfsSim,
        next_layer: usize,
        cur_actives: &[BundleId],
    ) {
        self.prefetch_layer_from(cache, sim, next_layer, cur_actives, None);
    }

    /// [`prefetch_layer`](Self::prefetch_layer) with an optional
    /// phase-1 prep: a prepared prediction whose (issuer, budget) tag
    /// matches replaces the in-commit `predict_into` call (the
    /// predictor is pure and its seeds are provably unchanged since
    /// preparation — see [`prepare_token`](Self::prepare_token)); a
    /// mismatch recomputes inline. The residency filter and the flash
    /// submit stay in this serial call.
    fn prefetch_layer_from(
        &mut self,
        cache: &NeuronCache,
        sim: &mut UfsSim,
        next_layer: usize,
        cur_actives: &[BundleId],
        mut prep: Option<&mut TokenPrep>,
    ) {
        let Some(pf) = self.prefetcher.as_ref() else {
            return;
        };
        let mut budget_slots = pf.config().budget_slots(self.cfg.bundle_bytes);
        if let Some(grant) = self.prefetch_grant {
            let grant_slots =
                if self.cfg.bundle_bytes == 0 { 0 } else { grant / self.cfg.bundle_bytes };
            budget_slots = budget_slots.min(grant_slots);
        }
        if budget_slots == 0 {
            return;
        }
        let lookahead = pf.config().lookahead.max(1);
        let threshold = self.threshold();
        let issuer = next_layer.saturating_sub(1);
        let last = next_layer.saturating_add(lookahead).min(self.space.n_layers);
        for target in next_layer..last {
            if self.outstanding[target].is_some() {
                continue;
            }
            let prepared = match prep.as_deref_mut() {
                Some(p) => p.take_prediction(
                    issuer,
                    target,
                    budget_slots,
                    &mut self.scratch.predicted,
                ),
                None => false,
            };
            if !prepared {
                let seeds: [&[BundleId]; 2] = [cur_actives, &self.last_actives[target]];
                pf.predict_into(
                    target,
                    &seeds,
                    budget_slots,
                    &mut self.scratch.predict,
                    &mut self.scratch.predicted,
                );
            }
            if self.scratch.predicted.is_empty() {
                continue;
            }
            let layout = &self.layouts[target];
            // predict_into() already caps at budget_slots; the residency
            // filter only shrinks the list further
            self.scratch.pf_slots.clear();
            for &b in &self.scratch.predicted {
                let s = layout.slot_of(b);
                if !cache.contains(target, s) {
                    self.scratch.pf_slots.push(s);
                }
            }
            self.scratch.pf_slots.sort_unstable();
            if self.scratch.pf_slots.is_empty() {
                continue;
            }
            plan_runs_into(&self.scratch.pf_slots, &mut self.scratch.pf_base_runs);
            // the run list must outlive this call (it rides with the
            // in-flight batch), so it cycles through a free pool instead
            // of being allocated per speculation
            let mut runs = self.scratch.run_pool.pop().unwrap_or_default();
            collapse_runs_into(&self.scratch.pf_base_runs, threshold, &mut runs);
            lower_runs_into(&self.cfg, &self.space, target, &runs, &mut self.scratch.pf_cmds);
            let ticket = sim.submit_batch(&self.scratch.pf_cmds);
            if self.trace.is_some() {
                let service_ns = sim.ticket_elapsed_ns(ticket).unwrap_or(0.0);
                self.trace_span(Phase::Prefetch, sim.clock_ns(), service_ns);
                self.trace_mark(
                    MarkKind::PrefetchSubmit,
                    sim.clock_ns(),
                    target as f64,
                    self.scratch.pf_cmds.len() as f64,
                );
            }
            self.outstanding[target] = Some(OutstandingPrefetch { runs, ticket });
        }
    }

    /// Wait + reconcile the speculative batch covering `plan.layer`, if
    /// any: charge the uncovered stall, admit the speculative runs into
    /// the cache, and account hit/waste volume.
    fn reconcile_prefetch(
        &mut self,
        cache: &mut NeuronCache,
        plan: &LayerPlan,
        sim: &mut UfsSim,
    ) -> TokenIo {
        let mut io = TokenIo::default();
        let Some(out) = self.outstanding[plan.layer].take() else {
            return io;
        };
        let w = sim.wait(out.ticket);
        cache.admit(plan.layer, &out.runs);
        let (pf_total, pf_extra) = plan_volume(&out.runs);
        let hits = plan.prefetched.len() as u64;
        io.prefetch_hit_bundles = hits;
        // gap slots merged in by access collapse are collapse overhead,
        // not misprediction: classify them as extra_bundles exactly like
        // the demand path does, so waste counters blame the predictor
        // only for slots it actually chose.
        io.extra_bundles = pf_extra;
        io.prefetch_wasted_bundles = (pf_total - pf_extra).saturating_sub(hits);
        if self.trace.is_some() {
            if hits > 0 {
                self.trace_mark(
                    MarkKind::PrefetchHit,
                    sim.clock_ns(),
                    hits as f64,
                    plan.layer as f64,
                );
            }
            if io.prefetch_wasted_bundles > 0 {
                self.trace_mark(
                    MarkKind::PrefetchWaste,
                    sim.clock_ns(),
                    io.prefetch_wasted_bundles as f64,
                    plan.layer as f64,
                );
            }
        }
        io.read_bundles = pf_total;
        io.commands = w.batch.commands as u64;
        io.bytes = w.batch.bytes as u64;
        io.elapsed_ns = w.batch.elapsed_ns;
        io.stall_ns = w.stall_ns;
        // recycle the drained run buffer for the next speculation
        let mut runs = out.runs;
        runs.clear();
        self.scratch.run_pool.push(runs);
        io
    }

    // -----------------------------------------------------------------------
    // Commit paths
    // -----------------------------------------------------------------------

    /// Submit the plan's demand batch on the async timeline (timing only).
    pub fn submit_layer(&mut self, plan: &LayerPlan, sim: &mut UfsSim) -> Ticket {
        sim.submit_batch(&plan.commands)
    }

    /// Like `submit_layer` but also copies real bytes out of the flash
    /// image (engine path). Bytes are appended run-by-run in order.
    pub fn submit_layer_read(
        &mut self,
        plan: &LayerPlan,
        sim: &mut UfsSim,
        out: &mut Vec<u8>,
    ) -> Ticket {
        sim.submit_read_batch(&plan.commands, out)
    }

    /// Wait the demand batch, reconcile speculation, admit into cache,
    /// feed the adaptive controller, and return the metrics contribution.
    pub fn complete_layer(
        &mut self,
        cache: &mut NeuronCache,
        plan: &LayerPlan,
        ticket: Ticket,
        sim: &mut UfsSim,
    ) -> TokenIo {
        let sat = sim.device().sat_bandwidth;
        // The speculative batch sits ahead of the demand batch in the
        // serial device queue: reconcile it first so stalls attribute in
        // completion order.
        let mut io = self.reconcile_prefetch(cache, plan, sim);
        let w = sim.wait(ticket);
        io.add(&self.finish_commit(cache, plan, w.batch.elapsed_ns, w.stall_ns, sat));
        io
    }

    /// Charge a plan to the flash sim synchronously, admit into cache,
    /// feed the adaptive controller, and return the metrics contribution.
    pub fn commit_layer(
        &mut self,
        cache: &mut NeuronCache,
        plan: &LayerPlan,
        sim: &mut UfsSim,
    ) -> TokenIo {
        let sat = sim.device().sat_bandwidth;
        let mut io = self.reconcile_prefetch(cache, plan, sim);
        let batch = sim.charge(&plan.commands);
        io.add(&self.finish_commit(cache, plan, batch.elapsed_ns, batch.elapsed_ns, sat));
        io
    }

    /// Like `commit_layer` but also copies real bytes out of the flash
    /// image (engine path). Bytes are appended run-by-run in order.
    pub fn commit_layer_read(
        &mut self,
        cache: &mut NeuronCache,
        plan: &LayerPlan,
        sim: &mut UfsSim,
        out: &mut Vec<u8>,
    ) -> TokenIo {
        let sat = sim.device().sat_bandwidth;
        let mut io = self.reconcile_prefetch(cache, plan, sim);
        let batch = sim.read_batch(&plan.commands, out);
        io.add(&self.finish_commit(cache, plan, batch.elapsed_ns, batch.elapsed_ns, sat));
        io
    }

    fn finish_commit(
        &mut self,
        cache: &mut NeuronCache,
        plan: &LayerPlan,
        elapsed_ns: f64,
        stall_ns: f64,
        sat: f64,
    ) -> TokenIo {
        cache.admit(plan.layer, &plan.runs);
        let (total_slots, extra_slots) = plan_volume(&plan.runs);
        let bytes = total_slots * self.cfg.bundle_bytes as u64;
        let demand_bytes = plan.missed.len() as u64 * self.cfg.bundle_bytes as u64;
        self.adaptive
            .observe(demand_bytes as f64, bytes as f64, elapsed_ns, sat);
        TokenIo {
            demanded_bundles: (plan.missed.len() + plan.cached.len() + plan.prefetched.len())
                as u64,
            read_bundles: total_slots,
            extra_bundles: extra_slots,
            cached_bundles: plan.cached.len() as u64,
            prefetch_hit_bundles: 0,
            prefetch_wasted_bundles: 0,
            commands: plan.commands.len() as u64,
            bytes,
            elapsed_ns,
            stall_ns,
        }
    }

    /// Phase-1 planning for one token (DESIGN.md §Parallel-decode):
    /// compute everything the serial commit can be relieved of without
    /// touching shared state — the sorted demanded slot list per layer
    /// and, in overlapped mode, the predictor's lookahead predictions.
    /// Reads only this pipeline's own state (layouts, predictor,
    /// previous-token seeds, the already-installed prefetch grant), so
    /// disjoint sessions can prepare concurrently while the shared
    /// cache and flash timeline stay untouched.
    pub fn prepare_token(
        &mut self,
        actives: &[Vec<BundleId>],
        overlapped: bool,
        prep: &mut TokenPrep,
    ) {
        assert_eq!(actives.len(), self.space.n_layers);
        prep.reset(self.space.n_layers);
        for (layer, act) in actives.iter().enumerate() {
            self.layouts[layer].slots_for_into(act, &mut prep.slots[layer]);
            prep.slots_valid[layer] = true;
        }
        if !overlapped {
            return;
        }
        let Some(pf) = self.prefetcher.as_ref() else {
            return;
        };
        // mirror `prefetch_layer`'s budget gate exactly — the grant is
        // installed before the round serves (arbitrate_round), so it
        // cannot change between preparation and commit
        let mut budget_slots = pf.config().budget_slots(self.cfg.bundle_bytes);
        if let Some(grant) = self.prefetch_grant {
            let grant_slots =
                if self.cfg.bundle_bytes == 0 { 0 } else { grant / self.cfg.bundle_bytes };
            budget_slots = budget_slots.min(grant_slots);
        }
        if budget_slots == 0 {
            return;
        }
        let lookahead = pf.config().lookahead.max(1);
        // The deviation-free lookahead schedule: target T is first
        // issued while layer max(T - lookahead, 0) computes (each
        // issuing layer L covers targets L+1..=L+lookahead, earliest
        // issuer wins). Its seeds are the issuer's activations of THIS
        // token and the target's previous-token activations — the
        // latter is refilled only when the commit plans the target
        // layer itself, which is strictly after the issue point, so
        // both seeds are exactly what the inline call would read. When
        // the commit deviates (a target whose prediction came up empty
        // or fully resident is retried by a later layer with different
        // seeds), tag validation fails and the commit recomputes
        // inline.
        for target in 1..self.space.n_layers {
            let issuer = target.saturating_sub(lookahead);
            let p = &mut prep.preds[target];
            let seeds: [&[BundleId]; 2] = [&actives[issuer], &self.last_actives[target]];
            pf.predict_into(
                target,
                &seeds,
                budget_slots,
                &mut self.scratch.predict,
                &mut p.predicted,
            );
            p.issuer = issuer;
            p.budget = budget_slots;
            p.valid = true;
        }
    }

    /// Trace-driven step: process all layers of one token against `sim`,
    /// fully synchronously (the historical model; bit-stable with seeds).
    /// Steady-state cost is zero heap allocations: the per-layer plan is
    /// the pipeline's own reusable buffer, taken out for the loop.
    pub fn step_token(
        &mut self,
        cache: &mut NeuronCache,
        sim: &mut UfsSim,
        actives: &[Vec<BundleId>],
    ) -> TokenIo {
        self.step_token_from(cache, sim, actives, None)
    }

    /// [`step_token`](Self::step_token) consuming a phase-1
    /// [`TokenPrep`] filled by [`prepare_token`](Self::prepare_token).
    /// Bit-identical results: prepared values are used only when
    /// provably equal to what the inline path computes.
    pub fn step_token_prepared(
        &mut self,
        cache: &mut NeuronCache,
        sim: &mut UfsSim,
        actives: &[Vec<BundleId>],
        prep: &mut TokenPrep,
    ) -> TokenIo {
        self.step_token_from(cache, sim, actives, Some(prep))
    }

    fn step_token_from(
        &mut self,
        cache: &mut NeuronCache,
        sim: &mut UfsSim,
        actives: &[Vec<BundleId>],
        mut prep: Option<&mut TokenPrep>,
    ) -> TokenIo {
        assert_eq!(actives.len(), self.space.n_layers);
        let mut tok = TokenIo::default();
        let mut plan = std::mem::take(&mut self.scratch.plan);
        for (layer, act) in actives.iter().enumerate() {
            self.plan_layer_from(cache, layer, act, &mut plan, prep.as_deref_mut());
            if self.trace.is_some() {
                self.trace_mark(
                    MarkKind::Plan,
                    sim.clock_ns(),
                    layer as f64,
                    plan.missed.len() as f64,
                );
            }
            tok.add(&self.commit_layer(cache, &plan, sim));
            if self.trace.is_some() {
                self.trace_mark(MarkKind::Commit, sim.clock_ns(), layer as f64, 0.0);
            }
        }
        self.scratch.plan = plan;
        tok
    }

    /// Trace-driven step with the overlapped I/O–compute schedule: per
    /// layer, the demand batch is submitted, speculation for upcoming
    /// layers is issued behind it, the demand wait charges only what
    /// compute can't hide, and `compute_ns_per_layer` of simulated
    /// compute advances the host clock while speculation drains.
    ///
    /// With no prefetcher attached and `compute_ns_per_layer == 0.0`
    /// this is bit-identical to [`step_token`].
    pub fn step_token_overlapped(
        &mut self,
        cache: &mut NeuronCache,
        sim: &mut UfsSim,
        actives: &[Vec<BundleId>],
        compute_ns_per_layer: f64,
    ) -> TokenIo {
        self.step_token_overlapped_from(cache, sim, actives, compute_ns_per_layer, None)
    }

    /// [`step_token_overlapped`](Self::step_token_overlapped) consuming
    /// a phase-1 [`TokenPrep`] filled by
    /// [`prepare_token`](Self::prepare_token). Bit-identical results:
    /// each prepared value carries a tag (layer, or issuer + budget)
    /// and is consumed only when the commit path would have computed
    /// the exact same inputs; on any mismatch the commit recomputes
    /// inline.
    pub fn step_token_overlapped_prepared(
        &mut self,
        cache: &mut NeuronCache,
        sim: &mut UfsSim,
        actives: &[Vec<BundleId>],
        compute_ns_per_layer: f64,
        prep: &mut TokenPrep,
    ) -> TokenIo {
        self.step_token_overlapped_from(cache, sim, actives, compute_ns_per_layer, Some(prep))
    }

    fn step_token_overlapped_from(
        &mut self,
        cache: &mut NeuronCache,
        sim: &mut UfsSim,
        actives: &[Vec<BundleId>],
        compute_ns_per_layer: f64,
        mut prep: Option<&mut TokenPrep>,
    ) -> TokenIo {
        assert_eq!(actives.len(), self.space.n_layers);
        let mut tok = TokenIo::default();
        let mut plan = std::mem::take(&mut self.scratch.plan);
        for (layer, act) in actives.iter().enumerate() {
            self.plan_layer_from(cache, layer, act, &mut plan, prep.as_deref_mut());
            if self.trace.is_some() {
                self.trace_mark(
                    MarkKind::Plan,
                    sim.clock_ns(),
                    layer as f64,
                    plan.missed.len() as f64,
                );
            }
            let ticket = self.submit_layer(&plan, sim);
            if layer + 1 < self.space.n_layers {
                self.prefetch_layer_from(cache, sim, layer + 1, act, prep.as_deref_mut());
            }
            tok.add(&self.complete_layer(cache, &plan, ticket, sim));
            if self.trace.is_some() {
                self.trace_mark(MarkKind::Commit, sim.clock_ns(), layer as f64, 0.0);
            }
            if compute_ns_per_layer > 0.0 {
                sim.advance_compute(compute_ns_per_layer);
            }
        }
        self.scratch.plan = plan;
        tok
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{Admission, KeySpace, NeuronCache, S3Fifo};
    use crate::config::devices;
    use crate::prefetch::{PrefetchConfig, Prefetcher};
    use crate::trace::{DatasetProfile, TraceGen};

    fn mk_pipeline(collapse: bool, cache_cap: usize) -> (IoPipeline, NeuronCache, UfsSim) {
        let space = NeuronSpace::new(2, 64, 128);
        let layouts = vec![Layout::identity(64), Layout::identity(64)];
        let cache = NeuronCache::new(
            Box::new(S3Fifo::new(cache_cap)),
            Admission::All,
            7,
            KeySpace::of(&space),
        );
        let cfg = PipelineConfig {
            bundle_bytes: 128,
            collapse,
            initial_threshold: 2,
            max_threshold: 8,
            window: 4,
            sub_reads_per_run: 1,
        };
        let sim = UfsSim::new(devices()[0].clone(), space.image_bytes());
        (IoPipeline::new(cfg, space, layouts), cache, sim)
    }

    #[test]
    fn plan_covers_all_misses() {
        let (mut p, mut cache, _sim) = mk_pipeline(true, 0);
        let plan = p.plan_layer(&mut cache, 0, &[1, 2, 3, 10, 12]);
        assert!(plan.cached.is_empty());
        assert!(plan.prefetched.is_empty());
        assert_eq!(plan.missed.len(), 5);
        for &s in &plan.missed {
            assert!(plan.runs.iter().any(|r| s >= r.start && s < r.end()));
        }
        // collapse with threshold 2 merges 10 and 12
        assert_eq!(plan.runs.len(), 2);
    }

    #[test]
    fn commands_map_to_byte_extents() {
        let (mut p, mut cache, _sim) = mk_pipeline(false, 0);
        let plan = p.plan_layer(&mut cache, 1, &[0, 1]);
        assert_eq!(plan.commands.len(), 1);
        let c = plan.commands[0];
        assert_eq!(c.offset, p.space.layer_base(1));
        assert_eq!(c.len, 2 * 128);
    }

    #[test]
    fn sub_reads_split_runs() {
        let (mut p, mut cache, _sim) = mk_pipeline(false, 0);
        p.cfg.sub_reads_per_run = 2;
        let plan = p.plan_layer(&mut cache, 0, &[0, 1, 2, 3]);
        assert_eq!(plan.commands.len(), 2);
        let total: usize = plan.commands.iter().map(|c| c.len).sum();
        assert_eq!(total, 4 * 128);
    }

    #[test]
    fn cache_reduces_second_token_reads() {
        let (mut p, mut cache, mut sim) = mk_pipeline(false, 64);
        let t1 = p.step_token(&mut cache, &mut sim, &[vec![1, 2, 3], vec![4, 5]]);
        assert_eq!(t1.cached_bundles, 0);
        let t2 = p.step_token(&mut cache, &mut sim, &[vec![1, 2, 3], vec![4, 5]]);
        assert_eq!(t2.cached_bundles, 5);
        assert_eq!(t2.commands, 0);
        assert_eq!(t2.elapsed_ns, 0.0);
    }

    #[test]
    fn collapse_reduces_commands_and_reads_extra() {
        let (mut p, mut cache, mut sim) = mk_pipeline(true, 0);
        // gaps of 1: 0,2,4,6 -> one command with threshold >=1
        let t = p.step_token(&mut cache, &mut sim, &[vec![0, 2, 4, 6], vec![]]);
        assert_eq!(t.commands, 1);
        assert_eq!(t.extra_bundles, 3);
        assert_eq!(t.read_bundles, 7);
        assert_eq!(t.demanded_bundles, 4);

        let (mut p2, mut cache2, mut sim2) = mk_pipeline(false, 0);
        let t2 = p2.step_token(&mut cache2, &mut sim2, &[vec![0, 2, 4, 6], vec![]]);
        assert_eq!(t2.commands, 4);
        assert!(t.elapsed_ns < t2.elapsed_ns, "collapse should be faster");
    }

    #[test]
    fn read_path_returns_real_bytes() {
        let (mut p, mut cache, mut sim) = mk_pipeline(false, 0);
        // write a recognizable pattern into slot 3 of layer 0
        let (off, len) = p.space.slot_range(0, 3);
        sim.write_image(off, &vec![0xAB; len]);
        let plan = p.plan_layer(&mut cache, 0, &[3]);
        let mut out = Vec::new();
        let t = p.commit_layer_read(&mut cache, &plan, &mut sim, &mut out);
        assert_eq!(out, vec![0xAB; 128]);
        assert_eq!(t.commands, 1);
    }

    #[test]
    fn layouts_redirect_reads() {
        let space = NeuronSpace::new(1, 8, 16);
        // bundle 0 lives at slot 7
        let order: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 0];
        let layouts = vec![Layout::from_order(&order).unwrap()];
        let mut cache =
            NeuronCache::new(Box::new(S3Fifo::new(0)), Admission::All, 1, KeySpace::of(&space));
        let cfg = PipelineConfig {
            bundle_bytes: 16,
            collapse: false,
            initial_threshold: 0,
            max_threshold: 4,
            window: 4,
            sub_reads_per_run: 1,
        };
        let mut p = IoPipeline::new(cfg, space, layouts);
        let plan = p.plan_layer(&mut cache, 0, &[0]);
        assert_eq!(plan.runs[0].start, 7);
        assert_eq!(plan.commands[0].offset, 7 * 16);
    }

    // -- overlapped mode ----------------------------------------------------

    fn mk_prefetching_pipeline(
        cache_cap: usize,
        budget_bytes: usize,
    ) -> (IoPipeline, NeuronCache, UfsSim, crate::trace::Trace) {
        let n = 256;
        let space = NeuronSpace::new(2, n, 128);
        let layouts = vec![Layout::identity(n), Layout::identity(n)];
        let cache = NeuronCache::new(
            Box::new(S3Fifo::new(cache_cap)),
            Admission::All,
            7,
            KeySpace::of(&space),
        );
        let cfg = PipelineConfig {
            bundle_bytes: 128,
            collapse: true,
            initial_threshold: 2,
            max_threshold: 8,
            window: 8,
            sub_reads_per_run: 1,
        };
        let sim = UfsSim::new(devices()[0].clone(), space.image_bytes());
        let mut p = IoPipeline::new(cfg, space, layouts);
        let mut tg = TraceGen::new(2, n, 28, &DatasetProfile::alpaca(), 3, 9);
        let calib = tg.generate(128);
        let pcfg = PrefetchConfig {
            enabled: true,
            budget_bytes,
            lookahead: 1,
            max_partners: 8,
        };
        p.set_prefetcher(Some(Prefetcher::from_trace(&calib, pcfg, 2)));
        let eval = tg.generate(40);
        (p, cache, sim, eval)
    }

    #[test]
    fn overlapped_disabled_is_bit_identical_to_sync() {
        let mut tg = TraceGen::new(2, 64, 10, &DatasetProfile::wikitext(), 5, 6);
        let eval = tg.generate(25);
        let (mut a, mut cache_a, mut sim_a) = mk_pipeline(true, 32);
        let (mut b, mut cache_b, mut sim_b) = mk_pipeline(true, 32);
        for tok in &eval.tokens {
            a.step_token(&mut cache_a, &mut sim_a, tok);
            b.step_token_overlapped(&mut cache_b, &mut sim_b, tok, 0.0);
        }
        let (sa, sb) = (sim_a.stats(), sim_b.stats());
        assert_eq!(sim_a.clock_ns().to_bits(), sim_b.clock_ns().to_bits());
        assert_eq!(sa.total_busy_ns.to_bits(), sb.total_busy_ns.to_bits());
        assert_eq!(sa.total_commands, sb.total_commands);
        assert_eq!(sa.total_bytes, sb.total_bytes);
        assert_eq!(sa.total_batches, sb.total_batches);
    }

    #[test]
    fn prefetch_produces_hits_and_overlap() {
        let (mut p, mut cache, mut sim, eval) = mk_prefetching_pipeline(0, 16 * 128);
        let compute = 200_000.0; // generous per-layer compute window
        let mut tok = TokenIo::default();
        for t in &eval.tokens {
            tok.add(&p.step_token_overlapped(&mut cache, &mut sim, t, compute));
        }
        assert!(tok.prefetch_hit_bundles > 0, "no speculative hits");
        let s = sim.stats();
        assert!(s.total_hidden_ns > 0.0, "no overlap achieved");
        assert!(s.overlap_ratio() > 0.0);
        // every layer drained its speculation
        assert_eq!(p.outstanding_prefetches(), 0);
        assert_eq!(sim.in_flight(), 0);
    }

    #[test]
    fn prefetch_hits_shrink_demand_commands() {
        // same stream with and without prefetch: speculation must strictly
        // reduce the host-visible stall time given ample compute overlap
        let (mut with, mut cache_w, mut sim_with, eval) = mk_prefetching_pipeline(0, 32 * 128);
        let (mut without, mut cache_n, mut sim_without, _) = mk_prefetching_pipeline(0, 32 * 128);
        without.set_prefetcher(None);
        let compute = 400_000.0;
        let mut stall_with = 0.0;
        let mut stall_without = 0.0;
        for t in &eval.tokens {
            stall_with += with
                .step_token_overlapped(&mut cache_w, &mut sim_with, t, compute)
                .stall_ns;
            stall_without += without
                .step_token_overlapped(&mut cache_n, &mut sim_without, t, compute)
                .stall_ns;
        }
        assert!(
            stall_with < stall_without,
            "prefetch should cut stalls: {stall_with} vs {stall_without}"
        );
    }

    #[test]
    fn overlapped_run_is_deterministic() {
        let (mut a, mut cache_a, mut sim_a, eval) = mk_prefetching_pipeline(64, 24 * 128);
        let (mut b, mut cache_b, mut sim_b, _) = mk_prefetching_pipeline(64, 24 * 128);
        for t in &eval.tokens {
            a.step_token_overlapped(&mut cache_a, &mut sim_a, t, 150_000.0);
            b.step_token_overlapped(&mut cache_b, &mut sim_b, t, 150_000.0);
        }
        let (sa, sb) = (sim_a.stats(), sim_b.stats());
        assert_eq!(sim_a.clock_ns().to_bits(), sim_b.clock_ns().to_bits());
        assert_eq!(sa.total_busy_ns.to_bits(), sb.total_busy_ns.to_bits());
        assert_eq!(sa.total_stall_ns.to_bits(), sb.total_stall_ns.to_bits());
        assert_eq!(sa.total_hidden_ns.to_bits(), sb.total_hidden_ns.to_bits());
        assert_eq!(sa.total_commands, sb.total_commands);
        assert_eq!(sa.total_bytes, sb.total_bytes);
    }

    #[test]
    fn prefetched_slots_excluded_from_demand_batch() {
        let (mut p, mut cache, mut sim, _eval) = mk_prefetching_pipeline(0, 64 * 128);
        // seed the predictor path: run one token so last_actives exist
        let tok0 = vec![vec![1, 2, 3], vec![10, 11, 12]];
        p.step_token_overlapped(&mut cache, &mut sim, &tok0, 50_000.0);
        // now speculate for layer 1 from layer 0's actives
        let plan0 = p.plan_layer(&mut cache, 0, &[1, 2, 3]);
        let t0 = p.submit_layer(&plan0, &mut sim);
        p.prefetch_layer(&cache, &mut sim, 1, &[1, 2, 3]);
        assert_eq!(p.outstanding_prefetches(), 1);
        p.complete_layer(&mut cache, &plan0, t0, &mut sim);
        // layer 1 demand: the previous token's slots 10..12 are highly
        // ranked seeds, so they must be covered by the speculation
        let plan1 = p.plan_layer(&mut cache, 1, &[10, 11, 12]);
        assert!(
            !plan1.prefetched.is_empty(),
            "expected speculative coverage, got missed={:?}",
            plan1.missed
        );
        for s in &plan1.prefetched {
            assert!(!plan1.missed.contains(s));
        }
        let t1 = p.submit_layer(&plan1, &mut sim);
        let io = p.complete_layer(&mut cache, &plan1, t1, &mut sim);
        assert_eq!(io.prefetch_hit_bundles, plan1.prefetched.len() as u64);
        assert_eq!(p.outstanding_prefetches(), 0);
    }

    #[test]
    fn prefetch_grant_caps_and_full_grant_is_identity() {
        // grant 0: speculation is suppressed entirely
        let (mut p, cache, mut sim, _eval) = mk_prefetching_pipeline(0, 16 * 128);
        p.set_prefetch_grant(Some(0));
        p.prefetch_layer(&cache, &mut sim, 1, &[1, 2, 3]);
        assert_eq!(p.outstanding_prefetches(), 0);
        assert_eq!(sim.stats().total_batches, 0);

        // a grant at the configured budget replays the un-arbitrated
        // pipeline bit-for-bit
        let (mut a, mut cache_a, mut sim_a, eval) = mk_prefetching_pipeline(32, 16 * 128);
        let (mut b, mut cache_b, mut sim_b, _) = mk_prefetching_pipeline(32, 16 * 128);
        b.set_prefetch_grant(Some(16 * 128));
        for t in &eval.tokens {
            a.step_token_overlapped(&mut cache_a, &mut sim_a, t, 150_000.0);
            b.step_token_overlapped(&mut cache_b, &mut sim_b, t, 150_000.0);
        }
        assert_eq!(sim_a.clock_ns().to_bits(), sim_b.clock_ns().to_bits());
        assert_eq!(sim_a.stats().total_commands, sim_b.stats().total_commands);
        assert_eq!(sim_a.stats().total_bytes, sim_b.stats().total_bytes);

        // a tighter grant shrinks speculative traffic (cache capacity 0
        // so warmth effects cannot mask the cap)
        let (mut full, mut cache_f, mut sim_f, eval) = mk_prefetching_pipeline(0, 16 * 128);
        let (mut capped, mut cache_g, mut sim_g, _) = mk_prefetching_pipeline(0, 16 * 128);
        capped.set_prefetch_grant(Some(4 * 128));
        for t in &eval.tokens {
            full.step_token_overlapped(&mut cache_f, &mut sim_f, t, 150_000.0);
            capped.step_token_overlapped(&mut cache_g, &mut sim_g, t, 150_000.0);
        }
        assert!(
            sim_g.stats().total_bytes < sim_f.stats().total_bytes,
            "4-slot grant should read less than the 16-slot budget: {} vs {}",
            sim_g.stats().total_bytes,
            sim_f.stats().total_bytes
        );
    }
}
