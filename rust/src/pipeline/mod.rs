//! The per-token I/O pipeline (paper Figure 7, online half):
//!
//!   activated bundles -> layout (bundle->slot) -> cache filter
//!     -> run planning -> access collapse -> flash batch
//!     -> cache admission -> adaptive-controller feedback
//!
//! The same pipeline object serves both the trace-driven paper benches
//! (timing-only `step_token`) and the real PJRT engine (`plan_layer` +
//! `commit_layer`, which also return the byte-level commands so the
//! engine can read actual weights).

use crate::access::{collapse_runs, plan_runs, AdaptiveCollapse, SlotRun};
use crate::cache::NeuronCache;
use crate::config::RunConfig;
use crate::flash::{ReadCmd, UfsSim};
use crate::metrics::TokenIo;
use crate::neuron::{BundleId, Layout, NeuronSpace, Slot};

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub bundle_bytes: usize,
    /// Access collapse enabled (RIPPLE online stage).
    pub collapse: bool,
    pub initial_threshold: u32,
    /// Cap on the gap threshold, in bundles. Defaults to the device
    /// knee size / bundle size: beyond that the gap fill costs more
    /// than the command it saves even in the fully IOPS-bound regime.
    pub max_threshold: u32,
    /// Adaptive-controller window, tokens.
    pub window: usize,
    /// Commands issued per planned run: 1 when neurons are stored as
    /// bundles (LLMFlash, RIPPLE); `ffn_linears` for the Llama.cpp
    /// baseline, whose up/down(/gate) rows live in separate matrix
    /// regions and need separate reads.
    pub sub_reads_per_run: usize,
}

impl PipelineConfig {
    pub fn from_run(cfg: &RunConfig) -> Self {
        let bundle_bytes = cfg.model.bundle_bytes(cfg.precision);
        let knee = cfg.device.knee_bytes();
        let max_threshold = ((knee / bundle_bytes as f64) as u32).max(1);
        Self {
            bundle_bytes,
            collapse: cfg.collapse,
            initial_threshold: cfg.collapse_threshold as u32,
            max_threshold,
            window: 16,
            sub_reads_per_run: 1,
        }
    }
}

/// One layer's planned I/O.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub layer: usize,
    /// Demanded slots served by DRAM cache.
    pub cached: Vec<Slot>,
    /// Demanded slots that must be read.
    pub missed: Vec<Slot>,
    /// Post-collapse read runs covering all missed slots.
    pub runs: Vec<SlotRun>,
    /// Byte-level commands for the flash sim (sub_reads applied).
    pub commands: Vec<ReadCmd>,
}

pub struct IoPipeline {
    cfg: PipelineConfig,
    space: NeuronSpace,
    layouts: Vec<Layout>,
    pub cache: NeuronCache,
    adaptive: AdaptiveCollapse,
}

impl IoPipeline {
    pub fn new(
        cfg: PipelineConfig,
        space: NeuronSpace,
        layouts: Vec<Layout>,
        cache: NeuronCache,
    ) -> Self {
        assert_eq!(layouts.len(), space.n_layers);
        for l in &layouts {
            assert_eq!(l.len(), space.per_layer);
        }
        let adaptive =
            AdaptiveCollapse::new(cfg.initial_threshold, cfg.max_threshold, cfg.window);
        Self { cfg, space, layouts, cache, adaptive }
    }

    pub fn layouts(&self) -> &[Layout] {
        &self.layouts
    }

    pub fn space(&self) -> &NeuronSpace {
        &self.space
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    pub fn threshold(&self) -> u32 {
        if self.cfg.collapse { self.adaptive.threshold() } else { 0 }
    }

    /// Plan one layer: map to slots, filter through cache, plan + collapse
    /// runs, lower to byte commands.
    pub fn plan_layer(&mut self, layer: usize, actives: &[BundleId]) -> LayerPlan {
        let layout = &self.layouts[layer];
        let slots = layout.slots_for(actives);
        let (cached, missed) = self.cache.filter(layer, &slots);
        let base_runs = plan_runs(&missed);
        let runs = collapse_runs(&base_runs, self.threshold());
        let commands = self.lower_runs(layer, &runs);
        LayerPlan { layer, cached, missed, runs, commands }
    }

    fn lower_runs(&self, layer: usize, runs: &[SlotRun]) -> Vec<ReadCmd> {
        let bb = self.cfg.bundle_bytes;
        let sub = self.cfg.sub_reads_per_run.max(1);
        let mut cmds = Vec::with_capacity(runs.len() * sub);
        for r in runs {
            let (offset, _) = self.space.slot_range(layer, r.start);
            let total = r.len as usize * bb;
            // sub_reads > 1 models unbundled storage: the run's bytes are
            // split across `sub` matrix regions read separately.
            let part = total / sub;
            for i in 0..sub {
                let len = if i + 1 == sub { total - part * (sub - 1) } else { part };
                if len > 0 {
                    cmds.push(ReadCmd { offset: offset + (i * part) as u64, len });
                }
            }
        }
        cmds
    }

    /// Charge a plan to the flash sim, admit into cache, feed the
    /// adaptive controller, and return the metrics contribution.
    pub fn commit_layer(&mut self, plan: &LayerPlan, sim: &mut UfsSim) -> TokenIo {
        let sat = sim.device().sat_bandwidth;
        let batch = sim.charge(&plan.commands);
        self.finish_commit(plan, batch.elapsed_ns, sat)
    }

    /// Like `commit_layer` but also copies real bytes out of the flash
    /// image (engine path). Bytes are appended run-by-run in order.
    pub fn commit_layer_read(
        &mut self,
        plan: &LayerPlan,
        sim: &mut UfsSim,
        out: &mut Vec<u8>,
    ) -> TokenIo {
        let sat = sim.device().sat_bandwidth;
        let batch = sim.read_batch(&plan.commands, out);
        self.finish_commit(plan, batch.elapsed_ns, sat)
    }

    fn finish_commit(&mut self, plan: &LayerPlan, elapsed_ns: f64, sat: f64) -> TokenIo {
        self.cache.admit(plan.layer, &plan.runs);
        let (total_slots, extra_slots) = crate::access::plan_volume(&plan.runs);
        let bytes = total_slots * self.cfg.bundle_bytes as u64;
        let demand_bytes = plan.missed.len() as u64 * self.cfg.bundle_bytes as u64;
        self.adaptive
            .observe(demand_bytes as f64, bytes as f64, elapsed_ns, sat);
        TokenIo {
            demanded_bundles: (plan.missed.len() + plan.cached.len()) as u64,
            read_bundles: total_slots,
            extra_bundles: extra_slots,
            cached_bundles: plan.cached.len() as u64,
            commands: plan.commands.len() as u64,
            bytes,
            elapsed_ns,
        }
    }

    /// Trace-driven step: process all layers of one token against `sim`.
    pub fn step_token(&mut self, sim: &mut UfsSim, actives: &[Vec<BundleId>]) -> TokenIo {
        assert_eq!(actives.len(), self.space.n_layers);
        let mut tok = TokenIo::default();
        for (layer, act) in actives.iter().enumerate() {
            let plan = self.plan_layer(layer, act);
            tok.add(&self.commit_layer(&plan, sim));
        }
        tok
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{Admission, NeuronCache, S3Fifo};
    use crate::config::devices;

    fn mk_pipeline(collapse: bool, cache_cap: usize) -> (IoPipeline, UfsSim) {
        let space = NeuronSpace::new(2, 64, 128);
        let layouts = vec![Layout::identity(64), Layout::identity(64)];
        let cache = NeuronCache::new(
            Box::new(S3Fifo::new(cache_cap)),
            Admission::All,
            7,
        );
        let cfg = PipelineConfig {
            bundle_bytes: 128,
            collapse,
            initial_threshold: 2,
            max_threshold: 8,
            window: 4,
            sub_reads_per_run: 1,
        };
        let sim = UfsSim::new(devices()[0].clone(), space.image_bytes());
        (IoPipeline::new(cfg, space, layouts, cache), sim)
    }

    #[test]
    fn plan_covers_all_misses() {
        let (mut p, _sim) = mk_pipeline(true, 0);
        let plan = p.plan_layer(0, &[1, 2, 3, 10, 12]);
        assert!(plan.cached.is_empty());
        assert_eq!(plan.missed.len(), 5);
        for &s in &plan.missed {
            assert!(plan.runs.iter().any(|r| s >= r.start && s < r.end()));
        }
        // collapse with threshold 2 merges 10 and 12
        assert_eq!(plan.runs.len(), 2);
    }

    #[test]
    fn commands_map_to_byte_extents() {
        let (mut p, _sim) = mk_pipeline(false, 0);
        let plan = p.plan_layer(1, &[0, 1]);
        assert_eq!(plan.commands.len(), 1);
        let c = plan.commands[0];
        assert_eq!(c.offset, p.space.layer_base(1));
        assert_eq!(c.len, 2 * 128);
    }

    #[test]
    fn sub_reads_split_runs() {
        let (mut p, _sim) = mk_pipeline(false, 0);
        p.cfg.sub_reads_per_run = 2;
        let plan = p.plan_layer(0, &[0, 1, 2, 3]);
        assert_eq!(plan.commands.len(), 2);
        let total: usize = plan.commands.iter().map(|c| c.len).sum();
        assert_eq!(total, 4 * 128);
    }

    #[test]
    fn cache_reduces_second_token_reads() {
        let (mut p, mut sim) = mk_pipeline(false, 64);
        let t1 = p.step_token(&mut sim, &[vec![1, 2, 3], vec![4, 5]]);
        assert_eq!(t1.cached_bundles, 0);
        let t2 = p.step_token(&mut sim, &[vec![1, 2, 3], vec![4, 5]]);
        assert_eq!(t2.cached_bundles, 5);
        assert_eq!(t2.commands, 0);
        assert_eq!(t2.elapsed_ns, 0.0);
    }

    #[test]
    fn collapse_reduces_commands_and_reads_extra() {
        let (mut p, mut sim) = mk_pipeline(true, 0);
        // gaps of 1: 0,2,4,6 -> one command with threshold >=1
        let t = p.step_token(&mut sim, &[vec![0, 2, 4, 6], vec![]]);
        assert_eq!(t.commands, 1);
        assert_eq!(t.extra_bundles, 3);
        assert_eq!(t.read_bundles, 7);
        assert_eq!(t.demanded_bundles, 4);

        let (mut p2, mut sim2) = mk_pipeline(false, 0);
        let t2 = p2.step_token(&mut sim2, &[vec![0, 2, 4, 6], vec![]]);
        assert_eq!(t2.commands, 4);
        assert!(t.elapsed_ns < t2.elapsed_ns, "collapse should be faster");
    }

    #[test]
    fn read_path_returns_real_bytes() {
        let (mut p, mut sim) = mk_pipeline(false, 0);
        // write a recognizable pattern into slot 3 of layer 0
        let (off, len) = p.space.slot_range(0, 3);
        sim.write_image(off, &vec![0xAB; len]);
        let plan = p.plan_layer(0, &[3]);
        let mut out = Vec::new();
        let t = p.commit_layer_read(&plan, &mut sim, &mut out);
        assert_eq!(out, vec![0xAB; 128]);
        assert_eq!(t.commands, 1);
    }

    #[test]
    fn layouts_redirect_reads() {
        let space = NeuronSpace::new(1, 8, 16);
        // bundle 0 lives at slot 7
        let order: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 0];
        let layouts = vec![Layout::from_order(&order).unwrap()];
        let cache = NeuronCache::new(Box::new(S3Fifo::new(0)), Admission::All, 1);
        let cfg = PipelineConfig {
            bundle_bytes: 16,
            collapse: false,
            initial_threshold: 0,
            max_threshold: 4,
            window: 4,
            sub_reads_per_run: 1,
        };
        let mut p = IoPipeline::new(cfg, space, layouts, cache);
        let plan = p.plan_layer(0, &[0]);
        assert_eq!(plan.runs[0].start, 7);
        assert_eq!(plan.commands[0].offset, 7 * 16);
    }
}
