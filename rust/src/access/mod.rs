//! Online read planning: runs, access collapse (paper §5.1) and the
//! adaptive threshold / bottleneck controller.

mod adaptive;

pub use adaptive::{AdaptiveCollapse, BottleneckState};

use crate::neuron::Slot;

/// A contiguous run of flash slots to read with ONE command.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotRun {
    pub start: Slot,
    /// Total slots read (demanded + speculative gap fill).
    pub len: u32,
    /// Speculative slots included by collapse (len - demanded).
    pub extra: u32,
}

impl SlotRun {
    pub fn end(&self) -> Slot {
        self.start + self.len
    }

    pub fn demanded(&self) -> u32 {
        self.len - self.extra
    }
}

/// Group sorted, deduplicated slots into maximal contiguous runs,
/// reusing the caller's buffer (§Perf: the per-token hot path clears
/// and refills one scratch vector instead of allocating).
pub fn plan_runs_into(sorted_slots: &[Slot], out: &mut Vec<SlotRun>) {
    debug_assert!(sorted_slots.windows(2).all(|w| w[0] < w[1]), "slots must be sorted+unique");
    out.clear();
    let mut it = sorted_slots.iter().copied();
    let Some(first) = it.next() else {
        return;
    };
    let mut start = first;
    let mut len = 1u32;
    for s in it {
        if s == start + len {
            len += 1;
        } else {
            out.push(SlotRun { start, len, extra: 0 });
            start = s;
            len = 1;
        }
    }
    out.push(SlotRun { start, len, extra: 0 });
}

/// Allocating convenience wrapper over [`plan_runs_into`].
pub fn plan_runs(sorted_slots: &[Slot]) -> Vec<SlotRun> {
    let mut runs = Vec::new();
    plan_runs_into(sorted_slots, &mut runs);
    runs
}

/// Access collapse: merge adjacent runs whose gap is at most `threshold`
/// slots, speculatively reading the `gap` slots in between (paper §5.1).
/// One merge trades `gap * bundle_bytes` extra transfer for one fewer
/// command — a win whenever the device is IOPS-bound. The output buffer
/// is cleared and refilled (must not alias `runs`).
pub fn collapse_runs_into(runs: &[SlotRun], threshold: u32, out: &mut Vec<SlotRun>) {
    out.clear();
    if threshold == 0 || runs.len() < 2 {
        out.extend_from_slice(runs);
        return;
    }
    out.push(runs[0]);
    for &r in &runs[1..] {
        let last = out.last_mut().unwrap();
        debug_assert!(r.start >= last.end(), "runs must be sorted and disjoint");
        let gap = r.start - last.end();
        if gap <= threshold {
            last.extra += gap + r.extra;
            last.len += gap + r.len;
        } else {
            out.push(r);
        }
    }
}

/// Allocating convenience wrapper over [`collapse_runs_into`].
pub fn collapse_runs(runs: &[SlotRun], threshold: u32) -> Vec<SlotRun> {
    let mut out = Vec::with_capacity(runs.len());
    collapse_runs_into(runs, threshold, &mut out);
    out
}

/// Total slots and extra slots across a plan.
pub fn plan_volume(runs: &[SlotRun]) -> (u64, u64) {
    let total: u64 = runs.iter().map(|r| r.len as u64).sum();
    let extra: u64 = runs.iter().map(|r| r.extra as u64).sum();
    (total, extra)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn slots(v: &[u32]) -> Vec<Slot> {
        v.to_vec()
    }

    #[test]
    fn runs_from_scattered_slots() {
        let r = plan_runs(&slots(&[1, 2, 3, 7, 9, 10]));
        assert_eq!(
            r,
            vec![
                SlotRun { start: 1, len: 3, extra: 0 },
                SlotRun { start: 7, len: 1, extra: 0 },
                SlotRun { start: 9, len: 2, extra: 0 },
            ]
        );
    }

    #[test]
    fn empty_and_single() {
        assert!(plan_runs(&[]).is_empty());
        assert_eq!(plan_runs(&[5]), vec![SlotRun { start: 5, len: 1, extra: 0 }]);
    }

    #[test]
    fn collapse_merges_small_gaps() {
        // paper's Figure 9: n1,n2 .. n4 with n3 missing -> one read
        let runs = plan_runs(&slots(&[0, 1, 3]));
        let merged = collapse_runs(&runs, 1);
        assert_eq!(merged, vec![SlotRun { start: 0, len: 4, extra: 1 }]);
        // threshold 0 keeps them separate
        assert_eq!(collapse_runs(&runs, 0).len(), 2);
    }

    #[test]
    fn collapse_respects_threshold() {
        let runs = plan_runs(&slots(&[0, 5])); // gap of 4
        assert_eq!(collapse_runs(&runs, 3).len(), 2);
        let m = collapse_runs(&runs, 4);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].len, 6);
        assert_eq!(m[0].extra, 4);
    }

    #[test]
    fn collapse_chains_multiple_merges() {
        let runs = plan_runs(&slots(&[0, 2, 4, 6]));
        let m = collapse_runs(&runs, 1);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].len, 7);
        assert_eq!(m[0].extra, 3);
    }

    #[test]
    fn volume_accounting() {
        let runs = collapse_runs(&plan_runs(&slots(&[0, 1, 3, 10])), 1);
        let (total, extra) = plan_volume(&runs);
        assert_eq!(total, 5); // 0..4 (4 slots incl gap) + 10
        assert_eq!(extra, 1);
    }

    #[test]
    fn prop_plans_cover_all_demanded_slots() {
        prop::run_bool(
            "collapse-covers",
            prop::Config { cases: 60, max_size: 200, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = size.max(4) * 4;
                let k = rng.range(1, size.max(2));
                let mut s: Vec<u32> = rng
                    .sample_indices(n, k.min(n))
                    .into_iter()
                    .map(|x| x as u32)
                    .collect();
                s.sort_unstable();
                let threshold = rng.below(8) as u32;
                (s, threshold)
            },
            |(s, threshold)| {
                let merged = collapse_runs(&plan_runs(s), *threshold);
                // every demanded slot inside some run
                s.iter().all(|&slot| {
                    merged.iter().any(|r| slot >= r.start && slot < r.end())
                })
                // runs sorted and disjoint
                && merged.windows(2).all(|w| w[0].end() < w[1].start)
                // extra accounting consistent: total - extra == demanded
                && {
                    let (total, extra) = plan_volume(&merged);
                    total - extra == s.len() as u64
                }
            },
        );
    }

    #[test]
    fn prop_collapse_never_increases_commands() {
        prop::run_bool(
            "collapse-monotone",
            prop::Config { cases: 40, max_size: 128, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = size.max(4) * 4;
                let k = rng.range(1, size.max(2));
                let mut s: Vec<u32> = rng
                    .sample_indices(n, k.min(n))
                    .into_iter()
                    .map(|x| x as u32)
                    .collect();
                s.sort_unstable();
                s
            },
            |s| {
                let base = plan_runs(s);
                let mut prev = base.len();
                for t in 0..6 {
                    let m = collapse_runs(&base, t);
                    if m.len() > prev {
                        return false;
                    }
                    prev = m.len();
                }
                true
            },
        );
    }
}
