//! Runtime governance of access collapse (paper §5.1):
//!
//! 1. **Extra-bandwidth trade-off** — the gap threshold is adjusted
//!    online by hill climbing on *effective* bandwidth (demanded bytes /
//!    elapsed time): after each observation window the controller keeps
//!    moving the threshold in the current direction while effective
//!    bandwidth improves, and reverses direction when it regresses.
//! 2. **Storage-bottleneck detection** — if achieved raw bandwidth is
//!    within `SATURATION_FRACTION` of the device's sustained rate, the
//!    device is bandwidth-bound, speculative reads can only hurt, and
//!    collapse is disabled until utilization drops again.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BottleneckState {
    IopsBound,
    BandwidthBound,
}

#[derive(Clone, Debug)]
pub struct AdaptiveCollapse {
    threshold: u32,
    min_threshold: u32,
    max_threshold: u32,
    /// +1 or -1: current hill-climbing direction.
    direction: i32,
    /// Effective bandwidth of the previous window (bytes/sec).
    prev_effective_bw: f64,
    /// Tokens per observation window.
    window: usize,
    seen_in_window: usize,
    /// Window accumulators.
    acc_demand_bytes: f64,
    acc_total_bytes: f64,
    acc_elapsed_ns: f64,
    state: BottleneckState,
}

/// Raw bandwidth above this fraction of saturation = bandwidth-bound.
const SATURATION_FRACTION: f64 = 0.90;

impl AdaptiveCollapse {
    pub fn new(initial_threshold: u32, max_threshold: u32, window: usize) -> Self {
        Self {
            threshold: initial_threshold.min(max_threshold),
            min_threshold: 0,
            max_threshold,
            direction: 1,
            prev_effective_bw: 0.0,
            window: window.max(1),
            seen_in_window: 0,
            acc_demand_bytes: 0.0,
            acc_total_bytes: 0.0,
            acc_elapsed_ns: 0.0,
            state: BottleneckState::IopsBound,
        }
    }

    /// Threshold the planner should use right now (0 when disabled).
    pub fn threshold(&self) -> u32 {
        match self.state {
            BottleneckState::IopsBound => self.threshold,
            BottleneckState::BandwidthBound => 0,
        }
    }

    pub fn state(&self) -> BottleneckState {
        self.state
    }

    /// Record one token's I/O outcome.
    ///
    /// `demand_bytes` — bytes of activated (useful) neurons;
    /// `total_bytes` — bytes actually transferred (incl. speculative);
    /// `elapsed_ns` — simulated flash time for the token's batch;
    /// `sat_bandwidth` — device sustained rate (bytes/sec).
    pub fn observe(
        &mut self,
        demand_bytes: f64,
        total_bytes: f64,
        elapsed_ns: f64,
        sat_bandwidth: f64,
    ) {
        self.acc_demand_bytes += demand_bytes;
        self.acc_total_bytes += total_bytes;
        self.acc_elapsed_ns += elapsed_ns;
        self.seen_in_window += 1;
        if self.seen_in_window < self.window {
            return;
        }

        let secs = (self.acc_elapsed_ns / 1e9).max(1e-12);
        let raw_bw = self.acc_total_bytes / secs;
        let effective_bw = self.acc_demand_bytes / secs;

        // (2) bottleneck detector
        self.state = if raw_bw >= SATURATION_FRACTION * sat_bandwidth {
            BottleneckState::BandwidthBound
        } else {
            BottleneckState::IopsBound
        };

        // (1) hill-climb the threshold on effective bandwidth
        if self.state == BottleneckState::IopsBound {
            if effective_bw + 1.0 < self.prev_effective_bw {
                self.direction = -self.direction;
            }
            let next = self.threshold as i64 + self.direction as i64;
            self.threshold =
                next.clamp(self.min_threshold as i64, self.max_threshold as i64) as u32;
        }
        self.prev_effective_bw = effective_bw;

        self.seen_in_window = 0;
        self.acc_demand_bytes = 0.0;
        self.acc_total_bytes = 0.0;
        self.acc_elapsed_ns = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_iops_bound_with_initial_threshold() {
        let a = AdaptiveCollapse::new(4, 16, 8);
        assert_eq!(a.threshold(), 4);
        assert_eq!(a.state(), BottleneckState::IopsBound);
    }

    #[test]
    fn detects_bandwidth_bound_and_disables() {
        let mut a = AdaptiveCollapse::new(4, 16, 2);
        // raw bandwidth ~= saturation (1e9 B/s device, 1ms for 1MB)
        for _ in 0..2 {
            a.observe(900_000.0, 1_000_000.0, 1e6, 1e9);
        }
        assert_eq!(a.state(), BottleneckState::BandwidthBound);
        assert_eq!(a.threshold(), 0);
        // utilization drops -> re-enables
        for _ in 0..2 {
            a.observe(10_000.0, 12_000.0, 1e6, 1e9);
        }
        assert_eq!(a.state(), BottleneckState::IopsBound);
        assert!(a.threshold() > 0);
    }

    #[test]
    fn climbs_up_while_improving() {
        let mut a = AdaptiveCollapse::new(2, 16, 1);
        // effective bandwidth keeps improving -> threshold keeps rising
        for i in 0..5 {
            a.observe(1_000.0 * (i + 1) as f64, 2_000.0, 1e6, 1e12);
        }
        assert!(a.threshold() > 2, "threshold={}", a.threshold());
    }

    #[test]
    fn reverses_on_regression() {
        let mut a = AdaptiveCollapse::new(8, 16, 1);
        a.observe(10_000.0, 11_000.0, 1e6, 1e12); // establish baseline
        let up = a.threshold();
        a.observe(1_000.0, 11_000.0, 1e6, 1e12); // big regression
        let down = a.threshold();
        assert!(down < up, "up={up} down={down}");
    }

    #[test]
    fn threshold_stays_in_bounds() {
        let mut a = AdaptiveCollapse::new(0, 4, 1);
        for i in 0..50 {
            // alternate improvement/regression to wander
            let d = if i % 2 == 0 { 1_000.0 } else { 100_000.0 };
            a.observe(d, 120_000.0, 1e6, 1e12);
            assert!(a.threshold() <= 4);
        }
    }
}
