//! Co-activation pattern extraction (paper §4.1, Step 1).
//!
//! For each neuron we keep a per-token activation *bitset* (one bit per
//! calibration token). Activation frequency f(i) is a popcount; the
//! co-activation count f(i,j) is the popcount of the AND of two rows —
//! 16 word-ops for a 1000-token calibration set. This makes the full
//! pairwise scan the offline greedy needs (`O(n²)` popcounts) cheap
//! enough to match the paper's Table-4 search times without ever
//! materializing an n×n matrix (Mistral's 14k-bundle layers would need
//! ~800 MB/layer dense).
//!
//! Distances: the paper defines dist(i,j) = 1 − P(ij) and always compares
//! distances, so any monotone-decreasing transform of f(i,j) induces the
//! same order; internally we rank by raw co-count and expose P(i)/P(ij)
//! for reporting and tests.

#![warn(missing_docs)]

use crate::neuron::BundleId;
use crate::trace::Trace;

/// Per-layer co-activation statistics over a calibration trace,
/// stored as one activation bitset per neuron.
#[derive(Clone, Debug)]
pub struct CoactStats {
    n_neurons: usize,
    n_tokens: usize,
    words_per_neuron: usize,
    /// Row-major: neuron i's token bitset at
    /// `bits[i*words_per_neuron .. (i+1)*words_per_neuron]`.
    bits: Vec<u64>,
    /// Total activation count over ALL neurons — Eq. 1's denominator,
    /// computed once at construction (§Perf: `p_i` used to rescan every
    /// bitset, an O(n · words) popcount per call).
    total_freq: u64,
}

impl CoactStats {
    /// Accumulate from one layer of a trace.
    pub fn from_trace_layer(trace: &Trace, layer: usize) -> Self {
        Self::from_sets(trace.per_layer, trace.layer(layer))
    }

    /// Accumulate from an iterator of per-token activation sets.
    pub fn from_sets<'a, I>(n_neurons: usize, tokens: I) -> Self
    where
        I: IntoIterator<Item = &'a [BundleId]>,
    {
        let sets: Vec<&[BundleId]> = tokens.into_iter().collect();
        let n_tokens = sets.len();
        let words = n_tokens.div_ceil(64).max(1);
        let mut bits = vec![0u64; n_neurons * words];
        let mut total_freq = 0u64;
        for (t, set) in sets.iter().enumerate() {
            let (w, b) = (t / 64, t % 64);
            for &i in set.iter() {
                let cell = &mut bits[i as usize * words + w];
                // sets may repeat a neuron; count each bit exactly once
                total_freq += u64::from(*cell & (1u64 << b) == 0);
                *cell |= 1u64 << b;
            }
        }
        Self { n_neurons, n_tokens, words_per_neuron: words, bits, total_freq }
    }

    /// Number of neurons (bundles) in the layer.
    pub fn n_neurons(&self) -> usize {
        self.n_neurons
    }

    /// Number of calibration tokens accumulated.
    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    #[inline]
    fn row(&self, i: usize) -> &[u64] {
        &self.bits[i * self.words_per_neuron..(i + 1) * self.words_per_neuron]
    }

    /// Activation count of neuron `i` over the calibration tokens.
    #[inline]
    pub fn freq(&self, i: BundleId) -> u32 {
        self.row(i as usize).iter().map(|w| w.count_ones()).sum()
    }

    /// Co-activation count of the pair (i, j).
    #[inline]
    pub fn co_count(&self, i: BundleId, j: BundleId) -> u32 {
        let (a, b) = (self.row(i as usize), self.row(j as usize));
        a.iter().zip(b).map(|(x, y)| (x & y).count_ones()).sum()
    }

    /// P(i) per Eq. 1 (frequency normalized over all neurons). The
    /// denominator is cached at construction — O(words) per call, not
    /// O(n · words).
    pub fn p_i(&self, i: BundleId) -> f64 {
        if self.total_freq == 0 {
            0.0
        } else {
            self.freq(i) as f64 / self.total_freq as f64
        }
    }

    /// Empirical pairwise activation probability (per-token), used by
    /// tests; Eq. 3's dist(i,j) = 1 − P(ij) ranks identically to
    /// ranking by co_count descending.
    pub fn p_ij(&self, i: BundleId, j: BundleId) -> f64 {
        if self.n_tokens == 0 {
            0.0
        } else {
            self.co_count(i, j) as f64 / self.n_tokens as f64
        }
    }

    /// dist(i,j) := 1 − P(ij) (paper Eq. 3, with P(ij) per-token).
    pub fn dist(&self, i: BundleId, j: BundleId) -> f64 {
        1.0 - self.p_ij(i, j)
    }

    /// The `m` strongest partners of neuron `i` (by co-count, desc),
    /// excluding zero-co-count pairs and `i` itself. Uses partial
    /// selection so memory/time stay O(n) + O(m log m) even for dense
    /// co-activation (Mistral-scale layers).
    pub fn top_partners(&self, i: BundleId, m: usize) -> Vec<(BundleId, u32)> {
        let mut all: Vec<(BundleId, u32)> = (0..self.n_neurons as u32)
            .filter(|&j| j != i)
            .map(|j| (j, self.co_count(i, j)))
            .filter(|&(_, c)| c > 0)
            .collect();
        let cmp = |a: &(BundleId, u32), b: &(BundleId, u32)| {
            b.1.cmp(&a.1).then(a.0.cmp(&b.0))
        };
        if all.len() > m {
            all.select_nth_unstable_by(m - 1, cmp);
            all.truncate(m);
        }
        all.sort_unstable_by(cmp);
        all
    }

    /// All candidate pairs for the greedy search: for each neuron its
    /// top-`m` partners, deduped (`i < j`), sorted by co-count descending.
    /// This is the kNN sparsification described in DESIGN.md — pairs
    /// outside every neuron's top-m are nearly-always-zero co-count and
    /// tie at dist≈1, so they cannot beat any retained pair.
    pub fn candidate_pairs(&self, m: usize) -> Vec<(BundleId, BundleId, u32)> {
        self.candidate_pairs_parallel(m, 1)
    }

    /// `candidate_pairs` with the O(n²) co-count scan sharded over
    /// `threads` workers (§Perf: this scan dominates the offline search;
    /// sharding by neuron range is deterministic — results are merged and
    /// globally re-sorted, so the output is identical to the serial path).
    ///
    /// # Example
    ///
    /// ```
    /// use ripple::coact::CoactStats;
    ///
    /// // three tokens over a 4-neuron layer
    /// let tokens: [&[u32]; 3] = [&[0, 1, 2], &[0, 1], &[1, 2]];
    /// let stats = CoactStats::from_sets(4, tokens.iter().copied());
    ///
    /// // sharding the scan never changes the result
    /// assert_eq!(
    ///     stats.candidate_pairs_parallel(2, 4),
    ///     stats.candidate_pairs(2),
    /// );
    ///
    /// // strongest pair first: neurons 0 and 1 co-fire twice
    /// let (a, b, count) = stats.candidate_pairs(2)[0];
    /// assert_eq!((a, b, count), (0, 1, 2));
    /// ```
    pub fn candidate_pairs_parallel(
        &self,
        m: usize,
        threads: usize,
    ) -> Vec<(BundleId, BundleId, u32)> {
        let n = self.n_neurons as u32;
        let threads = threads.clamp(1, n.max(1) as usize);
        let shard = |lo: u32, hi: u32| -> Vec<(BundleId, BundleId, u32)> {
            let mut out = Vec::with_capacity(((hi - lo) as usize) * m);
            // §Perf: reuse one scratch buffer across neurons (the naive
            // per-neuron Vec allocation dominated the scan at 16k-neuron
            // layers) and hoist row(i) out of the j loop.
            let mut scratch: Vec<(BundleId, u32)> = Vec::with_capacity(self.n_neurons);
            let cmp = |a: &(BundleId, u32), b: &(BundleId, u32)| {
                b.1.cmp(&a.1).then(a.0.cmp(&b.0))
            };
            for i in lo..hi {
                scratch.clear();
                let row_i = self.row(i as usize);
                for j in 0..n {
                    if j == i {
                        continue;
                    }
                    let row_j = self.row(j as usize);
                    let mut c = 0u32;
                    for (x, y) in row_i.iter().zip(row_j) {
                        c += (x & y).count_ones();
                    }
                    if c > 0 {
                        scratch.push((j, c));
                    }
                }
                if scratch.len() > m {
                    scratch.select_nth_unstable_by(m - 1, cmp);
                    scratch.truncate(m);
                }
                for &(j, c) in scratch.iter() {
                    let (a, b) = if i < j { (i, j) } else { (j, i) };
                    out.push((a, b, c));
                }
            }
            out
        };
        let mut pairs: Vec<(BundleId, BundleId, u32)> = if threads == 1 {
            shard(0, n)
        } else {
            let chunk = n.div_ceil(threads as u32).max(1);
            let shards: Vec<Vec<_>> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..threads as u32)
                    .map(|t| {
                        let lo = t * chunk;
                        let hi = ((t + 1) * chunk).min(n);
                        scope.spawn(move || if lo < hi { shard(lo, hi) } else { Vec::new() })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            shards.into_iter().flatten().collect()
        };
        pairs.sort_unstable();
        pairs.dedup();
        pairs.sort_unstable_by(|a, b| b.2.cmp(&a.2).then((a.0, a.1).cmp(&(b.0, b.1))));
        pairs
    }

    /// Figure-6 statistic: mean co-activation "contrast" — the ratio of
    /// the average top-partner co-count to the average random-pair
    /// co-count. >> 1 means strong visible block structure.
    pub fn contrast(&self, sample: usize, seed: u64) -> f64 {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let mut top = 0.0;
        let mut rnd = 0.0;
        let mut cnt = 0.0;
        for _ in 0..sample {
            let i = rng.below(self.n_neurons) as u32;
            let partners = self.top_partners(i, 1);
            if let Some(&(_, c)) = partners.first() {
                top += c as f64;
                let j = rng.below(self.n_neurons) as u32;
                rnd += self.co_count(i, j) as f64;
                cnt += 1.0;
            }
        }
        if cnt == 0.0 || rnd == 0.0 { f64::INFINITY } else { top / rnd }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(sets: &[&[u32]]) -> CoactStats {
        CoactStats::from_sets(8, sets.iter().copied())
    }

    #[test]
    fn freq_and_cocount() {
        let s = stats(&[&[0, 1, 2], &[0, 1], &[3]]);
        assert_eq!(s.freq(0), 2);
        assert_eq!(s.freq(1), 2);
        assert_eq!(s.freq(3), 1);
        assert_eq!(s.co_count(0, 1), 2);
        assert_eq!(s.co_count(0, 3), 0);
        assert_eq!(s.co_count(2, 1), 1);
    }

    #[test]
    fn probabilities() {
        let s = stats(&[&[0, 1], &[0]]);
        // total freq = 3; P(0) = 2/3
        assert!((s.p_i(0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.p_ij(0, 1) - 0.5).abs() < 1e-12);
        assert!((s.dist(0, 1) - 0.5).abs() < 1e-12);
        assert_eq!(s.dist(0, 3), 1.0);
    }

    #[test]
    fn p_i_sums_to_one_over_all_neurons() {
        // the cached denominator must equal the popcount rescan it
        // replaced: P sums to exactly 1 whenever anything activated
        let s = stats(&[&[0, 1, 2], &[0, 1], &[3], &[7]]);
        let sum: f64 = (0..8u32).map(|i| s.p_i(i)).sum();
        assert!((sum - 1.0).abs() < 1e-12, "sum={sum}");
        // duplicate ids within one token count once, like the bitset
        let d = stats(&[&[4, 4, 5]]);
        let sum: f64 = (0..8u32).map(|i| d.p_i(i)).sum();
        assert!((sum - 1.0).abs() < 1e-12, "sum={sum}");
        assert!((d.p_i(4) - 0.5).abs() < 1e-12);
        // and an empty trace stays at zero instead of dividing by it
        let e = stats(&[]);
        assert_eq!(e.p_i(0), 0.0);
    }

    #[test]
    fn top_partners_ordering() {
        let s = stats(&[&[0, 1, 2], &[0, 1], &[0, 2], &[0, 1]]);
        let p = s.top_partners(0, 2);
        assert_eq!(p[0], (1, 3));
        assert_eq!(p[1], (2, 2));
    }

    #[test]
    fn candidate_pairs_dedup_and_order() {
        let s = stats(&[&[0, 1, 2], &[0, 1], &[1, 2]]);
        let pairs = s.candidate_pairs(4);
        // each unordered pair appears once
        let mut seen = std::collections::HashSet::new();
        for &(a, b, _) in &pairs {
            assert!(a < b);
            assert!(seen.insert((a, b)));
        }
        // sorted by count desc
        assert!(pairs.windows(2).all(|w| w[0].2 >= w[1].2));
    }

    #[test]
    fn more_than_64_tokens() {
        // exercise multi-word bitsets
        let sets: Vec<Vec<u32>> = (0..130).map(|t| vec![(t % 8) as u32, 7]).collect();
        let refs: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
        let s = CoactStats::from_sets(8, refs.iter().copied());
        assert_eq!(s.n_tokens(), 130);
        assert_eq!(s.freq(7), 130);
        // neuron 0 fires on tokens 0,8,16,... => 17 times; 7 always co-fires
        assert_eq!(s.co_count(0, 7), s.freq(0));
    }

    #[test]
    fn contrast_high_for_correlated_trace() {
        use crate::trace::generator::{DatasetProfile, LayerTraceGen};
        let mut g = LayerTraceGen::new(1024, 100, &DatasetProfile::alpaca(), 3, 0, 11);
        let sets: Vec<Vec<u32>> = (0..256).map(|_| g.sample()).collect();
        let refs: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
        let s = CoactStats::from_sets(1024, refs.iter().copied());
        let c = s.contrast(64, 1);
        assert!(c > 3.0, "contrast={c}");
    }
}
