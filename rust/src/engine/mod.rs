//! The opt-micro inference engine: real weights in simulated flash, real
//! compute through PJRT artifacts, RIPPLE's I/O pipeline in between.
//!
//! Per decode step and layer:
//!   1. attention block        -> PJRT `attn_b{B}` artifact
//!   2. activation selection   -> host (oracle scores) or PJRT
//!                                `predictor_b{B}` (Deja-Vu low-rank)
//!   3. I/O                    -> IoPipeline: cache filter, run planning,
//!                                access collapse, UfsSim read of the
//!                                *actual bundle bytes*
//!   4. gather + sparse FFN    -> PJRT `ffn_sparse_b{B}` artifact over
//!                                the gathered top-K bundle slots
//!   5. final head             -> PJRT `head_b{B}`
//!
//! Bytes for missed bundles come from the flash image read-back (so the
//! placement/planner/reader path is on the numerical path); cached
//! bundles come from the DRAM-resident copy, which is what a cache *is*.

mod linalg;
mod weights;

pub use linalg::{argmax, layer_norm, matmul_nn, matmul_nt};
pub use weights::{Golden, ModelMeta, Tensor, Weights};

use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::cache::{KeySpace, NeuronCache};
use crate::config::{DeviceConfig, Precision};
use crate::flash::UfsSim;
use crate::metrics::RunMetrics;
use crate::neuron::{BundleId, Layout, NeuronSpace, Slot};
use crate::pipeline::{IoPipeline, LayerPlan, PipelineConfig};
use crate::prefetch::{PrefetchConfig, Prefetcher};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Executable, Runtime};
use crate::trace::Trace;

/// How activated neurons are chosen per token.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Selection {
    /// Ground truth: sign of the true FFN pre-activation (host-computed).
    Oracle,
    /// Low-rank predictor artifact; scores above `threshold` activate.
    Predictor { threshold: f32 },
}

#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub batch: usize,
    pub selection: Selection,
    pub device: DeviceConfig,
    pub cache_ratio: f64,
    pub cache_policy: String,
    pub collapse: bool,
    /// Speculative next-layer prefetch on the async flash timeline.
    /// Takes effect once a predictor is attached via `enable_prefetch`
    /// (it learns from a recorded activation trace); until then — and
    /// with `enabled: false` — the engine's flash timeline is
    /// bit-identical to the synchronous baseline.
    pub prefetch: PrefetchConfig,
    pub seed: u64,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            batch: 1,
            selection: Selection::Oracle,
            device: crate::config::devices()[0].clone(),
            cache_ratio: 0.1,
            cache_policy: "linking".to_string(),
            collapse: true,
            prefetch: PrefetchConfig::default(),
            seed: 42,
        }
    }
}

struct LayerParams {
    // attention-side literals (DRAM-resident, prefetched — paper §4.1)
    ln1_g: xla::Literal,
    ln1_b: xla::Literal,
    wq: xla::Literal,
    bq: xla::Literal,
    wk: xla::Literal,
    bk: xla::Literal,
    wv: xla::Literal,
    bv: xla::Literal,
    wo: xla::Literal,
    bo: xla::Literal,
    ln2_g: xla::Literal,
    ln2_b: xla::Literal,
    bd: xla::Literal,
    // host copies for selection + canonical bundle source
    ln2_g_h: Vec<f32>,
    ln2_b_h: Vec<f32>,
    u: Vec<f32>,  // (N, D)
    bu: Vec<f32>, // (N,)
    dn: Vec<f32>, // (N, D)
    p1: xla::Literal,
    p2: xla::Literal,
}

pub struct Engine {
    pub meta: ModelMeta,
    opts: EngineOptions,
    attn: Rc<Executable>,
    ffn_sparse: Rc<Executable>,
    ffn_dense: Rc<Executable>,
    predictor: Rc<Executable>,
    head: Rc<Executable>,
    layers: Vec<LayerParams>,
    embed: Vec<f32>,     // (V, D)
    pos_embed: Vec<f32>, // (S, D)
    ln_f_g: xla::Literal,
    ln_f_b: xla::Literal,
    embed_lit: xla::Literal,
    // serving state
    kv: Vec<(xla::Literal, xla::Literal)>,
    pos: usize,
    // I/O state
    space: NeuronSpace,
    pub sim: UfsSim,
    pipeline: IoPipeline,
    /// DRAM neuron cache — owned by the engine, borrowed by the
    /// pipeline per call (shared-state ownership, DESIGN.md §Serving).
    cache: NeuronCache,
    pub io_metrics: RunMetrics,
    /// Modeled per-layer compute window (deterministic; see DESIGN.md
    /// §Async-flash-timeline) that overlapped I/O can hide behind.
    compute_ns_per_layer: f64,
    /// When set, true activation sets are recorded per decode step.
    recorder: Option<Trace>,
    scratch: Vec<u8>,
    /// Reusable per-layer I/O plan (§Perf: the decode loop refills it
    /// instead of allocating a fresh plan per layer).
    io_plan: LayerPlan,
}

impl Engine {
    pub fn load(artifacts_dir: impl AsRef<Path>, opts: EngineOptions) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let mut rt = Runtime::cpu(dir)?;
        let meta = ModelMeta::load(dir)?;
        let w = Weights::load(dir)?;
        anyhow::ensure!(
            meta.batch_variants.contains(&opts.batch),
            "batch {} not among compiled variants {:?}",
            opts.batch,
            meta.batch_variants
        );
        let b = opts.batch;
        let attn = rt.load(&format!("attn_b{b}"))?;
        let ffn_sparse = rt.load(&format!("ffn_sparse_b{b}"))?;
        let ffn_dense = rt.load(&format!("ffn_dense_b{b}"))?;
        let predictor = rt.load(&format!("predictor_b{b}"))?;
        let head = rt.load(&format!("head_b{b}"))?;

        let d = meta.d_model as i64;
        let n = meta.d_ffn;
        let r = meta.pred_rank as i64;
        let vecl = |t: &Tensor| lit_f32(&t.data, &[t.numel() as i64]);
        let matl = |t: &Tensor, dims: &[i64]| lit_f32(&t.data, dims);

        let mut layers = Vec::with_capacity(meta.n_layers);
        for li in 0..meta.n_layers {
            let g = |name: &str| w.get(&format!("layer{li}.{name}"));
            layers.push(LayerParams {
                ln1_g: vecl(g("ln1_g")?)?,
                ln1_b: vecl(g("ln1_b")?)?,
                wq: matl(g("wq")?, &[d, d])?,
                bq: vecl(g("bq")?)?,
                wk: matl(g("wk")?, &[d, d])?,
                bk: vecl(g("bk")?)?,
                wv: matl(g("wv")?, &[d, d])?,
                bv: vecl(g("bv")?)?,
                wo: matl(g("wo")?, &[d, d])?,
                bo: vecl(g("bo")?)?,
                ln2_g: vecl(g("ln2_g")?)?,
                ln2_b: vecl(g("ln2_b")?)?,
                bd: vecl(g("bd")?)?,
                ln2_g_h: g("ln2_g")?.data.clone(),
                ln2_b_h: g("ln2_b")?.data.clone(),
                u: g("u")?.data.clone(),
                bu: g("bu")?.data.clone(),
                dn: g("dn")?.data.clone(),
                p1: matl(g("p1")?, &[d, r])?,
                p2: matl(g("p2")?, &[r, n as i64])?,
            });
        }

        let bundle_bytes = (2 * meta.d_model + 1) * Precision::Fp32.bytes_per_elem();
        let space = NeuronSpace::new(meta.n_layers, n, bundle_bytes);
        let layouts = vec![Layout::identity(n); meta.n_layers];
        let image = build_flash_image(&space, &layouts, &layers);
        let sim = UfsSim::with_image(opts.device.clone(), image);

        let cache_cap = (space.total() as f64 * opts.cache_ratio) as usize;
        let cache = NeuronCache::from_config(
            &opts.cache_policy,
            cache_cap,
            KeySpace::of(&space),
            opts.seed,
        )?;
        let pcfg = PipelineConfig {
            bundle_bytes,
            collapse: opts.collapse,
            initial_threshold: 4,
            max_threshold: ((opts.device.knee_bytes() / bundle_bytes as f64) as u32).max(1),
            window: 16,
            sub_reads_per_run: 1,
        };
        let pipeline = IoPipeline::new(pcfg, space.clone(), layouts);

        // Deterministic per-layer compute estimate (attention projections
        // plus the sparse FFN over top-K bundles) — the window overlapped
        // I/O gets to hide behind. No wall clock: the simulated timeline
        // must replay bit-identically.
        let dm = meta.d_model as f64;
        let layer_flops = 8.0 * dm * dm + 4.0 * meta.top_k as f64 * dm;
        let compute_ns_per_layer = layer_flops
            / (crate::bench::workloads::EFFECTIVE_GFLOPS_OP12 * opts.device.soc_speed);

        let kv = Self::fresh_kv(&meta, b)?;
        Ok(Self {
            attn,
            ffn_sparse,
            ffn_dense,
            predictor,
            head,
            embed: w.get("embed")?.data.clone(),
            pos_embed: w.get("pos_embed")?.data.clone(),
            ln_f_g: vecl(w.get("ln_f_g")?)?,
            ln_f_b: vecl(w.get("ln_f_b")?)?,
            embed_lit: matl(w.get("embed")?, &[meta.vocab as i64, d])?,
            layers,
            kv,
            pos: 0,
            space,
            sim,
            pipeline,
            cache,
            io_metrics: RunMetrics::new(),
            compute_ns_per_layer,
            recorder: None,
            scratch: Vec::new(),
            io_plan: LayerPlan::default(),
            meta,
            opts,
        })
    }

    fn fresh_kv(meta: &ModelMeta, b: usize) -> Result<Vec<(xla::Literal, xla::Literal)>> {
        let zeros = vec![0f32; b * meta.max_seq * meta.d_model];
        let dims = [b as i64, meta.max_seq as i64, meta.d_model as i64];
        (0..meta.n_layers)
            .map(|_| Ok((lit_f32(&zeros, &dims)?, lit_f32(&zeros, &dims)?)))
            .collect()
    }

    pub fn batch(&self) -> usize {
        self.opts.batch
    }

    pub fn options(&self) -> &EngineOptions {
        &self.opts
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn layouts(&self) -> &[Layout] {
        self.pipeline.layouts()
    }

    /// Reset the KV cache / position for a new request batch.
    pub fn reset_sequence(&mut self) -> Result<()> {
        self.kv = Self::fresh_kv(&self.meta, self.opts.batch)?;
        self.pos = 0;
        Ok(())
    }

    /// Zero every per-run I/O statistic — the run metrics, the flash
    /// simulator counters AND the cache hit/miss/cross-hit counters —
    /// while keeping cache *contents* warm. Runners that reuse one
    /// engine across measurement windows must call this between
    /// windows; resetting only the first two silently carries cache
    /// stats across rows (the ISSUE 9 stats-bleed bug).
    pub fn reset_io_stats(&mut self) {
        self.io_metrics = RunMetrics::new();
        self.sim.reset_stats();
        self.cache.reset_stats();
    }

    /// Install new flash layouts (the offline stage's output): rewrites
    /// the flash image and rebuilds the pipeline (cache is cold after a
    /// re-placement, as in the paper's offline->online handoff).
    pub fn set_layouts(&mut self, layouts: Vec<Layout>) -> Result<()> {
        anyhow::ensure!(layouts.len() == self.meta.n_layers, "layout count mismatch");
        let image = build_flash_image(&self.space, &layouts, &self.layers);
        self.sim = UfsSim::with_image(self.opts.device.clone(), image);
        let cache_cap = (self.space.total() as f64 * self.opts.cache_ratio) as usize;
        self.cache = NeuronCache::from_config(
            &self.opts.cache_policy,
            cache_cap,
            KeySpace::of(&self.space),
            self.opts.seed,
        )?;
        let pcfg = self.pipeline.config().clone();
        let prefetcher = self.pipeline.take_prefetcher();
        self.pipeline = IoPipeline::new(pcfg, self.space.clone(), layouts);
        self.pipeline.set_prefetcher(prefetcher);
        self.io_metrics = RunMetrics::new();
        Ok(())
    }

    /// Attach the speculative prefetcher, learned from a recorded
    /// activation trace (usually the output of [`Engine::calibrate`]).
    /// Requires `opts.prefetch.enabled`; the trace geometry must match
    /// the model. From here on `decode_step` runs the overlapped
    /// submit/speculate/complete schedule per layer.
    pub fn enable_prefetch(&mut self, calib: &Trace) -> Result<()> {
        anyhow::ensure!(
            self.opts.prefetch.enabled,
            "prefetch disabled in EngineOptions"
        );
        anyhow::ensure!(
            calib.n_layers == self.meta.n_layers && calib.per_layer == self.meta.d_ffn,
            "calibration trace geometry ({}x{}) does not match model ({}x{})",
            calib.n_layers,
            calib.per_layer,
            self.meta.n_layers,
            self.meta.d_ffn
        );
        let pf = Prefetcher::from_trace(calib, self.opts.prefetch.clone(), 2);
        self.pipeline.set_prefetcher(Some(pf));
        Ok(())
    }

    pub fn prefetch_active(&self) -> bool {
        self.pipeline.has_prefetcher()
    }

    /// Modeled per-layer compute window on the simulated timeline, ns.
    pub fn compute_ns_per_layer(&self) -> f64 {
        self.compute_ns_per_layer
    }

    /// Start/stop recording ground-truth activation traces.
    pub fn record_traces(&mut self, on: bool) {
        self.recorder = if on {
            Some(Trace::new(self.meta.n_layers, self.meta.d_ffn))
        } else {
            None
        };
    }

    pub fn take_trace(&mut self) -> Option<Trace> {
        self.recorder.take()
    }

    fn embed_ids(&self, ids: &[u8]) -> Vec<f32> {
        let d = self.meta.d_model;
        let mut x = vec![0f32; ids.len() * d];
        for (r, &id) in ids.iter().enumerate() {
            let e = &self.embed[id as usize * d..(id as usize + 1) * d];
            let p = &self.pos_embed[self.pos * d..(self.pos + 1) * d];
            for i in 0..d {
                x[r * d + i] = e[i] + p[i];
            }
        }
        x
    }

    /// Oracle pre-activation scores for one layer: ln(x) @ U^T + bu.
    fn oracle_scores(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        let (b, d, n) = (self.opts.batch, self.meta.d_model, self.meta.d_ffn);
        let lp = &self.layers[layer];
        let xn = layer_norm(x, b, d, &lp.ln2_g_h, &lp.ln2_b_h, 1e-5);
        matmul_nt(&xn, b, d, &lp.u, n, Some(&lp.bu))
    }

    /// Select activated bundles from per-batch scores (union over batch,
    /// capped at top_k by best score).
    fn select(&self, scores: &[f32], threshold: f32) -> Vec<BundleId> {
        let (b, n, k) = (self.opts.batch, self.meta.d_ffn, self.meta.top_k);
        let mut best = vec![f32::NEG_INFINITY; n];
        for r in 0..b {
            for j in 0..n {
                let s = scores[r * n + j];
                if s > best[j] {
                    best[j] = s;
                }
            }
        }
        let mut act: Vec<BundleId> =
            (0..n as u32).filter(|&j| best[j as usize] > threshold).collect();
        if act.len() > k {
            act.sort_by(|&a, &bb| {
                best[bb as usize].partial_cmp(&best[a as usize]).unwrap()
            });
            act.truncate(k);
        }
        act.sort_unstable();
        act
    }

    /// One decode step over the whole batch; returns (B * vocab) logits.
    /// Token ids beyond the batch are an error; caller pads.
    pub fn decode_step(&mut self, ids: &[u8]) -> Result<Vec<f32>> {
        anyhow::ensure!(ids.len() == self.opts.batch, "ids len != batch");
        anyhow::ensure!(self.pos < self.meta.max_seq, "sequence full (max_seq)");
        let (b, d) = (self.opts.batch, self.meta.d_model);
        let mut x = self.embed_ids(ids);
        let mut recorded: Vec<Vec<BundleId>> = Vec::new();

        for li in 0..self.meta.n_layers {
            // 1. attention (PJRT)
            let x_lit = lit_f32(&x, &[b as i64, d as i64])?;
            let lp = &self.layers[li];
            let (kc, vc) = &self.kv[li];
            let outs = self.attn.run(&[
                x_lit.clone(),
                lp.ln1_g.clone(),
                lp.ln1_b.clone(),
                lp.wq.clone(),
                lp.bq.clone(),
                lp.wk.clone(),
                lp.bk.clone(),
                lp.wv.clone(),
                lp.bv.clone(),
                lp.wo.clone(),
                lp.bo.clone(),
                kc.clone(),
                vc.clone(),
                lit_i32(self.pos as i32),
            ])?;
            anyhow::ensure!(outs.len() == 3, "attn artifact must return (y, k, v)");
            let mut it = outs.into_iter();
            let y_lit = it.next().unwrap();
            self.kv[li] = (it.next().unwrap(), it.next().unwrap());
            let y = to_vec_f32(&y_lit)?;

            // 2. selection
            let oracle = matches!(self.opts.selection, Selection::Oracle)
                || self.recorder.is_some();
            let oracle_scores = if oracle { Some(self.oracle_scores(li, &y)) } else { None };
            let active = match self.opts.selection {
                Selection::Oracle => self.select(oracle_scores.as_ref().unwrap(), 0.0),
                Selection::Predictor { threshold } => {
                    let lp = &self.layers[li];
                    let outs = self.predictor.run(&[
                        y_lit.clone(),
                        lp.ln2_g.clone(),
                        lp.ln2_b.clone(),
                        lp.p1.clone(),
                        lp.p2.clone(),
                    ])?;
                    let scores = to_vec_f32(&outs[0])?;
                    self.select(&scores, threshold)
                }
            };
            if let Some(sc) = &oracle_scores {
                if self.recorder.is_some() {
                    recorded.push(self.select(sc, 0.0));
                }
            }

            // 3. I/O through the RIPPLE pipeline (real bytes). With a
            // prefetcher attached, the demand batch is submitted on the
            // async timeline, speculation for the next layer goes out
            // behind it, and the modeled compute window advances the
            // clock so the speculative reads drain underneath it.
            self.scratch.clear();
            let mut plan = std::mem::take(&mut self.io_plan);
            self.pipeline.plan_layer_into(&mut self.cache, li, &active, &mut plan);
            let mut buf = std::mem::take(&mut self.scratch);
            let io = if self.pipeline.has_prefetcher() {
                let ticket =
                    self.pipeline.submit_layer_read(&plan, &mut self.sim, &mut buf);
                if li + 1 < self.meta.n_layers {
                    self.pipeline
                        .prefetch_layer(&self.cache, &mut self.sim, li + 1, &active);
                }
                let io = self
                    .pipeline
                    .complete_layer(&mut self.cache, &plan, ticket, &mut self.sim);
                self.sim.advance_compute(self.compute_ns_per_layer);
                self.io_metrics.record_compute(self.compute_ns_per_layer);
                io
            } else {
                self.pipeline
                    .commit_layer_read(&mut self.cache, &plan, &mut self.sim, &mut buf)
            };
            self.io_metrics.record(&io, self.space.bundle_bytes);

            // 4. gather + sparse FFN (PJRT). Restore the reusable
            // buffers BEFORE propagating any error so a recovering
            // caller keeps the pre-reserved hot-path capacities.
            let gathered = self.gather(li, &active, &plan, &buf);
            self.scratch = buf;
            self.io_plan = plan;
            let (u_act, bu_act, d_act) = gathered?;
            let lp = &self.layers[li];
            let k = self.meta.top_k as i64;
            let outs = self.ffn_sparse.run(&[
                y_lit,
                lp.ln2_g.clone(),
                lp.ln2_b.clone(),
                lit_f32(&u_act, &[k, d as i64])?,
                lit_f32(&bu_act, &[k])?,
                lit_f32(&d_act, &[k, d as i64])?,
                lp.bd.clone(),
            ])?;
            x = to_vec_f32(&outs[0])?;
        }

        if let Some(tr) = &mut self.recorder {
            tr.push_token(recorded);
        }

        // 5. head (PJRT)
        let x_lit = lit_f32(&x, &[b as i64, d as i64])?;
        let outs = self.head.run(&[
            x_lit,
            self.ln_f_g.clone(),
            self.ln_f_b.clone(),
            self.embed_lit.clone(),
        ])?;
        self.pos += 1;
        to_vec_f32(&outs[0])
    }

    /// Gather the activated bundles into top-K slot buffers. Missed slots
    /// come from the flash read-back `buf`; cached slots from the
    /// DRAM-resident canonical weights.
    fn gather(
        &self,
        layer: usize,
        active: &[BundleId],
        plan: &crate::pipeline::LayerPlan,
        buf: &[u8],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (d, k) = (self.meta.d_model, self.meta.top_k);
        anyhow::ensure!(active.len() <= k, "active exceeds top_k");
        let bb = self.space.bundle_bytes;
        // slot -> byte offset in buf (runs are concatenated in order)
        let mut run_bases = Vec::with_capacity(plan.runs.len());
        let mut base = 0usize;
        for r in &plan.runs {
            run_bases.push((r.start, r.end(), base));
            base += r.len as usize * bb;
        }
        anyhow::ensure!(base == buf.len(), "read buffer size mismatch");
        let locate = |slot: Slot| -> Option<usize> {
            run_bases
                .iter()
                .find(|&&(s, e, _)| slot >= s && slot < e)
                .map(|&(s, _, b0)| b0 + (slot - s) as usize * bb)
        };

        let layout = &self.pipeline.layouts()[layer];
        let lp = &self.layers[layer];
        let mut u_act = vec![0f32; k * d];
        let mut bu_act = vec![0f32; k];
        let mut d_act = vec![0f32; k * d];
        for (si, &bid) in active.iter().enumerate() {
            let slot = layout.slot_of(bid);
            if let Some(off) = locate(slot) {
                // bundle bytes: u_row (d f32) | bu (1 f32) | d_row (d f32)
                let words = &buf[off..off + bb];
                for i in 0..d {
                    u_act[si * d + i] =
                        f32::from_le_bytes(words[i * 4..i * 4 + 4].try_into().unwrap());
                }
                bu_act[si] =
                    f32::from_le_bytes(words[d * 4..d * 4 + 4].try_into().unwrap());
                for i in 0..d {
                    let o = (d + 1 + i) * 4;
                    d_act[si * d + i] =
                        f32::from_le_bytes(words[o..o + 4].try_into().unwrap());
                }
            } else {
                // cache hit: DRAM-resident copy
                let b = bid as usize;
                u_act[si * d..(si + 1) * d].copy_from_slice(&lp.u[b * d..(b + 1) * d]);
                bu_act[si] = lp.bu[b];
                d_act[si * d..(si + 1) * d].copy_from_slice(&lp.dn[b * d..(b + 1) * d]);
            }
        }
        Ok((u_act, bu_act, d_act))
    }

    /// Exact dense decode step (no sparsity, no I/O) — oracle/baseline.
    pub fn decode_step_dense(&mut self, ids: &[u8]) -> Result<Vec<f32>> {
        anyhow::ensure!(ids.len() == self.opts.batch, "ids len != batch");
        let (b, d, n) = (self.opts.batch, self.meta.d_model, self.meta.d_ffn);
        let mut x = self.embed_ids(ids);
        for li in 0..self.meta.n_layers {
            let x_lit = lit_f32(&x, &[b as i64, d as i64])?;
            let lp = &self.layers[li];
            let (kc, vc) = &self.kv[li];
            let outs = self.attn.run(&[
                x_lit,
                lp.ln1_g.clone(),
                lp.ln1_b.clone(),
                lp.wq.clone(),
                lp.bq.clone(),
                lp.wk.clone(),
                lp.bk.clone(),
                lp.wv.clone(),
                lp.bv.clone(),
                lp.wo.clone(),
                lp.bo.clone(),
                kc.clone(),
                vc.clone(),
                lit_i32(self.pos as i32),
            ])?;
            let mut it = outs.into_iter();
            let y_lit = it.next().unwrap();
            self.kv[li] = (it.next().unwrap(), it.next().unwrap());
            let outs = self.ffn_dense.run(&[
                y_lit,
                lp.ln2_g.clone(),
                lp.ln2_b.clone(),
                lit_f32(&lp.u, &[n as i64, d as i64])?,
                lit_f32(&lp.bu, &[n as i64])?,
                lit_f32(&lp.dn, &[n as i64, d as i64])?,
                lp.bd.clone(),
            ])?;
            x = to_vec_f32(&outs[0])?;
        }
        let x_lit = lit_f32(&x, &[b as i64, d as i64])?;
        let outs = self.head.run(&[
            x_lit,
            self.ln_f_g.clone(),
            self.ln_f_b.clone(),
            self.embed_lit.clone(),
        ])?;
        self.pos += 1;
        to_vec_f32(&outs[0])
    }

    /// Greedy generation for a batch of prompts (right-padded with 0x20).
    /// Returns one generated byte-vector per prompt slot.
    pub fn generate(
        &mut self,
        prompts: &[Vec<u8>],
        max_new: usize,
        dense: bool,
    ) -> Result<Vec<Vec<u8>>> {
        let b = self.opts.batch;
        anyhow::ensure!(!prompts.is_empty() && prompts.len() <= b, "bad prompt count");
        let plen = prompts.iter().map(Vec::len).max().unwrap();
        anyhow::ensure!(plen + max_new <= self.meta.max_seq, "exceeds max_seq");
        self.reset_sequence()?;

        let step = |ids: &[u8], this: &mut Self| -> Result<Vec<f32>> {
            if dense { this.decode_step_dense(ids) } else { this.decode_step(ids) }
        };

        let mut logits = vec![0f32; b * self.meta.vocab];
        for t in 0..plen {
            let ids: Vec<u8> = (0..b)
                .map(|r| {
                    prompts
                        .get(r)
                        .and_then(|p| p.get(t).copied())
                        .unwrap_or(b' ')
                })
                .collect();
            logits = step(&ids, self)?;
        }
        let mut outs = vec![Vec::with_capacity(max_new); prompts.len()];
        let v = self.meta.vocab;
        let mut cur: Vec<u8> =
            (0..b).map(|r| argmax(&logits[r * v..(r + 1) * v]) as u8).collect();
        for _ in 0..max_new {
            for (r, o) in outs.iter_mut().enumerate() {
                o.push(cur[r]);
            }
            if outs[0].len() == max_new {
                break;
            }
            logits = step(&cur.clone(), self)?;
            cur = (0..b).map(|r| argmax(&logits[r * v..(r + 1) * v]) as u8).collect();
        }
        Ok(outs)
    }

    /// Calibration helper: generate with trace recording from a prompt,
    /// then return the recorded ground-truth activation trace.
    pub fn calibrate(&mut self, prompt: &[u8], tokens: usize) -> Result<Trace> {
        self.record_traces(true);
        let prompts = vec![prompt.to_vec(); self.opts.batch.min(1).max(1)];
        let mut batch_prompts = Vec::new();
        for _ in 0..self.opts.batch {
            batch_prompts.push(prompts[0].clone());
        }
        self.generate(&batch_prompts, tokens, false)?;
        self.take_trace()
            .context("recorder vanished")
    }
}

fn build_flash_image(
    space: &NeuronSpace,
    layouts: &[Layout],
    layers: &[LayerParams],
) -> Vec<u8> {
    let d = layers[0].u.len() / layers[0].bu.len();
    let mut image = vec![0u8; space.image_bytes() as usize];
    for (li, layout) in layouts.iter().enumerate() {
        let lp = &layers[li];
        for slot in 0..space.per_layer as u32 {
            let b = layout.bundle_at(slot) as usize;
            let (off, _) = space.slot_range(li, slot);
            let mut o = off as usize;
            for i in 0..d {
                image[o..o + 4].copy_from_slice(&lp.u[b * d + i].to_le_bytes());
                o += 4;
            }
            image[o..o + 4].copy_from_slice(&lp.bu[b].to_le_bytes());
            o += 4;
            for i in 0..d {
                image[o..o + 4].copy_from_slice(&lp.dn[b * d + i].to_le_bytes());
                o += 4;
            }
        }
    }
    image
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    fn engine(opts: EngineOptions) -> Option<Engine> {
        let dir = default_artifacts_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Engine::load(dir, opts).unwrap())
    }

    #[test]
    fn sparse_oracle_matches_golden_prefix() {
        // With oracle selection and enough top-K slots, the sparse path
        // must reproduce the dense golden decode bit-for-bit tokens.
        let Some(mut e) = engine(EngineOptions::default()) else { return };
        let golden = Golden::load(default_artifacts_dir()).unwrap();
        let out = e
            .generate(&[golden.prompt.clone()], golden.generated.len(), false)
            .unwrap();
        assert_eq!(out[0], golden.generated, "sparse decode diverged from golden");
    }

    #[test]
    fn dense_matches_golden_logits() {
        let Some(mut e) = engine(EngineOptions::default()) else { return };
        let golden = Golden::load(default_artifacts_dir()).unwrap();
        e.reset_sequence().unwrap();
        let mut logits = Vec::new();
        for t in 0..golden.prompt.len() {
            logits = e.decode_step_dense(&[golden.prompt[t]]).unwrap();
        }
        for (a, b) in logits.iter().zip(&golden.first_logits) {
            assert!((a - b).abs() < 1e-3, "dense logits diverge: {a} vs {b}");
        }
    }

    #[test]
    fn io_metrics_flow() {
        let Some(mut e) = engine(EngineOptions::default()) else { return };
        e.generate(&[b"hello".to_vec()], 4, false).unwrap();
        assert!(e.io_metrics.tokens >= 8);
        assert!(e.io_metrics.totals.commands > 0);
        assert!(e.sim.stats().total_bytes > 0);
    }

    #[test]
    fn replacement_preserves_numerics() {
        // Re-placing neurons permutes flash but must not change outputs.
        let Some(mut e) = engine(EngineOptions::default()) else { return };
        let prompt = b"the quick".to_vec();
        let base = e.generate(&[prompt.clone()], 6, false).unwrap();

        let trace = e.calibrate(b"the quick brown fox", 24).unwrap();
        let layouts = crate::placement::place_model(
            &trace,
            crate::placement::GreedyParams::default(),
            2,
        );
        e.set_layouts(layouts).unwrap();
        let after = e.generate(&[prompt], 6, false).unwrap();
        assert_eq!(base, after, "re-placement changed model outputs");
    }

    #[test]
    fn predictor_mode_runs() {
        let opts = EngineOptions {
            selection: Selection::Predictor { threshold: -0.1 },
            ..Default::default()
        };
        let Some(mut e) = engine(opts) else { return };
        let out = e.generate(&[b"abc".to_vec()], 4, false).unwrap();
        assert_eq!(out[0].len(), 4);
    }

    #[test]
    fn prefetch_preserves_numerics() {
        // Speculation only changes *when* bytes move, never which bytes
        // feed the FFN: outputs must be identical with prefetch on.
        let opts = EngineOptions {
            prefetch: PrefetchConfig { enabled: true, ..Default::default() },
            ..Default::default()
        };
        let Some(mut e) = engine(opts) else { return };
        let prompt = b"the quick".to_vec();
        let base = e.generate(&[prompt.clone()], 6, false).unwrap();
        assert!(!e.prefetch_active());

        let calib = e.calibrate(b"the quick brown fox", 24).unwrap();
        e.enable_prefetch(&calib).unwrap();
        assert!(e.prefetch_active());
        let after = e.generate(&[prompt], 6, false).unwrap();
        assert_eq!(base, after, "prefetch changed model outputs");
        let t = &e.io_metrics.totals;
        assert!(t.prefetch_hit_bundles + t.prefetch_wasted_bundles > 0);
        assert!(t.stall_ns <= t.elapsed_ns + 1e-6);
    }

    #[test]
    fn batch4_generates_per_slot() {
        let opts = EngineOptions { batch: 4, ..Default::default() };
        let Some(mut e) = engine(opts) else { return };
        let prompts = vec![
            b"aaa".to_vec(),
            b"the quick".to_vec(),
            b"012".to_vec(),
            b"llm".to_vec(),
        ];
        let outs = e.generate(&prompts, 3, false).unwrap();
        assert_eq!(outs.len(), 4);
        assert!(outs.iter().all(|o| o.len() == 3));
    }
}
