//! Tiny f32 host-side linear algebra for the coordinator's *selection*
//! work (layernorm + score matmuls + argmax). All FLOP-heavy model math
//! runs in the PJRT artifacts; these helpers only size with the neuron
//! count, mirroring how serving stacks keep routing math on the host.

/// y = layernorm(x) * g + b, row-wise over a (rows, d) matrix.
pub fn layer_norm(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32], eps: f32) -> Vec<f32> {
    assert_eq!(x.len(), rows * d);
    assert_eq!(g.len(), d);
    assert_eq!(b.len(), d);
    let mut out = vec![0f32; rows * d];
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for i in 0..d {
            out[r * d + i] = (row[i] - mean) * inv * g[i] + b[i];
        }
    }
    out
}

/// C(rows, n) = A(rows, d) @ B(n, d)^T (+ `bias[n]` if given).
pub fn matmul_nt(a: &[f32], rows: usize, d: usize, b: &[f32], n: usize, bias: Option<&[f32]>) -> Vec<f32> {
    assert_eq!(a.len(), rows * d);
    assert_eq!(b.len(), n * d);
    let mut out = vec![0f32; rows * n];
    for r in 0..rows {
        let arow = &a[r * d..(r + 1) * d];
        let orow = &mut out[r * n..(r + 1) * n];
        for j in 0..n {
            let brow = &b[j * d..(j + 1) * d];
            let mut acc = 0f32;
            for k in 0..d {
                acc += arow[k] * brow[k];
            }
            orow[j] = acc + bias.map_or(0.0, |bb| bb[j]);
        }
    }
    out
}

/// C(rows, n) = A(rows, d) @ B(d, n) — row-major B.
pub fn matmul_nn(a: &[f32], rows: usize, d: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), rows * d);
    assert_eq!(b.len(), d * n);
    let mut out = vec![0f32; rows * n];
    for r in 0..rows {
        let arow = &a[r * d..(r + 1) * d];
        let orow = &mut out[r * n..(r + 1) * n];
        for k in 0..d {
            let av = arow[k];
            if av == 0.0 {
                continue;
            }
            let brow = &b[k * n..(k + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_norm_normalizes() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let y = layer_norm(&x, 1, 4, &g, &b, 1e-5);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn matmul_nt_small() {
        // A = [[1,2]], B = [[3,4],[5,6]] -> [1*3+2*4, 1*5+2*6] = [11, 17]
        let c = matmul_nt(&[1.0, 2.0], 1, 2, &[3.0, 4.0, 5.0, 6.0], 2, None);
        assert_eq!(c, vec![11.0, 17.0]);
        let cb = matmul_nt(&[1.0, 2.0], 1, 2, &[3.0, 4.0, 5.0, 6.0], 2, Some(&[1.0, -1.0]));
        assert_eq!(cb, vec![12.0, 16.0]);
    }

    #[test]
    fn matmul_nn_matches_nt_via_transpose() {
        // B(d,n) vs Bt(n,d)
        let a = [0.5, -1.0, 2.0]; // 1x3
        let b_nn = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3x2 row-major
        let b_nt = [1.0, 3.0, 5.0, 2.0, 4.0, 6.0]; // 2x3 (transposed)
        let c1 = matmul_nn(&a, 1, 3, &b_nn, 2);
        let c2 = matmul_nt(&a, 1, 3, &b_nt, 2, None);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
