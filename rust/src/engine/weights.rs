//! Loader for the AOT weight export (artifacts/weights.bin + manifest.json)
//! and the model/golden metadata emitted by python/compile/aot.py.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug)]
pub struct Weights {
    tensors: HashMap<String, Tensor>,
}

impl Weights {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .context("reading manifest.json")?;
        let manifest = Json::parse(&manifest_text).context("parsing manifest.json")?;
        anyhow::ensure!(manifest.req_str("dtype")? == "f32", "expected f32 weights");
        let raw = std::fs::read(dir.join("weights.bin")).context("reading weights.bin")?;
        anyhow::ensure!(
            raw.len() == manifest.req_usize("total_bytes")?,
            "weights.bin size mismatch"
        );
        let mut tensors = HashMap::new();
        let entries = manifest
            .req("tensors")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest `tensors` is not an object"))?;
        for (name, meta) in entries {
            let shape: Vec<usize> = meta
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("bad shape for {name}"))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            let offset = meta.req_usize("offset_bytes")?;
            let n = meta.req_usize("num_elems")?;
            anyhow::ensure!(
                shape.iter().product::<usize>() == n,
                "shape/numel mismatch for {name}"
            );
            anyhow::ensure!(offset + n * 4 <= raw.len(), "tensor {name} out of bounds");
            let mut data = vec![0f32; n];
            for (i, chunk) in raw[offset..offset + n * 4].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            tensors.insert(name.clone(), Tensor { shape, data });
        }
        Ok(Self { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight tensor `{name}` missing from manifest"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

/// model_config.json — must mirror python/compile/model.py::ModelConfig.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
    pub top_k: usize,
    pub pred_rank: usize,
    pub batch_variants: Vec<usize>,
}

impl ModelMeta {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(dir.as_ref().join("model_config.json"))
            .context("reading model_config.json")?;
        let j = Json::parse(&text)?;
        Ok(Self {
            vocab: j.req_usize("vocab")?,
            d_model: j.req_usize("d_model")?,
            n_heads: j.req_usize("n_heads")?,
            n_layers: j.req_usize("n_layers")?,
            d_ffn: j.req_usize("d_ffn")?,
            max_seq: j.req_usize("max_seq")?,
            top_k: j.req_usize("top_k")?,
            pred_rank: j.req_usize("pred_rank")?,
            batch_variants: j
                .req("batch_variants")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("batch_variants not a list"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
        })
    }
}

/// golden.json — dense-decode test vectors.
#[derive(Clone, Debug)]
pub struct Golden {
    pub prompt: Vec<u8>,
    pub generated: Vec<u8>,
    pub first_logits: Vec<f32>,
    pub last_logits: Vec<f32>,
}

impl Golden {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(dir.as_ref().join("golden.json"))
            .context("reading golden.json")?;
        let j = Json::parse(&text)?;
        let bytes = |key: &str| -> Result<Vec<u8>> {
            Ok(j.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("{key} not a list"))?
                .iter()
                .filter_map(|v| v.as_usize().map(|u| u as u8))
                .collect())
        };
        let floats = |key: &str| -> Result<Vec<f32>> {
            Ok(j.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("{key} not a list"))?
                .iter()
                .filter_map(|v| v.as_f64().map(|f| f as f32))
                .collect())
        };
        Ok(Self {
            prompt: bytes("prompt")?,
            generated: bytes("generated")?,
            first_logits: floats("first_logits")?,
            last_logits: floats("last_logits")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    #[test]
    fn loads_real_manifest() {
        let dir = default_artifacts_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let w = Weights::load(&dir).unwrap();
        let meta = ModelMeta::load(&dir).unwrap();
        assert_eq!(meta.d_model, 64);
        let emb = w.get("embed").unwrap();
        assert_eq!(emb.shape, vec![meta.vocab, meta.d_model]);
        let u0 = w.get("layer0.u").unwrap();
        assert_eq!(u0.shape, vec![meta.d_ffn, meta.d_model]);
        assert!(w.get("layer0.p1").is_ok());
        assert!(w.get("nope").is_err());
    }

    #[test]
    fn loads_golden() {
        let dir = default_artifacts_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let g = Golden::load(&dir).unwrap();
        assert!(!g.prompt.is_empty());
        assert_eq!(g.first_logits.len(), 256);
        assert_eq!(g.generated.len(), 8);
    }
}
