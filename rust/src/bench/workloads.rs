//! Trace-driven experiment runner shared by every paper bench.
//!
//! A `System` bundles the placement, read granularity, collapse and cache
//! settings of each comparison point in the paper's evaluation:
//!
//! | system          | layout     | sparsity        | collapse | cache    |
//! |-----------------|------------|-----------------|----------|----------|
//! | llamacpp        | structural | none (dense     | no       | s3fifo   |
//! |                 |            | streams all     |          |          |
//! |                 |            | offloaded rows) |          |          |
//! | llmflash        | structural | activated       | no       | s3fifo   |
//! |                 |            | bundles         |          |          |
//! | ripple-offline  | ripple     | activated       | no       | s3fifo   |
//! | ripple          | ripple     | activated       | yes      | linking  |
//!
//! llama.cpp has no activation-sparsity support: its flash offload path
//! mmap-streams every offloaded weight each token (large sequential
//! reads, but ~10x the volume). LLMFlash adds sparsity + row-column
//! bundling; RIPPLE adds placement and the online stage on top.
//!
//! Scale note (DESIGN.md §Substitutions): layers of our synthetic
//! activation model are statistically identical, so experiments simulate
//! `sim_layers` representative layers and report per-token latency scaled
//! by `n_layers / sim_layers`. IOPS/bandwidth/access-length metrics are
//! ratios and need no scaling.

use crate::cache::{Admission, CacheParams, KeySpace, NeuronCache};
use crate::config::{DeviceConfig, ModelConfig, Precision};
use crate::flash::UfsSim;
use crate::metrics::{FleetSummary, RunMetrics, ServeSummary};
use crate::neuron::{Layout, NeuronSpace};
use crate::pipeline::{IoPipeline, PipelineConfig};
use crate::placement::{self, GreedyParams};
use crate::prefetch::{PrefetchConfig, Prefetcher};
use crate::trace::{DatasetProfile, Trace, TraceGen};

/// One comparison point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    LlamaCpp,
    LlmFlash,
    RippleOffline,
    Ripple,
}

impl System {
    pub fn name(self) -> &'static str {
        match self {
            System::LlamaCpp => "llama.cpp",
            System::LlmFlash => "LLMFlash",
            System::RippleOffline => "RIPPLE(off)",
            System::Ripple => "RIPPLE",
        }
    }

    pub fn all() -> [System; 4] {
        [System::LlamaCpp, System::LlmFlash, System::RippleOffline, System::Ripple]
    }

    /// Stable lowercase key used by the CLI and the harness JSON schema.
    pub fn key(self) -> &'static str {
        match self {
            System::LlamaCpp => "llamacpp",
            System::LlmFlash => "llmflash",
            System::RippleOffline => "ripple-offline",
            System::Ripple => "ripple",
        }
    }

    /// Inverse of [`System::key`]; also accepts `llama.cpp`.
    pub fn by_key(s: &str) -> anyhow::Result<System> {
        Ok(match s {
            "llamacpp" | "llama.cpp" => System::LlamaCpp,
            "llmflash" => System::LlmFlash,
            "ripple-offline" => System::RippleOffline,
            "ripple" => System::Ripple,
            _ => anyhow::bail!(
                "unknown system `{s}` (llamacpp|llmflash|ripple-offline|ripple)"
            ),
        })
    }
}

#[derive(Clone, Debug)]
pub struct Workload {
    pub model: ModelConfig,
    pub device: DeviceConfig,
    pub dataset: DatasetProfile,
    pub precision: Precision,
    pub cache_ratio: f64,
    pub calib_tokens: usize,
    pub eval_tokens: usize,
    /// Representative layers simulated (see module docs).
    pub sim_layers: usize,
    pub seed: u64,
    /// Greedy-search kNN width.
    pub knn: usize,
    /// Placement-search threads.
    pub threads: usize,
    /// Speculative prefetch on the async flash timeline (off by default:
    /// the synchronous baseline replays the seed timeline bit-for-bit).
    pub prefetch: PrefetchConfig,
    /// Modeled per-layer compute window that overlapped I/O can hide,
    /// ns. Derived from the sparse-deployment compute estimate; both the
    /// synchronous and overlapped paths count it toward end-to-end
    /// latency, only the overlapped path advances the sim clock with it.
    pub compute_ns_per_layer: f64,
}

impl Workload {
    pub fn new(model: ModelConfig, device: DeviceConfig, dataset: DatasetProfile) -> Self {
        let sim_layers = model.n_layers.min(4);
        let compute_ns_per_layer =
            compute_sparse_ms_per_token(&model, &device) * 1e6 / model.n_layers as f64;
        Self {
            model,
            device,
            dataset,
            precision: Precision::Fp16,
            cache_ratio: 0.1,
            calib_tokens: 256,
            eval_tokens: 100,
            sim_layers,
            seed: 7,
            knn: 48,
            threads: std::thread::available_parallelism().map_or(4, |n| n.get()),
            prefetch: PrefetchConfig::default(),
            compute_ns_per_layer,
        }
    }

    /// Build from a JSON-loadable `RunConfig` (CLI `simulate --config`):
    /// carries model/device/precision/cache-ratio/seed and the prefetch
    /// knobs; system axes (collapse, cache policy, placement) stay on
    /// `SystemSpec`.
    pub fn from_run(cfg: &crate::config::RunConfig, dataset: DatasetProfile) -> Self {
        let mut w = Workload::new(cfg.model.clone(), cfg.device.clone(), dataset);
        w.precision = cfg.precision;
        w.cache_ratio = cfg.cache_ratio;
        w.seed = cfg.seed;
        w.prefetch = cfg.prefetch_config();
        w
    }

    fn model_seed(&self) -> u64 {
        // community structure is a property of the model (Figure 15)
        self.model
            .name
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
                (h ^ b as u64).wrapping_mul(0x1_0000_01b3)
            })
    }

    pub fn calibration_trace(&self) -> Trace {
        let mut tg = TraceGen::new(
            self.sim_layers,
            self.model.neurons_per_layer,
            self.model.activated_per_layer(),
            &self.dataset,
            self.model_seed(),
            self.seed, // calibration stream
        );
        tg.generate(self.calib_tokens)
    }

    pub fn eval_trace(&self, dataset: &DatasetProfile) -> Trace {
        let mut tg = TraceGen::new(
            self.sim_layers,
            self.model.neurons_per_layer,
            self.model.activated_per_layer(),
            dataset,
            self.model_seed(),
            self.seed ^ 0xDEAD_BEEF, // held-out stream
        );
        tg.generate(self.eval_tokens)
    }

    /// Per-session held-out stream for multi-session serving: session 0
    /// is bit-identical to [`Workload::eval_trace`] (so a sessions=1
    /// serve run reproduces the single-stream experiment exactly);
    /// later sessions draw fresh streams over the SAME model community
    /// structure and dataset popularity — statistically-identical users
    /// whose hot sets overlap, which is what shared-cache reuse feeds on.
    pub fn session_eval_trace(&self, dataset: &DatasetProfile, session: usize) -> Trace {
        let stream = self.seed
            ^ 0xDEAD_BEEF
            ^ (session as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut tg = TraceGen::new(
            self.sim_layers,
            self.model.neurons_per_layer,
            self.model.activated_per_layer(),
            dataset,
            self.model_seed(),
            stream,
        );
        tg.generate(self.eval_tokens)
    }

    pub fn layer_scale(&self) -> f64 {
        self.model.n_layers as f64 / self.sim_layers as f64
    }
}

#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub system: System,
    pub metrics: RunMetrics,
    /// Wall-clock spent in the offline placement search, seconds
    /// (already includes co-activation extraction).
    pub placement_secs: f64,
    /// Wall-clock spent in the per-token decode loop, seconds. Like
    /// `placement_secs` it is non-deterministic and therefore lives in
    /// the Markdown report ONLY, never in the JSON (§Perf: the `perf`
    /// preset reads simulated-tokens/sec off it).
    pub decode_wall_secs: f64,
    /// Multiply per-token latency by this to get full-model figures.
    pub layer_scale: f64,
    pub bundle_bytes: usize,
    /// Multi-session serving summary (`None` for single-stream runs).
    pub serve: Option<ServeSummary>,
    /// Fleet-level open-loop summary (`None` except for fleet rows).
    pub fleet: Option<FleetSummary>,
    /// Per-phase latency attribution from the flight recorder (`None`
    /// unless the run was traced).
    pub attribution: Option<crate::obs::AttributionSummary>,
}

impl ExperimentResult {
    /// Full-model mean I/O latency per token, ms.
    pub fn latency_ms(&self) -> f64 {
        self.metrics.mean_latency_ns() * self.layer_scale / 1e6
    }

    /// Full-model simulated end-to-end latency per token, ms: compute
    /// plus the flash time compute could not hide (== compute + I/O for
    /// the synchronous systems).
    pub fn e2e_ms(&self) -> f64 {
        self.metrics.mean_e2e_ns() * self.layer_scale / 1e6
    }

    /// Fraction of flash busy time hidden under compute.
    pub fn overlap_ratio(&self) -> f64 {
        self.metrics.overlap_ratio()
    }

    /// Simulated tokens decoded per wall-clock second (Markdown-only:
    /// wall time is non-deterministic and never serialized to JSON).
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_wall_secs <= 0.0 {
            0.0
        } else {
            self.metrics.tokens as f64 / self.decode_wall_secs
        }
    }

    pub fn effective_bandwidth_gbps(&self) -> f64 {
        self.metrics.effective_bandwidth() / 1e9
    }
}

/// Compute layouts for a system given a calibration trace.
pub fn layouts_for(
    system: System,
    calib: &Trace,
    knn: usize,
    threads: usize,
) -> (Vec<Layout>, f64) {
    let n = calib.per_layer;
    match system {
        System::LlamaCpp | System::LlmFlash => {
            (vec![Layout::identity(n); calib.n_layers], 0.0)
        }
        System::RippleOffline | System::Ripple => {
            let t0 = std::time::Instant::now();
            let layouts = placement::place_model(calib, GreedyParams { knn, ..Default::default() }, threads);
            (layouts, t0.elapsed().as_secs_f64())
        }
    }
}

fn pipeline_for_spec(
    spec: SystemSpec,
    w: &Workload,
    layouts: Vec<Layout>,
) -> anyhow::Result<(IoPipeline, NeuronCache, UfsSim)> {
    pipeline_with(spec, w, layouts, None, None)
}

/// The neuron address space a workload simulates.
pub fn neuron_space(w: &Workload) -> NeuronSpace {
    let bundle_bytes = w.model.bundle_bytes(w.precision);
    NeuronSpace::new(w.sim_layers, w.model.neurons_per_layer, bundle_bytes)
}

/// Total DRAM cache capacity in slots — the paper's `cache_ratio`
/// fraction of all simulated bundles. Multi-session private-cache runs
/// split exactly this capacity across sessions so shared-vs-private
/// comparisons are at equal total DRAM.
pub fn cache_capacity(w: &Workload) -> usize {
    (neuron_space(w).total() as f64 * w.cache_ratio) as usize
}

/// The single `PipelineConfig` construction every experiment path uses
/// (default-path sweeps, ablations, and the serving simulation), so
/// rows stay comparable across runners. `fixed_threshold` pins the
/// collapse threshold by disabling the adaptive window.
pub fn pipeline_config(
    spec: SystemSpec,
    w: &Workload,
    fixed_threshold: Option<u32>,
) -> PipelineConfig {
    let bundle_bytes = w.model.bundle_bytes(w.precision);
    let knee_threshold = ((w.device.knee_bytes() / bundle_bytes as f64) as u32).max(1);
    let (initial, max_threshold, window) = match fixed_threshold {
        Some(t) => (t, t, usize::MAX),
        None => (4, knee_threshold, 16),
    };
    PipelineConfig {
        bundle_bytes,
        collapse: spec.collapse,
        initial_threshold: initial,
        max_threshold,
        window,
        sub_reads_per_run: spec.sub_reads,
    }
}

/// The single pipeline/cache/simulator construction every experiment
/// path uses (shared with the harness's ablation runner, so ablation
/// rows stay comparable with default-path rows). `admission` overrides
/// only the admission layer of the policy the spec names (the eviction
/// core and its seed are untouched, so ablation rows stay bit-identical
/// with default-path rows of the same policy). The cache is returned as
/// a separate value — pipelines borrow it per call, so multiple
/// pipelines can share one cache (DESIGN.md §Serving).
pub fn pipeline_with(
    spec: SystemSpec,
    w: &Workload,
    layouts: Vec<Layout>,
    admission: Option<Admission>,
    fixed_threshold: Option<u32>,
) -> anyhow::Result<(IoPipeline, NeuronCache, UfsSim)> {
    let space = neuron_space(w);
    let cache_cap = cache_capacity(w);
    let keys = KeySpace::of(&space);
    let mut cache = NeuronCache::from_config_with(
        spec.cache_policy,
        cache_cap,
        keys,
        w.seed,
        spec.cache_params,
    )?;
    if let Some(adm) = admission {
        cache.set_admission(adm);
    }
    let cfg = pipeline_config(spec, w, fixed_threshold);
    let sim = UfsSim::new(w.device.clone(), space.image_bytes());
    Ok((IoPipeline::new(cfg, space, layouts), cache, sim))
}

/// Fully-explicit system spec, for ablations that vary one axis at a
/// time (the named `System`s are presets of this).
#[derive(Clone, Copy, Debug)]
pub struct SystemSpec {
    pub ripple_placement: bool,
    pub collapse: bool,
    pub cache_policy: &'static str,
    /// Dense (sparsity-oblivious) streaming, llama.cpp-style.
    pub dense: bool,
    pub sub_reads: usize,
    /// Policy tuning knobs (associativity, linking-admission segment
    /// gate); the defaults reproduce the pre-cachelab behaviour exactly.
    pub cache_params: CacheParams,
}

impl SystemSpec {
    pub fn of(system: System, ffn_linears: usize) -> Self {
        match system {
            System::LlamaCpp => Self {
                ripple_placement: false,
                collapse: false,
                cache_policy: "s3fifo",
                dense: true,
                sub_reads: ffn_linears,
                cache_params: CacheParams::default(),
            },
            System::LlmFlash => Self {
                ripple_placement: false,
                collapse: false,
                cache_policy: "s3fifo",
                dense: false,
                sub_reads: 1,
                cache_params: CacheParams::default(),
            },
            System::RippleOffline => Self {
                ripple_placement: true,
                collapse: false,
                cache_policy: "s3fifo",
                dense: false,
                sub_reads: 1,
                cache_params: CacheParams::default(),
            },
            System::Ripple => Self {
                ripple_placement: true,
                collapse: true,
                cache_policy: "linking",
                dense: false,
                sub_reads: 1,
                cache_params: CacheParams::default(),
            },
        }
    }
}

/// Run one (workload, system) experiment end to end.
pub fn run_experiment(w: &Workload, system: System) -> anyhow::Result<ExperimentResult> {
    run_experiment_eval(w, system, &w.dataset.clone())
}

/// Run a fully-explicit spec (reported as the nearest named system).
pub fn run_spec(
    w: &Workload,
    spec: SystemSpec,
    eval_dataset: &DatasetProfile,
) -> anyhow::Result<ExperimentResult> {
    run_inner(w, spec, eval_dataset, named_system(spec), None)
}

/// Like [`run_spec`] but with a flight recorder attached to the flash
/// device, the I/O pipeline, and the per-token decode loop. Tracing is
/// observation-only: the simulated timeline is bit-identical to the
/// untraced run.
pub fn run_spec_traced(
    w: &Workload,
    spec: SystemSpec,
    eval_dataset: &DatasetProfile,
    trace: Option<&crate::obs::TraceHandle>,
) -> anyhow::Result<ExperimentResult> {
    run_inner(w, spec, eval_dataset, named_system(spec), trace)
}

fn named_system(spec: SystemSpec) -> System {
    match (spec.dense, spec.ripple_placement, spec.collapse) {
        (true, _, _) => System::LlamaCpp,
        (false, false, _) => System::LlmFlash,
        (false, true, false) => System::RippleOffline,
        (false, true, true) => System::Ripple,
    }
}

/// Like `run_experiment` but evaluating on a (possibly different)
/// dataset than the calibration one (Figure 15).
pub fn run_experiment_eval(
    w: &Workload,
    system: System,
    eval_dataset: &DatasetProfile,
) -> anyhow::Result<ExperimentResult> {
    run_inner(w, SystemSpec::of(system, w.model.ffn_linears), eval_dataset, system, None)
}

/// Shared-scan construction for overlapped (prefetch-enabled) ripple
/// runs: one dominant O(n²) co-count scan per layer feeds BOTH the
/// placement search and the prefetcher adjacency (§Perf). Layouts are
/// identical to `place_model`'s — same knn, same deterministic pair
/// list regardless of scan sharding. The serving path reuses this
/// exact constructor so a `sessions == 1` serve run replays the
/// single-stream experiment's placement and prefetcher bit-for-bit.
pub fn ripple_overlapped_artifacts(
    w: &Workload,
    calib: &Trace,
) -> (Vec<Layout>, Prefetcher) {
    let scan_threads = (w.threads / calib.n_layers.max(1)).max(1);
    let mut stats = Vec::with_capacity(calib.n_layers);
    let mut pairs = Vec::with_capacity(calib.n_layers);
    let mut layouts = Vec::with_capacity(calib.n_layers);
    for l in 0..calib.n_layers {
        let s = crate::coact::CoactStats::from_trace_layer(calib, l);
        let p = s.candidate_pairs_parallel(w.knn, scan_threads);
        layouts.push(placement::search_with_pairs(&s, &p).layout);
        stats.push(s);
        pairs.push(p);
    }
    let pf = Prefetcher::from_layer_pairs(&stats, &pairs, w.prefetch.clone());
    (layouts, pf)
}

fn run_inner(
    w: &Workload,
    spec: SystemSpec,
    eval_dataset: &DatasetProfile,
    report_as: System,
    trace: Option<&crate::obs::TraceHandle>,
) -> anyhow::Result<ExperimentResult> {
    let calib = w.calibration_trace();
    // speculative prefetch learns from the same calibration trace as the
    // placement search (dense streaming has nothing to speculate about)
    let overlapped = w.prefetch.enabled && !spec.dense;
    let mut prefetcher: Option<Prefetcher> = None;
    let (layouts, placement_secs) = if spec.ripple_placement {
        let t0 = std::time::Instant::now();
        let layouts = if overlapped {
            let (layouts, pf) = ripple_overlapped_artifacts(w, &calib);
            prefetcher = Some(pf);
            layouts
        } else {
            placement::place_model(
                &calib,
                GreedyParams { knn: w.knn, ..Default::default() },
                w.threads,
            )
        };
        (layouts, t0.elapsed().as_secs_f64())
    } else {
        (vec![Layout::identity(calib.per_layer); calib.n_layers], 0.0)
    };
    let (mut pipeline, mut cache, mut sim) = pipeline_for_spec(spec, w, layouts)?;
    let bundle_bytes = pipeline.config().bundle_bytes;
    if overlapped {
        let pf = match prefetcher {
            Some(pf) => pf,
            // non-ripple placement: no shared scan to reuse
            None => Prefetcher::from_trace(&calib, w.prefetch.clone(), w.threads),
        };
        pipeline.set_prefetcher(Some(pf));
    }
    if let Some(tr) = trace {
        sim.set_trace(Some(tr.clone()));
        pipeline.set_trace(Some(tr.clone()), 0);
    }

    // dense baselines execute the full FFN per token; sparse systems pay
    // the sparse-deployment estimate — e2e comparisons across systems
    // must not charge llama.cpp the sparse flop count.
    let compute_ns_per_layer = if spec.dense {
        compute_ms_per_token(&w.model, &w.device) * 1e6 / w.model.n_layers as f64
    } else {
        w.compute_ns_per_layer
    };

    let eval = w.eval_trace(eval_dataset);
    let mut metrics = RunMetrics::new();
    // dense mode is sparsity-oblivious: every token touches every bundle.
    let dense_tok: Vec<Vec<crate::neuron::BundleId>> = if spec.dense {
        vec![(0..w.model.neurons_per_layer as u32).collect(); w.sim_layers]
    } else {
        Vec::new()
    };
    let t_decode = std::time::Instant::now();
    for tok in &eval.tokens {
        let step_start = sim.clock_ns();
        let t = if spec.dense {
            let mut t = pipeline.step_token(&mut cache, &mut sim, &dense_tok);
            // effective bandwidth counts only the neurons the model
            // actually activates (paper §6.1), not what dense streaming
            // happened to transfer.
            t.demanded_bundles = tok.iter().map(Vec::len).sum::<usize>() as u64;
            t
        } else if overlapped {
            pipeline.step_token_overlapped(&mut cache, &mut sim, tok, compute_ns_per_layer)
        } else {
            pipeline.step_token(&mut cache, &mut sim, tok)
        };
        metrics.record(&t, bundle_bytes);
        // compute happens either way; only the overlapped path lets the
        // flash timeline hide underneath it
        metrics.record_compute(compute_ns_per_layer * w.sim_layers as f64);
        if let Some(tr) = trace {
            let compute = compute_ns_per_layer * w.sim_layers as f64;
            let stall = t.stall_ns;
            tr.with(|rec| rec.token(0, step_start, 0.0, stall, compute, stall + compute));
        }
    }
    let decode_wall_secs = t_decode.elapsed().as_secs_f64();
    Ok(ExperimentResult {
        system: report_as,
        metrics,
        placement_secs,
        decode_wall_secs,
        layer_scale: w.layer_scale(),
        bundle_bytes,
        serve: None,
        fleet: None,
        attribution: None,
    })
}

/// Convenience: small-scale workload used in unit/integration tests.
pub fn tiny_workload() -> Workload {
    let model = ModelConfig {
        name: "tiny",
        n_params: 1_000_000,
        n_layers: 2,
        neurons_per_layer: 512,
        neuron_dim: 128,
        ffn_linears: 2,
        sparsity: 0.12,
    };
    let mut w = Workload::new(
        model,
        crate::config::devices()[0].clone(),
        DatasetProfile::alpaca(),
    );
    w.calib_tokens = 128;
    w.eval_tokens = 40;
    w.threads = 2;
    w
}

/// Bench-scale workload: 2 representative layers, shorter calibration,
/// narrower kNN — keeps `cargo bench` in minutes while preserving every
/// ratio the paper's figures report (see module docs on layer scaling).
pub fn bench_workload(model_name: &str, device_idx: usize, dataset: DatasetProfile) -> Workload {
    let model = crate::config::model_by_name(model_name).expect("model");
    let device = crate::config::devices()[device_idx].clone();
    let mut w = Workload::new(model, device, dataset);
    w.sim_layers = w.model.n_layers.min(2);
    w.calib_tokens = 256;
    w.eval_tokens = 64;
    w.knn = 64; // Ablation A: wider kNN keeps helping up to ~64
    w
}

/// Fixed per-device effective compute throughput used by the Table-1
/// style compute estimates (calibrated so OPT-350M lands near the
/// paper's 34 ms/token on the OnePlus 12; see benches/table1).
pub const EFFECTIVE_GFLOPS_OP12: f64 = 30.0;

pub fn compute_ms_per_token(model: &ModelConfig, device: &DeviceConfig) -> f64 {
    // dense decode ~= 2 FLOPs per parameter per token
    let flops = 2.0 * model.n_params as f64;
    flops / (EFFECTIVE_GFLOPS_OP12 * 1e9 * device.soc_speed) * 1e3
}

/// Sparse-deployment compute estimate: attention runs dense (~1/3 of the
/// parameters), the FFN (~2/3) only touches activated neurons.
pub fn compute_sparse_ms_per_token(model: &ModelConfig, device: &DeviceConfig) -> f64 {
    let p = model.n_params as f64;
    let flops = 2.0 * (p / 3.0 + model.sparsity * 2.0 * p / 3.0);
    flops / (EFFECTIVE_GFLOPS_OP12 * 1e9 * device.soc_speed) * 1e3
}

/// Table-1 load model: llama.cpp-style dense streaming of the offloaded
/// half of the model per token, read in page-sized chunks.
pub fn dense_stream_load_ms(model: &ModelConfig, device: &DeviceConfig, offload: f64) -> f64 {
    let bytes = model.n_params as f64 * 2.0 * offload; // fp16
    let chunk = 128 * 1024;
    let n_chunks = (bytes / chunk as f64).ceil();
    let t_ns = n_chunks
        * (device.cmd_latency_ns + chunk as f64 / device.sat_bandwidth * 1e9);
    t_ns / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ripple_beats_llmflash_on_tiny_workload() {
        let w = tiny_workload();
        let flash = run_experiment(&w, System::LlmFlash).unwrap();
        let ripple = run_experiment(&w, System::Ripple).unwrap();
        assert!(
            ripple.latency_ms() < flash.latency_ms(),
            "ripple={:.3}ms llmflash={:.3}ms",
            ripple.latency_ms(),
            flash.latency_ms()
        );
        assert!(
            ripple.metrics.mean_access_len() > flash.metrics.mean_access_len()
        );
    }

    #[test]
    fn llamacpp_is_worst() {
        // needs a realistic geometry: dense streaming only loses when
        // sparsity is low and bundles are paper-sized (tiny_workload's
        // 514-byte bundles make sequential dense reads win, correctly)
        let mut w = bench_workload("OPT-350M", 0, DatasetProfile::alpaca());
        w.calib_tokens = 96;
        w.eval_tokens = 24;
        w.sim_layers = 1;
        w.knn = 16;
        let cpp = run_experiment(&w, System::LlamaCpp).unwrap();
        let flash = run_experiment(&w, System::LlmFlash).unwrap();
        assert!(cpp.latency_ms() > flash.latency_ms());
        // dense streaming moves ~1/sparsity x the bytes of the sparse systems
        assert!(cpp.metrics.totals.bytes > 3 * flash.metrics.totals.bytes);
        // ...but in large sequential reads, so its *raw* bandwidth is high
        // while its *effective* (activated-neuron) bandwidth is poor
        assert!(
            cpp.metrics.effective_bandwidth() < flash.metrics.effective_bandwidth()
        );
    }

    #[test]
    fn placement_time_reported() {
        let w = tiny_workload();
        let r = run_experiment(&w, System::Ripple).unwrap();
        assert!(r.placement_secs > 0.0);
        let b = run_experiment(&w, System::LlmFlash).unwrap();
        assert_eq!(b.placement_secs, 0.0);
    }

    #[test]
    fn compute_estimates_sane() {
        let models = crate::config::models();
        let dev = &crate::config::devices()[0];
        let c350 = compute_ms_per_token(&models[0], dev);
        assert!((20.0..60.0).contains(&c350), "c350={c350}");
        let load = dense_stream_load_ms(&models[0], dev, 0.5);
        assert!(load > c350, "load should dominate: {load} vs {c350}");
    }

    #[test]
    fn deterministic_experiments() {
        let w = tiny_workload();
        let a = run_experiment(&w, System::Ripple).unwrap();
        let b = run_experiment(&w, System::Ripple).unwrap();
        assert_eq!(a.metrics.totals.commands, b.metrics.totals.commands);
        assert!((a.latency_ms() - b.latency_ms()).abs() < 1e-9);
    }

    #[test]
    fn prefetch_overlaps_and_stays_deterministic() {
        let mut w = tiny_workload();
        w.prefetch.enabled = true;
        w.prefetch.budget_bytes = 64 * w.model.bundle_bytes(w.precision);
        let a = run_experiment(&w, System::Ripple).unwrap();
        assert!(a.metrics.totals.prefetch_hit_bundles > 0, "no speculative hits");
        assert!(a.overlap_ratio() > 0.0, "no overlap achieved");
        assert!(a.metrics.totals.stall_ns < a.metrics.totals.elapsed_ns);
        // bit-stable across identical runs, speculation and all
        let b = run_experiment(&w, System::Ripple).unwrap();
        assert_eq!(
            a.metrics.totals.stall_ns.to_bits(),
            b.metrics.totals.stall_ns.to_bits()
        );
        assert_eq!(
            a.metrics.totals.elapsed_ns.to_bits(),
            b.metrics.totals.elapsed_ns.to_bits()
        );
        assert_eq!(a.metrics.totals.commands, b.metrics.totals.commands);
        assert_eq!(
            a.metrics.totals.prefetch_hit_bundles,
            b.metrics.totals.prefetch_hit_bundles
        );
    }

    #[test]
    fn workload_from_run_config_carries_prefetch() {
        let cfg = crate::config::RunConfig::from_json_str(
            r#"{"model": "OPT-1.3B", "cache_ratio": 0.2, "prefetch": true,
                "prefetch_budget_bytes": 65536, "seed": 5}"#,
        )
        .unwrap();
        let w = Workload::from_run(&cfg, DatasetProfile::wikitext());
        assert_eq!(w.model.name, "OPT-1.3B");
        assert!((w.cache_ratio - 0.2).abs() < 1e-12);
        assert_eq!(w.seed, 5);
        assert!(w.prefetch.enabled);
        assert_eq!(w.prefetch.budget_bytes, 65536);
        assert_eq!(w.dataset.name, "wikitext");
    }

    #[test]
    fn session_zero_trace_is_the_single_stream_eval_trace() {
        let w = tiny_workload();
        let single = w.eval_trace(&w.dataset);
        let s0 = w.session_eval_trace(&w.dataset, 0);
        assert_eq!(single.tokens, s0.tokens, "session 0 must replay the eval stream");
        // other sessions draw distinct streams over the same structure
        let s1 = w.session_eval_trace(&w.dataset, 1);
        let s2 = w.session_eval_trace(&w.dataset, 2);
        assert_ne!(s0.tokens, s1.tokens);
        assert_ne!(s1.tokens, s2.tokens);
        assert_eq!(s1.n_tokens(), w.eval_tokens);
    }

    #[test]
    fn sync_run_reports_zero_overlap() {
        let w = tiny_workload();
        let r = run_experiment(&w, System::Ripple).unwrap();
        assert_eq!(r.metrics.totals.prefetch_hit_bundles, 0);
        assert_eq!(r.metrics.totals.prefetch_wasted_bundles, 0);
        assert!(r.overlap_ratio().abs() < 1e-9);
        // e2e = io + compute for the serial schedule
        let want = r.metrics.mean_stall_ns() + r.metrics.compute_ns / r.metrics.tokens as f64;
        assert!((r.metrics.mean_e2e_ns() - want).abs() < 1e-6);
    }
}
