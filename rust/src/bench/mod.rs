//! Shared harness for the paper-reproduction benches (benches/*.rs).
//!
//! The offline registry has no criterion; each bench is a
//! `harness = false` binary that uses `time_fn` for wall-clock loops and
//! `workloads::run_experiment` for the trace-driven simulation studies,
//! then prints the paper's rows via `util::stats::Table`.

pub mod workloads;

use std::time::Instant;

/// Wall-clock a closure: warmup, then `iters` timed runs; returns
/// (mean_ns, min_ns, max_ns).
pub fn time_fn<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> (f64, f64, f64) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    (mean, min, max)
}

/// Standard bench banner so bench_output.txt is self-describing.
pub fn banner(id: &str, title: &str) {
    println!("\n=== {id}: {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures() {
        let (mean, min, max) = time_fn(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>())
        });
        assert!(min <= mean && mean <= max);
        assert!(mean > 0.0);
    }
}
