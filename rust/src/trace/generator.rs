//! Correlated activation-trace generator.
//!
//! Stand-in for real calibration datasets (Alpaca / OpenWebText /
//! WikiText). The generative model mirrors what Figure 6 visualizes:
//! neurons belong to overlapping *communities* that tend to fire
//! together; per token a few communities light up (with per-member
//! dropout) plus some independent noise neurons.
//!
//! Crucially — matching the paper's Figure 15 finding that co-activation
//! is "an intrinsic property of the model itself" — the community
//! *structure* is derived from the model seed only; a dataset profile
//! merely re-weights which communities are popular and how noisy
//! activation is. Placements learned on one dataset therefore transfer
//! to another, exactly as in the paper.
//!
//! Community members are drawn uniformly over bundle ids, so the
//! structural (model-order) layout has no accidental locality — adjacent
//! rows of a weight matrix are not correlated, as in real LLMs.

use crate::neuron::BundleId;
use crate::util::rng::{Rng, Zipf};

/// Dataset-level knobs (the model's community structure is shared).
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Zipf skew over community popularity: higher = hotter head.
    pub zipf_s: f64,
    /// Probability each member of an active community fires.
    pub member_p: f64,
    /// Fraction of a token's activations that are independent noise.
    pub noise_frac: f64,
    /// Seed folded into community *popularity* (not structure).
    pub weight_seed: u64,
}

impl DatasetProfile {
    pub fn alpaca() -> Self {
        // Task-specific instructions: strongly clustered, low noise.
        Self { name: "alpaca", zipf_s: 1.10, member_p: 0.90, noise_frac: 0.08, weight_seed: 101 }
    }

    pub fn openwebtext() -> Self {
        // Web-scale mixture: flatter community popularity, noisier.
        Self { name: "openwebtext", zipf_s: 0.85, member_p: 0.82, noise_frac: 0.16, weight_seed: 202 }
    }

    pub fn wikitext() -> Self {
        // Encyclopedic: in between, fairly regular.
        Self { name: "wikitext", zipf_s: 1.00, member_p: 0.87, noise_frac: 0.11, weight_seed: 303 }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "alpaca" => Ok(Self::alpaca()),
            "openwebtext" => Ok(Self::openwebtext()),
            "wikitext" => Ok(Self::wikitext()),
            _ => anyhow::bail!("unknown dataset `{name}` (alpaca|openwebtext|wikitext)"),
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::alpaca(), Self::openwebtext(), Self::wikitext()]
    }
}

/// Per-layer generator.
pub struct LayerTraceGen {
    n_neurons: usize,
    target_active: usize,
    communities: Vec<Vec<BundleId>>,
    popularity: Zipf,
    /// Community index permutation: maps popularity rank -> community
    /// (dataset-specific, so different datasets heat different clusters).
    rank_to_community: Vec<usize>,
    member_p: f64,
    noise_frac: f64,
    rng: Rng,
}

impl LayerTraceGen {
    pub fn new(
        n_neurons: usize,
        target_active: usize,
        profile: &DatasetProfile,
        model_seed: u64,
        layer: usize,
        stream_seed: u64,
    ) -> Self {
        assert!(target_active >= 1 && target_active <= n_neurons);
        // Community structure: model-intrinsic (model_seed + layer only).
        let mut struct_rng = Rng::new(model_seed ^ (layer as u64).wrapping_mul(0x1000_0000_1b3));
        let mean_size = (n_neurons / 64).clamp(8, 96);
        let n_comm = (2 * n_neurons / mean_size).max(4);
        let communities: Vec<Vec<BundleId>> = (0..n_comm)
            .map(|_| {
                let size = struct_rng.range(mean_size / 2, mean_size * 3 / 2 + 1);
                let mut m: Vec<BundleId> = struct_rng
                    .sample_indices(n_neurons, size.min(n_neurons))
                    .into_iter()
                    .map(|i| i as BundleId)
                    .collect();
                m.sort_unstable();
                m
            })
            .collect();
        // Popularity ranking: dataset-specific.
        let mut rank_to_community: Vec<usize> = (0..n_comm).collect();
        let mut weight_rng =
            Rng::new(profile.weight_seed ^ model_seed ^ (layer as u64).wrapping_mul(0xcbf2_9ce4));
        weight_rng.shuffle(&mut rank_to_community);
        Self {
            n_neurons,
            target_active,
            communities,
            popularity: Zipf::new(n_comm, profile.zipf_s),
            rank_to_community,
            member_p: profile.member_p,
            noise_frac: profile.noise_frac,
            rng: Rng::new(stream_seed ^ (layer as u64).wrapping_mul(0x9E37_79B9)),
        }
    }

    /// Mean community size (for picking how many to light per token).
    fn mean_members(&self) -> f64 {
        let total: usize = self.communities.iter().map(Vec::len).sum();
        total as f64 / self.communities.len() as f64 * self.member_p
    }

    /// Sample one token's activated bundle set (sorted, deduped).
    pub fn sample(&mut self) -> Vec<BundleId> {
        let noise_target = (self.target_active as f64 * self.noise_frac) as usize;
        let comm_target = self.target_active - noise_target;
        let n_comm_active =
            ((comm_target as f64 / self.mean_members()).round() as usize).max(1);

        let mut active: Vec<BundleId> = Vec::with_capacity(self.target_active * 2);
        for _ in 0..n_comm_active {
            let rank = self.popularity.sample(&mut self.rng);
            let c = &self.communities[self.rank_to_community[rank]];
            for &m in c {
                if self.rng.chance(self.member_p) {
                    active.push(m);
                }
            }
        }
        for _ in 0..noise_target {
            active.push(self.rng.below(self.n_neurons) as BundleId);
        }
        active.sort_unstable();
        active.dedup();
        active
    }
}

/// Whole-model generator: one `LayerTraceGen` per layer.
pub struct TraceGen {
    pub layers: Vec<LayerTraceGen>,
}

impl TraceGen {
    pub fn new(
        n_layers: usize,
        n_neurons: usize,
        target_active: usize,
        profile: &DatasetProfile,
        model_seed: u64,
        stream_seed: u64,
    ) -> Self {
        let layers = (0..n_layers)
            .map(|l| {
                LayerTraceGen::new(n_neurons, target_active, profile, model_seed, l, stream_seed)
            })
            .collect();
        Self { layers }
    }

    /// Generate a full trace of `n_tokens`.
    pub fn generate(&mut self, n_tokens: usize) -> super::Trace {
        let n_layers = self.layers.len();
        let per_layer = self.layers[0].n_neurons;
        let mut tr = super::Trace::new(n_layers, per_layer);
        for _ in 0..n_tokens {
            let tok = self.layers.iter_mut().map(|l| l.sample()).collect();
            tr.push_token(tok);
        }
        tr
    }
}

/// Open-loop arrival process for the fleet simulator (DESIGN.md §Fleet).
///
/// Times are virtual nanoseconds on the same axis as the serving sim's
/// `clock_ns`. Every process is generated deterministically from a
/// [`Rng`] stream, so a fleet sweep point is a pure function of its
/// seed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Deterministic spacing: session `i` arrives at exactly
    /// `i * spacing_ns` (the closed-loop shape `SessionManager` uses,
    /// kept bit-compatible for the golden reduction test).
    Fixed {
        /// Gap between consecutive arrivals, virtual ns.
        spacing_ns: f64,
    },
    /// Memoryless Poisson stream: exponential inter-arrival gaps.
    Poisson {
        /// Mean arrival rate, sessions per virtual second.
        rate_per_s: f64,
    },
    /// Bursty traffic: bursts of `burst` *coincident* arrivals, with
    /// exponential gaps between bursts sized so the long-run mean rate
    /// stays `rate_per_s`. The coincident timestamps deliberately
    /// exercise event-heap tie-breaking.
    Bursty {
        /// Long-run mean arrival rate, sessions per virtual second.
        rate_per_s: f64,
        /// Arrivals per burst (>= 1; 1 degenerates to Poisson).
        burst: usize,
    },
    /// Diurnal load curve: a Poisson process whose instantaneous rate
    /// swings sinusoidally around `rate_per_s`, sampled by thinning a
    /// homogeneous process at the peak rate.
    Diurnal {
        /// Mean arrival rate, sessions per virtual second.
        rate_per_s: f64,
        /// Period of one load cycle, virtual seconds.
        period_s: f64,
        /// Swing amplitude in `[0, 1]`: instantaneous rate is
        /// `rate * (1 + depth * sin(2*pi*t/period))`.
        depth: f64,
    },
}

/// Stateful generator yielding one monotone non-decreasing arrival time
/// per call. `Fixed` is index-based (`i as f64 * spacing_ns`, not an
/// accumulated sum) so it reproduces `SessionManager`'s arrival grid
/// bit-for-bit.
pub struct ArrivalGen {
    process: ArrivalProcess,
    rng: Rng,
    t_ns: f64,
    idx: u64,
    burst_left: usize,
}

impl ArrivalGen {
    pub fn new(process: ArrivalProcess, seed: u64) -> Self {
        match process {
            ArrivalProcess::Fixed { spacing_ns } => {
                assert!(spacing_ns.is_finite() && spacing_ns >= 0.0);
            }
            ArrivalProcess::Poisson { rate_per_s } => {
                assert!(rate_per_s.is_finite() && rate_per_s > 0.0);
            }
            ArrivalProcess::Bursty { rate_per_s, burst } => {
                assert!(rate_per_s.is_finite() && rate_per_s > 0.0);
                assert!(burst >= 1);
            }
            ArrivalProcess::Diurnal { rate_per_s, period_s, depth } => {
                assert!(rate_per_s.is_finite() && rate_per_s > 0.0);
                assert!(period_s.is_finite() && period_s > 0.0);
                assert!((0.0..=1.0).contains(&depth));
            }
        }
        Self { process, rng: Rng::new(seed), t_ns: 0.0, idx: 0, burst_left: 0 }
    }

    /// Exponential gap with the given rate (events per ns). `1 - u` keeps
    /// the argument of `ln` strictly positive.
    fn exp_gap(&mut self, rate_per_ns: f64) -> f64 {
        -(1.0 - self.rng.f64()).ln() / rate_per_ns
    }

    /// Next arrival time, virtual ns (non-decreasing across calls).
    pub fn next_ns(&mut self) -> f64 {
        match self.process {
            ArrivalProcess::Fixed { spacing_ns } => {
                let t = self.idx as f64 * spacing_ns;
                self.idx += 1;
                t
            }
            ArrivalProcess::Poisson { rate_per_s } => {
                self.t_ns += self.exp_gap(rate_per_s / 1e9);
                self.t_ns
            }
            ArrivalProcess::Bursty { rate_per_s, burst } => {
                if self.burst_left == 0 {
                    // bursts arrive at rate/burst so the mean stays put
                    self.t_ns += self.exp_gap(rate_per_s / burst as f64 / 1e9);
                    self.burst_left = burst;
                }
                self.burst_left -= 1;
                self.t_ns
            }
            ArrivalProcess::Diurnal { rate_per_s, period_s, depth } => {
                // thinning: candidates at the peak rate, accepted with
                // probability rate(t)/peak — exact for rate(t) <= peak
                let peak_per_ns = rate_per_s * (1.0 + depth) / 1e9;
                loop {
                    self.t_ns += self.exp_gap(peak_per_ns);
                    let phase = 2.0 * std::f64::consts::PI * self.t_ns
                        / (period_s * 1e9);
                    let accept = (1.0 + depth * phase.sin()) / (1.0 + depth);
                    if self.rng.f64() < accept {
                        return self.t_ns;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(profile: DatasetProfile, seed: u64) -> LayerTraceGen {
        LayerTraceGen::new(4096, 400, &profile, 7, 0, seed)
    }

    #[test]
    fn sample_sorted_unique_in_range() {
        let mut g = gen(DatasetProfile::alpaca(), 1);
        for _ in 0..50 {
            let s = g.sample();
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| (i as usize) < 4096));
            assert!(!s.is_empty());
        }
    }

    #[test]
    fn sparsity_near_target() {
        let mut g = gen(DatasetProfile::wikitext(), 2);
        let mean: f64 =
            (0..200).map(|_| g.sample().len() as f64).sum::<f64>() / 200.0;
        // within 40% of target (communities make exact control loose)
        assert!((240.0..560.0).contains(&mean), "mean={mean}");
    }

    #[test]
    fn coactivation_exceeds_independence() {
        // Two neurons in the same community co-fire far more often than
        // two random neurons would under independence.
        let mut g = gen(DatasetProfile::alpaca(), 3);
        let samples: Vec<Vec<BundleId>> = (0..400).map(|_| g.sample()).collect();
        // find the most frequent pair among members of community 0
        let c0 = g.communities[0].clone();
        let (a, b) = (c0[0], c0[1]);
        let fa = samples.iter().filter(|s| s.binary_search(&a).is_ok()).count() as f64;
        let fb = samples.iter().filter(|s| s.binary_search(&b).is_ok()).count() as f64;
        let fab = samples
            .iter()
            .filter(|s| s.binary_search(&a).is_ok() && s.binary_search(&b).is_ok())
            .count() as f64;
        let n = samples.len() as f64;
        // joint frequency must beat the independence baseline clearly
        assert!(
            fab / n > 2.0 * (fa / n) * (fb / n),
            "fab={fab} fa={fa} fb={fb}"
        );
    }

    #[test]
    fn structure_shared_across_datasets() {
        // Same model seed => same communities, independent of profile.
        let g1 = gen(DatasetProfile::alpaca(), 1);
        let g2 = gen(DatasetProfile::openwebtext(), 9);
        assert_eq!(g1.communities, g2.communities);
        // ...but popularity ranking differs
        assert_ne!(g1.rank_to_community, g2.rank_to_community);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = gen(DatasetProfile::alpaca(), 5);
        let mut b = gen(DatasetProfile::alpaca(), 5);
        for _ in 0..10 {
            assert_eq!(a.sample(), b.sample());
        }
    }

    #[test]
    fn whole_model_generate() {
        let mut tg = TraceGen::new(2, 512, 64, &DatasetProfile::wikitext(), 3, 4);
        let tr = tg.generate(20);
        assert_eq!(tr.n_tokens(), 20);
        assert_eq!(tr.n_layers, 2);
        let sp = tr.sparsity();
        assert!(sp > 0.0 && sp < 0.5, "sparsity={sp}");
    }

    // ---- open-loop arrival processes -----------------------------------

    fn arrivals(p: ArrivalProcess, seed: u64, n: usize) -> Vec<f64> {
        let mut g = ArrivalGen::new(p, seed);
        (0..n).map(|_| g.next_ns()).collect()
    }

    fn all_processes() -> Vec<ArrivalProcess> {
        vec![
            ArrivalProcess::Fixed { spacing_ns: 2.5e6 },
            ArrivalProcess::Poisson { rate_per_s: 1_000.0 },
            ArrivalProcess::Bursty { rate_per_s: 1_000.0, burst: 8 },
            ArrivalProcess::Diurnal { rate_per_s: 1_000.0, period_s: 0.5, depth: 0.8 },
        ]
    }

    #[test]
    fn arrivals_deterministic_given_seed() {
        for p in all_processes() {
            let a = arrivals(p, 42, 500);
            let b = arrivals(p, 42, 500);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{p:?}: same seed must replay the exact sequence"
            );
            // a different seed moves every stochastic process
            if !matches!(p, ArrivalProcess::Fixed { .. }) {
                let c = arrivals(p, 43, 500);
                assert_ne!(a, c, "{p:?}: seed must matter");
            }
        }
    }

    #[test]
    fn arrivals_monotone_nonnegative() {
        for p in all_processes() {
            let a = arrivals(p, 7, 2_000);
            assert!(a[0] >= 0.0);
            assert!(
                a.windows(2).all(|w| w[0] <= w[1]),
                "{p:?}: arrival times must be non-decreasing"
            );
        }
    }

    #[test]
    fn fixed_matches_session_manager_grid_bitwise() {
        // the golden reduction depends on `i as f64 * spacing`, not an
        // accumulated sum (which rounds differently)
        let spacing = 0.3e6;
        let a = arrivals(ArrivalProcess::Fixed { spacing_ns: spacing }, 0, 64);
        for (i, t) in a.iter().enumerate() {
            assert_eq!(t.to_bits(), (i as f64 * spacing).to_bits());
        }
    }

    #[test]
    fn poisson_empirical_mean_within_tolerance() {
        // rate 1000/s => mean gap 1e6 ns; 8000 samples keep the sample
        // mean within ~4 sigma of 10%
        let a = arrivals(ArrivalProcess::Poisson { rate_per_s: 1_000.0 }, 11, 8_000);
        let mean_gap = a.last().unwrap() / (a.len() - 1) as f64;
        assert!(
            (0.9e6..1.1e6).contains(&mean_gap),
            "poisson mean inter-arrival {mean_gap} ns, want ~1e6"
        );
    }

    #[test]
    fn bursty_emits_coincident_groups_at_the_target_rate() {
        let p = ArrivalProcess::Bursty { rate_per_s: 1_000.0, burst: 8 };
        let a = arrivals(p, 5, 8_000);
        // arrivals come in groups of exactly `burst` equal timestamps
        for chunk in a.chunks(8) {
            assert!(chunk.iter().all(|t| t.to_bits() == chunk[0].to_bits()));
        }
        assert!(a[7] < a[8], "distinct bursts must be separated in time");
        // long-run mean rate stays ~rate_per_s
        let mean_gap = a.last().unwrap() / (a.len() - 1) as f64;
        assert!((0.85e6..1.15e6).contains(&mean_gap), "bursty mean gap {mean_gap}");
    }

    #[test]
    fn diurnal_mean_rate_stays_near_nominal() {
        // thinning preserves the mean: over whole periods the time-average
        // of rate*(1 + depth*sin) is the nominal rate
        let p = ArrivalProcess::Diurnal { rate_per_s: 1_000.0, period_s: 0.1, depth: 0.9 };
        let a = arrivals(p, 13, 10_000);
        let mean_gap = a.last().unwrap() / (a.len() - 1) as f64;
        assert!(
            (0.85e6..1.15e6).contains(&mean_gap),
            "diurnal mean inter-arrival {mean_gap} ns, want ~1e6"
        );
    }
}
