//! Correlated activation-trace generator.
//!
//! Stand-in for real calibration datasets (Alpaca / OpenWebText /
//! WikiText). The generative model mirrors what Figure 6 visualizes:
//! neurons belong to overlapping *communities* that tend to fire
//! together; per token a few communities light up (with per-member
//! dropout) plus some independent noise neurons.
//!
//! Crucially — matching the paper's Figure 15 finding that co-activation
//! is "an intrinsic property of the model itself" — the community
//! *structure* is derived from the model seed only; a dataset profile
//! merely re-weights which communities are popular and how noisy
//! activation is. Placements learned on one dataset therefore transfer
//! to another, exactly as in the paper.
//!
//! Community members are drawn uniformly over bundle ids, so the
//! structural (model-order) layout has no accidental locality — adjacent
//! rows of a weight matrix are not correlated, as in real LLMs.

use crate::neuron::BundleId;
use crate::util::rng::{Rng, Zipf};

/// Dataset-level knobs (the model's community structure is shared).
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Zipf skew over community popularity: higher = hotter head.
    pub zipf_s: f64,
    /// Probability each member of an active community fires.
    pub member_p: f64,
    /// Fraction of a token's activations that are independent noise.
    pub noise_frac: f64,
    /// Seed folded into community *popularity* (not structure).
    pub weight_seed: u64,
}

impl DatasetProfile {
    pub fn alpaca() -> Self {
        // Task-specific instructions: strongly clustered, low noise.
        Self { name: "alpaca", zipf_s: 1.10, member_p: 0.90, noise_frac: 0.08, weight_seed: 101 }
    }

    pub fn openwebtext() -> Self {
        // Web-scale mixture: flatter community popularity, noisier.
        Self { name: "openwebtext", zipf_s: 0.85, member_p: 0.82, noise_frac: 0.16, weight_seed: 202 }
    }

    pub fn wikitext() -> Self {
        // Encyclopedic: in between, fairly regular.
        Self { name: "wikitext", zipf_s: 1.00, member_p: 0.87, noise_frac: 0.11, weight_seed: 303 }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "alpaca" => Ok(Self::alpaca()),
            "openwebtext" => Ok(Self::openwebtext()),
            "wikitext" => Ok(Self::wikitext()),
            _ => anyhow::bail!("unknown dataset `{name}` (alpaca|openwebtext|wikitext)"),
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::alpaca(), Self::openwebtext(), Self::wikitext()]
    }
}

/// Per-layer generator.
pub struct LayerTraceGen {
    n_neurons: usize,
    target_active: usize,
    communities: Vec<Vec<BundleId>>,
    popularity: Zipf,
    /// Community index permutation: maps popularity rank -> community
    /// (dataset-specific, so different datasets heat different clusters).
    rank_to_community: Vec<usize>,
    member_p: f64,
    noise_frac: f64,
    rng: Rng,
}

impl LayerTraceGen {
    pub fn new(
        n_neurons: usize,
        target_active: usize,
        profile: &DatasetProfile,
        model_seed: u64,
        layer: usize,
        stream_seed: u64,
    ) -> Self {
        assert!(target_active >= 1 && target_active <= n_neurons);
        // Community structure: model-intrinsic (model_seed + layer only).
        let mut struct_rng = Rng::new(model_seed ^ (layer as u64).wrapping_mul(0x1000_0000_1b3));
        let mean_size = (n_neurons / 64).clamp(8, 96);
        let n_comm = (2 * n_neurons / mean_size).max(4);
        let communities: Vec<Vec<BundleId>> = (0..n_comm)
            .map(|_| {
                let size = struct_rng.range(mean_size / 2, mean_size * 3 / 2 + 1);
                let mut m: Vec<BundleId> = struct_rng
                    .sample_indices(n_neurons, size.min(n_neurons))
                    .into_iter()
                    .map(|i| i as BundleId)
                    .collect();
                m.sort_unstable();
                m
            })
            .collect();
        // Popularity ranking: dataset-specific.
        let mut rank_to_community: Vec<usize> = (0..n_comm).collect();
        let mut weight_rng =
            Rng::new(profile.weight_seed ^ model_seed ^ (layer as u64).wrapping_mul(0xcbf2_9ce4));
        weight_rng.shuffle(&mut rank_to_community);
        Self {
            n_neurons,
            target_active,
            communities,
            popularity: Zipf::new(n_comm, profile.zipf_s),
            rank_to_community,
            member_p: profile.member_p,
            noise_frac: profile.noise_frac,
            rng: Rng::new(stream_seed ^ (layer as u64).wrapping_mul(0x9E37_79B9)),
        }
    }

    /// Mean community size (for picking how many to light per token).
    fn mean_members(&self) -> f64 {
        let total: usize = self.communities.iter().map(Vec::len).sum();
        total as f64 / self.communities.len() as f64 * self.member_p
    }

    /// Sample one token's activated bundle set (sorted, deduped).
    pub fn sample(&mut self) -> Vec<BundleId> {
        let noise_target = (self.target_active as f64 * self.noise_frac) as usize;
        let comm_target = self.target_active - noise_target;
        let n_comm_active =
            ((comm_target as f64 / self.mean_members()).round() as usize).max(1);

        let mut active: Vec<BundleId> = Vec::with_capacity(self.target_active * 2);
        for _ in 0..n_comm_active {
            let rank = self.popularity.sample(&mut self.rng);
            let c = &self.communities[self.rank_to_community[rank]];
            for &m in c {
                if self.rng.chance(self.member_p) {
                    active.push(m);
                }
            }
        }
        for _ in 0..noise_target {
            active.push(self.rng.below(self.n_neurons) as BundleId);
        }
        active.sort_unstable();
        active.dedup();
        active
    }
}

/// Whole-model generator: one `LayerTraceGen` per layer.
pub struct TraceGen {
    pub layers: Vec<LayerTraceGen>,
}

impl TraceGen {
    pub fn new(
        n_layers: usize,
        n_neurons: usize,
        target_active: usize,
        profile: &DatasetProfile,
        model_seed: u64,
        stream_seed: u64,
    ) -> Self {
        let layers = (0..n_layers)
            .map(|l| {
                LayerTraceGen::new(n_neurons, target_active, profile, model_seed, l, stream_seed)
            })
            .collect();
        Self { layers }
    }

    /// Generate a full trace of `n_tokens`.
    pub fn generate(&mut self, n_tokens: usize) -> super::Trace {
        let n_layers = self.layers.len();
        let per_layer = self.layers[0].n_neurons;
        let mut tr = super::Trace::new(n_layers, per_layer);
        for _ in 0..n_tokens {
            let tok = self.layers.iter_mut().map(|l| l.sample()).collect();
            tr.push_token(tok);
        }
        tr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(profile: DatasetProfile, seed: u64) -> LayerTraceGen {
        LayerTraceGen::new(4096, 400, &profile, 7, 0, seed)
    }

    #[test]
    fn sample_sorted_unique_in_range() {
        let mut g = gen(DatasetProfile::alpaca(), 1);
        for _ in 0..50 {
            let s = g.sample();
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| (i as usize) < 4096));
            assert!(!s.is_empty());
        }
    }

    #[test]
    fn sparsity_near_target() {
        let mut g = gen(DatasetProfile::wikitext(), 2);
        let mean: f64 =
            (0..200).map(|_| g.sample().len() as f64).sum::<f64>() / 200.0;
        // within 40% of target (communities make exact control loose)
        assert!((240.0..560.0).contains(&mean), "mean={mean}");
    }

    #[test]
    fn coactivation_exceeds_independence() {
        // Two neurons in the same community co-fire far more often than
        // two random neurons would under independence.
        let mut g = gen(DatasetProfile::alpaca(), 3);
        let samples: Vec<Vec<BundleId>> = (0..400).map(|_| g.sample()).collect();
        // find the most frequent pair among members of community 0
        let c0 = g.communities[0].clone();
        let (a, b) = (c0[0], c0[1]);
        let fa = samples.iter().filter(|s| s.binary_search(&a).is_ok()).count() as f64;
        let fb = samples.iter().filter(|s| s.binary_search(&b).is_ok()).count() as f64;
        let fab = samples
            .iter()
            .filter(|s| s.binary_search(&a).is_ok() && s.binary_search(&b).is_ok())
            .count() as f64;
        let n = samples.len() as f64;
        // joint frequency must beat the independence baseline clearly
        assert!(
            fab / n > 2.0 * (fa / n) * (fb / n),
            "fab={fab} fa={fa} fb={fb}"
        );
    }

    #[test]
    fn structure_shared_across_datasets() {
        // Same model seed => same communities, independent of profile.
        let g1 = gen(DatasetProfile::alpaca(), 1);
        let g2 = gen(DatasetProfile::openwebtext(), 9);
        assert_eq!(g1.communities, g2.communities);
        // ...but popularity ranking differs
        assert_ne!(g1.rank_to_community, g2.rank_to_community);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = gen(DatasetProfile::alpaca(), 5);
        let mut b = gen(DatasetProfile::alpaca(), 5);
        for _ in 0..10 {
            assert_eq!(a.sample(), b.sample());
        }
    }

    #[test]
    fn whole_model_generate() {
        let mut tg = TraceGen::new(2, 512, 64, &DatasetProfile::wikitext(), 3, 4);
        let tr = tg.generate(20);
        assert_eq!(tr.n_tokens(), 20);
        assert_eq!(tr.n_layers, 2);
        let sp = tr.sparsity();
        assert!(sp > 0.0 && sp < 0.5, "sparsity={sp}");
    }
}
