//! Activation traces: which FFN bundles fire for each token.
//!
//! Two sources feed the same format:
//! * `generator` — the synthetic correlated-activation model standing in
//!   for Alpaca / OpenWebText / WikiText calibration runs (DESIGN.md
//!   §Substitutions), and
//! * the engine's recorder — *real* ReLU activations of opt-micro.

pub mod generator;

pub use generator::{ArrivalGen, ArrivalProcess, DatasetProfile, LayerTraceGen, TraceGen};

use crate::neuron::BundleId;

/// An in-memory trace: `tokens[t][layer]` = sorted activated bundle ids.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub n_layers: usize,
    pub per_layer: usize,
    pub tokens: Vec<Vec<Vec<BundleId>>>,
}

impl Trace {
    pub fn new(n_layers: usize, per_layer: usize) -> Self {
        Self { n_layers, per_layer, tokens: Vec::new() }
    }

    /// Append one token's activations (one sorted vec per layer).
    pub fn push_token(&mut self, per_layer_actives: Vec<Vec<BundleId>>) {
        assert_eq!(per_layer_actives.len(), self.n_layers);
        debug_assert!(per_layer_actives
            .iter()
            .all(|v| v.windows(2).all(|w| w[0] < w[1])));
        self.tokens.push(per_layer_actives);
    }

    pub fn n_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Iterator over one layer's activation sets.
    pub fn layer(&self, layer: usize) -> impl Iterator<Item = &[BundleId]> + '_ {
        self.tokens.iter().map(move |t| t[layer].as_slice())
    }

    /// Mean fraction of bundles activated per token (across all layers).
    pub fn sparsity(&self) -> f64 {
        if self.tokens.is_empty() {
            return 0.0;
        }
        let total: usize = self
            .tokens
            .iter()
            .map(|t| t.iter().map(Vec::len).sum::<usize>())
            .sum();
        total as f64 / (self.tokens.len() * self.n_layers * self.per_layer) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_iterate() {
        let mut tr = Trace::new(2, 8);
        tr.push_token(vec![vec![1, 3], vec![0, 7]]);
        tr.push_token(vec![vec![2], vec![0]]);
        assert_eq!(tr.n_tokens(), 2);
        let l0: Vec<_> = tr.layer(0).collect();
        assert_eq!(l0[0], &[1, 3]);
        assert_eq!(l0[1], &[2]);
    }

    #[test]
    fn sparsity_computed() {
        let mut tr = Trace::new(1, 10);
        tr.push_token(vec![vec![0, 1, 2]]);
        tr.push_token(vec![vec![5]]);
        assert!((tr.sparsity() - 0.2).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn layer_arity_checked() {
        let mut tr = Trace::new(2, 8);
        tr.push_token(vec![vec![1]]);
    }
}
