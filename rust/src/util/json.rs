//! Minimal JSON parser + writer (the offline registry has no `serde`).
//!
//! Supports the full JSON grammar minus exotic escapes (\u surrogate
//! pairs are decoded; other escapes per RFC 8259). Used for configs,
//! artifact manifests and experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce readable errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a number"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a number"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a string"))
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let t = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(t, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // surrogate pair: 😀
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"k":null},"t":true}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn writer_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn helpers() {
        let j = obj(vec![("n", num(3.0)), ("s", s("hi"))]);
        assert_eq!(j.req_usize("n").unwrap(), 3);
        assert_eq!(j.req_str("s").unwrap(), "hi");
        assert!(j.req_usize("missing").is_err());
    }
}
