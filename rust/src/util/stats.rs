//! Summary statistics and latency recording for benches and metrics.

/// Streaming scalar summary (count/mean/min/max via Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exact-percentile recorder: stores samples, sorts on query.
/// Fine for bench-scale sample counts (<= millions).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Pre-size for `n` further samples so recording stays off the
    /// allocator (the zero-alloc serve gate records per-token latencies
    /// through here).
    pub fn reserve(&mut self, n: usize) {
        self.xs.reserve(n);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// p in [0, 100]; nearest-rank method.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = (p / 100.0 * (self.xs.len() - 1) as f64).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }

    /// The p99.9 tail (fleet SLO accounting). Nearest-rank like every
    /// other percentile here: below ~500 samples the 99.9th rank rounds
    /// to the last element, so p99 == p99.9 == max for small N.
    pub fn p999(&mut self) -> f64 {
        self.percentile(99.9)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Fixed-bucket histogram over a linear range (for Figure 12's
/// continuous-access-length distribution).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    width: f64,
    buckets: Vec<u64>,
    overflow: u64,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Self {
            lo,
            width: (hi - lo) / n_buckets as f64,
            buckets: vec![0; n_buckets],
            overflow: 0,
            total: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.buckets[0] += 1;
            return;
        }
        let idx = ((x - self.lo) / self.width) as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn bucket_count(&self, i: usize) -> u64 {
        self.buckets[i]
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn bucket_lo(&self, i: usize) -> f64 {
        self.lo + self.width * i as f64
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }
}

/// Pretty table printer used by every bench to emit the paper's rows.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a nanosecond count human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{b:.0}B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1}KB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.1}MB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2}GB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let mut p = Percentiles::new();
        for i in 0..101 {
            p.add(i as f64);
        }
        assert_eq!(p.percentile(0.0), 0.0);
        assert_eq!(p.percentile(50.0), 50.0);
        assert_eq!(p.percentile(99.0), 99.0);
        assert_eq!(p.percentile(100.0), 100.0);
    }

    #[test]
    fn p999_equals_p99_equals_max_for_small_n() {
        // nearest-rank: until the sample count resolves the 99.9th
        // (and 99th) rank, both tails collapse onto the max
        for n in 1..=10 {
            let mut p = Percentiles::new();
            for i in 0..n {
                p.add(i as f64);
            }
            let max = (n - 1) as f64;
            assert_eq!(p.percentile(99.0), max, "n={n}");
            assert_eq!(p.p999(), max, "n={n}");
        }
    }

    #[test]
    fn p999_separates_from_p99_at_scale() {
        let mut p = Percentiles::new();
        for i in 0..10_000 {
            p.add(i as f64);
        }
        assert_eq!(p.percentile(99.0), 9899.0);
        assert_eq!(p.p999(), 9989.0);
        assert_eq!(p.percentile(100.0), 9999.0);
    }

    #[test]
    fn p999_empty_is_zero() {
        let mut p = Percentiles::new();
        assert_eq!(p.p999(), 0.0);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.7, 9.9, 25.0] {
            h.add(x);
        }
        assert_eq!(h.bucket_count(0), 1);
        assert_eq!(h.bucket_count(1), 2);
        assert_eq!(h.bucket_count(9), 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.total(), 5);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["model", "ms"]);
        t.row(&["opt".into(), "1.5".into()]);
        let r = t.render();
        assert!(r.contains("model"));
        assert!(r.contains("opt"));
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1_500.0), "1.50us");
        assert_eq!(fmt_ns(2_000_000.0), "2.00ms");
        assert_eq!(fmt_bytes(2048.0), "2.0KB");
    }
}
