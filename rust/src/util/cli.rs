//! Tiny CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (program name excluded).
    /// `flag_names` lists the options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Self {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["serve", "--model", "opt", "--n=3", "extra"], &[]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("model"), Some("opt"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["--verbose", "--out", "x.json"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--dry-run"], &[]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn numeric_defaults_and_errors() {
        let a = parse(&["--k", "abc"], &[]);
        assert!(a.get_usize("k", 1).is_err());
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_f64("missing", 0.5).unwrap(), 0.5);
    }
}
