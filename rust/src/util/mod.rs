//! Small self-contained utilities.
//!
//! The build environment resolves crates from a pinned offline set that
//! lacks `rand`, `serde`, `clap` and `proptest`; these modules provide the
//! minimal equivalents the rest of the library needs (see DESIGN.md
//! §Environment-Substitutions).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
