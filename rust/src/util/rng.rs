//! Deterministic PRNGs (SplitMix64 + xoshiro256**) and samplers.
//!
//! The offline registry has no `rand` crate; these are the standard,
//! well-tested generators implemented from their reference C sources.
//! Everything simulation-related in this repo is seeded through here so
//! every paper experiment is exactly reproducible.

/// SplitMix64 — used to seed xoshiro and for cheap stateless streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse RNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, n)`. Debiased via rejection (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.below(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }
}

/// Zipf(s) sampler over `[0, n)` via precomputed CDF + binary search.
/// Models the skewed neuron-popularity distribution (hot neurons).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn prob(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 from the SplitMix64 paper code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_uniformish() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.below(4)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for &(n, k) in &[(100, 10), (10, 10), (50, 40)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_skewed_and_normalized() {
        let z = Zipf::new(100, 1.0);
        let total: f64 = (0..100).map(|i| z.prob(i)).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(z.prob(0) > z.prob(50));
        let mut r = Rng::new(1);
        let mut c0 = 0;
        for _ in 0..10_000 {
            if z.sample(&mut r) == 0 {
                c0 += 1;
            }
        }
        // p(0) ~ 0.192 for n=100, s=1
        assert!((1_500..2_400).contains(&c0), "c0={c0}");
    }
}
