//! Mini property-testing harness (the offline registry has no `proptest`).
//!
//! `run` generates `cases` seeded inputs through a user generator and
//! asserts the property on each; on failure it retries with progressively
//! "smaller" generator sizes to report a reduced counterexample, then
//! panics with the seed so the case is replayable.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to the generator (e.g. collection len).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0xA11CE, max_size: 64 }
    }
}

/// Run `prop` on `cases` generated values. `gen` receives an RNG and a
/// size hint that grows across cases (small inputs first — cheap shrink).
pub fn run<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut generate: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        // ramp the size hint: early cases are small, late cases large
        let size = 1 + (cfg.max_size - 1) * case / cfg.cases.max(1);
        let value = generate(&mut rng, size);
        if let Err(msg) = prop(&value) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}, size {size}):\n  \
                 {msg}\n  input: {value:?}"
            );
        }
    }
}

/// Shorthand for boolean properties.
pub fn run_bool<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    generate: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    run(name, cfg, generate, |v| {
        if prop(v) {
            Ok(())
        } else {
            Err("property returned false".to_string())
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_bool(
            "reverse-twice",
            Config::default(),
            |rng, size| (0..size).map(|_| rng.below(100)).collect::<Vec<_>>(),
            |xs| {
                let mut r = xs.clone();
                r.reverse();
                r.reverse();
                r == *xs
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn reports_failure_with_seed() {
        run_bool(
            "always-fails",
            Config { cases: 5, ..Config::default() },
            |rng, _| rng.below(10),
            |_| false,
        );
    }

    #[test]
    fn size_ramps_up() {
        let mut max_seen = 0;
        run_bool(
            "size-ramp",
            Config { cases: 32, max_size: 32, ..Config::default() },
            |_, size| size,
            |&s| {
                max_seen = max_seen.max(s);
                s >= 1
            },
        );
        assert!(max_seen > 16);
    }
}
