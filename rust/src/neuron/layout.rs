//! Flash layout: a bundle->slot permutation with its inverse.
//!
//! The permutation is the artifact RIPPLE's offline stage produces
//! (Algorithm 1's Hamiltonian path, linearized into flash order). All
//! online read planning works in slot space so that co-located bundles
//! turn into adjacent slots and hence continuous reads.

use super::{BundleId, Slot};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Layout {
    /// bundle id -> flash slot
    to_slot: Vec<Slot>,
    /// flash slot -> bundle id
    to_bundle: Vec<BundleId>,
}

impl Layout {
    /// Identity layout (the model-structure order llama.cpp uses).
    pub fn identity(n: usize) -> Self {
        Self {
            to_slot: (0..n as u32).collect(),
            to_bundle: (0..n as u32).collect(),
        }
    }

    /// Build from an *order*: `order[s]` is the bundle placed at slot `s`.
    /// Validates that `order` is a permutation of `0..n`.
    pub fn from_order(order: &[BundleId]) -> anyhow::Result<Self> {
        let n = order.len();
        let mut to_slot = vec![u32::MAX; n];
        for (slot, &b) in order.iter().enumerate() {
            anyhow::ensure!((b as usize) < n, "bundle {b} out of range {n}");
            anyhow::ensure!(
                to_slot[b as usize] == u32::MAX,
                "bundle {b} appears twice in order"
            );
            to_slot[b as usize] = slot as u32;
        }
        Ok(Self { to_slot, to_bundle: order.to_vec() })
    }

    pub fn len(&self) -> usize {
        self.to_slot.len()
    }

    pub fn is_empty(&self) -> bool {
        self.to_slot.is_empty()
    }

    #[inline]
    pub fn slot_of(&self, b: BundleId) -> Slot {
        self.to_slot[b as usize]
    }

    #[inline]
    pub fn bundle_at(&self, s: Slot) -> BundleId {
        self.to_bundle[s as usize]
    }

    pub fn order(&self) -> &[BundleId] {
        &self.to_bundle
    }

    /// Map a set of activated bundles to sorted flash slots, reusing
    /// the caller's buffer (§Perf: the per-token hot path clears and
    /// refills one scratch vector instead of allocating).
    pub fn slots_for_into(&self, bundles: &[BundleId], out: &mut Vec<Slot>) {
        out.clear();
        out.extend(bundles.iter().map(|&b| self.slot_of(b)));
        out.sort_unstable();
    }

    /// Allocating convenience wrapper over [`Layout::slots_for_into`].
    pub fn slots_for(&self, bundles: &[BundleId]) -> Vec<Slot> {
        let mut slots = Vec::with_capacity(bundles.len());
        self.slots_for_into(bundles, &mut slots);
        slots
    }

    /// Verify internal consistency (used by property tests).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.to_slot.len() == self.to_bundle.len());
        for b in 0..self.to_slot.len() {
            let s = self.to_slot[b];
            anyhow::ensure!(
                self.to_bundle[s as usize] as usize == b,
                "layout inverse broken at bundle {b}"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn identity_roundtrip() {
        let l = Layout::identity(16);
        for b in 0..16u32 {
            assert_eq!(l.slot_of(b), b);
            assert_eq!(l.bundle_at(b), b);
        }
        l.validate().unwrap();
    }

    #[test]
    fn from_order_inverse() {
        let l = Layout::from_order(&[2, 0, 1, 3]).unwrap();
        assert_eq!(l.bundle_at(0), 2);
        assert_eq!(l.slot_of(2), 0);
        assert_eq!(l.slot_of(0), 1);
        l.validate().unwrap();
    }

    #[test]
    fn rejects_non_permutations() {
        assert!(Layout::from_order(&[0, 0, 1]).is_err());
        assert!(Layout::from_order(&[0, 5]).is_err());
    }

    #[test]
    fn slots_sorted() {
        let l = Layout::from_order(&[3, 1, 0, 2]).unwrap();
        let s = l.slots_for(&[0, 3]);
        assert_eq!(s, vec![0, 2]);
    }

    #[test]
    fn prop_random_permutation_roundtrips() {
        prop::run_bool(
            "layout-roundtrip",
            prop::Config { cases: 32, max_size: 256, ..Default::default() },
            |rng: &mut Rng, size| {
                let mut order: Vec<u32> = (0..size as u32).collect();
                rng.shuffle(&mut order);
                order
            },
            |order| {
                let l = Layout::from_order(order).unwrap();
                l.validate().is_ok()
                    && (0..order.len() as u32)
                        .all(|b| l.bundle_at(l.slot_of(b)) == b)
            },
        );
    }
}
