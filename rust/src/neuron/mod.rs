//! Neuron-bundle identity and flash layout.
//!
//! A *bundle* is the paper's unit of neuron storage: the bound rows/columns
//! of the FFN matrices whose activation is decided by the same intermediate
//! value (up row + down column for OPT; gate+up+down for Llama-style).
//! A *layout* is a permutation mapping bundle id -> flash slot; RIPPLE's
//! offline stage produces this permutation, the baselines use others.

pub mod layout;

pub use layout::Layout;

/// A bundle id within one FFN block (layer-local, `0..neurons_per_layer`).
pub type BundleId = u32;

/// A flash slot index (layer-local; slot `s` occupies bytes
/// `[region_base + s*bundle_bytes, +bundle_bytes)` of the flash image).
pub type Slot = u32;

/// Per-layer neuron addressing for one model.
#[derive(Clone, Debug)]
pub struct NeuronSpace {
    pub n_layers: usize,
    pub per_layer: usize,
    pub bundle_bytes: usize,
}

impl NeuronSpace {
    pub fn new(n_layers: usize, per_layer: usize, bundle_bytes: usize) -> Self {
        assert!(n_layers > 0 && per_layer > 0 && bundle_bytes > 0);
        Self { n_layers, per_layer, bundle_bytes }
    }

    pub fn total(&self) -> usize {
        self.n_layers * self.per_layer
    }

    /// Byte offset of a layer's slot region within the flash image.
    pub fn layer_base(&self, layer: usize) -> u64 {
        assert!(layer < self.n_layers);
        (layer * self.per_layer * self.bundle_bytes) as u64
    }

    /// Byte range of `slot` in `layer`.
    pub fn slot_range(&self, layer: usize, slot: Slot) -> (u64, usize) {
        assert!((slot as usize) < self.per_layer, "slot out of range");
        (
            self.layer_base(layer) + slot as u64 * self.bundle_bytes as u64,
            self.bundle_bytes,
        )
    }

    pub fn image_bytes(&self) -> u64 {
        self.total() as u64 * self.bundle_bytes as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addressing() {
        let s = NeuronSpace::new(4, 512, 2064);
        assert_eq!(s.total(), 2048);
        assert_eq!(s.layer_base(0), 0);
        assert_eq!(s.layer_base(1), 512 * 2064);
        let (off, len) = s.slot_range(2, 3);
        assert_eq!(off, (2 * 512 + 3) as u64 * 2064);
        assert_eq!(len, 2064);
        assert_eq!(s.image_bytes(), 2048 * 2064);
    }

    #[test]
    #[should_panic]
    fn slot_bounds_checked() {
        let s = NeuronSpace::new(1, 8, 16);
        s.slot_range(0, 8);
    }
}
