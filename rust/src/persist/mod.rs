//! Persistence for offline-stage artifacts: placement layouts and
//! activation traces.
//!
//! The offline stage is run once per (model, calibration set); serving
//! processes then load the resulting layouts at startup — exactly how
//! the paper deploys (flash is rewritten once, off the request path).
//! Format: a small self-describing binary container (magic, version,
//! section of u32-LE arrays) — no serde in the offline registry.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::neuron::Layout;
use crate::trace::Trace;

const LAYOUT_MAGIC: &[u8; 8] = b"RIPLAY01";
const TRACE_MAGIC: &[u8; 8] = b"RIPTRC01";

fn write_u32s(w: &mut impl Write, xs: &[u32]) -> Result<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32s(r: &mut impl Read) -> Result<Vec<u32>> {
    let mut len8 = [0u8; 8];
    r.read_exact(&mut len8)?;
    let n = u64::from_le_bytes(len8) as usize;
    anyhow::ensure!(n <= 1 << 28, "unreasonable array length {n}");
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Save per-layer layouts (the offline stage's product).
pub fn save_layouts(path: impl AsRef<Path>, layouts: &[Layout]) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    f.write_all(LAYOUT_MAGIC)?;
    f.write_all(&(layouts.len() as u64).to_le_bytes())?;
    for l in layouts {
        write_u32s(&mut f, l.order())?;
    }
    Ok(())
}

pub fn load_layouts(path: impl AsRef<Path>) -> Result<Vec<Layout>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == LAYOUT_MAGIC, "not a RIPPLE layout file");
    let mut n8 = [0u8; 8];
    f.read_exact(&mut n8)?;
    let n = u64::from_le_bytes(n8) as usize;
    anyhow::ensure!(n <= 4096, "unreasonable layer count {n}");
    (0..n)
        .map(|i| {
            let order = read_u32s(&mut f)?;
            Layout::from_order(&order)
                .with_context(|| format!("layer {i}: corrupt permutation"))
        })
        .collect()
}

/// Save an activation trace (calibration reuse / sharing across runs).
pub fn save_trace(path: impl AsRef<Path>, trace: &Trace) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())?;
    f.write_all(TRACE_MAGIC)?;
    for v in [trace.n_layers as u64, trace.per_layer as u64, trace.tokens.len() as u64] {
        f.write_all(&v.to_le_bytes())?;
    }
    for tok in &trace.tokens {
        for layer in tok {
            write_u32s(&mut f, layer)?;
        }
    }
    Ok(())
}

pub fn load_trace(path: impl AsRef<Path>) -> Result<Trace> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == TRACE_MAGIC, "not a RIPPLE trace file");
    let mut u64buf = [0u8; 8];
    let mut next = || -> Result<u64> {
        f.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let n_layers = next()? as usize;
    let per_layer = next()? as usize;
    let n_tokens = next()? as usize;
    anyhow::ensure!(n_layers <= 4096 && n_tokens <= 1 << 24, "corrupt header");
    let mut trace = Trace::new(n_layers, per_layer);
    for _ in 0..n_tokens {
        let mut tok = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let v = read_u32s(&mut f)?;
            anyhow::ensure!(
                v.iter().all(|&b| (b as usize) < per_layer),
                "bundle id out of range"
            );
            tok.push(v);
        }
        trace.push_token(tok);
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{DatasetProfile, TraceGen};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ripple-persist-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn layouts_roundtrip() {
        let layouts = vec![
            Layout::from_order(&[2, 0, 1, 3]).unwrap(),
            Layout::identity(4),
        ];
        let p = tmp("layouts.bin");
        save_layouts(&p, &layouts).unwrap();
        let back = load_layouts(&p).unwrap();
        assert_eq!(back, layouts);
    }

    #[test]
    fn trace_roundtrip() {
        let mut tg = TraceGen::new(3, 64, 10, &DatasetProfile::alpaca(), 1, 2);
        let trace = tg.generate(20);
        let p = tmp("trace.bin");
        save_trace(&p, &trace).unwrap();
        let back = load_trace(&p).unwrap();
        assert_eq!(back.n_layers, 3);
        assert_eq!(back.per_layer, 64);
        assert_eq!(back.tokens, trace.tokens);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"not a ripple file at all").unwrap();
        assert!(load_layouts(&p).is_err());
        assert!(load_trace(&p).is_err());
    }

    #[test]
    fn rejects_cross_format() {
        let p = tmp("cross.bin");
        save_layouts(&p, &[Layout::identity(4)]).unwrap();
        assert!(load_trace(&p).is_err());
    }

    #[test]
    fn rejects_corrupt_permutation() {
        // hand-craft a layout file with a duplicate entry
        let p = tmp("corrupt.bin");
        let mut f = std::fs::File::create(&p).unwrap();
        use std::io::Write;
        f.write_all(LAYOUT_MAGIC).unwrap();
        f.write_all(&1u64.to_le_bytes()).unwrap();
        f.write_all(&3u64.to_le_bytes()).unwrap();
        for x in [0u32, 0, 1] {
            f.write_all(&x.to_le_bytes()).unwrap();
        }
        drop(f);
        assert!(load_layouts(&p).is_err());
    }
}
