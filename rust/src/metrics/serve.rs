//! Per-session and aggregate serving metrics (DESIGN.md §Serving).
//!
//! The multi-session simulation measures what the single-stream
//! `RunMetrics` cannot: tail token latency under contention (p50/p95/
//! p99), queueing delay before a session is admitted to a decode slot,
//! fairness across sessions, and how much of the DRAM cache's value
//! comes from *cross-session* co-activation reuse. Everything here is
//! virtual-time arithmetic on simulated quantities — no wall clock —
//! so serve reports stay byte-deterministic.

use crate::util::stats::Percentiles;

use super::TokenIo;

/// One decode session's lifetime statistics.
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// Session id (also its arrival order).
    pub id: usize,
    /// Virtual arrival time on the serving clock, ns.
    pub arrival_ns: f64,
    /// Time spent waiting for a decode slot (admission - arrival), ns.
    pub queue_delay_ns: f64,
    /// Virtual completion time of the session's last token, ns.
    pub finished_ns: f64,
    /// Tokens decoded.
    pub tokens: u64,
    /// Summed per-token I/O contribution.
    pub totals: TokenIo,
    /// Per-token serve latency (queueing within the round + own I/O +
    /// compute), ns.
    pub latency_ns: Percentiles,
    sum_latency_ns: f64,
    sum_service_ns: f64,
    sum_round_queue_ns: f64,
}

impl SessionStats {
    /// A fresh session arriving at `arrival_ns`.
    pub fn new(id: usize, arrival_ns: f64) -> Self {
        Self {
            id,
            arrival_ns,
            queue_delay_ns: 0.0,
            finished_ns: 0.0,
            tokens: 0,
            totals: TokenIo::default(),
            latency_ns: Percentiles::new(),
            sum_latency_ns: 0.0,
            sum_service_ns: 0.0,
            sum_round_queue_ns: 0.0,
        }
    }

    /// Record one decoded token and its observed serve latency.
    pub fn record_token(&mut self, io: &TokenIo, latency_ns: f64) {
        self.tokens += 1;
        self.totals.add(io);
        self.latency_ns.add(latency_ns);
        self.sum_latency_ns += latency_ns;
    }

    /// Attribute the same token's latency to its two components: the
    /// session's *own service time* (flash stall + compute window) and
    /// the *in-round queueing delay* it spent waiting for the round's
    /// earlier sessions on the shared device. `service + queue` equals
    /// the latency passed to [`record_token`] for the token.
    pub fn record_service_split(&mut self, service_ns: f64, round_queue_ns: f64) {
        self.sum_service_ns += service_ns;
        self.sum_round_queue_ns += round_queue_ns;
    }

    /// Mean per-token serve latency, ns.
    pub fn mean_latency_ns(&self) -> f64 {
        if self.tokens == 0 { 0.0 } else { self.sum_latency_ns / self.tokens as f64 }
    }

    /// Mean own-service time per token (stall + compute), ns.
    pub fn mean_service_ns(&self) -> f64 {
        if self.tokens == 0 { 0.0 } else { self.sum_service_ns / self.tokens as f64 }
    }

    /// Mean in-round queueing delay per token, ns: time the session's
    /// token spent behind its round predecessors' service.
    pub fn mean_round_queue_ns(&self) -> f64 {
        if self.tokens == 0 { 0.0 } else { self.sum_round_queue_ns / self.tokens as f64 }
    }
}

/// Aggregate outcome of one multi-session serve run.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Per-session statistics, indexed by session id.
    pub sessions: Vec<SessionStats>,
    /// Every token's serve latency across all sessions, ns.
    pub all_latency_ns: Percentiles,
    /// Virtual time from first arrival to last completion, ns.
    pub makespan_ns: f64,
    /// Decode-slot count the run was configured with.
    pub max_concurrent: usize,
    /// Highest number of simultaneously active sessions observed.
    pub peak_active: usize,
    /// True when all sessions shared one DRAM cache.
    pub shared_cache: bool,
    /// Total cache hits across sessions (shared or summed private).
    pub cache_hits: u64,
    /// Hits served by an entry a *different* session admitted (always 0
    /// with private caches).
    pub cache_cross_hits: u64,
}

impl ServeMetrics {
    /// Total tokens decoded across sessions.
    pub fn tokens(&self) -> u64 {
        self.sessions.iter().map(|s| s.tokens).sum()
    }

    /// Mean queueing delay before admission, ns.
    pub fn mean_queue_delay_ns(&self) -> f64 {
        if self.sessions.is_empty() {
            0.0
        } else {
            self.sessions.iter().map(|s| s.queue_delay_ns).sum::<f64>()
                / self.sessions.len() as f64
        }
    }

    /// Jain's fairness index over per-session mean token latency, in
    /// (0, 1]; 1.0 = perfectly equal service.
    pub fn fairness(&self) -> f64 {
        let xs: Vec<f64> =
            self.sessions.iter().map(|s| s.mean_latency_ns()).filter(|&x| x > 0.0).collect();
        if xs.is_empty() {
            return 1.0;
        }
        let sum: f64 = xs.iter().sum();
        let sum_sq: f64 = xs.iter().map(|x| x * x).sum();
        if sum_sq == 0.0 { 1.0 } else { sum * sum / (xs.len() as f64 * sum_sq) }
    }

    /// Fraction of cache hits that were cross-session reuse, in [0, 1].
    pub fn cross_session_hit_ratio(&self) -> f64 {
        if self.cache_hits == 0 {
            0.0
        } else {
            self.cache_cross_hits as f64 / self.cache_hits as f64
        }
    }

    /// Simulated serving throughput, tokens/sec of virtual time.
    pub fn throughput_tokens_per_s(&self) -> f64 {
        if self.makespan_ns == 0.0 {
            0.0
        } else {
            self.tokens() as f64 / (self.makespan_ns / 1e9)
        }
    }

    /// Per-session speculative-prefetch and latency-split attribution,
    /// full-model-scaled like [`ServeMetrics::summary`]. Only
    /// prefetch-enabled serve runs attach this to their summary;
    /// prefetch-off summaries keep the historical shape (and their
    /// report JSON stays byte-identical).
    pub fn prefetch_attribution(
        &self,
        layer_scale: f64,
        bundle_bytes: usize,
    ) -> Vec<SessionPrefetchSummary> {
        let ms = |ns: f64| ns * layer_scale / 1e6;
        self.sessions
            .iter()
            .map(|s| {
                let busy = s.totals.elapsed_ns;
                let overlap = if busy == 0.0 {
                    0.0
                } else {
                    (1.0 - s.totals.stall_ns / busy).max(0.0)
                };
                SessionPrefetchSummary {
                    id: s.id,
                    prefetch_hit_bundles: s.totals.prefetch_hit_bundles,
                    prefetch_wasted_bundles: s.totals.prefetch_wasted_bundles,
                    prefetch_hit_bytes: s.totals.prefetch_hit_bundles
                        * bundle_bytes as u64,
                    prefetch_wasted_bytes: s.totals.prefetch_wasted_bundles
                        * bundle_bytes as u64,
                    overlap_ratio: overlap,
                    mean_service_ms: ms(s.mean_service_ns()),
                    mean_round_queue_ms: ms(s.mean_round_queue_ns()),
                }
            })
            .collect()
    }

    /// Condense into the flat summary the harness reports serialize.
    /// `layer_scale` lifts per-representative-layer latencies to the
    /// full model, exactly like `ExperimentResult::latency_ms`;
    /// `cache_hit_ratio` is the aggregate demanded-bundle hit ratio of
    /// the run (computed by the caller from its `RunMetrics`).
    pub fn summary(&mut self, layer_scale: f64, cache_hit_ratio: f64) -> ServeSummary {
        let ms = |ns: f64| ns * layer_scale / 1e6;
        let (p50, p95, p99, p999) = (
            self.all_latency_ns.percentile(50.0),
            self.all_latency_ns.percentile(95.0),
            self.all_latency_ns.percentile(99.0),
            self.all_latency_ns.p999(),
        );
        ServeSummary {
            sessions: self.sessions.len(),
            max_concurrent: self.max_concurrent,
            peak_active: self.peak_active,
            shared_cache: self.shared_cache,
            tokens: self.tokens(),
            p50_ms: ms(p50),
            p95_ms: ms(p95),
            p99_ms: ms(p99),
            p999_ms: ms(p999),
            mean_ms: ms(self.all_latency_ns.mean()),
            mean_queue_delay_ms: ms(self.mean_queue_delay_ns()),
            fairness: self.fairness(),
            cache_hit_ratio,
            cross_session_hit_ratio: self.cross_session_hit_ratio(),
            makespan_ms: ms(self.makespan_ns),
            // prefetch-enabled callers attach attribution afterwards
            // (see `prefetch_attribution`); the defaults keep
            // prefetch-off summaries in the historical shape
            prefetch_hit_bundles: 0,
            prefetch_wasted_bundles: 0,
            session_prefetch: Vec::new(),
        }
    }
}

/// One session's speculative-prefetch attribution in a serve summary:
/// what its share of the arbitrated budget bought (hits), what it
/// burned (waste), and where its serve latency went.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SessionPrefetchSummary {
    /// Session id.
    pub id: usize,
    /// Demanded bundles served by the session's in-flight speculation.
    pub prefetch_hit_bundles: u64,
    /// Speculative bundles the session read but never demanded.
    pub prefetch_wasted_bundles: u64,
    /// `prefetch_hit_bundles` in bytes.
    pub prefetch_hit_bytes: u64,
    /// `prefetch_wasted_bundles` in bytes.
    pub prefetch_wasted_bytes: u64,
    /// Fraction of the session's flash busy time hidden under compute.
    pub overlap_ratio: f64,
    /// Full-model mean own-service time per token (stall + compute), ms.
    pub mean_service_ms: f64,
    /// Full-model mean in-round queueing delay per token, ms.
    pub mean_round_queue_ms: f64,
}

/// Flat, full-model-scaled serve summary carried by `ExperimentResult`
/// and serialized into `BENCH_serve.json` (all simulated quantities —
/// deterministic).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeSummary {
    /// Number of sessions served.
    pub sessions: usize,
    /// Configured decode-slot count.
    pub max_concurrent: usize,
    /// Highest simultaneous session count observed.
    pub peak_active: usize,
    /// Shared (true) vs private per-session caches.
    pub shared_cache: bool,
    /// Total tokens decoded.
    pub tokens: u64,
    /// Full-model p50 token serve latency, ms.
    pub p50_ms: f64,
    /// Full-model p95 token serve latency, ms.
    pub p95_ms: f64,
    /// Full-model p99 token serve latency, ms.
    pub p99_ms: f64,
    /// Full-model p99.9 token serve latency, ms. Serialized only for
    /// fleet rows and prefetch-attributed serve rows (non-empty
    /// `session_prefetch`), so prefetch-off serve JSON stays
    /// byte-identical to historical reports.
    pub p999_ms: f64,
    /// Full-model mean token serve latency, ms.
    pub mean_ms: f64,
    /// Full-model mean admission queueing delay, ms.
    pub mean_queue_delay_ms: f64,
    /// Jain's fairness index over per-session mean latency.
    pub fairness: f64,
    /// Aggregate demanded-bundle cache hit ratio.
    pub cache_hit_ratio: f64,
    /// Fraction of hits that were cross-session reuse.
    pub cross_session_hit_ratio: f64,
    /// Full-model virtual makespan, ms.
    pub makespan_ms: f64,
    /// Aggregate speculative hits across sessions, bundles (0 for
    /// prefetch-off runs).
    pub prefetch_hit_bundles: u64,
    /// Aggregate wasted speculation across sessions, bundles.
    pub prefetch_wasted_bundles: u64,
    /// Per-session attribution rows; empty for prefetch-off runs, which
    /// keeps their serialized reports byte-identical to the historical
    /// schema.
    pub session_prefetch: Vec<SessionPrefetchSummary>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(ns: f64) -> TokenIo {
        TokenIo {
            demanded_bundles: 10,
            read_bundles: 6,
            cached_bundles: 4,
            commands: 3,
            bytes: 600,
            elapsed_ns: ns,
            stall_ns: ns,
            ..Default::default()
        }
    }

    #[test]
    fn session_records_latency_and_totals() {
        let mut s = SessionStats::new(0, 100.0);
        s.record_token(&tok(1e6), 2e6);
        s.record_token(&tok(1e6), 4e6);
        assert_eq!(s.tokens, 2);
        assert_eq!(s.totals.commands, 6);
        assert!((s.mean_latency_ns() - 3e6).abs() < 1e-9);
    }

    #[test]
    fn fairness_index_bounds() {
        let mut m = ServeMetrics::default();
        for id in 0..4 {
            let mut s = SessionStats::new(id, 0.0);
            s.record_token(&tok(1e6), 1e6); // equal latencies
            m.sessions.push(s);
        }
        assert!((m.fairness() - 1.0).abs() < 1e-12);
        // one session 9x slower drags fairness below 1
        m.sessions[3].record_token(&tok(1e6), 17e6);
        let f = m.fairness();
        assert!(f < 1.0 && f > 0.25, "fairness={f}");
    }

    #[test]
    fn empty_metrics_are_zero() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.tokens(), 0);
        assert_eq!(m.mean_queue_delay_ns(), 0.0);
        assert_eq!(m.fairness(), 1.0);
        assert_eq!(m.cross_session_hit_ratio(), 0.0);
        assert_eq!(m.throughput_tokens_per_s(), 0.0);
        let s = m.summary(2.0, 0.0);
        assert_eq!(s.tokens, 0);
        assert_eq!(s.p99_ms, 0.0);
    }

    #[test]
    fn service_split_means_reconstruct_latency() {
        let mut s = SessionStats::new(0, 0.0);
        // token 1: 1.5ms own service after 0.5ms behind the round
        s.record_token(&tok(1e6), 2e6);
        s.record_service_split(1.5e6, 0.5e6);
        // token 2: 3ms own service, served first in its round
        s.record_token(&tok(1e6), 3e6);
        s.record_service_split(3e6, 0.0);
        assert!((s.mean_service_ns() - 2.25e6).abs() < 1e-9);
        assert!((s.mean_round_queue_ns() - 0.25e6).abs() < 1e-9);
        assert!(
            (s.mean_service_ns() + s.mean_round_queue_ns() - s.mean_latency_ns()).abs()
                < 1e-9
        );
    }

    #[test]
    fn prefetch_attribution_scales_and_counts_per_session() {
        let mut m = ServeMetrics::default();
        let mut s = SessionStats::new(0, 0.0);
        let mut t = tok(2e6);
        t.stall_ns = 0.5e6; // 75% of flash time hidden
        t.prefetch_hit_bundles = 6;
        t.prefetch_wasted_bundles = 2;
        s.record_token(&t, 1e6);
        s.record_service_split(1e6, 0.0);
        m.sessions.push(s);
        let rows = m.prefetch_attribution(2.0, 100);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].prefetch_hit_bundles, 6);
        assert_eq!(rows[0].prefetch_wasted_bundles, 2);
        assert_eq!(rows[0].prefetch_hit_bytes, 600);
        assert_eq!(rows[0].prefetch_wasted_bytes, 200);
        assert!((rows[0].overlap_ratio - 0.75).abs() < 1e-12);
        // ns → full-model ms with layer_scale 2
        assert!((rows[0].mean_service_ms - 2.0).abs() < 1e-12);
        assert_eq!(rows[0].mean_round_queue_ms, 0.0);
    }

    #[test]
    fn summary_scales_by_layer_scale() {
        let mut m = ServeMetrics::default();
        let mut s = SessionStats::new(0, 0.0);
        s.record_token(&tok(1e6), 2e6);
        m.all_latency_ns.add(2e6);
        m.sessions.push(s);
        m.makespan_ns = 2e6;
        m.max_concurrent = 4;
        m.cache_hits = 8;
        m.cache_cross_hits = 2;
        let sum = m.summary(3.0, 0.4);
        assert!((sum.p50_ms - 6.0).abs() < 1e-9);
        // single sample: every tail percentile collapses onto it
        assert_eq!(sum.p999_ms.to_bits(), sum.p99_ms.to_bits());
        assert!((sum.makespan_ms - 6.0).abs() < 1e-9);
        assert!((sum.cross_session_hit_ratio - 0.25).abs() < 1e-12);
        assert!((sum.cache_hit_ratio - 0.4).abs() < 1e-12);
        assert_eq!(sum.tokens, 1);
    }
}
