//! I/O metrics: per-token and aggregated counters the paper reports
//! (I/O latency per token, IOPS, effective bandwidth, transfer volume).

use crate::util::stats::{Percentiles, Summary};

/// One token's I/O outcome across all layers.
#[derive(Clone, Copy, Debug, Default)]
pub struct TokenIo {
    /// Activated (demanded) bundles this token.
    pub demanded_bundles: u64,
    /// Bundles actually transferred from flash (demanded misses + speculative).
    pub read_bundles: u64,
    /// Speculative bundles read by access collapse.
    pub extra_bundles: u64,
    /// Bundles served from the DRAM cache.
    pub cached_bundles: u64,
    /// Read commands issued.
    pub commands: u64,
    /// Bytes transferred.
    pub bytes: u64,
    /// Simulated flash time, nanoseconds.
    pub elapsed_ns: f64,
}

impl TokenIo {
    pub fn add(&mut self, other: &TokenIo) {
        self.demanded_bundles += other.demanded_bundles;
        self.read_bundles += other.read_bundles;
        self.extra_bundles += other.extra_bundles;
        self.cached_bundles += other.cached_bundles;
        self.commands += other.commands;
        self.bytes += other.bytes;
        self.elapsed_ns += other.elapsed_ns;
    }
}

/// Aggregation over a run of tokens.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub tokens: u64,
    pub totals: TokenIo,
    pub latency_ns: Percentiles,
    pub commands_per_token: Summary,
    /// Demanded bytes (useful traffic) per token — the numerator of the
    /// paper's *effective bandwidth*.
    pub demanded_bytes: u64,
}

impl RunMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, t: &TokenIo, bundle_bytes: usize) {
        self.tokens += 1;
        self.totals.add(t);
        self.latency_ns.add(t.elapsed_ns);
        self.commands_per_token.add(t.commands as f64);
        self.demanded_bytes += t.demanded_bundles * bundle_bytes as u64;
    }

    /// Mean I/O latency per token, ns.
    pub fn mean_latency_ns(&self) -> f64 {
        if self.tokens == 0 { 0.0 } else { self.totals.elapsed_ns / self.tokens as f64 }
    }

    /// Achieved IOPS.
    pub fn iops(&self) -> f64 {
        if self.totals.elapsed_ns == 0.0 {
            0.0
        } else {
            self.totals.commands as f64 / (self.totals.elapsed_ns / 1e9)
        }
    }

    /// Raw bandwidth (all transferred bytes / busy time), bytes/sec.
    pub fn raw_bandwidth(&self) -> f64 {
        if self.totals.elapsed_ns == 0.0 {
            0.0
        } else {
            self.totals.bytes as f64 / (self.totals.elapsed_ns / 1e9)
        }
    }

    /// *Effective* bandwidth (paper §6.1: only activated neurons count),
    /// bytes/sec. Cache hits don't add time, so serving more from cache
    /// raises this metric — exactly as in the paper.
    pub fn effective_bandwidth(&self) -> f64 {
        if self.totals.elapsed_ns == 0.0 {
            0.0
        } else {
            self.demanded_bytes as f64 / (self.totals.elapsed_ns / 1e9)
        }
    }

    /// Mean contiguous read length in bundles (Figure 12's metric).
    pub fn mean_access_len(&self) -> f64 {
        if self.totals.commands == 0 {
            0.0
        } else {
            self.totals.read_bundles as f64 / self.totals.commands as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(demand: u64, read: u64, extra: u64, cmds: u64, bytes: u64, ns: f64) -> TokenIo {
        TokenIo {
            demanded_bundles: demand,
            read_bundles: read,
            extra_bundles: extra,
            cached_bundles: demand - (read - extra),
            commands: cmds,
            bytes,
            elapsed_ns: ns,
        }
    }

    #[test]
    fn aggregates() {
        let mut m = RunMetrics::new();
        m.record(&tok(10, 8, 2, 4, 8 * 100, 1e6), 100);
        m.record(&tok(10, 10, 0, 5, 10 * 100, 1e6), 100);
        assert_eq!(m.tokens, 2);
        assert_eq!(m.totals.commands, 9);
        assert!((m.mean_latency_ns() - 1e6).abs() < 1.0);
        assert!((m.iops() - 9.0 / 2e-3).abs() < 1.0);
        // effective bandwidth counts demanded bytes (20*100) over 2ms
        assert!((m.effective_bandwidth() - 2_000.0 * 100.0 / 2e-3 / 100.0).abs() < 1e-6);
        assert!((m.mean_access_len() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        let m = RunMetrics::new();
        assert_eq!(m.mean_latency_ns(), 0.0);
        assert_eq!(m.iops(), 0.0);
        assert_eq!(m.effective_bandwidth(), 0.0);
    }
}
