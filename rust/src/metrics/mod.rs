//! I/O metrics: per-token and aggregated counters the paper reports
//! (I/O latency per token, IOPS, effective bandwidth, transfer volume),
//! plus the overlap/prefetch counters of the asynchronous pipeline
//! (stall time, hidden flash time, speculative hit/waste) and the
//! per-session serving statistics of the multi-session simulation
//! ([`serve`]).

pub mod fleet;
pub mod serve;

pub use fleet::FleetSummary;
pub use serve::{ServeMetrics, ServeSummary, SessionPrefetchSummary, SessionStats};

use crate::util::stats::{Percentiles, Summary};

/// One token's I/O outcome across all layers.
#[derive(Clone, Copy, Debug, Default)]
pub struct TokenIo {
    /// Activated (demanded) bundles this token.
    pub demanded_bundles: u64,
    /// Bundles actually transferred from flash (demanded misses + speculative).
    pub read_bundles: u64,
    /// Speculative bundles read by access collapse.
    pub extra_bundles: u64,
    /// Bundles served from the DRAM cache.
    pub cached_bundles: u64,
    /// Demanded bundles served by an in-flight speculative prefetch.
    pub prefetch_hit_bundles: u64,
    /// Speculatively prefetched bundles this token never demanded.
    pub prefetch_wasted_bundles: u64,
    /// Read commands issued.
    pub commands: u64,
    /// Bytes transferred.
    pub bytes: u64,
    /// Simulated flash (device busy) time, nanoseconds.
    pub elapsed_ns: f64,
    /// Host time actually blocked on flash, nanoseconds (== `elapsed_ns`
    /// on the synchronous path; smaller when reads overlap compute).
    pub stall_ns: f64,
}

impl TokenIo {
    pub fn add(&mut self, other: &TokenIo) {
        self.demanded_bundles += other.demanded_bundles;
        self.read_bundles += other.read_bundles;
        self.extra_bundles += other.extra_bundles;
        self.cached_bundles += other.cached_bundles;
        self.prefetch_hit_bundles += other.prefetch_hit_bundles;
        self.prefetch_wasted_bundles += other.prefetch_wasted_bundles;
        self.commands += other.commands;
        self.bytes += other.bytes;
        self.elapsed_ns += other.elapsed_ns;
        self.stall_ns += other.stall_ns;
    }
}

/// Aggregation over a run of tokens.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub tokens: u64,
    pub totals: TokenIo,
    pub latency_ns: Percentiles,
    pub commands_per_token: Summary,
    /// Demanded bytes (useful traffic) per token — the numerator of the
    /// paper's *effective bandwidth*.
    pub demanded_bytes: u64,
    /// Simulated compute time interleaved with I/O, nanoseconds (zero
    /// for pure trace-driven synchronous runs).
    pub compute_ns: f64,
}

impl RunMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, t: &TokenIo, bundle_bytes: usize) {
        self.tokens += 1;
        self.totals.add(t);
        self.latency_ns.add(t.elapsed_ns);
        self.commands_per_token.add(t.commands as f64);
        self.demanded_bytes += t.demanded_bundles * bundle_bytes as u64;
    }

    /// Account simulated compute that ran alongside (or between) the
    /// token's flash operations.
    pub fn record_compute(&mut self, ns: f64) {
        self.compute_ns += ns;
    }

    /// Mean I/O (device busy) latency per token, ns.
    pub fn mean_latency_ns(&self) -> f64 {
        if self.tokens == 0 { 0.0 } else { self.totals.elapsed_ns / self.tokens as f64 }
    }

    /// Mean host stall per token, ns: the I/O time that actually blocked
    /// the critical path. Equals `mean_latency_ns` without overlap.
    pub fn mean_stall_ns(&self) -> f64 {
        if self.tokens == 0 { 0.0 } else { self.totals.stall_ns / self.tokens as f64 }
    }

    /// Mean simulated end-to-end latency per token, ns: compute plus the
    /// flash time that compute could not hide.
    pub fn mean_e2e_ns(&self) -> f64 {
        if self.tokens == 0 {
            0.0
        } else {
            (self.totals.stall_ns + self.compute_ns) / self.tokens as f64
        }
    }

    /// Fraction of flash busy time hidden under compute, in [0, 1].
    pub fn overlap_ratio(&self) -> f64 {
        if self.totals.elapsed_ns == 0.0 {
            0.0
        } else {
            (1.0 - self.totals.stall_ns / self.totals.elapsed_ns).max(0.0)
        }
    }

    /// Fraction of demanded bundles served by the DRAM cache, clamped
    /// to [0, 1]. The clamp matters for dense (sparsity-oblivious)
    /// runs, where cache hits are counted over every streamed bundle
    /// but `demanded_bundles` is substituted with the activated subset
    /// (the paper's effective-bandwidth convention).
    pub fn cache_hit_ratio(&self) -> f64 {
        if self.totals.demanded_bundles == 0 {
            0.0
        } else {
            let r = self.totals.cached_bundles as f64 / self.totals.demanded_bundles as f64;
            r.min(1.0)
        }
    }

    /// Fraction of prefetched bundles that were demanded, in [0, 1].
    pub fn prefetch_hit_ratio(&self) -> f64 {
        let total = self.totals.prefetch_hit_bundles + self.totals.prefetch_wasted_bundles;
        if total == 0 {
            0.0
        } else {
            self.totals.prefetch_hit_bundles as f64 / total as f64
        }
    }

    /// Achieved IOPS.
    pub fn iops(&self) -> f64 {
        if self.totals.elapsed_ns == 0.0 {
            0.0
        } else {
            self.totals.commands as f64 / (self.totals.elapsed_ns / 1e9)
        }
    }

    /// Raw bandwidth (all transferred bytes / busy time), bytes/sec.
    pub fn raw_bandwidth(&self) -> f64 {
        if self.totals.elapsed_ns == 0.0 {
            0.0
        } else {
            self.totals.bytes as f64 / (self.totals.elapsed_ns / 1e9)
        }
    }

    /// *Effective* bandwidth (paper §6.1: only activated neurons count),
    /// bytes/sec. Cache hits don't add time, so serving more from cache
    /// raises this metric — exactly as in the paper.
    pub fn effective_bandwidth(&self) -> f64 {
        if self.totals.elapsed_ns == 0.0 {
            0.0
        } else {
            self.demanded_bytes as f64 / (self.totals.elapsed_ns / 1e9)
        }
    }

    /// Mean contiguous read length in bundles (Figure 12's metric).
    pub fn mean_access_len(&self) -> f64 {
        if self.totals.commands == 0 {
            0.0
        } else {
            self.totals.read_bundles as f64 / self.totals.commands as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(demand: u64, read: u64, extra: u64, cmds: u64, bytes: u64, ns: f64) -> TokenIo {
        TokenIo {
            demanded_bundles: demand,
            read_bundles: read,
            extra_bundles: extra,
            cached_bundles: demand - (read - extra),
            commands: cmds,
            bytes,
            elapsed_ns: ns,
            stall_ns: ns,
            ..Default::default()
        }
    }

    #[test]
    fn aggregates() {
        let mut m = RunMetrics::new();
        m.record(&tok(10, 8, 2, 4, 8 * 100, 1e6), 100);
        m.record(&tok(10, 10, 0, 5, 10 * 100, 1e6), 100);
        assert_eq!(m.tokens, 2);
        assert_eq!(m.totals.commands, 9);
        assert!((m.mean_latency_ns() - 1e6).abs() < 1.0);
        assert!((m.iops() - 9.0 / 2e-3).abs() < 1.0);
        // effective bandwidth counts demanded bytes (20*100) over 2ms
        assert!((m.effective_bandwidth() - 2_000.0 * 100.0 / 2e-3 / 100.0).abs() < 1e-6);
        assert!((m.mean_access_len() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        let m = RunMetrics::new();
        assert_eq!(m.mean_latency_ns(), 0.0);
        assert_eq!(m.iops(), 0.0);
        assert_eq!(m.effective_bandwidth(), 0.0);
        assert_eq!(m.overlap_ratio(), 0.0);
        assert_eq!(m.prefetch_hit_ratio(), 0.0);
        assert_eq!(m.mean_e2e_ns(), 0.0);
    }

    #[test]
    fn overlap_and_prefetch_ratios() {
        let mut m = RunMetrics::new();
        let mut t = tok(10, 8, 2, 4, 8 * 100, 1e6);
        // half the flash time was hidden under compute
        t.stall_ns = 0.5e6;
        t.prefetch_hit_bundles = 3;
        t.prefetch_wasted_bundles = 1;
        m.record(&t, 100);
        m.record_compute(2e6);
        assert!((m.overlap_ratio() - 0.5).abs() < 1e-12);
        assert!((m.prefetch_hit_ratio() - 0.75).abs() < 1e-12);
        assert!((m.mean_stall_ns() - 0.5e6).abs() < 1e-9);
        // e2e = stall (0.5ms) + compute (2ms)
        assert!((m.mean_e2e_ns() - 2.5e6).abs() < 1e-9);
    }

    #[test]
    fn cache_hit_ratio_clamped_for_dense_runs() {
        let mut m = RunMetrics::new();
        let mut t = tok(10, 8, 2, 4, 8 * 100, 1e6);
        t.cached_bundles = 4;
        m.record(&t, 100);
        assert!((m.cache_hit_ratio() - 0.4).abs() < 1e-12);
        // dense streaming: hits counted over all bundles, demanded only
        // over activated ones — the ratio must still cap at 1
        let mut m = RunMetrics::new();
        let mut t = tok(10, 8, 2, 4, 8 * 100, 1e6);
        t.cached_bundles = 25;
        m.record(&t, 100);
        assert_eq!(m.cache_hit_ratio(), 1.0);
    }

    #[test]
    fn token_add_sums_new_fields() {
        let mut a = TokenIo { prefetch_hit_bundles: 1, stall_ns: 5.0, ..Default::default() };
        let b = TokenIo {
            prefetch_hit_bundles: 2,
            prefetch_wasted_bundles: 4,
            stall_ns: 7.0,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.prefetch_hit_bundles, 3);
        assert_eq!(a.prefetch_wasted_bundles, 4);
        assert_eq!(a.stall_ns, 12.0);
    }
}
