//! Fleet-level serving statistics for the event-driven open-loop
//! simulator (DESIGN.md §Fleet): admission/rejection accounting,
//! SLO-violation rates, goodput, and the event counters the property
//! battery audits. Per-token latency tails ride on [`super::ServeSummary`]
//! (p99 / p99.9); this summary carries what the round-based serve path
//! has no notion of — open-loop load that the server may *refuse*.

/// Flat fleet summary carried by `ExperimentResult` and serialized into
/// `BENCH_fleet.json` as the schema-gated `fleet_metrics` object (the
/// keys exist only on fleet rows, so historical reports stay
/// byte-identical).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FleetSummary {
    /// Sessions the arrival process offered.
    pub offered_sessions: usize,
    /// Sessions admitted past the bounded queue.
    pub admitted_sessions: usize,
    /// Sessions turned away at admission (queue at its bound).
    pub rejected_sessions: usize,
    /// Admitted sessions that decoded their full token stream.
    pub completed_sessions: usize,
    /// Tokens across all offered sessions.
    pub offered_tokens: u64,
    /// Tokens actually decoded.
    pub completed_tokens: u64,
    /// Tokens refused with their rejected session.
    pub rejected_tokens: u64,
    /// `rejected_sessions / offered_sessions`.
    pub rejection_rate: f64,
    /// SLO-meeting tokens per virtual second of makespan (raw sim time,
    /// same axis as `ServeMetrics::throughput_tokens_per_s`). With no
    /// SLO configured every completed token counts.
    pub goodput_tokens_per_s: f64,
    /// Per-token latency SLO, full-model ms (0.0 = no SLO configured).
    pub slo_ms: f64,
    /// Completed tokens whose serve latency exceeded the SLO.
    pub slo_violations: u64,
    /// `slo_violations / completed_tokens`.
    pub slo_violation_rate: f64,
    /// Full-model p99 token serve latency, ms (mirrors the serve summary
    /// so fleet tables are self-contained).
    pub p99_ms: f64,
    /// Full-model p99.9 token serve latency, ms.
    pub p999_ms: f64,
    /// Session-arrival events retired by the event heap.
    pub arrival_events: u64,
    /// Per-token compute-completion events retired.
    pub token_events: u64,
    /// Flash ticket-completion events retired.
    pub ticket_events: u64,
}

impl FleetSummary {
    /// Offered load is conserved: every offered token was either decoded
    /// or rejected, and every offered session resolved one way.
    pub fn conserves_load(&self) -> bool {
        self.offered_tokens == self.completed_tokens + self.rejected_tokens
            && self.offered_sessions == self.admitted_sessions + self.rejected_sessions
            && self.completed_sessions <= self.admitted_sessions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_conservative() {
        assert!(FleetSummary::default().conserves_load());
    }

    #[test]
    fn conservation_detects_leaks() {
        let ok = FleetSummary {
            offered_sessions: 4,
            admitted_sessions: 3,
            rejected_sessions: 1,
            completed_sessions: 3,
            offered_tokens: 40,
            completed_tokens: 30,
            rejected_tokens: 10,
            ..Default::default()
        };
        assert!(ok.conserves_load());
        let leak = FleetSummary { completed_tokens: 29, ..ok };
        assert!(!leak.conserves_load());
    }
}
