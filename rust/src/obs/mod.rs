//! Flight-recorder tracing: zero-allocation span capture with per-phase
//! latency attribution.
//!
//! The simulation stack argues about *where time goes* — I/O stalls versus
//! compute overlap under an IOPS-constrained flash device — but summary
//! metrics aggregate the event sequence away. This module records the event
//! sequence itself: typed [`Span`]s (closed intervals on a track) and
//! [`Mark`]s (instants with a payload) captured into pre-sized ring buffers
//! so that the steady-state decode hot path stays allocation-free even with
//! tracing enabled (gated by `zero_alloc_decode.rs`).
//!
//! Design rules (see DESIGN.md §Observability):
//!
//! - **Virtual time only.** Every timestamp is a simulator `clock_ns` value
//!   (`f64` nanoseconds of virtual time). No wall clock is ever read, so two
//!   runs of the same workload produce bit-identical traces and trace files
//!   can be golden-tested.
//! - **No allocation after construction.** [`FlightRecorder::new`] pre-sizes
//!   every buffer ([`Ring`] spans/marks, fixed per-phase histograms, the
//!   capacity-K tail sampler). Recording a span, mark, or token touches no
//!   allocator.
//! - **Closed spans only.** Producers compute a span's duration before
//!   recording it; the recorder never stages open spans, so ring overflow
//!   (overwrite-oldest) cannot corrupt an in-progress chain.
//! - **Aggregates see everything.** [`SpanAggregate`] and the tail sampler
//!   are updated on every record, independent of ring capacity, so
//!   attribution totals are exact even when the raw ring has dropped events.
//!
//! The phase taxonomy mirrors the latency decomposition already reported by
//! `metrics::serve::SessionStats`: per-token round-queue wait, flash stall,
//! and compute, plus device-side flash service, speculative prefetch windows,
//! and fleet admission queueing. Three identities tie the recorder to the
//! existing accounting bit-for-bit (both sides accumulate the same `f64`
//! values in the same order starting from `0.0`):
//!
//! - Σ `FlashQueue` span durations == `RunMetrics::totals.stall_ns`
//! - Σ `Compute` span durations == `RunMetrics::compute_ns`
//! - Σ `FlashService` span durations == `FlashStats::total_busy_ns`

#![warn(missing_docs)]

pub mod export;

use std::sync::{Arc, Mutex};

use crate::util::stats::Histogram;

/// Phase of token service time a [`Span`] is attributed to.
///
/// Phases partition the latency decomposition: a token's end-to-end latency
/// is round-queue wait, then flash stall, then compute; the device track
/// independently records flash service windows; sessions additionally record
/// admission queueing (serve/fleet) and speculative prefetch windows.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Time a token's session spent waiting for earlier sessions in its
    /// decode round (in-round queueing delay, `served_at - round_start`).
    RoundQueue,
    /// Time the token stalled on demand flash reads (`TokenIo::stall_ns`).
    FlashQueue,
    /// Time the flash device spent servicing a submitted batch
    /// (`BatchResult::elapsed_ns`, charged on the device track).
    FlashService,
    /// Compute time for the token (`compute_ns_per_token`).
    Compute,
    /// Speculative prefetch service window for a layer (device time the
    /// prefetch batch occupies, recorded on the issuing session's track).
    Prefetch,
    /// Time a session waited in the admission queue before being granted a
    /// decode slot (`SessionStats::queue_delay_ns`).
    AdmissionQueue,
}

impl Phase {
    /// All phases in canonical report order.
    pub const ALL: [Phase; 6] = [
        Phase::FlashQueue,
        Phase::FlashService,
        Phase::Prefetch,
        Phase::Compute,
        Phase::RoundQueue,
        Phase::AdmissionQueue,
    ];

    /// Dense index of this phase into per-phase arrays (`0..6`).
    pub fn idx(self) -> usize {
        match self {
            Phase::FlashQueue => 0,
            Phase::FlashService => 1,
            Phase::Prefetch => 2,
            Phase::Compute => 3,
            Phase::RoundQueue => 4,
            Phase::AdmissionQueue => 5,
        }
    }

    /// Stable snake_case key used in JSON reports and trace event names.
    pub fn key(self) -> &'static str {
        match self {
            Phase::FlashQueue => "flash_queue",
            Phase::FlashService => "flash_service",
            Phase::Prefetch => "prefetch",
            Phase::Compute => "compute",
            Phase::RoundQueue => "round_queue",
            Phase::AdmissionQueue => "admission_queue",
        }
    }
}

/// Trace track (Perfetto "thread") an event belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Track {
    /// The shared flash device timeline (service windows + ticket marks).
    Device,
    /// The prefetch arbiter (per-round grant decisions).
    Arbiter,
    /// One decode session, identified by its session id.
    Session(u32),
}

impl Track {
    /// Stable Chrome-trace thread id: device = 0, arbiter = 1,
    /// session `sid` = `sid + 2`.
    pub fn tid(self) -> u64 {
        match self {
            Track::Device => 0,
            Track::Arbiter => 1,
            Track::Session(sid) => sid as u64 + 2,
        }
    }
}

/// A closed interval on a track, attributed to a [`Phase`].
///
/// Timestamps and durations are virtual-time nanoseconds (unscaled sim
/// units; the harness applies `layer_scale` only at report time).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    /// Track the span belongs to.
    pub track: Track,
    /// Phase the span's duration is attributed to.
    pub phase: Phase,
    /// Start timestamp (virtual ns).
    pub t_ns: f64,
    /// Duration (virtual ns, `>= 0`).
    pub dur_ns: f64,
}

/// Kind of instantaneous event recorded as a [`Mark`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MarkKind {
    /// Flash batch submitted (device track; `value` = commands,
    /// `aux` = bytes).
    FlashSubmit,
    /// Flash ticket waited to completion (device track; `value` = stall ns
    /// the waiter observed, `aux` = commands).
    FlashComplete,
    /// Flash ticket dropped without waiting (device track).
    FlashDrop,
    /// Speculative prefetch batch submitted (session track;
    /// `value` = target layer, `aux` = commands).
    PrefetchSubmit,
    /// Prefetched bundles consumed by the demand plan (session track;
    /// `value` = hit bundles, `aux` = layer).
    PrefetchHit,
    /// Prefetched bundles wasted (session track; `value` = wasted bundles,
    /// `aux` = layer).
    PrefetchWaste,
    /// Demand plan built for a layer (session track; `value` = layer,
    /// `aux` = missed bundles).
    Plan,
    /// Layer plan committed to the cache (session track; `value` = layer).
    Commit,
    /// Arbiter granted a session speculative budget for a round (arbiter
    /// track; `value` = granted bytes, `aux` = session id).
    Grant,
    /// Session arrival entered the admission queue (session track;
    /// `value` = queue depth after enqueue).
    Arrival,
    /// Session granted a decode slot (session track; `value` = queue delay
    /// ns it waited).
    Admit,
    /// Session arrival rejected by the admission bound (session track;
    /// `value` = refused tokens).
    Reject,
    /// Token finished (session track; `value` = recorded latency ns,
    /// `aux` = recorder-accounted phase sum ns).
    TokenDone,
}

impl MarkKind {
    /// Stable snake_case key used in trace event names.
    pub fn key(self) -> &'static str {
        match self {
            MarkKind::FlashSubmit => "flash_submit",
            MarkKind::FlashComplete => "flash_complete",
            MarkKind::FlashDrop => "flash_drop",
            MarkKind::PrefetchSubmit => "prefetch_submit",
            MarkKind::PrefetchHit => "prefetch_hit",
            MarkKind::PrefetchWaste => "prefetch_waste",
            MarkKind::Plan => "plan",
            MarkKind::Commit => "commit",
            MarkKind::Grant => "grant",
            MarkKind::Arrival => "arrival",
            MarkKind::Admit => "admit",
            MarkKind::Reject => "reject",
            MarkKind::TokenDone => "token_done",
        }
    }
}

/// An instantaneous event on a track with up to two numeric payload slots.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mark {
    /// Track the mark belongs to.
    pub track: Track,
    /// What happened.
    pub kind: MarkKind,
    /// Timestamp (virtual ns).
    pub t_ns: f64,
    /// Primary payload (meaning depends on [`MarkKind`]).
    pub value: f64,
    /// Secondary payload (meaning depends on [`MarkKind`]).
    pub aux: f64,
}

/// Fixed-capacity overwrite-oldest ring buffer.
///
/// `push` past capacity overwrites the oldest element and bumps the
/// [`dropped`](Ring::dropped) counter; it never allocates after
/// construction. Iteration yields elements oldest to newest.
#[derive(Clone, Debug)]
pub struct Ring<T: Copy> {
    items: Vec<T>,
    head: usize,
    dropped: u64,
}

impl<T: Copy> Ring<T> {
    /// Create a ring holding at most `cap` elements (`cap > 0`).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "ring capacity must be positive");
        Ring {
            items: Vec::with_capacity(cap),
            head: 0,
            dropped: 0,
        }
    }

    /// Append an element, overwriting the oldest if the ring is full.
    pub fn push(&mut self, v: T) {
        if self.items.len() < self.items.capacity() {
            self.items.push(v);
        } else {
            self.items[self.head] = v;
            self.head = (self.head + 1) % self.items.len();
            self.dropped += 1;
        }
    }

    /// Number of elements currently retained.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Number of elements overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Iterate retained elements oldest to newest.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.items[self.head..].iter().chain(self.items[..self.head].iter())
    }
}

/// Per-phase time-in-phase rollup, updated on every recorded span
/// independent of ring capacity.
#[derive(Clone, Debug)]
pub struct SpanAggregate {
    count: [u64; 6],
    sum_ns: [f64; 6],
    max_ns: [f64; 6],
    hist: Vec<Histogram>,
    tokens: u64,
    accounted_ns: f64,
    latency_ns: f64,
    exact_closures: u64,
}

impl SpanAggregate {
    /// Create an aggregate with one fixed-bucket histogram per phase
    /// spanning `[0, hist_max_ns)`.
    pub fn new(hist_max_ns: f64) -> Self {
        SpanAggregate {
            count: [0; 6],
            sum_ns: [0.0; 6],
            max_ns: [0.0; 6],
            hist: Phase::ALL
                .iter()
                .map(|_| Histogram::new(0.0, hist_max_ns, 32))
                .collect(),
            tokens: 0,
            accounted_ns: 0.0,
            latency_ns: 0.0,
            exact_closures: 0,
        }
    }

    fn observe(&mut self, phase: Phase, dur_ns: f64) {
        let i = phase.idx();
        self.count[i] += 1;
        self.sum_ns[i] += dur_ns;
        if dur_ns > self.max_ns[i] {
            self.max_ns[i] = dur_ns;
        }
        self.hist[i].add(dur_ns);
    }

    fn token(&mut self, accounted_ns: f64, latency_ns: f64) {
        self.tokens += 1;
        self.accounted_ns += accounted_ns;
        self.latency_ns += latency_ns;
        if accounted_ns.to_bits() == latency_ns.to_bits() {
            self.exact_closures += 1;
        }
    }

    /// Tokens recorded via [`FlightRecorder::token`].
    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    /// Σ per-token `(queue + stall) + compute` phase sums (virtual ns).
    pub fn accounted_ns(&self) -> f64 {
        self.accounted_ns
    }

    /// Σ per-token latencies as reported by the producer (virtual ns).
    pub fn latency_ns(&self) -> f64 {
        self.latency_ns
    }

    /// Tokens whose phase sum equalled the reported latency bit-for-bit.
    pub fn exact_closures(&self) -> u64 {
        self.exact_closures
    }

    /// Total time attributed to `phase` (virtual ns).
    pub fn phase_total_ns(&self, phase: Phase) -> f64 {
        self.sum_ns[phase.idx()]
    }

    /// Number of spans attributed to `phase`.
    pub fn phase_count(&self, phase: Phase) -> u64 {
        self.count[phase.idx()]
    }

    /// Longest single span attributed to `phase` (virtual ns).
    pub fn phase_max_ns(&self, phase: Phase) -> f64 {
        self.max_ns[phase.idx()]
    }

    /// Time-in-phase histogram for `phase`.
    pub fn histogram(&self, phase: Phase) -> &Histogram {
        &self.hist[phase.idx()]
    }
}

/// Full span chain for one token, retained by the tail sampler.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TokenChain {
    /// Session id.
    pub sid: u32,
    /// Round start timestamp (virtual ns).
    pub start_ns: f64,
    /// In-round queueing delay (virtual ns).
    pub queue_ns: f64,
    /// Flash stall (virtual ns).
    pub stall_ns: f64,
    /// Compute time (virtual ns).
    pub compute_ns: f64,
    /// Reported end-to-end latency (virtual ns).
    pub latency_ns: f64,
}

/// Capacity-K reservoir of the slowest tokens seen so far.
///
/// Deterministic: eviction scans for the current minimum-latency entry
/// (first index on ties) and replaces it only when the candidate's latency
/// is strictly greater. No randomness, no allocation after construction.
#[derive(Clone, Debug)]
pub struct TailSampler {
    k: usize,
    chains: Vec<TokenChain>,
}

impl TailSampler {
    /// Create a sampler retaining the slowest `k` tokens (`k == 0` disables
    /// retention).
    pub fn new(k: usize) -> Self {
        TailSampler {
            k,
            chains: Vec::with_capacity(k),
        }
    }

    /// Offer a token chain; keeps it iff it is among the slowest `k`.
    pub fn offer(&mut self, c: TokenChain) {
        if self.k == 0 {
            return;
        }
        if self.chains.len() < self.k {
            self.chains.push(c);
            return;
        }
        let mut min_i = 0;
        for (i, ch) in self.chains.iter().enumerate() {
            if ch.latency_ns < self.chains[min_i].latency_ns {
                min_i = i;
            }
        }
        if c.latency_ns > self.chains[min_i].latency_ns {
            self.chains[min_i] = c;
        }
    }

    /// Number of retained chains.
    pub fn len(&self) -> usize {
        self.chains.len()
    }

    /// True when no chains are retained.
    pub fn is_empty(&self) -> bool {
        self.chains.is_empty()
    }

    /// Retained chains sorted slowest-first (ties: earlier start, then
    /// lower session id). Allocates; call only at export/summary time.
    pub fn sorted(&self) -> Vec<TokenChain> {
        let mut v = self.chains.clone();
        v.sort_by(|a, b| {
            b.latency_ns
                .total_cmp(&a.latency_ns)
                .then(a.start_ns.total_cmp(&b.start_ns))
                .then(a.sid.cmp(&b.sid))
        });
        v
    }
}

/// Sizing knobs for a [`FlightRecorder`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceConfig {
    /// Span ring capacity (oldest spans are overwritten past this).
    pub span_capacity: usize,
    /// Mark ring capacity.
    pub mark_capacity: usize,
    /// Number of slowest-token chains the tail sampler retains.
    pub tail_k: usize,
    /// Upper bound of the per-phase histograms (virtual ns); durations at or
    /// above land in the overflow counter.
    pub hist_max_ns: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            span_capacity: 65536,
            mark_capacity: 65536,
            tail_k: 32,
            hist_max_ns: 1e7,
        }
    }
}

/// The flight recorder: pre-sized span/mark rings plus always-exact
/// aggregates and a tail sampler.
#[derive(Clone, Debug)]
pub struct FlightRecorder {
    spans: Ring<Span>,
    marks: Ring<Mark>,
    agg: SpanAggregate,
    tail: TailSampler,
}

impl FlightRecorder {
    /// Create a recorder; all buffers are sized here and never grow.
    pub fn new(cfg: TraceConfig) -> Self {
        FlightRecorder {
            spans: Ring::new(cfg.span_capacity),
            marks: Ring::new(cfg.mark_capacity),
            agg: SpanAggregate::new(cfg.hist_max_ns),
            tail: TailSampler::new(cfg.tail_k),
        }
    }

    /// Record a closed span and fold it into the per-phase aggregate.
    pub fn span(&mut self, track: Track, phase: Phase, t_ns: f64, dur_ns: f64) {
        self.agg.observe(phase, dur_ns);
        self.spans.push(Span {
            track,
            phase,
            t_ns,
            dur_ns,
        });
    }

    /// Record an instantaneous mark.
    pub fn mark(&mut self, track: Track, kind: MarkKind, t_ns: f64, value: f64, aux: f64) {
        self.marks.push(Mark {
            track,
            kind,
            t_ns,
            value,
            aux,
        });
    }

    /// Record one served token atomically: emits the RoundQueue, FlashQueue,
    /// and Compute spans back-to-back on the session's track, a `TokenDone`
    /// mark, the aggregate update, and a tail-sampler offer.
    ///
    /// `latency_ns` is the latency the producer reported; the recorder's own
    /// phase sum is `(queue_ns + stall_ns) + compute_ns` (the parenthesis
    /// order is load-bearing for the bit-for-bit closure property tests).
    pub fn token(
        &mut self,
        sid: u32,
        start_ns: f64,
        queue_ns: f64,
        stall_ns: f64,
        compute_ns: f64,
        latency_ns: f64,
    ) {
        let track = Track::Session(sid);
        self.span(track, Phase::RoundQueue, start_ns, queue_ns);
        self.span(track, Phase::FlashQueue, start_ns + queue_ns, stall_ns);
        self.span(
            track,
            Phase::Compute,
            start_ns + queue_ns + stall_ns,
            compute_ns,
        );
        let accounted = (queue_ns + stall_ns) + compute_ns;
        self.mark(
            track,
            MarkKind::TokenDone,
            start_ns + accounted,
            latency_ns,
            accounted,
        );
        self.agg.token(accounted, latency_ns);
        self.tail.offer(TokenChain {
            sid,
            start_ns,
            queue_ns,
            stall_ns,
            compute_ns,
            latency_ns,
        });
    }

    /// Retained spans, oldest to newest.
    pub fn spans(&self) -> impl Iterator<Item = &Span> {
        self.spans.iter()
    }

    /// Retained marks, oldest to newest.
    pub fn marks(&self) -> impl Iterator<Item = &Mark> {
        self.marks.iter()
    }

    /// Number of spans overwritten by ring overflow.
    pub fn spans_dropped(&self) -> u64 {
        self.spans.dropped()
    }

    /// Number of marks overwritten by ring overflow.
    pub fn marks_dropped(&self) -> u64 {
        self.marks.dropped()
    }

    /// Number of spans currently retained in the ring.
    pub fn spans_len(&self) -> usize {
        self.spans.len()
    }

    /// The always-exact per-phase rollup.
    pub fn aggregate(&self) -> &SpanAggregate {
        &self.agg
    }

    /// The slowest-token sampler.
    pub fn tail(&self) -> &TailSampler {
        &self.tail
    }

    /// Build the report-facing attribution summary. `layer_scale` converts
    /// sim-layer virtual time to full-model time, matching the scaling the
    /// harness applies to every other latency metric.
    pub fn attribution(&self, layer_scale: f64) -> AttributionSummary {
        let ms = |ns: f64| ns * layer_scale / 1e6;
        let phases = Phase::ALL
            .iter()
            .map(|&p| {
                let count = self.agg.phase_count(p);
                let total = ms(self.agg.phase_total_ns(p));
                PhaseAttribution {
                    phase: p.key().to_string(),
                    count,
                    total_ms: total,
                    mean_ms: if count == 0 { 0.0 } else { total / count as f64 },
                    max_ms: ms(self.agg.phase_max_ns(p)),
                }
            })
            .collect();
        let tail = self
            .tail
            .sorted()
            .into_iter()
            .map(|c| TailToken {
                sid: c.sid,
                start_ms: ms(c.start_ns),
                queue_ms: ms(c.queue_ns),
                stall_ms: ms(c.stall_ns),
                compute_ms: ms(c.compute_ns),
                latency_ms: ms(c.latency_ns),
            })
            .collect();
        AttributionSummary {
            tokens: self.agg.tokens(),
            accounted_ms: ms(self.agg.accounted_ns()),
            latency_ms: ms(self.agg.latency_ns()),
            closure_error_ms: ms(self.agg.latency_ns() - self.agg.accounted_ns()),
            exact_closures: self.agg.exact_closures(),
            spans_recorded: self.agg.count.iter().sum(),
            spans_dropped: self.spans.dropped(),
            marks_dropped: self.marks.dropped(),
            phases,
            tail,
        }
    }
}

/// Shared, clonable handle to a [`FlightRecorder`].
///
/// The recorder sits behind an `Arc<Mutex<..>>` so one handle can be
/// threaded through the flash sim, every session pipeline, and the manager
/// simultaneously. Locking an uncontended `std` mutex does not allocate, so
/// the zero-alloc decode gates hold with tracing attached.
#[derive(Clone, Debug)]
pub struct TraceHandle(Arc<Mutex<FlightRecorder>>);

impl TraceHandle {
    /// Create a handle around a freshly constructed recorder.
    pub fn new(cfg: TraceConfig) -> Self {
        TraceHandle(Arc::new(Mutex::new(FlightRecorder::new(cfg))))
    }

    /// Run `f` with exclusive access to the recorder (poison-proof).
    pub fn with<R>(&self, f: impl FnOnce(&mut FlightRecorder) -> R) -> R {
        let mut guard = self.0.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut guard)
    }
}

/// One row of the per-phase attribution table (report units: milliseconds
/// of full-model time).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseAttribution {
    /// Phase key (`flash_queue`, `flash_service`, `prefetch`, `compute`,
    /// `round_queue`, `admission_queue`).
    pub phase: String,
    /// Number of spans attributed to this phase.
    pub count: u64,
    /// Total time in phase (ms).
    pub total_ms: f64,
    /// Mean span duration (ms; 0.0 when no spans).
    pub mean_ms: f64,
    /// Longest single span (ms).
    pub max_ms: f64,
}

/// One retained slowest-token chain (report units: milliseconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TailToken {
    /// Session id.
    pub sid: u32,
    /// Round start (ms since run start).
    pub start_ms: f64,
    /// In-round queueing delay (ms).
    pub queue_ms: f64,
    /// Flash stall (ms).
    pub stall_ms: f64,
    /// Compute (ms).
    pub compute_ms: f64,
    /// End-to-end latency (ms).
    pub latency_ms: f64,
}

/// Report-facing rollup of a traced run: per-phase totals, closure
/// cross-check against the producer-reported latencies, ring-drop
/// accounting, and the slowest-token tail.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AttributionSummary {
    /// Tokens recorded.
    pub tokens: u64,
    /// Σ per-token phase sums (ms).
    pub accounted_ms: f64,
    /// Σ producer-reported token latencies (ms).
    pub latency_ms: f64,
    /// `latency_ms - accounted_ms` (should be ~0; exactly 0 when every
    /// closure was bit-exact).
    pub closure_error_ms: f64,
    /// Tokens whose phase sum equalled the reported latency bit-for-bit.
    pub exact_closures: u64,
    /// Spans folded into the aggregate (independent of ring drops).
    pub spans_recorded: u64,
    /// Spans lost to ring overflow (aggregates still counted them).
    pub spans_dropped: u64,
    /// Marks lost to ring overflow.
    pub marks_dropped: u64,
    /// Per-phase rollup in [`Phase::ALL`] order.
    pub phases: Vec<PhaseAttribution>,
    /// Slowest-token chains, slowest first.
    pub tail: Vec<TailToken>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest() {
        let mut r = Ring::new(3);
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let v: Vec<i32> = r.iter().copied().collect();
        assert_eq!(v, vec![2, 3, 4]);
    }

    #[test]
    fn token_closure_is_exact_by_construction() {
        let mut rec = FlightRecorder::new(TraceConfig::default());
        let (q, s, c) = (3.5, 7.25, 11.125);
        let latency = (q + s) + c;
        rec.token(0, 100.0, q, s, c, latency);
        assert_eq!(rec.aggregate().tokens(), 1);
        assert_eq!(rec.aggregate().exact_closures(), 1);
        assert_eq!(
            rec.aggregate().accounted_ns().to_bits(),
            latency.to_bits()
        );
    }

    #[test]
    fn tail_sampler_keeps_slowest() {
        let mut t = TailSampler::new(2);
        for (i, lat) in [5.0, 9.0, 1.0, 7.0].iter().enumerate() {
            t.offer(TokenChain {
                sid: i as u32,
                latency_ns: *lat,
                ..TokenChain::default()
            });
        }
        let v = t.sorted();
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].latency_ns, 9.0);
        assert_eq!(v[1].latency_ns, 7.0);
    }

    #[test]
    fn attribution_scales_to_ms() {
        let mut rec = FlightRecorder::new(TraceConfig::default());
        rec.token(0, 0.0, 0.0, 2e6, 1e6, 3e6);
        let a = rec.attribution(2.0);
        assert_eq!(a.tokens, 1);
        assert!((a.latency_ms - 6.0).abs() < 1e-12);
        let stall = a.phases.iter().find(|p| p.phase == "flash_queue").unwrap();
        assert!((stall.total_ms - 4.0).abs() < 1e-12);
        assert_eq!(a.tail.len(), 1);
    }
}
