//! Chrome trace-event (Perfetto) JSON export and validation.
//!
//! [`chrome_trace_json`] serializes a [`FlightRecorder`]'s retained spans
//! and marks into the Chrome trace-event format understood by
//! `ui.perfetto.dev` and `chrome://tracing`: one "thread" (track) per decode
//! session plus dedicated device and arbiter tracks. The output is fully
//! deterministic — virtual-time stamps, `BTreeMap`-ordered keys, and a
//! stable event sort — so two runs of the same workload produce
//! byte-identical trace files.
//!
//! [`validate_chrome_trace`] is the inverse used by the `trace-check` CLI
//! subcommand and the CI `trace-smoke` job: it parses a trace file and
//! checks it is well-formed against the subset of the schema we emit
//! (metadata first, finite timestamps, non-negative durations, and
//! monotonically non-decreasing timestamps within each track).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};

use super::{FlightRecorder, Track};

fn track_name(t: Track) -> String {
    match t {
        Track::Device => "device".to_string(),
        Track::Arbiter => "arbiter".to_string(),
        Track::Session(sid) => format!("session {sid}"),
    }
}

/// Serialize the recorder's retained spans and marks as a Chrome
/// trace-event JSON document.
///
/// Layout: a `traceEvents` array opening with one `"M"` thread-name
/// metadata record per present track (ascending thread id), followed by
/// `"X"` complete events for spans and `"i"` instant events for marks,
/// stably sorted by (timestamp, spans-before-marks, recording order).
/// Timestamps and durations are microseconds of virtual time (`ns / 1e3`),
/// the unit the trace-event format expects.
pub fn chrome_trace_json(rec: &FlightRecorder) -> String {
    // (tid -> name) for every track that actually recorded something.
    let mut tracks: BTreeMap<u64, String> = BTreeMap::new();
    for sp in rec.spans() {
        tracks.entry(sp.track.tid()).or_insert_with(|| track_name(sp.track));
    }
    for m in rec.marks() {
        tracks.entry(m.track.tid()).or_insert_with(|| track_name(m.track));
    }

    // Sort key: (ts, source_rank [spans first], ring index).
    let mut events: Vec<(f64, u8, usize, Json)> = Vec::new();
    for (i, sp) in rec.spans().enumerate() {
        events.push((
            sp.t_ns,
            0,
            i,
            json::obj(vec![
                ("ph", json::s("X")),
                ("pid", json::num(0.0)),
                ("tid", json::num(sp.track.tid() as f64)),
                ("ts", json::num(sp.t_ns / 1e3)),
                ("dur", json::num(sp.dur_ns / 1e3)),
                ("name", json::s(sp.phase.key())),
                ("cat", json::s("phase")),
            ]),
        ));
    }
    for (i, m) in rec.marks().enumerate() {
        events.push((
            m.t_ns,
            1,
            i,
            json::obj(vec![
                ("ph", json::s("i")),
                ("pid", json::num(0.0)),
                ("tid", json::num(m.track.tid() as f64)),
                ("ts", json::num(m.t_ns / 1e3)),
                ("name", json::s(m.kind.key())),
                ("s", json::s("t")),
                (
                    "args",
                    json::obj(vec![
                        ("value", json::num(m.value)),
                        ("aux", json::num(m.aux)),
                    ]),
                ),
            ]),
        ));
    }
    events.sort_by(|a, b| {
        a.0.total_cmp(&b.0)
            .then(a.1.cmp(&b.1))
            .then(a.2.cmp(&b.2))
    });

    let mut out: Vec<Json> = Vec::with_capacity(tracks.len() + events.len());
    for (tid, name) in &tracks {
        out.push(json::obj(vec![
            ("ph", json::s("M")),
            ("pid", json::num(0.0)),
            ("tid", json::num(*tid as f64)),
            ("name", json::s("thread_name")),
            ("args", json::obj(vec![("name", json::s(name))])),
        ]));
    }
    out.extend(events.into_iter().map(|e| e.3));

    json::obj(vec![
        ("displayTimeUnit", json::s("ms")),
        ("traceEvents", Json::Arr(out)),
    ])
    .to_string()
}

/// Summary of a validated trace file.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceCheck {
    /// Non-metadata events (spans + instants) in the file.
    pub events: usize,
    /// Distinct (pid, tid) tracks carrying events.
    pub tracks: usize,
}

/// Parse a Chrome trace-event JSON document and verify the invariants the
/// exporter guarantees: a `traceEvents` array; every non-metadata event has
/// a finite timestamp; `"X"` events have finite non-negative durations; and
/// timestamps are monotonically non-decreasing within each (pid, tid)
/// track.
pub fn validate_chrome_trace(text: &str) -> Result<TraceCheck> {
    let doc = Json::parse(text).map_err(|e| anyhow!("trace is not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("trace has no `traceEvents` array"))?;

    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let mut counted = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .req_str("ph")
            .map_err(|e| anyhow!("event {i}: {e}"))?;
        if ph == "M" {
            continue;
        }
        let pid = ev.req_f64("pid").map_err(|e| anyhow!("event {i}: {e}"))? as u64;
        let tid = ev.req_f64("tid").map_err(|e| anyhow!("event {i}: {e}"))? as u64;
        let ts = ev.req_f64("ts").map_err(|e| anyhow!("event {i}: {e}"))?;
        if !ts.is_finite() {
            bail!("event {i}: non-finite timestamp");
        }
        if ph == "X" {
            let dur = ev.req_f64("dur").map_err(|e| anyhow!("event {i}: {e}"))?;
            if !dur.is_finite() || dur < 0.0 {
                bail!("event {i}: bad duration {dur}");
            }
        }
        if let Some(&prev) = last_ts.get(&(pid, tid)) {
            if ts < prev {
                bail!(
                    "event {i}: timestamp {ts} regresses below {prev} on track (pid={pid}, tid={tid})"
                );
            }
        }
        last_ts.insert((pid, tid), ts);
        counted += 1;
    }
    Ok(TraceCheck {
        events: counted,
        tracks: last_ts.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::super::{MarkKind, Phase, TraceConfig};
    use super::*;

    #[test]
    fn export_roundtrips_through_validator() {
        let mut rec = FlightRecorder::new(TraceConfig::default());
        rec.span(Track::Device, Phase::FlashService, 10.0, 5.0);
        rec.mark(Track::Arbiter, MarkKind::Grant, 12.0, 4096.0, 0.0);
        rec.token(0, 0.0, 1.0, 2.0, 3.0, 6.0);
        let text = chrome_trace_json(&rec);
        let chk = validate_chrome_trace(&text).unwrap();
        // 3 token spans + 1 device span + 1 grant mark + 1 token_done mark.
        assert_eq!(chk.events, 6);
        assert_eq!(chk.tracks, 3);
    }

    #[test]
    fn export_is_deterministic() {
        let build = || {
            let mut rec = FlightRecorder::new(TraceConfig::default());
            for i in 0..50 {
                rec.token(i % 3, i as f64 * 10.0, 1.0, 2.0, 3.0, 6.0);
            }
            rec.span(Track::Device, Phase::FlashService, 7.0, 2.0);
            chrome_trace_json(&rec)
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn validator_rejects_regressing_timestamps() {
        let bad = r#"{"traceEvents":[
            {"ph":"X","pid":0,"tid":1,"ts":10,"dur":1,"name":"a"},
            {"ph":"X","pid":0,"tid":1,"ts":5,"dur":1,"name":"b"}
        ]}"#;
        assert!(validate_chrome_trace(bad).is_err());
    }

    #[test]
    fn validator_rejects_negative_duration() {
        let bad = r#"{"traceEvents":[
            {"ph":"X","pid":0,"tid":1,"ts":10,"dur":-1,"name":"a"}
        ]}"#;
        assert!(validate_chrome_trace(bad).is_err());
    }

    #[test]
    fn validator_rejects_non_json() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{}").is_err());
    }
}
