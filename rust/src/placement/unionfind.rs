//! Union-find (disjoint sets) with path halving + union by size.
//! Used by Algorithm 1 to track which neurons already share a link.

/// Disjoint-set forest over `0..n` element ids.
#[derive(Clone, Debug)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
    sets: usize,
}

impl UnionFind {
    /// `n` singleton sets.
    pub fn new(n: usize) -> Self {
        Self {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            sets: n,
        }
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    /// True when the structure tracks no elements.
    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint sets remaining.
    pub fn n_sets(&self) -> usize {
        self.sets
    }

    /// Representative of `x`'s set (with path halving).
    #[inline]
    pub fn find(&mut self, mut x: u32) -> u32 {
        // path halving
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    /// Union the sets of a and b; returns false if already joined.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (big, small) = if self.size[ra as usize] >= self.size[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[small as usize] = big;
        self.size[big as usize] += self.size[small as usize];
        self.sets -= 1;
        true
    }

    /// True when `a` and `b` share a set.
    pub fn same(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }

    /// Size of the set containing `x`.
    pub fn set_size(&mut self, x: u32) -> u32 {
        let r = self.find(x);
        self.size[r as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn basic_union_find() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.n_sets(), 5);
        assert!(uf.union(0, 1));
        assert!(!uf.union(1, 0));
        assert!(uf.same(0, 1));
        assert!(!uf.same(0, 2));
        assert_eq!(uf.n_sets(), 4);
        assert_eq!(uf.set_size(1), 2);
    }

    #[test]
    fn chain_unions_collapse() {
        let mut uf = UnionFind::new(100);
        for i in 0..99 {
            uf.union(i, i + 1);
        }
        assert_eq!(uf.n_sets(), 1);
        assert!(uf.same(0, 99));
        assert_eq!(uf.set_size(42), 100);
    }

    #[test]
    fn prop_union_count_invariant() {
        // successful unions + remaining sets == n
        prop::run_bool(
            "uf-count",
            prop::Config { cases: 40, max_size: 128, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = size.max(2);
                let ops: Vec<(u32, u32)> = (0..size * 2)
                    .map(|_| (rng.below(n) as u32, rng.below(n) as u32))
                    .collect();
                (n, ops)
            },
            |(n, ops)| {
                let mut uf = UnionFind::new(*n);
                let mut merged = 0;
                for &(a, b) in ops {
                    if uf.union(a, b) {
                        merged += 1;
                    }
                }
                uf.n_sets() + merged == *n
            },
        );
    }

    #[test]
    fn prop_same_is_transitive() {
        prop::run_bool(
            "uf-transitive",
            prop::Config { cases: 30, max_size: 64, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = size.max(3);
                let ops: Vec<(u32, u32)> = (0..size)
                    .map(|_| (rng.below(n) as u32, rng.below(n) as u32))
                    .collect();
                (n, ops)
            },
            |(n, ops)| {
                let mut uf = UnionFind::new(*n);
                for &(a, b) in ops {
                    uf.union(a, b);
                }
                for a in 0..*n as u32 {
                    for b in 0..*n as u32 {
                        if uf.same(a, b) {
                            let ra = uf.find(a);
                            if ra != uf.find(b) {
                                return false;
                            }
                        }
                    }
                }
                true
            },
        );
    }
}
