//! Offline correlation-aware clustering (paper §4): the placement search
//! (Algorithm 1) and the baseline layouts it is evaluated against.

#![warn(missing_docs)]

pub mod baselines;
mod greedy;
mod unionfind;

pub use greedy::{place_model, search, search_with_pairs, GreedyParams, SearchResult};
pub use unionfind::UnionFind;
