//! Baseline placements the paper compares against.
//!
//! * `structural` — the model-structure order every framework uses by
//!   default (llama.cpp stores FFN matrices row after row); this is the
//!   Llama.cpp baseline's layout.
//! * `llmflash` — LLM-in-a-Flash keeps the structural order but bundles
//!   each up-row with its bound down-column so one activation costs one
//!   read instead of two ("row-column bundling"). In this codebase the
//!   *bundle* is already the storage unit for every policy, so the
//!   LLMFlash layout is structural order over bundles; its improvement
//!   over Llama.cpp is modeled by read granularity (see pipeline): the
//!   Llama.cpp baseline issues `ffn_linears` separate sub-reads per
//!   activated neuron, LLMFlash issues one bundle read.
//! * `frequency` — hot-first ordering; an ablation showing popularity
//!   alone (no co-activation) is not enough for continuity.

use crate::coact::CoactStats;
use crate::neuron::{BundleId, Layout};

/// The model-structure (identity) order every framework defaults to.
pub fn structural(n: usize) -> Layout {
    Layout::identity(n)
}

/// LLM-in-a-Flash's row-column-bundled layout: structural order over
/// bundles (see module docs — the bundling itself is modeled by read
/// granularity, not by reordering).
pub fn llmflash(n: usize) -> Layout {
    Layout::identity(n)
}

/// Order bundles by activation frequency, descending (stable by id).
pub fn frequency(stats: &CoactStats) -> Layout {
    let n = stats.n_neurons();
    let mut order: Vec<BundleId> = (0..n as u32).collect();
    order.sort_by(|&a, &b| stats.freq(b).cmp(&stats.freq(a)).then(a.cmp(&b)));
    Layout::from_order(&order).expect("frequency order is a permutation")
}

/// Resolve a placement-policy name (RunConfig::placement) to a layout for
/// one layer.
pub fn by_name(
    name: &str,
    stats: &CoactStats,
    params: super::GreedyParams,
) -> anyhow::Result<Layout> {
    match name {
        "ripple" => Ok(super::search(stats, params).layout),
        "structural" | "llamacpp" => Ok(structural(stats.n_neurons())),
        "llmflash" => Ok(llmflash(stats.n_neurons())),
        "frequency" => Ok(frequency(stats)),
        _ => anyhow::bail!(
            "unknown placement `{name}` (ripple|structural|llmflash|frequency)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structural_is_identity() {
        let l = structural(8);
        for i in 0..8u32 {
            assert_eq!(l.slot_of(i), i);
        }
    }

    #[test]
    fn frequency_orders_hot_first() {
        // neuron 2 fires 3x, neuron 0 2x, neuron 1 1x
        let sets: [&[u32]; 3] = [&[0, 2], &[0, 2], &[1, 2]];
        let s = CoactStats::from_sets(3, sets.iter().copied());
        let l = frequency(&s);
        assert_eq!(l.bundle_at(0), 2);
        assert_eq!(l.bundle_at(1), 0);
        assert_eq!(l.bundle_at(2), 1);
    }

    #[test]
    fn by_name_dispatch() {
        let sets: [&[u32]; 2] = [&[0, 1], &[1, 2]];
        let s = CoactStats::from_sets(4, sets.iter().copied());
        for name in ["ripple", "structural", "llmflash", "frequency"] {
            let l = by_name(name, &s, super::super::GreedyParams::default()).unwrap();
            assert_eq!(l.len(), 4);
            l.validate().unwrap();
        }
        assert!(by_name("bogus", &s, Default::default()).is_err());
    }
}
