//! Algorithm 1: heuristic-driven greedy neuron-placement search.
//!
//! Treat every neuron as a 1-element link; repeatedly take the closest
//! pair of link *endpoints* (dist(i,j) = 1 − P(ij), i.e. highest
//! co-count first) and merge their links end-to-end, skipping pairs whose
//! endpoint is already interior (NbrCnt == 2) or that would close a cycle
//! (same union-find set). The result is a Hamiltonian path whose order
//! becomes the flash layout.
//!
//! The pair queue is the kNN-sparsified candidate set from
//! `CoactStats::candidate_pairs` (see coact/mod.rs): pairs outside every
//! neuron's top-m partners have ~zero co-count, tie at dist≈1, and can
//! never displace a retained pair — they only matter for the final
//! fragment stitching, where order among them is irrelevant to expected
//! I/O (Eq. 5's second term is zero for such pairs). Fragments left after
//! the queue drains are concatenated hottest-first, which additionally
//! clusters the hot region of flash (helps the cache's segment policy).

use crate::coact::CoactStats;
use crate::neuron::{BundleId, Layout};

use super::unionfind::UnionFind;

/// Tuning knobs for the greedy placement search.
#[derive(Clone, Copy, Debug)]
pub struct GreedyParams {
    /// Top-m co-activation partners per neuron kept in the pair queue.
    pub knn: usize,
    /// Worker threads for the pairwise co-count scan (§Perf).
    pub scan_threads: usize,
}

impl Default for GreedyParams {
    fn default() -> Self {
        Self { knn: 48, scan_threads: 1 }
    }
}

/// Outcome of a placement search, with search diagnostics.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// The placed bundle order (slot assignment) for the layer.
    pub layout: Layout,
    /// Pairs examined from the queue.
    pub pairs_scanned: usize,
    /// Pairs that became links.
    pub links_made: usize,
    /// Path fragments stitched in the final pass.
    pub fragments: usize,
}

/// Run Algorithm 1 on one layer's co-activation statistics.
pub fn search(stats: &CoactStats, params: GreedyParams) -> SearchResult {
    let pairs = stats.candidate_pairs_parallel(params.knn, params.scan_threads.max(1));
    search_with_pairs(stats, &pairs)
}

/// Algorithm 1 over a precomputed candidate pair list (deduped, sorted
/// by co-count descending — `CoactStats::candidate_pairs*` output).
/// Lets callers share the dominant O(n²) co-count scan with other
/// consumers (e.g. the speculative prefetcher's adjacency).
pub fn search_with_pairs(
    stats: &CoactStats,
    pairs: &[(BundleId, BundleId, u32)],
) -> SearchResult {
    let n = stats.n_neurons();
    assert!(n > 0);

    let mut nbr_cnt = vec![0u8; n];
    let mut uf = UnionFind::new(n);
    // doubly-linked path structure: up to 2 neighbors per neuron
    const NONE: u32 = u32::MAX;
    let mut nbr = vec![[NONE; 2]; n];

    let mut links_made = 0usize;
    let mut pairs_scanned = 0usize;
    for &(a, b, _count) in pairs {
        pairs_scanned += 1;
        let (ai, bi) = (a as usize, b as usize);
        if nbr_cnt[ai] == 2 || nbr_cnt[bi] == 2 {
            continue; // endpoint already interior to a link
        }
        if !uf.union(a, b) {
            continue; // would close a cycle
        }
        let slot_a = nbr_cnt[ai] as usize;
        let slot_b = nbr_cnt[bi] as usize;
        nbr[ai][slot_a] = b;
        nbr[bi][slot_b] = a;
        nbr_cnt[ai] += 1;
        nbr_cnt[bi] += 1;
        links_made += 1;
    }

    // Walk each fragment from one endpoint to the other.
    let mut visited = vec![false; n];
    let mut fragments: Vec<(Vec<BundleId>, u64)> = Vec::new(); // (path, total freq)
    for start in 0..n as u32 {
        if visited[start as usize] || nbr_cnt[start as usize] == 2 {
            continue; // only start walks at endpoints / isolated nodes
        }
        let mut path = Vec::new();
        let mut freq_sum = 0u64;
        let mut prev = NONE;
        let mut cur = start;
        loop {
            visited[cur as usize] = true;
            path.push(cur);
            freq_sum += stats.freq(cur) as u64;
            let [x, y] = nbr[cur as usize];
            let next = if x != NONE && x != prev {
                x
            } else if y != NONE && y != prev {
                y
            } else {
                break;
            };
            prev = cur;
            cur = next;
        }
        fragments.push((path, freq_sum));
    }
    debug_assert!(visited.iter().all(|&v| v), "cycle slipped through");

    // Stitch fragments hottest-first (mean per-neuron frequency).
    fragments.sort_by(|a, b| {
        let fa = a.1 as f64 / a.0.len() as f64;
        let fb = b.1 as f64 / b.0.len() as f64;
        fb.partial_cmp(&fa).unwrap().then(a.0[0].cmp(&b.0[0]))
    });
    let n_fragments = fragments.len();
    let mut order: Vec<BundleId> = Vec::with_capacity(n);
    for (path, _) in fragments {
        order.extend(path);
    }

    let layout = Layout::from_order(&order).expect("greedy produced non-permutation");
    SearchResult { layout, pairs_scanned, links_made, fragments: n_fragments }
}

/// Place every layer of a model, optionally in parallel (the paper
/// parallelizes the offline search across layers, §6.4).
pub fn place_model(
    traces: &crate::trace::Trace,
    params: GreedyParams,
    threads: usize,
) -> Vec<Layout> {
    let n_layers = traces.n_layers;
    // Two-level parallelism: layers outer, pair-scan shards inner —
    // spare cores go to the scan when there are few layers (§Perf).
    let mut params = params;
    if params.scan_threads <= 1 && threads > n_layers {
        params.scan_threads = threads / n_layers.max(1);
    }
    if threads <= 1 || n_layers == 1 {
        return (0..n_layers)
            .map(|l| search(&CoactStats::from_trace_layer(traces, l), params).layout)
            .collect();
    }
    let mut layouts: Vec<Option<Layout>> = vec![None; n_layers];
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<Layout>>> =
        (0..n_layers).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n_layers) {
            scope.spawn(|| loop {
                let l = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if l >= n_layers {
                    break;
                }
                let stats = CoactStats::from_trace_layer(traces, l);
                let r = search(&stats, params);
                *slots[l].lock().unwrap() = Some(r.layout);
            });
        }
    });
    for (l, slot) in slots.into_iter().enumerate() {
        layouts[l] = slot.into_inner().unwrap();
    }
    layouts.into_iter().map(Option::unwrap).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::{DatasetProfile, LayerTraceGen};
    use crate::trace::Trace;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn stats_from(sets: &[&[u32]], n: usize) -> CoactStats {
        CoactStats::from_sets(n, sets.iter().copied())
    }

    #[test]
    fn hand_checkable_chain() {
        // tokens: {0,1} x3, {1,2} x2, {3} alone.
        let s = stats_from(&[&[0, 1], &[0, 1], &[0, 1], &[1, 2], &[1, 2], &[3]], 4);
        let r = search(&s, GreedyParams::default());
        let order = r.layout.order().to_vec();
        // 0-1 strongest link, 1-2 next; 1 must sit between 0 and 2.
        let pos = |b: u32| order.iter().position(|&x| x == b).unwrap() as isize;
        assert_eq!((pos(0) - pos(1)).abs(), 1, "order={order:?}");
        assert_eq!((pos(1) - pos(2)).abs(), 1, "order={order:?}");
    }

    #[test]
    fn respects_interior_rule() {
        // 1 co-fires with 0, 2 AND 3; only two of those can be adjacent.
        let s = stats_from(
            &[&[0, 1], &[0, 1], &[0, 1], &[1, 2], &[1, 2], &[1, 3]],
            4,
        );
        let r = search(&s, GreedyParams::default());
        let order = r.layout.order();
        let pos1 = order.iter().position(|&x| x == 1).unwrap();
        let mut adj = 0;
        if pos1 > 0 { adj += 1; }
        if pos1 + 1 < order.len() { adj += 1; }
        assert!(adj <= 2);
        r.layout.validate().unwrap();
    }

    #[test]
    fn output_is_permutation_on_correlated_trace() {
        let mut g = LayerTraceGen::new(512, 64, &DatasetProfile::alpaca(), 1, 0, 2);
        let sets: Vec<Vec<u32>> = (0..200).map(|_| g.sample()).collect();
        let refs: Vec<&[u32]> = sets.iter().map(|v| v.as_slice()).collect();
        let s = CoactStats::from_sets(512, refs.iter().copied());
        let r = search(&s, GreedyParams::default());
        assert_eq!(r.layout.len(), 512);
        r.layout.validate().unwrap();
        assert!(r.links_made > 100, "links={}", r.links_made);
    }

    /// Expected discontiguous runs per token under a layout (lower=better).
    fn mean_runs(layout: &Layout, sets: &[Vec<u32>]) -> f64 {
        let mut total = 0usize;
        for set in sets {
            let slots = layout.slots_for(set);
            let mut runs = 1;
            for w in slots.windows(2) {
                if w[1] != w[0] + 1 {
                    runs += 1;
                }
            }
            total += runs;
        }
        total as f64 / sets.len() as f64
    }

    #[test]
    fn greedy_beats_structural_on_runs() {
        // The headline offline effect: far fewer discontiguous runs.
        let mut g = LayerTraceGen::new(1024, 100, &DatasetProfile::alpaca(), 5, 0, 3);
        let calib: Vec<Vec<u32>> = (0..300).map(|_| g.sample()).collect();
        let eval: Vec<Vec<u32>> = (0..100).map(|_| g.sample()).collect();
        let refs: Vec<&[u32]> = calib.iter().map(|v| v.as_slice()).collect();
        let s = CoactStats::from_sets(1024, refs.iter().copied());
        let ripple = search(&s, GreedyParams::default()).layout;
        let structural = Layout::identity(1024);
        let r_ripple = mean_runs(&ripple, &eval);
        let r_struct = mean_runs(&structural, &eval);
        assert!(
            r_ripple < r_struct * 0.6,
            "ripple={r_ripple:.1} structural={r_struct:.1}"
        );
    }

    #[test]
    fn deterministic() {
        let mut g = LayerTraceGen::new(256, 32, &DatasetProfile::wikitext(), 2, 0, 4);
        let sets: Vec<Vec<u32>> = (0..100).map(|_| g.sample()).collect();
        let refs: Vec<&[u32]> = sets.iter().map(|v| v.as_slice()).collect();
        let s = CoactStats::from_sets(256, refs.iter().copied());
        let a = search(&s, GreedyParams::default());
        let b = search(&s, GreedyParams::default());
        assert_eq!(a.layout, b.layout);
    }

    #[test]
    fn place_model_parallel_matches_serial() {
        let mut tg = crate::trace::TraceGen::new(
            3, 256, 32, &DatasetProfile::alpaca(), 9, 10);
        let tr: Trace = tg.generate(80);
        let serial = place_model(&tr, GreedyParams::default(), 1);
        let parallel = place_model(&tr, GreedyParams::default(), 4);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn prop_always_a_permutation() {
        prop::run_bool(
            "greedy-permutation",
            prop::Config { cases: 24, max_size: 128, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = size.max(4);
                let sets: Vec<Vec<u32>> = (0..40)
                    .map(|_| {
                        let k = rng.range(1, (n / 2).max(2));
                        let mut v: Vec<u32> = rng
                            .sample_indices(n, k)
                            .into_iter()
                            .map(|x| x as u32)
                            .collect();
                        v.sort_unstable();
                        v
                    })
                    .collect();
                (n, sets)
            },
            |(n, sets)| {
                let refs: Vec<&[u32]> = sets.iter().map(|v| v.as_slice()).collect();
                let s = CoactStats::from_sets(*n, refs.iter().copied());
                let r = search(&s, GreedyParams { knn: 8, ..Default::default() });
                r.layout.len() == *n && r.layout.validate().is_ok()
            },
        );
    }

    #[test]
    fn single_neuron_layer() {
        let s = stats_from(&[&[0]], 1);
        let r = search(&s, GreedyParams::default());
        assert_eq!(r.layout.order(), &[0]);
    }
}
