//! Stable-schema sweep reports: `BENCH_<name>.json` and a Markdown
//! rendering with optional baseline deltas.
//!
//! The JSON contains **only simulated, deterministic** quantities —
//! wall-clock timings (placement-search seconds) appear exclusively in
//! the Markdown footer — so re-running the same matrix produces
//! byte-identical files regardless of machine load or `--threads`.
//! Object keys serialize sorted (the writer is `BTreeMap`-backed) and
//! the scenario array preserves matrix expansion order. Schema changes
//! must bump [`SCHEMA_VERSION`].

use std::collections::BTreeMap;

use crate::bench::workloads::ExperimentResult;
use crate::cache::Admission;
use crate::util::json::{self, Json};

use super::scenario::ScenarioSpec;

/// Version stamped into every report; parsers reject newer files.
/// v2 added the per-scenario `serve` spec/metrics objects (null for
/// single-stream rows); v1 baselines still load.
pub const SCHEMA_VERSION: u64 = 2;

/// One scenario's spec plus its measured outcome.
pub struct ScenarioResult {
    /// The fully-resolved experiment point that ran.
    pub spec: ScenarioSpec,
    /// Aggregated metrics (plus placement wall-clock, Markdown-only).
    pub outcome: ExperimentResult,
}

impl ScenarioResult {
    /// Full-model mean I/O (device busy) latency per token, ms.
    pub fn io_ms(&self) -> f64 {
        self.outcome.latency_ms()
    }

    /// Full-model simulated end-to-end latency per token, ms.
    pub fn e2e_ms(&self) -> f64 {
        self.outcome.e2e_ms()
    }

    /// Full-model mean host stall per token, ms.
    pub fn stall_ms(&self) -> f64 {
        self.outcome.metrics.mean_stall_ns() * self.outcome.layer_scale / 1e6
    }

    /// Full-model transferred bytes per token, MB.
    pub fn io_mb_per_token(&self) -> f64 {
        let m = &self.outcome.metrics;
        m.totals.bytes as f64 / m.tokens.max(1) as f64 * self.outcome.layer_scale / 1e6
    }

    /// Full-model read commands per token.
    pub fn commands_per_token(&self) -> f64 {
        let m = &self.outcome.metrics;
        m.totals.commands as f64 / m.tokens.max(1) as f64 * self.outcome.layer_scale
    }
}

/// A completed sweep: every scenario result in expansion order.
pub struct SweepReport {
    /// Matrix name (becomes the `BENCH_<name>` file stem).
    pub name: String,
    /// Per-scenario results, in matrix expansion order.
    pub results: Vec<ScenarioResult>,
}

impl SweepReport {
    /// The stable-schema JSON document.
    pub fn to_json(&self) -> Json {
        let scenarios: Vec<Json> = self.results.iter().map(scenario_json).collect();
        json::obj(vec![
            ("schema_version", json::num(SCHEMA_VERSION as f64)),
            ("name", json::s(&self.name)),
            ("scenarios", json::arr(scenarios)),
        ])
    }

    /// The JSON document serialized (deterministic bytes).
    pub fn json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Human-readable Markdown: one row per scenario, a delta section
    /// when a baseline is supplied, and a wall-clock footer (the only
    /// non-deterministic content — never part of the JSON).
    pub fn to_markdown(&self, baseline: Option<&Baseline>) -> String {
        let mut out = String::new();
        out.push_str(&format!("# BENCH {}\n\n", self.name));
        out.push_str(&format!(
            "{} scenarios | schema v{SCHEMA_VERSION} | simulated metrics only \
             (deterministic; wall-clock excluded from JSON)\n\n",
            self.results.len()
        ));
        out.push_str(
            "| model | device | dataset | system | config | io ms/tok | e2e ms/tok \
             | overlap | cache hit | pf hit | IO MB/tok | eff MB/s | raw MB/s |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|---|\n");
        for r in &self.results {
            let m = &r.outcome.metrics;
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {:.2} | {:.2} | {:.0}% | {:.0}% | {:.0}% \
                 | {:.2} | {:.0} | {:.0} |\n",
                r.spec.model,
                r.spec.device,
                r.spec.dataset,
                r.spec.system.key(),
                config_label(&r.spec),
                r.io_ms(),
                r.e2e_ms(),
                m.overlap_ratio() * 100.0,
                m.cache_hit_ratio() * 100.0,
                m.prefetch_hit_ratio() * 100.0,
                r.io_mb_per_token(),
                m.effective_bandwidth() / 1e6,
                m.raw_bandwidth() / 1e6,
            ));
        }
        self.push_serving_sections(&mut out);
        self.push_fleet_sections(&mut out);
        self.push_attribution_sections(&mut out);
        self.push_throughput_section(&mut out);
        if let Some(base) = baseline {
            out.push_str(&format!("\n## vs baseline `{}`\n\n", base.name));
            out.push_str(
                "| scenario | e2e ms/tok | base e2e | d e2e | io ms/tok | base io | d io |\n",
            );
            out.push_str("|---|---|---|---|---|---|---|\n");
            let mut missing = 0usize;
            for r in &self.results {
                match base.get(&r.spec.name) {
                    Some(b) => out.push_str(&format!(
                        "| {} | {:.2} | {:.2} | {} | {:.2} | {:.2} | {} |\n",
                        r.spec.name,
                        r.e2e_ms(),
                        b.e2e_ms,
                        fmt_delta(delta_pct(r.e2e_ms(), b.e2e_ms)),
                        r.io_ms(),
                        b.io_ms,
                        fmt_delta(delta_pct(r.io_ms(), b.io_ms)),
                    )),
                    None => {
                        missing += 1;
                        out.push_str(&format!(
                            "| {} | {:.2} | - | - | {:.2} | - | - |\n",
                            r.spec.name,
                            r.e2e_ms(),
                            r.io_ms(),
                        ));
                    }
                }
            }
            if missing > 0 {
                out.push_str(&format!(
                    "\n{missing} scenario(s) had no match in the baseline (compared by \
                     scenario name).\n"
                ));
            }
        }
        let place_secs: f64 = self.results.iter().map(|r| r.outcome.placement_secs).sum();
        let decode_secs: f64 =
            self.results.iter().map(|r| r.outcome.decode_wall_secs).sum();
        // loaded fixtures carry no wall timings; don't render a
        // misleading "0.00s decode loop" for them
        let decode_note = if decode_secs > 0.0 {
            format!(", decode loops total {decode_secs:.2}s")
        } else {
            String::new()
        };
        out.push_str(&format!(
            "\nWall-clock (non-deterministic, not in JSON): placement search total \
             {place_secs:.2}s{decode_note}.\n"
        ));
        out
    }

    /// Multi-session sections: the per-scenario serving table and, when
    /// a scenario has both shared- and private-cache variants of the
    /// same (sessions, slots, arrival) point, the shared-vs-private
    /// delta table (the headline comparison of DESIGN.md §Serving).
    fn push_serving_sections(&self, out: &mut String) {
        let rows: Vec<&ScenarioResult> =
            self.results.iter().filter(|r| r.outcome.serve.is_some()).collect();
        if rows.is_empty() {
            return;
        }
        out.push_str("\n## Serving (multi-session)\n\n");
        out.push_str(
            "| scenario | sessions | slots | peak | cache | p50 ms | p95 ms | p99 ms \
             | queue ms | fairness | agg hit | cross hit | makespan ms |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|---|\n");
        for r in &rows {
            let sv = r.outcome.serve.as_ref().unwrap();
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {:.2} | {:.2} | {:.2} | {:.2} | {:.3} \
                 | {:.0}% | {:.0}% | {:.1} |\n",
                r.spec.name,
                sv.sessions,
                sv.max_concurrent,
                sv.peak_active,
                if sv.shared_cache { "shared" } else { "private" },
                sv.p50_ms,
                sv.p95_ms,
                sv.p99_ms,
                sv.mean_queue_delay_ms,
                sv.fairness,
                sv.cache_hit_ratio * 100.0,
                sv.cross_session_hit_ratio * 100.0,
                sv.makespan_ms,
            ));
        }
        // shared vs private at equal total DRAM, matched by pair id
        let pair_id = |r: &ScenarioResult| -> String {
            let point = r.spec.serve.as_ref().unwrap();
            let prefix =
                r.spec.name.strip_suffix(&point.label()).unwrap_or(&r.spec.name);
            format!("{prefix}{}", point.pair_key())
        };
        let mut deltas = String::new();
        for r in &rows {
            let sv = r.outcome.serve.as_ref().unwrap();
            // fleet rows surface a ServeSummary but have no ServePoint,
            // so they never participate in the shared/private pairing
            if !sv.shared_cache || r.spec.serve.is_none() {
                continue;
            }
            let id = pair_id(r);
            let Some(partner) = rows.iter().find(|o| {
                o.spec.serve.is_some()
                    && !o.outcome.serve.as_ref().unwrap().shared_cache
                    && pair_id(o) == id
            }) else {
                continue;
            };
            let pv = partner.outcome.serve.as_ref().unwrap();
            deltas.push_str(&format!(
                "| {} | {:.1}% | {:.1}% | {:+.1}pp | {:.2} | {:.2} | {} |\n",
                r.spec.serve.as_ref().unwrap().pair_key(),
                sv.cache_hit_ratio * 100.0,
                pv.cache_hit_ratio * 100.0,
                (sv.cache_hit_ratio - pv.cache_hit_ratio) * 100.0,
                sv.mean_ms,
                pv.mean_ms,
                fmt_delta(delta_pct(sv.mean_ms, pv.mean_ms)),
            ));
        }
        if !deltas.is_empty() {
            out.push_str("\n### Shared vs private cache (equal total DRAM)\n\n");
            out.push_str(
                "| point | shared hit | private hit | d hit | shared e2e ms \
                 | private e2e ms | d e2e |\n",
            );
            out.push_str("|---|---|---|---|---|---|---|\n");
            out.push_str(&deltas);
        }
        // per-session speculative-prefetch attribution, arbitrated rows only
        let mut attrib = String::new();
        for r in &rows {
            let sv = r.outcome.serve.as_ref().unwrap();
            for p in &sv.session_prefetch {
                attrib.push_str(&format!(
                    "| {} | {} | {} | {} | {:.0}% | {:.2} | {:.2} |\n",
                    r.spec.name,
                    p.id,
                    p.prefetch_hit_bundles,
                    p.prefetch_wasted_bundles,
                    p.overlap_ratio * 100.0,
                    p.mean_service_ms,
                    p.mean_round_queue_ms,
                ));
            }
        }
        if !attrib.is_empty() {
            out.push_str("\n### Speculative prefetch attribution (per session)\n\n");
            out.push_str(
                "| scenario | session | pf hit | pf wasted | overlap | service ms \
                 | round queue ms |\n",
            );
            out.push_str("|---|---|---|---|---|---|---|\n");
            out.push_str(&attrib);
        }
    }

    /// Fleet sections (DESIGN.md §Fleet): the per-scenario open-loop
    /// table and, for groups of rows that differ only in arrival
    /// shape/rate (same [`FleetPoint::ramp_key`]), a load-ramp table
    /// showing how goodput and tail latency degrade with offered load.
    fn push_fleet_sections(&self, out: &mut String) {
        let rows: Vec<&ScenarioResult> = self
            .results
            .iter()
            .filter(|r| r.outcome.fleet.is_some() && r.spec.fleet.is_some())
            .collect();
        if rows.is_empty() {
            return;
        }
        let slo_cell = |fs: &crate::metrics::FleetSummary| -> String {
            if fs.slo_ms > 0.0 {
                format!("{:.1}%", fs.slo_violation_rate * 100.0)
            } else {
                "-".to_string()
            }
        };
        out.push_str("\n## Fleet (open-loop, event-driven)\n\n");
        out.push_str(
            "| scenario | arrival | sched | offered | admitted | rejected | done \
             | goodput tok/s | p99 ms | p99.9 ms | SLO viol | reject |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|\n");
        for &r in &rows {
            let fl = r.spec.fleet.as_ref().unwrap();
            let fs = r.outcome.fleet.as_ref().unwrap();
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {:.0} | {:.2} | {:.2} | {} \
                 | {:.1}% |\n",
                r.spec.name,
                fl.arrival.label(),
                fl.scheduler.key(),
                fs.offered_sessions,
                fs.admitted_sessions,
                fs.rejected_sessions,
                fs.completed_sessions,
                fs.goodput_tokens_per_s,
                fs.p99_ms,
                fs.p999_ms,
                slo_cell(fs),
                fs.rejection_rate * 100.0,
            ));
        }
        // load ramps: rows sharing everything but the arrival fragment,
        // grouped in expansion order
        let mut groups: Vec<(String, Vec<&ScenarioResult>)> = Vec::new();
        for &r in &rows {
            let key = r.spec.fleet.as_ref().unwrap().ramp_key();
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, members)) => members.push(r),
                None => groups.push((key, vec![r])),
            }
        }
        for (key, members) in groups.iter().filter(|(_, m)| m.len() > 1) {
            out.push_str(&format!("\n### Load ramp `{key}`\n\n"));
            out.push_str(
                "| arrival | goodput tok/s | p99 ms | p99.9 ms | SLO viol | reject |\n",
            );
            out.push_str("|---|---|---|---|---|---|\n");
            for &r in members {
                let fl = r.spec.fleet.as_ref().unwrap();
                let fs = r.outcome.fleet.as_ref().unwrap();
                out.push_str(&format!(
                    "| {} | {:.0} | {:.2} | {:.2} | {} | {:.1}% |\n",
                    fl.arrival.label(),
                    fs.goodput_tokens_per_s,
                    fs.p99_ms,
                    fs.p999_ms,
                    slo_cell(fs),
                    fs.rejection_rate * 100.0,
                ));
            }
        }
    }

    /// Flight-recorder attribution sections (DESIGN.md §Observability):
    /// per-scenario closure summary, the per-phase time split, and the
    /// retained slowest-token chains. Rendered only when at least one
    /// scenario ran with tracing enabled.
    fn push_attribution_sections(&self, out: &mut String) {
        let rows: Vec<&ScenarioResult> = self
            .results
            .iter()
            .filter(|r| r.outcome.attribution.is_some())
            .collect();
        if rows.is_empty() {
            return;
        }
        out.push_str("\n## Attribution (flight recorder)\n\n");
        out.push_str(
            "| scenario | tokens | accounted ms | latency ms | closure err ms \
             | exact | spans | dropped |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|---|\n");
        for r in &rows {
            let at = r.outcome.attribution.as_ref().unwrap();
            out.push_str(&format!(
                "| {} | {} | {:.3} | {:.3} | {:.6} | {}/{} | {} | {} |\n",
                r.spec.name,
                at.tokens,
                at.accounted_ms,
                at.latency_ms,
                at.closure_error_ms,
                at.exact_closures,
                at.tokens,
                at.spans_recorded,
                at.spans_dropped + at.marks_dropped,
            ));
        }
        out.push_str("\n### Time in phase\n\n");
        out.push_str("| scenario | phase | count | total ms | mean ms | max ms |\n");
        out.push_str("|---|---|---|---|---|---|\n");
        for r in &rows {
            let at = r.outcome.attribution.as_ref().unwrap();
            for p in at.phases.iter().filter(|p| p.count > 0) {
                out.push_str(&format!(
                    "| {} | {} | {} | {:.3} | {:.4} | {:.4} |\n",
                    r.spec.name, p.phase, p.count, p.total_ms, p.mean_ms, p.max_ms,
                ));
            }
        }
        let mut tail = String::new();
        for r in &rows {
            let at = r.outcome.attribution.as_ref().unwrap();
            for t in &at.tail {
                tail.push_str(&format!(
                    "| {} | {} | {:.2} | {:.3} | {:.3} | {:.3} | {:.3} |\n",
                    r.spec.name,
                    t.sid,
                    t.start_ms,
                    t.queue_ms,
                    t.stall_ms,
                    t.compute_ms,
                    t.latency_ms,
                ));
            }
        }
        if !tail.is_empty() {
            out.push_str("\n### Slowest tokens (tail samples)\n\n");
            out.push_str(
                "| scenario | session | start ms | queue ms | stall ms \
                 | compute ms | latency ms |\n",
            );
            out.push_str("|---|---|---|---|---|---|---|\n");
            out.push_str(&tail);
        }
    }

    /// Decode-throughput table (§Perf): simulated tokens per wall-clock
    /// second of the decode loop. Wall time is machine-dependent, so
    /// this section exists ONLY in the Markdown — the JSON stays a pure
    /// function of the spec and byte-diffs clean across machines.
    fn push_throughput_section(&self, out: &mut String) {
        let rows: Vec<&ScenarioResult> = self
            .results
            .iter()
            .filter(|r| r.outcome.decode_wall_secs > 0.0)
            .collect();
        if rows.is_empty() {
            return;
        }
        out.push_str("\n## Decode throughput (wall-clock, Markdown-only)\n\n");
        out.push_str("| scenario | tokens | decode wall s | simulated tok/s |\n");
        out.push_str("|---|---|---|---|\n");
        for r in rows {
            out.push_str(&format!(
                "| {} | {} | {:.3} | {:.0} |\n",
                r.spec.name,
                r.outcome.metrics.tokens,
                r.outcome.decode_wall_secs,
                r.outcome.decode_tokens_per_sec(),
            ));
        }
    }
}

/// Compact per-row description of the non-axis knobs.
fn config_label(spec: &ScenarioSpec) -> String {
    let mut parts = vec![format!("c{:.2}", spec.cache_ratio), spec.prefetch.label()];
    if let Some(p) = &spec.cache_policy {
        parts.push(format!("pol={p}"));
    }
    if let Some(ways) = spec.cache_ways {
        parts.push(format!("ways={ways}"));
    }
    if let Some(c) = spec.collapse {
        parts.push(format!("collapse={}", if c { "on" } else { "off" }));
    }
    if let Some(t) = spec.fixed_threshold {
        parts.push(format!("thr={t}"));
    }
    if spec.admission.is_some() {
        parts.push(format!("adm={}", admission_label(spec.admission)));
    }
    if spec.knn != 64 {
        parts.push(format!("knn={}", spec.knn));
    }
    if spec.calib_tokens != 256 {
        parts.push(format!("calib={}", spec.calib_tokens));
    }
    if let Some(sv) = &spec.serve {
        parts.push(sv.label());
    }
    if let Some(fl) = &spec.fleet {
        parts.push(fl.label());
    }
    parts.join(" ")
}

/// Stable string form of the admission override for spec serialization.
fn admission_label(a: Option<Admission>) -> String {
    match a {
        None => "default".to_string(),
        Some(Admission::All) => "all".to_string(),
        Some(Admission::Linking { segment_min, segment_p }) => {
            format!("linking(min={segment_min},p={segment_p})")
        }
    }
}

/// Serve-point spec object (`null` for single-stream scenarios).
/// Arbiter knobs serialize only when explicitly set, so prefetch-off
/// serve reports stay byte-identical to pre-arbiter baselines.
fn serve_spec_json(spec: &ScenarioSpec) -> Json {
    use crate::coordinator::ArbiterPolicy;
    match &spec.serve {
        None => Json::Null,
        Some(sv) => {
            let mut fields = vec![
                ("sessions", json::num(sv.sessions as f64)),
                ("max_concurrent", json::num(sv.max_concurrent as f64)),
                ("arrival_spacing_ms", json::num(sv.arrival_spacing_ms)),
                ("shared_cache", Json::Bool(sv.shared_cache)),
            ];
            match sv.arbiter {
                None => {}
                Some(ArbiterPolicy::FairShare) => {
                    fields.push(("arbiter", json::s("fair")));
                }
                Some(ArbiterPolicy::DeadlineAware { target_ns }) => {
                    fields.push(("arbiter", json::s("deadline")));
                    fields.push(("arbiter_deadline_target_ms", json::num(target_ns / 1e6)));
                }
            }
            if let Some(b) = sv.prefetch_global_budget {
                fields.push(("prefetch_global_budget_bytes", json::num(b as f64)));
            }
            json::obj(fields)
        }
    }
}

/// Serve outcome object (`null` for single-stream scenarios).
/// Per-session speculative-prefetch attribution serializes only for
/// prefetch-enabled serve rows (`session_prefetch` non-empty), keeping
/// synchronous-timeline rows byte-identical to pre-arbiter baselines.
fn serve_metrics_json(r: &ScenarioResult) -> Json {
    match &r.outcome.serve {
        None => Json::Null,
        Some(sv) => {
            let mut fields = vec![
                ("sessions", json::num(sv.sessions as f64)),
                ("peak_active", json::num(sv.peak_active as f64)),
                ("tokens", json::num(sv.tokens as f64)),
                ("p50_ms", json::num(sv.p50_ms)),
                ("p95_ms", json::num(sv.p95_ms)),
                ("p99_ms", json::num(sv.p99_ms)),
                ("mean_ms", json::num(sv.mean_ms)),
                ("mean_queue_delay_ms", json::num(sv.mean_queue_delay_ms)),
                ("fairness", json::num(sv.fairness)),
                ("cache_hit_ratio", json::num(sv.cache_hit_ratio)),
                ("cross_session_hit_ratio", json::num(sv.cross_session_hit_ratio)),
                ("makespan_ms", json::num(sv.makespan_ms)),
            ];
            // p99.9 serializes only on fleet rows and prefetch-attributed
            // serve rows: the extreme tail is the point of both sweeps,
            // and gating it keeps prefetch-off serve reports
            // byte-identical to pre-fleet baselines
            if r.outcome.fleet.is_some() || !sv.session_prefetch.is_empty() {
                fields.push(("p999_ms", json::num(sv.p999_ms)));
            }
            if !sv.session_prefetch.is_empty() {
                fields.push((
                    "prefetch_hit_bundles",
                    json::num(sv.prefetch_hit_bundles as f64),
                ));
                fields.push((
                    "prefetch_wasted_bundles",
                    json::num(sv.prefetch_wasted_bundles as f64),
                ));
                let per_session: Vec<Json> = sv
                    .session_prefetch
                    .iter()
                    .map(|p| {
                        json::obj(vec![
                            ("id", json::num(p.id as f64)),
                            (
                                "prefetch_hit_bundles",
                                json::num(p.prefetch_hit_bundles as f64),
                            ),
                            (
                                "prefetch_wasted_bundles",
                                json::num(p.prefetch_wasted_bundles as f64),
                            ),
                            ("prefetch_hit_bytes", json::num(p.prefetch_hit_bytes as f64)),
                            (
                                "prefetch_wasted_bytes",
                                json::num(p.prefetch_wasted_bytes as f64),
                            ),
                            ("overlap_ratio", json::num(p.overlap_ratio)),
                            ("mean_service_ms", json::num(p.mean_service_ms)),
                            ("mean_round_queue_ms", json::num(p.mean_round_queue_ms)),
                        ])
                    })
                    .collect();
                fields.push(("session_prefetch", json::arr(per_session)));
            }
            json::obj(fields)
        }
    }
}

/// Fleet-point spec echo. Unlike `serve`, the key itself is gated —
/// it exists only on fleet rows — so this never serializes `null` and
/// historical reports stay byte-identical.
fn fleet_spec_json(spec: &ScenarioSpec) -> Json {
    let fl = spec.fleet.as_ref().expect("fleet_spec_json requires a fleet row");
    let mut fields = vec![
        ("sessions", json::num(fl.sessions as f64)),
        ("max_concurrent", json::num(fl.max_concurrent as f64)),
        ("arrival", json::s(&fl.arrival.label())),
        ("scheduler", json::s(fl.scheduler.key())),
    ];
    if let Some(b) = fl.admission_bound {
        fields.push(("admission_bound", json::num(b as f64)));
    }
    if let Some(ms) = fl.slo_ms {
        fields.push(("slo_ms", json::num(ms)));
    }
    json::obj(fields)
}

/// Fleet outcome object (gated key, fleet rows only). SLO keys
/// serialize only when an SLO was configured, so no-SLO sweeps carry
/// no always-zero fields.
fn fleet_metrics_json(r: &ScenarioResult) -> Json {
    let fs = r.outcome.fleet.as_ref().expect("fleet_metrics_json requires a fleet row");
    let mut fields = vec![
        ("offered_sessions", json::num(fs.offered_sessions as f64)),
        ("admitted_sessions", json::num(fs.admitted_sessions as f64)),
        ("rejected_sessions", json::num(fs.rejected_sessions as f64)),
        ("completed_sessions", json::num(fs.completed_sessions as f64)),
        ("offered_tokens", json::num(fs.offered_tokens as f64)),
        ("completed_tokens", json::num(fs.completed_tokens as f64)),
        ("rejected_tokens", json::num(fs.rejected_tokens as f64)),
        ("rejection_rate", json::num(fs.rejection_rate)),
        ("goodput_tokens_per_s", json::num(fs.goodput_tokens_per_s)),
        ("p99_ms", json::num(fs.p99_ms)),
        ("p999_ms", json::num(fs.p999_ms)),
        ("arrival_events", json::num(fs.arrival_events as f64)),
        ("token_events", json::num(fs.token_events as f64)),
        ("ticket_events", json::num(fs.ticket_events as f64)),
    ];
    if fs.slo_ms > 0.0 {
        fields.push(("slo_ms", json::num(fs.slo_ms)));
        fields.push(("slo_violations", json::num(fs.slo_violations as f64)));
        fields.push(("slo_violation_rate", json::num(fs.slo_violation_rate)));
    }
    json::obj(fields)
}

/// Flight-recorder attribution object (gated key, traced rows only).
/// Everything here is simulated virtual time scaled to full-model ms,
/// so traced reports stay byte-deterministic like every other key.
fn attribution_json(at: &crate::obs::AttributionSummary) -> Json {
    let phases: Vec<Json> = at
        .phases
        .iter()
        .map(|p| {
            json::obj(vec![
                ("phase", json::s(&p.phase)),
                ("count", json::num(p.count as f64)),
                ("total_ms", json::num(p.total_ms)),
                ("mean_ms", json::num(p.mean_ms)),
                ("max_ms", json::num(p.max_ms)),
            ])
        })
        .collect();
    let tail: Vec<Json> = at
        .tail
        .iter()
        .map(|t| {
            json::obj(vec![
                ("sid", json::num(t.sid as f64)),
                ("start_ms", json::num(t.start_ms)),
                ("queue_ms", json::num(t.queue_ms)),
                ("stall_ms", json::num(t.stall_ms)),
                ("compute_ms", json::num(t.compute_ms)),
                ("latency_ms", json::num(t.latency_ms)),
            ])
        })
        .collect();
    json::obj(vec![
        ("tokens", json::num(at.tokens as f64)),
        ("accounted_ms", json::num(at.accounted_ms)),
        ("latency_ms", json::num(at.latency_ms)),
        ("closure_error_ms", json::num(at.closure_error_ms)),
        ("exact_closures", json::num(at.exact_closures as f64)),
        ("spans_recorded", json::num(at.spans_recorded as f64)),
        ("spans_dropped", json::num(at.spans_dropped as f64)),
        ("marks_dropped", json::num(at.marks_dropped as f64)),
        ("phases", json::arr(phases)),
        ("tail", json::arr(tail)),
    ])
}

fn scenario_json(r: &ScenarioResult) -> Json {
    let spec = &r.spec;
    let m = &r.outcome.metrics;
    let mut fields = vec![
        ("name", json::s(&spec.name)),
        ("model", json::s(&spec.model)),
        ("device", json::s(&spec.device)),
        ("dataset", json::s(&spec.dataset)),
        ("system", json::s(spec.system.key())),
        (
            "cache_policy",
            match &spec.cache_policy {
                Some(p) => json::s(p),
                None => Json::Null,
            },
        ),
        (
            "collapse",
            match spec.collapse {
                Some(b) => Json::Bool(b),
                None => Json::Null,
            },
        ),
        ("cache_ratio", json::num(spec.cache_ratio)),
        ("precision", json::s(spec.precision.name())),
        ("prefetch", Json::Bool(spec.prefetch.enabled)),
        ("prefetch_budget_bytes", json::num(spec.prefetch.budget_bytes as f64)),
        ("prefetch_lookahead", json::num(spec.prefetch.lookahead as f64)),
        ("calib_tokens", json::num(spec.calib_tokens as f64)),
        ("eval_tokens", json::num(spec.eval_tokens as f64)),
        ("sim_layers", json::num(spec.sim_layers as f64)),
        ("knn", json::num(spec.knn as f64)),
        ("seed", json::s(&spec.seed.to_string())),
        (
            "fixed_threshold",
            match spec.fixed_threshold {
                Some(t) => json::num(t as f64),
                None => Json::Null,
            },
        ),
        ("admission", json::s(&admission_label(spec.admission))),
        ("serve", serve_spec_json(spec)),
        ("serve_metrics", serve_metrics_json(r)),
    ];
    // the cache_ways key exists only on rows that override the
    // associativity (cachelab), so every pre-cachelab document is
    // byte-identical under SCHEMA_VERSION 2
    if let Some(ways) = spec.cache_ways {
        fields.push(("cache_ways", json::num(ways as f64)));
    }
    // fleet keys exist only on fleet rows (SCHEMA_VERSION stays 2:
    // non-fleet documents are byte-identical to pre-fleet builds)
    if spec.fleet.is_some() {
        fields.push(("fleet", fleet_spec_json(spec)));
        fields.push(("fleet_metrics", fleet_metrics_json(r)));
    }
    // the attribution key exists only on traced rows, so untraced
    // reports stay byte-identical to pre-tracing builds
    if let Some(at) = &r.outcome.attribution {
        fields.push(("attribution", attribution_json(at)));
    }
    fields.push((
        "metrics",
        json::obj(vec![
            ("tokens", json::num(m.tokens as f64)),
            ("io_ms_per_token", json::num(r.io_ms())),
            ("e2e_ms_per_token", json::num(r.e2e_ms())),
            ("stall_ms_per_token", json::num(r.stall_ms())),
            ("overlap_ratio", json::num(m.overlap_ratio())),
            ("cache_hit_ratio", json::num(m.cache_hit_ratio())),
            ("prefetch_hit_ratio", json::num(m.prefetch_hit_ratio())),
            ("prefetch_hit_bundles", json::num(m.totals.prefetch_hit_bundles as f64)),
            (
                "prefetch_wasted_bundles",
                json::num(m.totals.prefetch_wasted_bundles as f64),
            ),
            ("commands_per_token", json::num(r.commands_per_token())),
            ("io_mb_per_token", json::num(r.io_mb_per_token())),
            ("mean_access_len", json::num(m.mean_access_len())),
            ("iops", json::num(m.iops())),
            ("effective_bandwidth_mbps", json::num(m.effective_bandwidth() / 1e6)),
            ("raw_bandwidth_mbps", json::num(m.raw_bandwidth() / 1e6)),
            ("bundle_bytes", json::num(r.outcome.bundle_bytes as f64)),
            ("layer_scale", json::num(r.outcome.layer_scale)),
        ]),
    ));
    json::obj(fields)
}

/// Per-scenario metrics loaded back from a prior `BENCH_*.json` —
/// only the fields the delta section compares, so older or trimmed
/// baselines stay loadable.
#[derive(Clone, Copy, Debug)]
pub struct BaselineMetrics {
    /// `io_ms_per_token` of the prior run.
    pub io_ms: f64,
    /// `e2e_ms_per_token` of the prior run.
    pub e2e_ms: f64,
}

/// A prior sweep's JSON, indexed by scenario name for delta reporting.
pub struct Baseline {
    /// The prior sweep's matrix name.
    pub name: String,
    by_name: BTreeMap<String, BaselineMetrics>,
}

impl Baseline {
    /// Parse a `BENCH_*.json` document produced by [`SweepReport`].
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text)?;
        let version = j.req_usize("schema_version")?;
        anyhow::ensure!(
            version as u64 <= SCHEMA_VERSION,
            "baseline schema v{version} is newer than supported v{SCHEMA_VERSION}"
        );
        let name = j.req_str("name")?.to_string();
        let scenarios = j
            .req("scenarios")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("`scenarios` is not an array"))?;
        let mut by_name = BTreeMap::new();
        for sc in scenarios {
            let n = sc.req_str("name")?.to_string();
            let m = sc.req("metrics")?;
            by_name.insert(
                n,
                BaselineMetrics {
                    io_ms: m.req_f64("io_ms_per_token")?,
                    e2e_ms: m.req_f64("e2e_ms_per_token")?,
                },
            );
        }
        Ok(Self { name, by_name })
    }

    /// Look up a prior scenario by name.
    pub fn get(&self, scenario: &str) -> Option<&BaselineMetrics> {
        self.by_name.get(scenario)
    }

    /// Number of scenarios in the baseline.
    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    /// True when the baseline holds no scenarios.
    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }
}

/// Relative change in percent, `(new - old) / old * 100`; `None` when
/// the baseline value is (numerically) zero.
pub fn delta_pct(new: f64, old: f64) -> Option<f64> {
    if old.abs() < 1e-12 {
        None
    } else {
        Some((new - old) / old * 100.0)
    }
}

/// Render a delta as `+x.x%` / `-x.x%`, or `-` when undefined.
pub fn fmt_delta(d: Option<f64>) -> String {
    match d {
        None => "-".to_string(),
        Some(d) => format!("{d:+.1}%"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::System;
    use crate::metrics::{RunMetrics, TokenIo};

    fn fake_result(name: &str, elapsed_ns: f64) -> ScenarioResult {
        let mut m = RunMetrics::new();
        let t = TokenIo {
            demanded_bundles: 10,
            read_bundles: 8,
            cached_bundles: 2,
            commands: 4,
            bytes: 8 * 100,
            elapsed_ns,
            stall_ns: elapsed_ns,
            ..Default::default()
        };
        m.record(&t, 100);
        m.record_compute(5e5);
        ScenarioResult {
            spec: ScenarioSpec::new(name, "OPT-350M", System::Ripple),
            outcome: ExperimentResult {
                system: System::Ripple,
                metrics: m,
                placement_secs: 0.0,
                decode_wall_secs: 0.0,
                layer_scale: 2.0,
                bundle_bytes: 100,
                serve: None,
                fleet: None,
                attribution: None,
            },
        }
    }

    fn fake_serve_result(name: &str, shared: bool, hit: f64, mean_ms: f64) -> ScenarioResult {
        use crate::harness::scenario::ServePoint;
        use crate::metrics::ServeSummary;
        let point = ServePoint { shared_cache: shared, ..ServePoint::shared(4) };
        let mut r = fake_result(name, 1e6);
        r.spec.name = format!("{name}/{}", point.label());
        r.spec.serve = Some(point);
        r.outcome.serve = Some(ServeSummary {
            sessions: 4,
            max_concurrent: 4,
            peak_active: 4,
            shared_cache: shared,
            tokens: 64,
            p50_ms: mean_ms,
            p95_ms: mean_ms * 2.0,
            p99_ms: mean_ms * 3.0,
            mean_ms,
            mean_queue_delay_ms: 0.5,
            fairness: 0.9,
            cache_hit_ratio: hit,
            cross_session_hit_ratio: if shared { 0.3 } else { 0.0 },
            makespan_ms: 100.0,
            ..Default::default()
        });
        r
    }

    fn fake_fleet_result(name: &str, per_s: f64, slo: Option<f64>) -> ScenarioResult {
        use crate::harness::scenario::FleetPoint;
        use crate::metrics::{FleetSummary, ServeSummary};
        let mut point = FleetPoint::poisson(8, per_s);
        if let Some(ms) = slo {
            point = point.with_slo_ms(ms);
        }
        let mut r = fake_result(name, 1e6);
        r.spec.name = format!("{name}/{}", point.label());
        r.spec.fleet = Some(point);
        r.outcome.serve = Some(ServeSummary {
            sessions: 8,
            max_concurrent: 4,
            peak_active: 4,
            shared_cache: true,
            tokens: 96,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
            p999_ms: 3.5,
            mean_ms: 1.2,
            makespan_ms: 50.0,
            ..Default::default()
        });
        r.outcome.fleet = Some(FleetSummary {
            offered_sessions: 8,
            admitted_sessions: 8,
            completed_sessions: 8,
            offered_tokens: 96,
            completed_tokens: 96,
            goodput_tokens_per_s: 1900.0 + per_s,
            slo_ms: slo.unwrap_or(0.0),
            slo_violations: if slo.is_some() { 4 } else { 0 },
            slo_violation_rate: if slo.is_some() { 4.0 / 96.0 } else { 0.0 },
            p99_ms: 3.0,
            p999_ms: 3.5,
            arrival_events: 8,
            token_events: 96,
            ticket_events: 12,
            ..Default::default()
        });
        r
    }

    #[test]
    fn delta_math() {
        assert_eq!(delta_pct(110.0, 100.0), Some(10.0));
        assert_eq!(delta_pct(90.0, 100.0), Some(-10.0));
        assert_eq!(delta_pct(5.0, 0.0), None);
        assert!((delta_pct(1.0, 3.0).unwrap() - (-66.666_666_666_666_66)).abs() < 1e-9);
        assert_eq!(fmt_delta(Some(10.0)), "+10.0%");
        assert_eq!(fmt_delta(Some(-0.04)), "-0.0%");
        assert_eq!(fmt_delta(None), "-");
    }

    #[test]
    fn json_roundtrips_through_baseline() {
        let report = SweepReport {
            name: "t".to_string(),
            results: vec![fake_result("a", 1e6), fake_result("b", 2e6)],
        };
        let text = report.json_string();
        assert!(text.contains("\"schema_version\":2"));
        // single-stream rows carry null serve objects (stable schema)
        assert!(text.contains("\"serve\":null"));
        assert!(text.contains("\"serve_metrics\":null"));
        let base = Baseline::parse(&text).unwrap();
        assert_eq!(base.name, "t");
        assert_eq!(base.len(), 2);
        let a = base.get("a").unwrap();
        assert!((a.io_ms - report.results[0].io_ms()).abs() < 1e-9);
        assert!((a.e2e_ms - report.results[0].e2e_ms()).abs() < 1e-9);
        assert!(base.get("missing").is_none());
    }

    #[test]
    fn baseline_rejects_newer_schema() {
        let text = r#"{"schema_version": 99, "name": "x", "scenarios": []}"#;
        assert!(Baseline::parse(text).is_err());
        assert!(Baseline::parse("{").is_err());
    }

    #[test]
    fn markdown_has_rows_and_deltas() {
        let report = SweepReport {
            name: "t".to_string(),
            results: vec![fake_result("a", 1e6)],
        };
        let plain = report.to_markdown(None);
        assert!(plain.contains("# BENCH t"));
        assert!(plain.contains("| OPT-350M |"));
        assert!(!plain.contains("baseline"));

        // identical baseline -> +0.0% deltas
        let base = Baseline::parse(&report.json_string()).unwrap();
        let md = report.to_markdown(Some(&base));
        assert!(md.contains("vs baseline"));
        assert!(md.contains("+0.0%"));

        // a baseline missing the scenario is flagged
        let other = Baseline::parse(
            r#"{"schema_version": 1, "name": "old", "scenarios": []}"#,
        )
        .unwrap();
        let md = report.to_markdown(Some(&other));
        assert!(md.contains("had no match"));
    }

    #[test]
    fn throughput_section_is_markdown_only() {
        let mut r = fake_result("a", 1e6);
        r.outcome.decode_wall_secs = 0.5;
        let report = SweepReport { name: "t".to_string(), results: vec![r] };
        // wall-clock never reaches the JSON ...
        let json = report.json_string();
        assert!(!json.contains("decode_wall"));
        assert!(!json.contains("tok/s"));
        // ... but the Markdown reports simulated tokens per wall second
        let md = report.to_markdown(None);
        assert!(md.contains("## Decode throughput (wall-clock, Markdown-only)"), "{md}");
        assert!(md.contains("| a | 1 | 0.500 | 2 |"), "{md}");

        // without wall timings (loaded fixtures) the section is absent
        let bare = SweepReport { name: "t".to_string(), results: vec![fake_result("a", 1e6)] };
        assert!(!bare.to_markdown(None).contains("Decode throughput"));
    }

    #[test]
    fn json_is_deterministic_for_equal_inputs() {
        let a = SweepReport { name: "t".into(), results: vec![fake_result("a", 1e6)] };
        let b = SweepReport { name: "t".into(), results: vec![fake_result("a", 1e6)] };
        assert_eq!(a.json_string(), b.json_string());
    }

    #[test]
    fn serve_rows_serialize_and_render_the_delta_table() {
        let report = SweepReport {
            name: "serve".to_string(),
            results: vec![
                fake_serve_result("a", true, 0.6, 2.0),
                fake_serve_result("a", false, 0.4, 2.5),
            ],
        };
        let text = report.json_string();
        assert!(text.contains("\"serve_metrics\":{"));
        assert!(text.contains("\"cross_session_hit_ratio\""));
        assert!(text.contains("\"p99_ms\""));
        assert!(text.contains("\"shared_cache\":true"));
        // default points carry no arbiter knobs and no attribution —
        // the serialized row matches pre-arbiter baselines byte-for-byte
        assert!(!text.contains("\"arbiter\""));
        assert!(!text.contains("\"prefetch_global_budget_bytes\""));
        assert!(!text.contains("\"session_prefetch\""));
        // old baselines (io/e2e only) still parse the new schema
        let base = Baseline::parse(&text).unwrap();
        assert_eq!(base.len(), 2);

        let md = report.to_markdown(None);
        assert!(md.contains("## Serving (multi-session)"), "{md}");
        assert!(md.contains("### Shared vs private cache"), "{md}");
        // shared row wins by 20pp in this fixture
        assert!(md.contains("+20.0pp"), "{md}");
        assert!(md.contains("| shared |"));
        assert!(md.contains("| private |"));
    }

    #[test]
    fn arbitrated_serve_rows_serialize_attribution_and_knobs() {
        use crate::coordinator::ArbiterPolicy;
        use crate::metrics::SessionPrefetchSummary;
        let mut r = fake_serve_result("pf", true, 0.6, 2.0);
        let point = r
            .spec
            .serve
            .take()
            .unwrap()
            .with_arbiter(ArbiterPolicy::DeadlineAware { target_ns: 2e6 })
            .with_global_budget(128 * 1024);
        r.spec.serve = Some(point);
        let sv = r.outcome.serve.as_mut().unwrap();
        sv.prefetch_hit_bundles = 7;
        sv.prefetch_wasted_bundles = 3;
        sv.session_prefetch = vec![
            SessionPrefetchSummary {
                id: 0,
                prefetch_hit_bundles: 4,
                prefetch_wasted_bundles: 1,
                prefetch_hit_bytes: 400,
                prefetch_wasted_bytes: 100,
                overlap_ratio: 0.5,
                mean_service_ms: 1.5,
                mean_round_queue_ms: 0.5,
            },
            SessionPrefetchSummary {
                id: 1,
                prefetch_hit_bundles: 3,
                prefetch_wasted_bundles: 2,
                prefetch_hit_bytes: 300,
                prefetch_wasted_bytes: 200,
                overlap_ratio: 0.25,
                mean_service_ms: 1.75,
                mean_round_queue_ms: 0.25,
            },
        ];
        let report = SweepReport { name: "pf".to_string(), results: vec![r] };
        let text = report.json_string();
        assert!(text.contains("\"arbiter\":\"deadline\""), "{text}");
        assert!(text.contains("\"arbiter_deadline_target_ms\":2"), "{text}");
        assert!(text.contains("\"prefetch_global_budget_bytes\":131072"), "{text}");
        assert!(text.contains("\"session_prefetch\":["), "{text}");
        assert!(text.contains("\"mean_service_ms\""), "{text}");
        assert!(text.contains("\"mean_round_queue_ms\""), "{text}");
        // prefetch-attributed serve rows surface the extreme tail too
        assert!(text.contains("\"p999_ms\""), "{text}");
        // old baselines still parse the extended schema
        let base = Baseline::parse(&text).unwrap();
        assert_eq!(base.len(), 1);

        let md = report.to_markdown(None);
        assert!(md.contains("### Speculative prefetch attribution (per session)"), "{md}");
        assert!(md.contains("| 0 | 4 | 1 | 50% |"), "{md}");
        // serialization is still a pure function of the inputs
        assert_eq!(text, report.json_string());
    }

    #[test]
    fn fleet_rows_serialize_gated_keys_and_ramp_table() {
        let report = SweepReport {
            name: "fleet".to_string(),
            results: vec![
                fake_fleet_result("a", 100.0, Some(40.0)),
                fake_fleet_result("a", 200.0, Some(40.0)),
            ],
        };
        let text = report.json_string();
        assert!(text.contains("\"fleet\":{"), "{text}");
        assert!(text.contains("\"fleet_metrics\":{"), "{text}");
        assert!(text.contains("\"goodput_tokens_per_s\""), "{text}");
        assert!(text.contains("\"p999_ms\""), "{text}");
        assert!(text.contains("\"slo_violation_rate\""), "{text}");
        assert!(text.contains("\"scheduler\":\"fifo\""), "{text}");
        assert!(text.contains("\"arrival\":\"po100\""), "{text}");
        // old baselines (io/e2e only) still parse the extended schema
        let base = Baseline::parse(&text).unwrap();
        assert_eq!(base.len(), 2);
        // serialization stays a pure function of the inputs
        assert_eq!(text, report.json_string());

        let md = report.to_markdown(None);
        assert!(md.contains("## Fleet (open-loop, event-driven)"), "{md}");
        // the two rows differ only by arrival rate -> one load ramp
        assert!(md.contains("### Load ramp `f8c4-fifo-slo40ms`"), "{md}");
        assert!(md.contains("| po100 |"), "{md}");
        assert!(md.contains("| po200 |"), "{md}");
    }

    #[test]
    fn non_fleet_rows_never_grow_fleet_keys() {
        // the schema gate keeps historical BENCH json byte-stable:
        // serve + single-stream rows carry neither fleet keys nor p999
        let report = SweepReport {
            name: "serve".to_string(),
            results: vec![fake_result("a", 1e6), fake_serve_result("b", true, 0.6, 2.0)],
        };
        let text = report.json_string();
        assert!(!text.contains("\"fleet\""), "{text}");
        assert!(!text.contains("\"fleet_metrics\""), "{text}");
        assert!(!text.contains("\"p999_ms\""), "{text}");
        assert!(!text.contains("\"attribution\""), "{text}");
        assert!(!text.contains("\"cache_ways\""), "{text}");
        let md = report.to_markdown(None);
        assert!(!md.contains("## Fleet"), "{md}");
        assert!(!md.contains("Load ramp"), "{md}");

        // a fleet row without an SLO omits the SLO keys too
        let no_slo = SweepReport {
            name: "fleet".to_string(),
            results: vec![fake_fleet_result("a", 100.0, None)],
        };
        let text = no_slo.json_string();
        assert!(text.contains("\"fleet_metrics\""), "{text}");
        assert!(!text.contains("\"slo_violation_rate\""), "{text}");
        assert!(!text.contains("\"slo_ms\""), "{text}");
        // single ramp member -> no ramp table
        assert!(!no_slo.to_markdown(None).contains("Load ramp"));
    }

    #[test]
    fn cache_ways_serializes_only_when_overridden() {
        // schema-v2 gating: the key appears exactly on cachelab rows
        // that pin an associativity, and lands in the config label too
        let mut r = fake_result("ways", 1e6);
        r.spec.cache_policy = Some("setassoc".to_string());
        r.spec.cache_ways = Some(8);
        let report =
            SweepReport { name: "cachelab".to_string(), results: vec![r] };
        let text = report.json_string();
        assert!(text.contains("\"cache_ways\":8"), "{text}");
        assert!(config_label(&report.results[0].spec).contains("ways=8"));
    }

    #[test]
    fn traced_rows_serialize_attribution_and_render_sections() {
        use crate::obs::{PhaseAttribution, TailToken};
        let mut r = fake_result("traced", 1e6);
        r.outcome.attribution = Some(crate::obs::AttributionSummary {
            tokens: 1,
            accounted_ms: 3.0,
            latency_ms: 3.0,
            closure_error_ms: 0.0,
            exact_closures: 1,
            spans_recorded: 3,
            spans_dropped: 0,
            marks_dropped: 0,
            phases: vec![PhaseAttribution {
                phase: "flash_queue".to_string(),
                count: 1,
                total_ms: 2.0,
                mean_ms: 2.0,
                max_ms: 2.0,
            }],
            tail: vec![TailToken {
                sid: 0,
                start_ms: 0.0,
                queue_ms: 0.0,
                stall_ms: 2.0,
                compute_ms: 1.0,
                latency_ms: 3.0,
            }],
        });
        let report = SweepReport { name: "tr".to_string(), results: vec![r] };
        let text = report.json_string();
        assert!(text.contains("\"attribution\":{"), "{text}");
        assert!(text.contains("\"exact_closures\":1"), "{text}");
        assert!(text.contains("\"phase\":\"flash_queue\""), "{text}");
        assert!(text.contains("\"tail\":["), "{text}");
        // old baselines (io/e2e only) still parse the extended schema
        let base = Baseline::parse(&text).unwrap();
        assert_eq!(base.len(), 1);
        // serialization stays a pure function of the inputs
        assert_eq!(text, report.json_string());

        let md = report.to_markdown(None);
        assert!(md.contains("## Attribution (flight recorder)"), "{md}");
        assert!(md.contains("### Time in phase"), "{md}");
        assert!(md.contains("| traced | flash_queue | 1 |"), "{md}");
        assert!(md.contains("### Slowest tokens (tail samples)"), "{md}");
    }

    #[test]
    fn zero_token_rows_serialize_finite_numbers() {
        // regression: a scenario that decoded zero tokens (or an empty
        // traced recorder) must never leak NaN/inf into the report
        let mut r = fake_result("empty", 1e6);
        r.outcome.metrics = RunMetrics::new();
        r.outcome.attribution = Some(Default::default());
        let report = SweepReport { name: "z".to_string(), results: vec![r] };
        let text = report.json_string();
        assert!(!text.contains("NaN") && !text.contains("nan"), "{text}");
        assert!(!text.contains("inf") && !text.contains("Infinity"), "{text}");
        assert!(text.contains("\"tokens\":0"), "{text}");
        // the document still parses as a baseline
        assert!(Baseline::parse(&text).is_ok());
        let md = report.to_markdown(None);
        assert!(!md.contains("NaN") && !md.contains("inf"), "{md}");
    }

    #[test]
    fn serve_delta_table_skips_unpaired_rows() {
        let report = SweepReport {
            name: "serve".to_string(),
            results: vec![fake_serve_result("solo", true, 0.6, 2.0)],
        };
        let md = report.to_markdown(None);
        assert!(md.contains("## Serving (multi-session)"));
        assert!(!md.contains("### Shared vs private cache"));
    }
}
