//! Multi-threaded sweep runner.
//!
//! Scenarios run in parallel over a work-stealing index; results are
//! written back into slots keyed by scenario position, so the report
//! order — and therefore the JSON bytes — is the matrix expansion
//! order regardless of how many worker threads raced. Each scenario is
//! itself deterministic (seeded traces, no wall clock in any metric),
//! which the golden test in `rust/tests/harness_golden.rs` pins down:
//! `--threads 1` and `--threads 8` produce byte-identical JSON.
//!
//! The `threads` argument is the sweep's TOTAL budget: when rows carry
//! `decode_threads > 1` (DESIGN.md §Parallel-decode), the sweep worker
//! count shrinks via [`split_thread_budget`] so sweep workers times the
//! widest decode pool never exceed the budget.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::bench::workloads::{self, ExperimentResult, SystemSpec, Workload};
use crate::coordinator::fleet::{run_fleet_traced, FleetConfig};
use crate::coordinator::session::{run_serve_traced, ServeConfig};
use crate::metrics::RunMetrics;
use crate::obs::{AttributionSummary, TraceConfig, TraceHandle};

use super::report::{ScenarioResult, SweepReport};
use super::scenario::{FleetPoint, ScenarioMatrix, ScenarioSpec, ServePoint};

/// Salt folded into the workload seed to draw the fleet arrival stream:
/// keeps arrival times decoupled from the trace streams (which already
/// use the raw seed and its `0xDEAD_BEEF` offsets) while staying a pure
/// function of the scenario seed. Load-bearing for baseline
/// comparability; never change it.
const FLEET_ARRIVAL_SALT: u64 = 0xF1EE_7A11;

/// Default thread budget: one per available core, overridable with the
/// `RIPPLE_THREADS` env var (useful under cgroup limits, where
/// `available_parallelism` can over-report). Falls back to 4 — with a
/// one-time warning — when the override is malformed or the parallelism
/// query fails. Shared by the CLI and the bench wrappers.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("RIPPLE_THREADS") {
        match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => return n,
            _ => warn_once(&format!(
                "RIPPLE_THREADS={v:?} is not a positive integer; ignoring it"
            )),
        }
    }
    match std::thread::available_parallelism() {
        Ok(n) => n.get(),
        Err(e) => {
            warn_once(&format!(
                "available_parallelism() failed ({e}); assuming 4 threads \
                 (set RIPPLE_THREADS to override)"
            ));
            4
        }
    }
}

/// Print a thread-budget diagnostic at most once per process, so sweep
/// loops calling `default_threads` per scenario don't spam stderr.
fn warn_once(msg: &str) {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| eprintln!("warning: {msg}"));
}

/// Split a total thread budget between the sweep level and the decode
/// pools nested inside each scenario: the returned sweep worker count
/// guarantees `sweep_workers * max_decode <= budget` whenever the
/// budget allows any parallelism at all (the floor is one sweep worker,
/// so a budget smaller than `max_decode` degrades to serial sweeping
/// rather than refusing to run). Also clamped to the job count — extra
/// sweep workers past that would only idle.
pub fn split_thread_budget(budget: usize, jobs: usize, max_decode: usize) -> usize {
    (budget.max(1) / max_decode.max(1)).max(1).min(jobs.max(1))
}

/// Expand a matrix and run every scenario, treating `threads` as the
/// TOTAL thread budget shared by the sweep workers and each scenario's
/// decode pool (see [`split_thread_budget`]). Returns results in matrix
/// expansion order; the whole sweep drains before errors are inspected,
/// and the first failing scenario (in expansion order) is reported with
/// its name.
pub fn run_matrix(matrix: &ScenarioMatrix, threads: usize) -> anyhow::Result<SweepReport> {
    run_matrix_with(matrix, threads, None)
}

/// [`run_matrix`] with an optional decode-thread override, applied
/// AFTER expansion so scenario names (and therefore the JSON bytes)
/// never change: overriding lets CI re-run an identical matrix at
/// decode-thread counts 1 and 8 and byte-`cmp` the reports.
pub fn run_matrix_with(
    matrix: &ScenarioMatrix,
    threads: usize,
    decode_override: Option<usize>,
) -> anyhow::Result<SweepReport> {
    let mut specs = matrix.expand();
    anyhow::ensure!(!specs.is_empty(), "matrix `{}` expands to no scenarios", matrix.name);
    if let Some(dt) = decode_override {
        for s in &mut specs {
            s.decode_threads = dt.max(1);
        }
    }
    {
        let mut names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        for w in names.windows(2) {
            anyhow::ensure!(w[0] != w[1], "duplicate scenario name `{}`", w[0]);
        }
    }
    // avoid oversubscription: the widest per-scenario decode pool and
    // the sweep level split the one budget (when every row keeps the
    // default decode_threads=1 this is the historical sweep clamp), and
    // the per-scenario placement scan gets the cores the sweep level is
    // not using (results are thread-invariant either way)
    let max_decode = specs.iter().map(|s| s.decode_threads.max(1)).max().unwrap_or(1);
    let threads = split_thread_budget(threads, specs.len(), max_decode);
    let inner_threads = (default_threads() / threads).max(1);
    let slots: Vec<Mutex<Option<anyhow::Result<ExperimentResult>>>> =
        specs.iter().map(|_| Mutex::new(None)).collect();
    if threads == 1 {
        for (spec, slot) in specs.iter().zip(&slots) {
            *slot.lock().unwrap() = Some(run_scenario(spec, inner_threads));
        }
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= specs.len() {
                        break;
                    }
                    let r = run_scenario(&specs[i], inner_threads);
                    *slots[i].lock().unwrap() = Some(r);
                });
            }
        });
    }
    let mut results = Vec::with_capacity(specs.len());
    for (spec, slot) in specs.into_iter().zip(slots) {
        let filled = slot.into_inner().unwrap().expect("scenario slot filled");
        match filled {
            Ok(outcome) => results.push(ScenarioResult { spec, outcome }),
            Err(e) => anyhow::bail!("scenario `{}`: {e:#}", spec.name),
        }
    }
    Ok(SweepReport { name: matrix.name.clone(), results })
}

/// Run one scenario end to end. `threads` bounds the intra-scenario
/// placement-scan parallelism (never the results: every code path is
/// thread-count invariant).
pub fn run_scenario(spec: &ScenarioSpec, threads: usize) -> anyhow::Result<ExperimentResult> {
    let mut w = spec.workload()?;
    w.threads = threads.max(1);
    let sspec = spec.system_spec(w.model.ffn_linears)?;
    // dense streaming would silently ignore the knob (run_inner forces
    // the sync timeline); reject rather than report a config that did
    // not actually run
    anyhow::ensure!(
        !(sspec.dense && w.prefetch.enabled),
        "scenario `{}`: dense streaming (llamacpp) has no speculative prefetch; \
         use a sync prefetch point",
        spec.name
    );
    // flight recorder: one per traced scenario, attached to every layer
    // the scenario exercises (flash, pipeline, coordinator). Ablation
    // rows stay untraced — their custom loop has no recorder hook and
    // attribution would silently under-count.
    let trace = if spec.trace {
        Some(TraceHandle::new(TraceConfig::default()))
    } else {
        None
    };
    if let Some(sv) = &spec.serve {
        return run_serve_point(spec, sv, &w, sspec, trace.as_ref());
    }
    if let Some(fl) = &spec.fleet {
        return run_fleet_point(spec, fl, &w, sspec, trace.as_ref());
    }
    if spec.admission.is_some() || spec.fixed_threshold.is_some() {
        run_ablation(spec, &w, sspec)
    } else {
        let eval = w.dataset.clone();
        let mut r = workloads::run_spec_traced(&w, sspec, &eval, trace.as_ref())?;
        r.attribution = attribution_of(trace.as_ref(), &w);
        Ok(r)
    }
}

/// Fold a recorder (if any) into the report-facing attribution summary,
/// scaled to full-model milliseconds like every other latency figure.
fn attribution_of(trace: Option<&TraceHandle>, w: &Workload) -> Option<AttributionSummary> {
    trace.map(|t| t.with(|rec| rec.attribution(w.layer_scale())))
}

/// Multi-session serving path (DESIGN.md §Serving): N sessions through
/// one shared cache + flash timeline via `coordinator::session`. The
/// aggregate metrics land in the same `ExperimentResult` slots every
/// other row uses, plus the serve summary.
fn run_serve_point(
    spec: &ScenarioSpec,
    sv: &ServePoint,
    w: &Workload,
    sspec: SystemSpec,
    trace: Option<&TraceHandle>,
) -> anyhow::Result<ExperimentResult> {
    anyhow::ensure!(
        spec.admission.is_none() && spec.fixed_threshold.is_none(),
        "scenario `{}`: ablation knobs are not supported on serve points",
        spec.name
    );
    let mut cfg = ServeConfig {
        sessions: sv.sessions,
        max_concurrent: sv.max_concurrent,
        arrival_spacing_ns: sv.arrival_spacing_ms * 1e6,
        shared_cache: sv.shared_cache,
        decode_threads: spec.decode_threads.max(1),
        ..ServeConfig::default()
    };
    if let Some(policy) = sv.arbiter {
        cfg.arbiter = policy;
    }
    cfg.prefetch_global_budget = sv.prefetch_global_budget;
    let out = run_serve_traced(w, spec.system, sspec, &cfg, trace)
        .map_err(|e| anyhow::anyhow!("scenario `{}`: {e:#}", spec.name))?;
    Ok(ExperimentResult {
        system: spec.system,
        metrics: out.metrics,
        placement_secs: out.placement_secs,
        decode_wall_secs: out.decode_wall_secs,
        layer_scale: w.layer_scale(),
        bundle_bytes: out.bundle_bytes,
        serve: Some(out.summary),
        fleet: None,
        attribution: attribution_of(trace, w),
    })
}

/// Event-driven fleet path (DESIGN.md §Fleet): open-loop arrivals,
/// admission control, and SLO accounting via `coordinator::fleet`. The
/// aggregate metrics and serve summary land in the same
/// `ExperimentResult` slots serve rows use, plus the fleet summary.
fn run_fleet_point(
    spec: &ScenarioSpec,
    fl: &FleetPoint,
    w: &Workload,
    sspec: SystemSpec,
    trace: Option<&TraceHandle>,
) -> anyhow::Result<ExperimentResult> {
    let cfg = FleetConfig {
        sessions: fl.sessions,
        max_concurrent: fl.max_concurrent,
        arrival: fl.arrival.process(),
        arrival_seed: w.seed ^ FLEET_ARRIVAL_SALT,
        scheduler: fl.scheduler,
        admission_bound: fl.admission_bound,
        // the point's SLO is full-model ms; the simulator compares raw
        // per-layer-scaled ns, so divide the scale back out
        slo_ns: fl.slo_ms.map_or(f64::INFINITY, |ms| ms * 1e6 / w.layer_scale()),
        decode_threads: spec.decode_threads.max(1),
        ..FleetConfig::default()
    };
    let out = run_fleet_traced(w, spec.system, sspec, &cfg, trace)
        .map_err(|e| anyhow::anyhow!("scenario `{}`: {e:#}", spec.name))?;
    Ok(ExperimentResult {
        system: spec.system,
        metrics: out.metrics,
        placement_secs: out.placement_secs,
        decode_wall_secs: out.decode_wall_secs,
        layer_scale: w.layer_scale(),
        bundle_bytes: out.bundle_bytes,
        serve: Some(out.summary),
        fleet: Some(out.fleet),
        attribution: attribution_of(trace, w),
    })
}

/// Custom path for the ablation-only knobs (pinned collapse threshold,
/// explicit admission) that `SystemSpec` cannot express: synchronous
/// timeline through the same `workloads::pipeline_with` construction
/// every other experiment uses, so ablation rows stay comparable with
/// default-path rows in the same report.
fn run_ablation(
    spec: &ScenarioSpec,
    w: &Workload,
    sspec: SystemSpec,
) -> anyhow::Result<ExperimentResult> {
    anyhow::ensure!(!sspec.dense, "ablation knobs do not support dense streaming");
    anyhow::ensure!(!w.prefetch.enabled, "ablation knobs run on the synchronous timeline");
    let calib = w.calibration_trace();
    let (layouts, placement_secs) =
        workloads::layouts_for(spec.system, &calib, w.knn, w.threads);
    let (mut pipeline, mut cache, mut sim) =
        workloads::pipeline_with(sspec, w, layouts, spec.admission, spec.fixed_threshold)?;
    let bundle_bytes = pipeline.config().bundle_bytes;
    let eval = w.eval_trace(&w.dataset);
    let mut metrics = RunMetrics::new();
    let t_decode = std::time::Instant::now();
    for tok in &eval.tokens {
        let t = pipeline.step_token(&mut cache, &mut sim, tok);
        metrics.record(&t, bundle_bytes);
        metrics.record_compute(w.compute_ns_per_layer * w.sim_layers as f64);
    }
    let decode_wall_secs = t_decode.elapsed().as_secs_f64();
    Ok(ExperimentResult {
        system: spec.system,
        metrics,
        placement_secs,
        decode_wall_secs,
        layer_scale: w.layer_scale(),
        bundle_bytes,
        serve: None,
        fleet: None,
        attribution: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::System;
    use crate::cache::Admission;
    use crate::harness::scenario::PrefetchPoint;

    fn tiny_spec(name: &str) -> ScenarioSpec {
        let mut s = ScenarioSpec::new(name, "opt-micro", System::Ripple);
        s.calib_tokens = 64;
        s.eval_tokens = 16;
        s.sim_layers = 2;
        s.knn = 8;
        s
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut m = ScenarioMatrix::new("dup");
        m.extra.push(tiny_spec("a"));
        m.extra.push(tiny_spec("a"));
        let err = run_matrix(&m, 2).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate scenario name"));
    }

    #[test]
    fn empty_matrix_rejected() {
        let mut m = ScenarioMatrix::new("empty");
        m.models.clear();
        assert!(run_matrix(&m, 1).is_err());
    }

    #[test]
    fn ablation_path_matches_spirit_of_default_path() {
        // an explicit adaptive-threshold + linking admission scenario
        // runs the custom path and still produces sane sync metrics
        let mut s = tiny_spec("abl");
        s.admission = Some(Admission::Linking { segment_min: 4, segment_p: 0.5 });
        let r = run_scenario(&s, 2).unwrap();
        assert!(r.metrics.tokens == 16);
        assert!(r.metrics.mean_latency_ns() > 0.0);
        assert!(r.overlap_ratio().abs() < 1e-12, "ablations are sync-only");
        // deterministic
        let r2 = run_scenario(&s, 1).unwrap();
        assert_eq!(
            r.metrics.totals.elapsed_ns.to_bits(),
            r2.metrics.totals.elapsed_ns.to_bits()
        );
        assert_eq!(r.metrics.totals.commands, r2.metrics.totals.commands);
    }

    #[test]
    fn ablation_knobs_reject_prefetch_and_dense() {
        let mut s = tiny_spec("bad");
        s.fixed_threshold = Some(4);
        s.prefetch = PrefetchPoint::budget_kb(64);
        assert!(run_scenario(&s, 1).is_err());
        let mut s = tiny_spec("dense");
        s.system = System::LlamaCpp;
        s.fixed_threshold = Some(4);
        assert!(run_scenario(&s, 1).is_err());
    }

    #[test]
    fn dense_with_prefetch_rejected_instead_of_misreported() {
        let mut s = tiny_spec("dense-pf");
        s.system = System::LlamaCpp;
        s.prefetch = PrefetchPoint::budget_kb(64);
        let err = run_scenario(&s, 1).unwrap_err();
        assert!(format!("{err:#}").contains("no speculative prefetch"));
    }

    #[test]
    fn serve_point_runs_and_reports_summary() {
        let mut s = tiny_spec("serve-2");
        s.serve = Some(ServePoint { max_concurrent: 2, ..ServePoint::shared(2) });
        let r = run_scenario(&s, 1).unwrap();
        assert_eq!(r.metrics.tokens, 32, "2 sessions x 16 eval tokens");
        let sv = r.serve.as_ref().expect("serve summary");
        assert_eq!(sv.sessions, 2);
        assert_eq!(sv.tokens, 32);
        assert!(sv.shared_cache);
        assert!(sv.p50_ms > 0.0 && sv.p99_ms >= sv.p50_ms);
        assert!(r.overlap_ratio().abs() < 1e-12, "serve is sync-only");
    }

    #[test]
    fn serve_point_rejects_ablation_knobs_and_dense() {
        let sv = ServePoint { max_concurrent: 2, ..ServePoint::shared(2) };
        let mut s = tiny_spec("serve-abl");
        s.serve = Some(sv);
        s.fixed_threshold = Some(4);
        assert!(run_scenario(&s, 1).is_err());
        let mut s = tiny_spec("serve-dense");
        s.serve = Some(sv);
        s.system = System::LlamaCpp;
        assert!(run_scenario(&s, 1).is_err());
    }

    #[test]
    fn prefetch_serve_point_runs_overlapped_with_attribution() {
        let mut s = tiny_spec("serve-pf");
        s.prefetch = PrefetchPoint::budget_kb(64);
        s.serve = Some(
            ServePoint { max_concurrent: 2, ..ServePoint::shared(2) }
                .with_arbiter(crate::coordinator::ArbiterPolicy::FairShare),
        );
        let r = run_scenario(&s, 1).unwrap();
        let sv = r.serve.as_ref().expect("serve summary");
        assert_eq!(sv.sessions, 2);
        assert_eq!(sv.session_prefetch.len(), 2);
        let hits: u64 = sv.session_prefetch.iter().map(|p| p.prefetch_hit_bundles).sum();
        let waste: u64 =
            sv.session_prefetch.iter().map(|p| p.prefetch_wasted_bundles).sum();
        assert_eq!(hits, r.metrics.totals.prefetch_hit_bundles);
        assert_eq!(waste, r.metrics.totals.prefetch_wasted_bundles);
        assert!(
            r.overlap_ratio() > 0.0,
            "prefetch serve rows run the overlapped timeline"
        );
    }

    #[test]
    fn fleet_point_runs_and_reports_both_summaries() {
        use crate::harness::scenario::FleetPoint;
        let mut s = tiny_spec("fleet-3");
        s.fleet = Some(FleetPoint {
            max_concurrent: 2,
            ..FleetPoint::poisson(3, 100_000.0).with_slo_ms(50.0)
        });
        let r = run_scenario(&s, 1).unwrap();
        assert_eq!(r.metrics.tokens, 48, "3 sessions x 16 eval tokens");
        let fl = r.fleet.as_ref().expect("fleet summary");
        assert!(fl.conserves_load());
        assert_eq!(fl.offered_sessions, 3);
        assert_eq!(fl.completed_tokens, 48);
        assert!(fl.goodput_tokens_per_s >= 0.0);
        assert!((fl.slo_ms - 50.0).abs() < 1e-9);
        let sv = r.serve.as_ref().expect("serve summary rides along");
        assert_eq!(sv.tokens, 48);
        assert!(sv.p999_ms >= sv.p99_ms * 0.999);
        // deterministic and thread-invariant like every other row
        let r2 = run_scenario(&s, 2).unwrap();
        assert_eq!(r.fleet, r2.fleet);
    }

    #[test]
    fn traced_scenario_reports_attribution_and_untraced_stays_clean() {
        let mut s = tiny_spec("traced");
        s.trace = true;
        let r = run_scenario(&s, 1).unwrap();
        let at = r.attribution.as_ref().expect("traced rows carry attribution");
        assert_eq!(at.tokens, r.metrics.tokens as u64);
        // single-stream latencies are stall + compute by construction,
        // so every token closes bit-for-bit
        assert_eq!(at.exact_closures, at.tokens);
        assert!(at.closure_error_ms.abs() < 1e-9);
        assert!(at.accounted_ms > 0.0);
        let mut u = tiny_spec("untraced");
        u.trace = false;
        assert!(run_scenario(&u, 1).unwrap().attribution.is_none());
    }

    #[test]
    fn traced_serve_scenario_attribution_matches_latency_split() {
        let mut s = tiny_spec("serve-traced");
        s.trace = true;
        s.serve = Some(ServePoint { max_concurrent: 2, ..ServePoint::shared(2) });
        let r = run_scenario(&s, 1).unwrap();
        let at = r.attribution.as_ref().expect("attribution");
        assert_eq!(at.tokens, r.metrics.tokens as u64);
        // the FlashQueue phase total is the run's stall total, bitwise
        let stall_ms = r.metrics.totals.stall_ns * r.layer_scale / 1e6;
        let flash_q = at
            .phases
            .iter()
            .find(|p| p.phase == "flash_queue")
            .expect("flash_queue phase");
        assert_eq!(flash_q.total_ms.to_bits(), stall_ms.to_bits());
        // bit-identical across repeated traced runs
        let r2 = run_scenario(&s, 1).unwrap();
        assert_eq!(r.attribution, r2.attribution);
    }

    #[test]
    fn thread_budget_is_never_oversubscribed() {
        // sweep workers x widest decode pool stays within the budget
        // whenever the budget admits any parallelism at all
        for budget in 1..=32usize {
            for jobs in 1..=6usize {
                for max_decode in 1..=16usize {
                    let sweep = split_thread_budget(budget, jobs, max_decode);
                    assert!(sweep >= 1, "always at least one sweep worker");
                    assert!(sweep <= jobs, "no idle sweep workers");
                    assert!(
                        sweep == 1 || sweep * max_decode <= budget,
                        "oversubscribed: budget {budget}, jobs {jobs}, \
                         decode {max_decode} -> sweep {sweep}"
                    );
                }
            }
        }
        // all-dt=1 rows reproduce the historical sweep clamp
        assert_eq!(split_thread_budget(8, 3, 1), 3);
        assert_eq!(split_thread_budget(2, 5, 1), 2);
        // degenerate budgets degrade to serial sweeping, never zero
        assert_eq!(split_thread_budget(0, 4, 8), 1);
    }

    #[test]
    fn decode_override_keeps_names_and_results_byte_identical() {
        let mut m = ScenarioMatrix::new("ovr");
        let mut s = tiny_spec("serve-ovr");
        s.serve = Some(ServePoint { max_concurrent: 2, ..ServePoint::shared(3) });
        m.extra.push(s);
        let base = run_matrix(&m, 1).unwrap();
        let pooled = run_matrix_with(&m, 8, Some(4)).unwrap();
        assert_eq!(base.results.len(), pooled.results.len());
        for (a, b) in base.results.iter().zip(&pooled.results) {
            // the override must never rename a row (CI byte-cmp's the
            // dt=1 and dt=8 reports), and results are pool-invariant
            assert_eq!(a.spec.name, b.spec.name);
            assert_eq!(b.spec.decode_threads, 4);
            assert_eq!(
                a.outcome.metrics.totals.elapsed_ns.to_bits(),
                b.outcome.metrics.totals.elapsed_ns.to_bits()
            );
            assert_eq!(a.outcome.metrics.totals.commands, b.outcome.metrics.totals.commands);
            assert_eq!(a.outcome.serve, b.outcome.serve);
        }
    }

    #[test]
    fn fixed_threshold_changes_collapse_behaviour() {
        let mut off = tiny_spec("thr-off");
        off.fixed_threshold = Some(0);
        off.collapse = Some(false);
        let mut wide = tiny_spec("thr-16");
        wide.fixed_threshold = Some(16);
        wide.collapse = Some(true);
        let a = run_scenario(&off, 1).unwrap();
        let b = run_scenario(&wide, 1).unwrap();
        // gap-filling speculation only happens with collapse enabled
        assert_eq!(a.metrics.totals.extra_bundles, 0);
        assert!(b.metrics.totals.extra_bundles > 0);
    }
}
