//! Declarative scenario specs and the cartesian-product matrix.
//!
//! A [`ScenarioSpec`] pins every knob of one experiment point: model ×
//! device × dataset × system (placement strategy) × cache policy ×
//! prefetch configuration, plus the scale knobs (`calib_tokens`,
//! `eval_tokens`, `sim_layers`, `knn`) whose defaults mirror
//! `bench_workload` so scenario runs reproduce the historical bench
//! binaries bit-for-bit. A [`ScenarioMatrix`] holds one value list per
//! axis and expands to the cartesian product in a fixed axis order
//! (model → device → dataset → system → cache policy → collapse →
//! cache ratio → prefetch), so the scenario sequence — and therefore
//! the report row order and the JSON bytes — never depends on thread
//! count or timing.

use crate::bench::workloads::{System, SystemSpec, Workload};
use crate::cache::Admission;
use crate::config::{device_by_name, model_by_name, Precision};
use crate::coordinator::{ArbiterPolicy, FleetScheduler};
use crate::trace::{ArrivalProcess, DatasetProfile};

/// One point on the prefetch axis of a matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefetchPoint {
    /// Speculative prefetch on the overlapped flash timeline; off means
    /// the synchronous baseline (bit-identical to the seed timeline).
    pub enabled: bool,
    /// Per-target-layer speculative read budget, bytes.
    pub budget_bytes: usize,
    /// Layers of lookahead for speculation (>= 1).
    pub lookahead: usize,
}

impl PrefetchPoint {
    /// The synchronous baseline point (prefetch off).
    pub fn sync() -> Self {
        Self { enabled: false, budget_bytes: 256 * 1024, lookahead: 1 }
    }

    /// An overlapped point with a `kb`-KiB budget and lookahead 1.
    pub fn budget_kb(kb: usize) -> Self {
        Self { enabled: true, budget_bytes: kb * 1024, lookahead: 1 }
    }

    /// Stable label used in scenario names (`sync` or `pf<kb>KB-la<n>`).
    pub fn label(&self) -> String {
        if self.enabled {
            format!("pf{}KB-la{}", self.budget_bytes / 1024, self.lookahead)
        } else {
            "sync".to_string()
        }
    }
}

/// One point on the serving axis of a matrix (DESIGN.md §Serving):
/// N continuous-batched sessions through one shared flash timeline,
/// with one shared DRAM cache or equal-total private partitions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServePoint {
    /// Number of decode sessions.
    pub sessions: usize,
    /// Decode slots (continuous-batch width).
    pub max_concurrent: usize,
    /// Virtual gap between consecutive session arrivals, ms
    /// (full-model scale is NOT applied — this is raw sim time).
    pub arrival_spacing_ms: f64,
    /// Shared cache (true) vs private per-session partitions (false).
    pub shared_cache: bool,
    /// Prefetch-budget arbiter policy override; `None` keeps the
    /// fair-share default — and the historical label, so prefetch-off
    /// serve rows keep matching old baselines.
    pub arbiter: Option<ArbiterPolicy>,
    /// Global speculative byte budget across sessions per round; `None`
    /// defaults to per-session budget × sessions.
    pub prefetch_global_budget: Option<usize>,
}

impl ServePoint {
    /// A `sessions`-user shared-cache point, 4 decode slots, arrivals
    /// packed at t=0 (the maximum-contention configuration).
    pub fn shared(sessions: usize) -> Self {
        Self {
            sessions,
            max_concurrent: 4,
            arrival_spacing_ms: 0.0,
            shared_cache: true,
            arbiter: None,
            prefetch_global_budget: None,
        }
    }

    /// The same point with private per-session caches (equal total
    /// capacity) — the shared-vs-private comparison partner.
    pub fn private(sessions: usize) -> Self {
        Self { shared_cache: false, ..Self::shared(sessions) }
    }

    /// The same point with an explicit arbiter policy (prefetch-enabled
    /// serve rows only).
    pub fn with_arbiter(mut self, policy: ArbiterPolicy) -> Self {
        self.arbiter = Some(policy);
        self
    }

    /// The same point with an explicit global speculative byte budget.
    pub fn with_global_budget(mut self, bytes: usize) -> Self {
        self.prefetch_global_budget = Some(bytes);
        self
    }

    /// Arbiter/budget label suffix; empty for default points so old
    /// scenario names (and their baselines) stay unchanged.
    fn arbiter_suffix(&self) -> String {
        let mut out = String::new();
        match self.arbiter {
            None => {}
            Some(ArbiterPolicy::FairShare) => out.push_str("-fair"),
            Some(ArbiterPolicy::DeadlineAware { target_ns }) => {
                out.push_str(&format!("-dl{}ms", target_ns / 1e6));
            }
        }
        if let Some(b) = self.prefetch_global_budget {
            out.push_str(&format!("-g{}KB", b / 1024));
        }
        out
    }

    /// Stable label used in scenario names
    /// (`s<N>c<slots>-a<ms>ms-<shared|priv>[-<arbiter>][-g<kb>KB]`).
    pub fn label(&self) -> String {
        format!(
            "s{}c{}-a{}ms-{}{}",
            self.sessions,
            self.max_concurrent,
            self.arrival_spacing_ms,
            if self.shared_cache { "shared" } else { "priv" },
            self.arbiter_suffix()
        )
    }

    /// The label's sharing-independent prefix — shared and private rows
    /// of the same (sessions, slots, arrival, arbiter) point share it,
    /// which is how the report pairs them for the delta table.
    pub fn pair_key(&self) -> String {
        format!(
            "s{}c{}-a{}ms{}",
            self.sessions,
            self.max_concurrent,
            self.arrival_spacing_ms,
            self.arbiter_suffix()
        )
    }
}

/// One point on the arrival axis of a fleet sweep — the open-loop
/// traffic shape, in harness units (ms / per-second; the runner
/// converts to the simulator's raw ns).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalSpec {
    /// Deterministic grid: session `i` arrives at `i * spacing_ms`
    /// (spacing 0 = everyone at t=0 — the `SessionManager` shape).
    Fixed {
        /// Gap between consecutive arrivals, ms (raw sim time).
        spacing_ms: f64,
    },
    /// Poisson process at `per_s` arrivals per virtual second.
    Poisson {
        /// Mean arrival rate, 1/s.
        per_s: f64,
    },
    /// Bursts of `burst` coincident arrivals, Poisson-spaced so the
    /// long-run mean stays `per_s`.
    Bursty {
        /// Mean arrival rate, 1/s.
        per_s: f64,
        /// Sessions per burst (>= 1).
        burst: usize,
    },
    /// Sinusoidally-modulated Poisson (thinning) with period `period_s`
    /// and relative swing `depth` in [0, 1].
    Diurnal {
        /// Mean arrival rate, 1/s.
        per_s: f64,
        /// Modulation period, virtual seconds.
        period_s: f64,
        /// Relative swing in [0, 1].
        depth: f64,
    },
}

impl ArrivalSpec {
    /// Stable label fragment used in scenario names.
    pub fn label(&self) -> String {
        match self {
            ArrivalSpec::Fixed { spacing_ms } => format!("fx{spacing_ms}ms"),
            ArrivalSpec::Poisson { per_s } => format!("po{per_s}"),
            ArrivalSpec::Bursty { per_s, burst } => format!("bu{per_s}x{burst}"),
            ArrivalSpec::Diurnal { per_s, period_s, depth } => {
                format!("di{per_s}p{period_s}d{depth}")
            }
        }
    }

    /// Convert to the simulator's raw-ns arrival process.
    pub fn process(&self) -> ArrivalProcess {
        match *self {
            ArrivalSpec::Fixed { spacing_ms } => {
                ArrivalProcess::Fixed { spacing_ns: spacing_ms * 1e6 }
            }
            ArrivalSpec::Poisson { per_s } => ArrivalProcess::Poisson { rate_per_s: per_s },
            ArrivalSpec::Bursty { per_s, burst } => {
                ArrivalProcess::Bursty { rate_per_s: per_s, burst }
            }
            ArrivalSpec::Diurnal { per_s, period_s, depth } => {
                ArrivalProcess::Diurnal { rate_per_s: per_s, period_s, depth }
            }
        }
    }

    /// Validate the shape parameters (names the scenario on failure).
    fn validate(&self, scenario: &str) -> anyhow::Result<()> {
        match *self {
            ArrivalSpec::Fixed { spacing_ms } => {
                anyhow::ensure!(
                    spacing_ms.is_finite() && spacing_ms >= 0.0,
                    "scenario `{scenario}`: fixed arrival spacing must be finite and >= 0"
                );
            }
            ArrivalSpec::Poisson { per_s } => {
                anyhow::ensure!(
                    per_s.is_finite() && per_s > 0.0,
                    "scenario `{scenario}`: Poisson arrival rate must be finite and > 0"
                );
            }
            ArrivalSpec::Bursty { per_s, burst } => {
                anyhow::ensure!(
                    per_s.is_finite() && per_s > 0.0 && burst >= 1,
                    "scenario `{scenario}`: bursty arrivals need rate > 0 and burst >= 1"
                );
            }
            ArrivalSpec::Diurnal { per_s, period_s, depth } => {
                anyhow::ensure!(
                    per_s.is_finite()
                        && per_s > 0.0
                        && period_s.is_finite()
                        && period_s > 0.0
                        && (0.0..=1.0).contains(&depth),
                    "scenario `{scenario}`: diurnal arrivals need rate > 0, \
                     period > 0, depth in [0, 1]"
                );
            }
        }
        Ok(())
    }
}

/// One point on the fleet axis of a matrix (DESIGN.md §Fleet): the
/// event-driven open-loop serving simulation — arrival process ×
/// scheduler × admission bound × SLO over a shared cache and flash
/// timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetPoint {
    /// Sessions the arrival process offers.
    pub sessions: usize,
    /// Decode slots (continuous-batch width).
    pub max_concurrent: usize,
    /// Open-loop arrival shape.
    pub arrival: ArrivalSpec,
    /// Serve-order policy.
    pub scheduler: FleetScheduler,
    /// Admission bound (max sessions waiting); `None` = unbounded.
    pub admission_bound: Option<usize>,
    /// Per-token SLO in full-model ms; `None` = no SLO accounting.
    pub slo_ms: Option<f64>,
}

impl FleetPoint {
    /// A fixed-spacing FIFO point with 4 decode slots and unbounded
    /// admission — spacing 0 is the degenerate configuration pinned
    /// bit-for-bit to the round-based serve path.
    pub fn fixed(sessions: usize, spacing_ms: f64) -> Self {
        Self {
            sessions,
            max_concurrent: 4,
            arrival: ArrivalSpec::Fixed { spacing_ms },
            scheduler: FleetScheduler::Fifo,
            admission_bound: None,
            slo_ms: None,
        }
    }

    /// A Poisson-arrival FIFO point at `per_s` arrivals per virtual
    /// second, 4 decode slots, unbounded admission.
    pub fn poisson(sessions: usize, per_s: f64) -> Self {
        Self { arrival: ArrivalSpec::Poisson { per_s }, ..Self::fixed(sessions, 0.0) }
    }

    /// The same point under a different scheduler.
    pub fn with_scheduler(mut self, scheduler: FleetScheduler) -> Self {
        self.scheduler = scheduler;
        self
    }

    /// The same point with a bounded admission queue.
    pub fn with_bound(mut self, bound: usize) -> Self {
        self.admission_bound = Some(bound);
        self
    }

    /// The same point with a per-token SLO (full-model ms).
    pub fn with_slo_ms(mut self, ms: f64) -> Self {
        self.slo_ms = Some(ms);
        self
    }

    /// Stable label used in scenario names
    /// (`f<N>c<slots>-<arrival>-<sched>[-q<bound>][-slo<ms>ms]`).
    pub fn label(&self) -> String {
        let mut out = format!(
            "f{}c{}-{}-{}",
            self.sessions,
            self.max_concurrent,
            self.arrival.label(),
            self.scheduler.key()
        );
        if let Some(b) = self.admission_bound {
            out.push_str(&format!("-q{b}"));
        }
        if let Some(ms) = self.slo_ms {
            out.push_str(&format!("-slo{ms}ms"));
        }
        out
    }

    /// The label minus the arrival fragment — rows differing only in
    /// traffic shape/rate share it, which is how the report groups a
    /// load ramp into one table.
    pub fn ramp_key(&self) -> String {
        let mut out = format!("f{}c{}-{}", self.sessions, self.max_concurrent, self.scheduler.key());
        if let Some(b) = self.admission_bound {
            out.push_str(&format!("-q{b}"));
        }
        if let Some(ms) = self.slo_ms {
            out.push_str(&format!("-slo{ms}ms"));
        }
        out
    }
}

/// One fully-resolved experiment point of a sweep.
///
/// Field defaults (see [`ScenarioSpec::new`]) match the historical
/// `bench_workload` construction: OnePlus 12, alpaca, fp16, cache ratio
/// 0.1, 256 calibration / 64 eval tokens, 2 representative layers,
/// kNN 64, seed 7, prefetch off.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Unique (within a matrix) name; baseline deltas match on it.
    pub name: String,
    /// Model geometry name (`config::model_by_name`).
    pub model: String,
    /// Device profile name (`config::device_by_name`).
    pub device: String,
    /// Dataset profile name (`trace::DatasetProfile::by_name`).
    pub dataset: String,
    /// Comparison system — bundles the placement strategy, read
    /// granularity and default collapse/cache settings.
    pub system: System,
    /// Cache-policy override ("linking"|"s3fifo"|"lru"|"victim"|
    /// "setassoc"|"costaware"|"none"); `None` keeps the system's
    /// default policy.
    pub cache_policy: Option<String>,
    /// Set-associativity override for the `setassoc` policy; `None`
    /// keeps `cache::DEFAULT_WAYS` (other policies ignore it). Rows
    /// without it keep their names and JSON byte-identical.
    pub cache_ways: Option<usize>,
    /// Access-collapse override; `None` keeps the system default.
    pub collapse: Option<bool>,
    /// Fraction of all FFN bundles that fit the DRAM cache.
    pub cache_ratio: f64,
    /// Stored-weight precision.
    pub precision: Precision,
    /// Speculative-prefetch knobs.
    pub prefetch: PrefetchPoint,
    /// Calibration-trace length, tokens.
    pub calib_tokens: usize,
    /// Evaluation-trace length, tokens.
    pub eval_tokens: usize,
    /// Representative layers simulated (latency scales by
    /// `n_layers / sim_layers`, see `bench::workloads` docs).
    pub sim_layers: usize,
    /// Greedy-search kNN width.
    pub knn: usize,
    /// Workload RNG seed (trace generation).
    pub seed: u64,
    /// Ablation knob: pin the collapse gap threshold instead of the
    /// adaptive controller (sync-only custom pipeline path).
    pub fixed_threshold: Option<u32>,
    /// Ablation knob: explicit cache admission over an S3-FIFO policy
    /// (sync-only custom pipeline path).
    pub admission: Option<Admission>,
    /// Multi-session serving point; `None` = the historical
    /// single-stream experiment.
    pub serve: Option<ServePoint>,
    /// Event-driven open-loop fleet point; `None` = no fleet run.
    /// Mutually exclusive with `serve` and the ablation knobs.
    pub fleet: Option<FleetPoint>,
    /// Attach the flight recorder (DESIGN.md §Observability) and report
    /// per-phase attribution. Off by default: untraced reports stay
    /// byte-identical to pre-tracing builds.
    pub trace: bool,
    /// Plan-phase decode threads for serve/fleet rows (DESIGN.md
    /// §Parallel-decode). Results are decode-thread-count invariant, so
    /// this knob is wall-clock-only: it is NOT serialized to report
    /// JSON, and rows at 1 (the default) keep their historical names.
    pub decode_threads: usize,
}

impl ScenarioSpec {
    /// A spec with `bench_workload`-compatible defaults.
    pub fn new(name: &str, model: &str, system: System) -> Self {
        Self {
            name: name.to_string(),
            model: model.to_string(),
            device: "OnePlus 12".to_string(),
            dataset: "alpaca".to_string(),
            system,
            cache_policy: None,
            cache_ways: None,
            collapse: None,
            cache_ratio: 0.1,
            precision: Precision::Fp16,
            prefetch: PrefetchPoint::sync(),
            calib_tokens: 256,
            eval_tokens: 64,
            sim_layers: 2,
            knn: 64,
            seed: 7,
            fixed_threshold: None,
            admission: None,
            serve: None,
            fleet: None,
            trace: false,
            decode_threads: 1,
        }
    }

    /// Build the `Workload` this scenario runs — the exact construction
    /// the historical bench binaries used, so preset sweeps reproduce
    /// their numbers bit-for-bit.
    pub fn workload(&self) -> anyhow::Result<Workload> {
        if !(0.0..=1.0).contains(&self.cache_ratio) {
            anyhow::bail!(
                "scenario `{}`: cache_ratio {} out of [0, 1]",
                self.name,
                self.cache_ratio
            );
        }
        if self.calib_tokens == 0 || self.eval_tokens == 0 {
            anyhow::bail!("scenario `{}`: token counts must be positive", self.name);
        }
        if self.prefetch.lookahead < 1 {
            anyhow::bail!("scenario `{}`: prefetch lookahead must be >= 1", self.name);
        }
        if self.decode_threads < 1 {
            anyhow::bail!("scenario `{}`: decode_threads must be >= 1", self.name);
        }
        // same bound RunConfig enforces on the JSON-config path
        if self.prefetch.budget_bytes > 64 << 20 {
            anyhow::bail!(
                "scenario `{}`: prefetch budget {} unreasonable (max 64 MiB)",
                self.name,
                self.prefetch.budget_bytes
            );
        }
        if let Some(sv) = &self.serve {
            if sv.sessions == 0 || sv.max_concurrent == 0 {
                anyhow::bail!(
                    "scenario `{}`: serve point needs sessions >= 1 and \
                     max_concurrent >= 1",
                    self.name
                );
            }
            if sv.arrival_spacing_ms.is_nan() || sv.arrival_spacing_ms < 0.0 {
                anyhow::bail!(
                    "scenario `{}`: arrival spacing must be finite and >= 0",
                    self.name
                );
            }
            if (sv.arbiter.is_some() || sv.prefetch_global_budget.is_some())
                && !self.prefetch.enabled
            {
                anyhow::bail!(
                    "scenario `{}`: arbiter knobs need a prefetch-enabled point",
                    self.name
                );
            }
            if let Some(ArbiterPolicy::DeadlineAware { target_ns }) = sv.arbiter {
                if !target_ns.is_finite() || target_ns <= 0.0 {
                    anyhow::bail!(
                        "scenario `{}`: deadline target must be finite and > 0",
                        self.name
                    );
                }
            }
        }
        if let Some(fl) = &self.fleet {
            if self.serve.is_some() {
                anyhow::bail!(
                    "scenario `{}`: fleet and serve points are mutually exclusive",
                    self.name
                );
            }
            if self.fixed_threshold.is_some() || self.admission.is_some() {
                anyhow::bail!(
                    "scenario `{}`: fleet points don't compose with the \
                     ablation custom-pipeline knobs",
                    self.name
                );
            }
            if fl.sessions == 0 || fl.max_concurrent == 0 {
                anyhow::bail!(
                    "scenario `{}`: fleet point needs sessions >= 1 and \
                     max_concurrent >= 1",
                    self.name
                );
            }
            fl.arrival.validate(&self.name)?;
            if let Some(ms) = fl.slo_ms {
                anyhow::ensure!(
                    ms.is_finite() && ms > 0.0,
                    "scenario `{}`: fleet SLO must be finite and > 0",
                    self.name
                );
            }
        }
        let model = model_by_name(&self.model)?;
        let device = device_by_name(&self.device)?;
        let dataset = DatasetProfile::by_name(&self.dataset)?;
        let mut w = Workload::new(model, device, dataset);
        w.precision = self.precision;
        w.cache_ratio = self.cache_ratio;
        w.calib_tokens = self.calib_tokens;
        w.eval_tokens = self.eval_tokens;
        w.sim_layers = self.sim_layers.clamp(1, w.model.n_layers);
        w.knn = self.knn.max(1);
        w.seed = self.seed;
        w.prefetch.enabled = self.prefetch.enabled;
        w.prefetch.budget_bytes = self.prefetch.budget_bytes;
        w.prefetch.lookahead = self.prefetch.lookahead;
        Ok(w)
    }

    /// Resolve the `SystemSpec` this scenario executes: the named
    /// system's preset with the collapse / cache-policy / ways
    /// overrides applied.
    pub fn system_spec(&self, ffn_linears: usize) -> anyhow::Result<SystemSpec> {
        let mut spec = SystemSpec::of(self.system, ffn_linears);
        if let Some(c) = self.collapse {
            spec.collapse = c;
        }
        if let Some(p) = &self.cache_policy {
            // `policy_name` canonicalizes to the `'static` string
            // `SystemSpec` carries and is where the name set lives —
            // the harness accepts exactly what `from_config` builds.
            spec.cache_policy = crate::cache::policy_name(p)?;
        }
        if let Some(ways) = self.cache_ways {
            anyhow::ensure!(
                ways >= 1,
                "scenario `{}`: cache_ways must be >= 1",
                self.name
            );
            spec.cache_params.ways = ways;
        }
        Ok(spec)
    }
}

/// Derive a per-scenario seed from a base seed and the scenario name
/// (an FNV-style xor-multiply fold over the name bytes, folded into
/// the base — same mixer family as `Workload::model_seed`). Pure
/// function of its inputs; the constants are load-bearing for baseline
/// comparability and must never change.
pub fn derive_seed(base: u64, name: &str) -> u64 {
    name.bytes().fold(base ^ 0xcbf2_9ce4_8422_2325, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
    })
}

/// A declarative sweep: one value list per axis, expanded to the
/// cartesian product plus any hand-written `extra` scenarios.
#[derive(Clone, Debug)]
pub struct ScenarioMatrix {
    /// Sweep name — becomes `BENCH_<name>.json` / `.md`.
    pub name: String,
    /// Model axis.
    pub models: Vec<String>,
    /// Device axis.
    pub devices: Vec<String>,
    /// Dataset axis.
    pub datasets: Vec<String>,
    /// System (placement strategy) axis.
    pub systems: Vec<System>,
    /// DRAM cache ratio axis.
    pub cache_ratios: Vec<f64>,
    /// Cache-policy override axis (`None` = system default).
    pub cache_policies: Vec<Option<String>>,
    /// Access-collapse override axis (`None` = system default).
    pub collapse: Vec<Option<bool>>,
    /// Prefetch axis.
    pub prefetch: Vec<PrefetchPoint>,
    /// Serving axis (`None` = single-stream; names stay unchanged for
    /// `None`, so pre-serve baselines keep matching).
    pub serve: Vec<Option<ServePoint>>,
    /// Fleet axis (`None` = no fleet run; names stay unchanged for
    /// `None`, so pre-fleet baselines keep matching).
    pub fleet: Vec<Option<FleetPoint>>,
    /// Plan-phase decode-thread axis (innermost). Rows at 1 keep their
    /// historical names; other counts get a `/dt<n>` suffix. Results
    /// are decode-thread-count invariant, so sweeping this axis only
    /// changes wall-clock gauges, never the report JSON payload.
    pub decode_threads: Vec<usize>,
    /// Calibration tokens applied to every product scenario.
    pub calib_tokens: usize,
    /// Eval tokens applied to every product scenario.
    pub eval_tokens: usize,
    /// Representative layers applied to every product scenario.
    pub sim_layers: usize,
    /// kNN width applied to every product scenario.
    pub knn: usize,
    /// Precision applied to every product scenario.
    pub precision: Precision,
    /// Base workload seed (7 matches the historical benches).
    pub base_seed: u64,
    /// When true, each product scenario gets `derive_seed(base, name)`
    /// instead of the shared base seed.
    pub derive_seeds: bool,
    /// Hand-written scenarios appended verbatim after the product
    /// (non-product ablation rows).
    pub extra: Vec<ScenarioSpec>,
}

impl ScenarioMatrix {
    /// A single-point matrix (every axis a singleton) with
    /// `bench_workload`-compatible defaults.
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            models: vec!["OPT-350M".to_string()],
            devices: vec!["OnePlus 12".to_string()],
            datasets: vec!["alpaca".to_string()],
            systems: vec![System::Ripple],
            cache_ratios: vec![0.1],
            cache_policies: vec![None],
            collapse: vec![None],
            prefetch: vec![PrefetchPoint::sync()],
            serve: vec![None],
            fleet: vec![None],
            decode_threads: vec![1],
            calib_tokens: 256,
            eval_tokens: 64,
            sim_layers: 2,
            knn: 64,
            precision: Precision::Fp16,
            base_seed: 7,
            derive_seeds: false,
            extra: Vec::new(),
        }
    }

    /// Shrink the scale knobs of the matrix *and* of every `extra`
    /// scenario — used by the smoke preset and the determinism tests.
    pub fn scale_down(&mut self, calib: usize, eval: usize, sim_layers: usize, knn: usize) {
        self.calib_tokens = calib;
        self.eval_tokens = eval;
        self.sim_layers = sim_layers;
        self.knn = knn;
        for s in &mut self.extra {
            s.calib_tokens = calib;
            s.eval_tokens = eval;
            s.sim_layers = sim_layers;
            s.knn = knn;
        }
    }

    /// Expand to the full scenario list: the cartesian product in fixed
    /// axis order, then the `extra` scenarios. Deterministic — depends
    /// only on the matrix value, never on threads or timing.
    pub fn expand(&self) -> Vec<ScenarioSpec> {
        let mut out = Vec::new();
        for model in &self.models {
            for device in &self.devices {
                for dataset in &self.datasets {
                    for &system in &self.systems {
                        for policy in &self.cache_policies {
                            for &collapse in &self.collapse {
                                for &ratio in &self.cache_ratios {
                                    for &pf in &self.prefetch {
                                        for &sv in &self.serve {
                                            for &fl in &self.fleet {
                                                for &dt in &self.decode_threads {
                                                    let point = self.point(
                                                        model,
                                                        device,
                                                        dataset,
                                                        system,
                                                        policy,
                                                        collapse,
                                                        ratio,
                                                        pf,
                                                        sv,
                                                        fl,
                                                        dt,
                                                    );
                                                    out.push(point);
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out.extend(self.extra.iter().cloned());
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn point(
        &self,
        model: &str,
        device: &str,
        dataset: &str,
        system: System,
        policy: &Option<String>,
        collapse: Option<bool>,
        ratio: f64,
        pf: PrefetchPoint,
        sv: Option<ServePoint>,
        fl: Option<FleetPoint>,
        dt: usize,
    ) -> ScenarioSpec {
        let pol = policy.as_deref().unwrap_or("default");
        let col = match collapse {
            None => "collapse-default",
            Some(true) => "collapse-on",
            Some(false) => "collapse-off",
        };
        let mut name = format!(
            "{model}/{device}/{dataset}/{}/c{ratio:.2}/{pol}/{col}/{}",
            system.key(),
            pf.label()
        );
        if let Some(sv) = &sv {
            // single-stream names are unchanged, so old baselines match
            name.push('/');
            name.push_str(&sv.label());
        }
        if let Some(fl) = &fl {
            name.push('/');
            name.push_str(&fl.label());
        }
        if dt != 1 {
            // dt=1 rows keep their historical names, so every pre-pool
            // baseline (and the CI byte-cmp against dt>1 runs) matches
            name.push_str(&format!("/dt{dt}"));
        }
        let mut s = ScenarioSpec::new(&name, model, system);
        s.device = device.to_string();
        s.dataset = dataset.to_string();
        s.cache_policy = policy.clone();
        s.collapse = collapse;
        s.cache_ratio = ratio;
        s.prefetch = pf;
        s.serve = sv;
        s.fleet = fl;
        s.calib_tokens = self.calib_tokens;
        s.eval_tokens = self.eval_tokens;
        s.sim_layers = self.sim_layers;
        s.knn = self.knn;
        s.precision = self.precision;
        s.decode_threads = dt;
        s.seed = if self.derive_seeds {
            derive_seed(self.base_seed, &name)
        } else {
            self.base_seed
        };
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_is_full_product_in_stable_order() {
        let mut m = ScenarioMatrix::new("t");
        m.models = vec!["OPT-350M".into(), "OPT-1.3B".into()];
        m.systems = vec![System::LlmFlash, System::Ripple];
        m.cache_ratios = vec![0.05, 0.1];
        m.prefetch = vec![PrefetchPoint::sync(), PrefetchPoint::budget_kb(64)];
        let specs = m.expand();
        assert_eq!(specs.len(), 2 * 2 * 2 * 2);
        // model is the outermost axis, prefetch the innermost
        assert!(specs[0].name.contains("OPT-350M"));
        assert!(specs[0].name.ends_with("sync"));
        assert!(specs[1].name.ends_with("pf64KB-la1"));
        assert!(specs.last().unwrap().name.contains("OPT-1.3B"));
        // expansion is a pure function of the matrix
        let again = m.expand();
        assert_eq!(specs, again);
        // names are unique
        let mut names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), specs.len());
    }

    #[test]
    fn decode_thread_axis_expands_with_stable_labels() {
        let mut m = ScenarioMatrix::new("t");
        m.serve = vec![Some(ServePoint::shared(4))];
        m.decode_threads = vec![1, 8];
        let specs = m.expand();
        assert_eq!(specs.len(), 2);
        // dt=1 keeps the historical name so old baselines keep matching
        assert!(!specs[0].name.contains("/dt"));
        assert_eq!(specs[0].decode_threads, 1);
        // dt>1 rows get a suffix and are otherwise the same point
        assert!(specs[1].name.ends_with("/dt8"));
        assert_eq!(specs[1].decode_threads, 8);
        assert_eq!(
            specs[1].name.strip_suffix("/dt8").unwrap(),
            specs[0].name.as_str()
        );
        // both rows pass workload validation; dt=0 is rejected
        specs[0].workload().unwrap();
        specs[1].workload().unwrap();
        let mut bad = specs[0].clone();
        bad.decode_threads = 0;
        assert!(bad.workload().is_err());
    }

    #[test]
    fn extras_are_appended_and_scaled() {
        let mut m = ScenarioMatrix::new("t");
        m.extra.push(ScenarioSpec::new("custom", "opt-micro", System::Ripple));
        m.scale_down(32, 8, 1, 4);
        let specs = m.expand();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[1].name, "custom");
        assert_eq!(specs[1].calib_tokens, 32);
        assert_eq!(specs[1].knn, 4);
        assert_eq!(specs[0].eval_tokens, 8);
    }

    #[test]
    fn derived_seeds_are_stable_and_distinct() {
        let a = derive_seed(7, "scenario-a");
        assert_eq!(a, derive_seed(7, "scenario-a"));
        assert_ne!(a, derive_seed(7, "scenario-b"));
        assert_ne!(a, derive_seed(8, "scenario-a"));

        let mut m = ScenarioMatrix::new("t");
        m.derive_seeds = true;
        m.cache_ratios = vec![0.05, 0.1];
        let specs = m.expand();
        assert_ne!(specs[0].seed, specs[1].seed);
        assert_eq!(specs[0].seed, derive_seed(7, &specs[0].name));
    }

    #[test]
    fn workload_mirrors_bench_construction() {
        let mut spec = ScenarioSpec::new("x", "OPT-350M", System::Ripple);
        spec.cache_ratio = 0.2;
        spec.prefetch = PrefetchPoint::budget_kb(64);
        let w = spec.workload().unwrap();
        assert_eq!(w.model.name, "OPT-350M");
        assert_eq!(w.device.name, "OnePlus 12");
        assert_eq!(w.sim_layers, 2);
        assert_eq!(w.calib_tokens, 256);
        assert_eq!(w.eval_tokens, 64);
        assert_eq!(w.knn, 64);
        assert_eq!(w.seed, 7);
        assert!((w.cache_ratio - 0.2).abs() < 1e-12);
        assert!(w.prefetch.enabled);
        assert_eq!(w.prefetch.budget_bytes, 64 * 1024);
    }

    #[test]
    fn workload_rejects_bad_knobs() {
        let mut spec = ScenarioSpec::new("x", "OPT-350M", System::Ripple);
        spec.cache_ratio = 3.0;
        assert!(spec.workload().is_err());
        let mut spec = ScenarioSpec::new("x", "nope", System::Ripple);
        spec.cache_ratio = 0.1;
        assert!(spec.workload().is_err());
        let mut spec = ScenarioSpec::new("x", "OPT-350M", System::Ripple);
        spec.eval_tokens = 0;
        assert!(spec.workload().is_err());
        let mut spec = ScenarioSpec::new("x", "OPT-350M", System::Ripple);
        spec.prefetch = PrefetchPoint { enabled: true, budget_bytes: 65 << 20, lookahead: 1 };
        assert!(spec.workload().is_err());
    }

    #[test]
    fn serve_axis_expands_with_stable_labels() {
        let mut m = ScenarioMatrix::new("t");
        m.serve = vec![None, Some(ServePoint::shared(4)), Some(ServePoint::private(4))];
        let specs = m.expand();
        assert_eq!(specs.len(), 3);
        // single-stream names are unchanged by the new axis
        assert!(specs[0].name.ends_with("sync"), "{}", specs[0].name);
        assert!(specs[0].serve.is_none());
        assert!(specs[1].name.ends_with("s4c4-a0ms-shared"), "{}", specs[1].name);
        assert!(specs[2].name.ends_with("s4c4-a0ms-priv"), "{}", specs[2].name);
        assert_eq!(specs[1].serve.unwrap().sessions, 4);
        assert!(!specs[2].serve.unwrap().shared_cache);
        // shared/private partners share the pairing key
        assert_eq!(ServePoint::shared(4).pair_key(), ServePoint::private(4).pair_key());
        assert_ne!(ServePoint::shared(2).pair_key(), ServePoint::shared(4).pair_key());
    }

    #[test]
    fn workload_rejects_bad_serve_points() {
        let mut spec = ScenarioSpec::new("x", "OPT-350M", System::Ripple);
        spec.serve = Some(ServePoint { sessions: 0, ..ServePoint::shared(1) });
        assert!(spec.workload().is_err());
        spec.serve = Some(ServePoint { max_concurrent: 0, ..ServePoint::shared(2) });
        assert!(spec.workload().is_err());
        spec.serve = Some(ServePoint { arrival_spacing_ms: -1.0, ..ServePoint::shared(2) });
        assert!(spec.workload().is_err());
        spec.serve = Some(ServePoint::shared(2));
        assert!(spec.workload().is_ok());
        // arbiter knobs require a prefetch-enabled point
        spec.serve = Some(ServePoint::shared(2).with_arbiter(ArbiterPolicy::FairShare));
        assert!(spec.workload().is_err());
        spec.serve = Some(ServePoint::shared(2).with_global_budget(64 * 1024));
        assert!(spec.workload().is_err());
        spec.prefetch = PrefetchPoint::budget_kb(64);
        assert!(spec.workload().is_ok());
        // deadline target must be positive and finite
        spec.serve = Some(
            ServePoint::shared(2)
                .with_arbiter(ArbiterPolicy::DeadlineAware { target_ns: 0.0 }),
        );
        assert!(spec.workload().is_err());
        spec.serve = Some(
            ServePoint::shared(2)
                .with_arbiter(ArbiterPolicy::DeadlineAware { target_ns: 1e6 }),
        );
        assert!(spec.workload().is_ok());
    }

    #[test]
    fn arbiter_points_extend_labels_without_touching_defaults() {
        // default points keep the historical label and pair key
        assert_eq!(ServePoint::shared(4).label(), "s4c4-a0ms-shared");
        assert_eq!(ServePoint::shared(4).pair_key(), "s4c4-a0ms");
        let fair = ServePoint::shared(4)
            .with_arbiter(ArbiterPolicy::FairShare)
            .with_global_budget(128 * 1024);
        assert_eq!(fair.label(), "s4c4-a0ms-shared-fair-g128KB");
        let dl = ServePoint::private(2)
            .with_arbiter(ArbiterPolicy::DeadlineAware { target_ns: 2e6 });
        assert_eq!(dl.label(), "s2c4-a0ms-priv-dl2ms");
        // shared/private partners still pair across the arbiter axis
        assert_eq!(
            fair.pair_key(),
            ServePoint::private(4)
                .with_arbiter(ArbiterPolicy::FairShare)
                .with_global_budget(128 * 1024)
                .pair_key()
        );
        assert_ne!(fair.pair_key(), ServePoint::shared(4).pair_key());
    }

    #[test]
    fn fleet_axis_expands_with_stable_labels() {
        let mut m = ScenarioMatrix::new("t");
        m.fleet = vec![
            None,
            Some(FleetPoint::fixed(8, 0.0)),
            Some(
                FleetPoint::poisson(64, 200.0)
                    .with_scheduler(FleetScheduler::ShortestRemaining)
                    .with_bound(16)
                    .with_slo_ms(40.0),
            ),
        ];
        let specs = m.expand();
        assert_eq!(specs.len(), 3);
        // non-fleet names are unchanged by the new axis
        assert!(specs[0].name.ends_with("sync"), "{}", specs[0].name);
        assert!(specs[0].fleet.is_none());
        assert!(specs[1].name.ends_with("f8c4-fx0ms-fifo"), "{}", specs[1].name);
        assert!(
            specs[2].name.ends_with("f64c4-po200-srt-q16-slo40ms"),
            "{}",
            specs[2].name
        );
        assert_eq!(specs[2].fleet.unwrap().sessions, 64);
        // rows differing only in arrival share the ramp key
        assert_eq!(
            FleetPoint::poisson(8, 100.0).ramp_key(),
            FleetPoint::poisson(8, 400.0).ramp_key()
        );
        assert_ne!(
            FleetPoint::poisson(8, 100.0).ramp_key(),
            FleetPoint::poisson(8, 100.0).with_bound(4).ramp_key()
        );
    }

    #[test]
    fn workload_rejects_bad_fleet_points() {
        let mut spec = ScenarioSpec::new("x", "OPT-350M", System::Ripple);
        spec.fleet = Some(FleetPoint { sessions: 0, ..FleetPoint::fixed(1, 0.0) });
        assert!(spec.workload().is_err());
        spec.fleet = Some(FleetPoint::fixed(2, -1.0));
        assert!(spec.workload().is_err());
        spec.fleet = Some(FleetPoint::poisson(2, 0.0));
        assert!(spec.workload().is_err());
        spec.fleet = Some(FleetPoint {
            arrival: ArrivalSpec::Bursty { per_s: 100.0, burst: 0 },
            ..FleetPoint::fixed(2, 0.0)
        });
        assert!(spec.workload().is_err());
        spec.fleet = Some(FleetPoint {
            arrival: ArrivalSpec::Diurnal { per_s: 100.0, period_s: 1.0, depth: 2.0 },
            ..FleetPoint::fixed(2, 0.0)
        });
        assert!(spec.workload().is_err());
        spec.fleet = Some(FleetPoint::poisson(2, 100.0).with_slo_ms(0.0));
        assert!(spec.workload().is_err());
        spec.fleet = Some(FleetPoint::poisson(2, 100.0).with_slo_ms(25.0));
        assert!(spec.workload().is_ok());
        // fleet and serve are mutually exclusive
        spec.serve = Some(ServePoint::shared(2));
        assert!(spec.workload().is_err());
        spec.serve = None;
        // and the ablation custom-pipeline knobs don't compose
        spec.fixed_threshold = Some(4);
        assert!(spec.workload().is_err());
    }

    #[test]
    fn system_spec_overrides() {
        let mut spec = ScenarioSpec::new("x", "OPT-350M", System::Ripple);
        spec.collapse = Some(false);
        spec.cache_policy = Some("s3fifo".to_string());
        let s = spec.system_spec(2).unwrap();
        assert!(!s.collapse);
        assert_eq!(s.cache_policy, "s3fifo");
        assert!(s.ripple_placement);
        spec.cache_policy = Some("bogus".to_string());
        assert!(spec.system_spec(2).is_err());
    }

    #[test]
    fn system_spec_accepts_cachelab_policies_and_ways() {
        let mut spec = ScenarioSpec::new("x", "OPT-350M", System::Ripple);
        for p in ["victim", "setassoc", "costaware"] {
            spec.cache_policy = Some(p.to_string());
            assert_eq!(spec.system_spec(2).unwrap().cache_policy, p);
        }
        // default params reproduce the pre-cachelab spec exactly
        assert_eq!(
            spec.system_spec(2).unwrap().cache_params,
            crate::cache::CacheParams::default()
        );
        spec.cache_ways = Some(8);
        assert_eq!(spec.system_spec(2).unwrap().cache_params.ways, 8);
        spec.cache_ways = Some(0);
        assert!(spec.system_spec(2).is_err());
    }
}
