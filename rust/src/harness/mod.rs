//! Scenario-matrix experiment harness (DESIGN.md §Scenario-harness).
//!
//! The paper's claims rest on sweeps across devices, models, cache
//! policies and placement strategies; this module makes those sweeps a
//! first-class, reproducible artifact instead of ad-hoc bench binaries:
//!
//! * [`scenario`] — [`ScenarioSpec`] (one experiment point) and
//!   [`ScenarioMatrix`] (axes + cartesian-product expansion),
//! * [`presets`] — named matrices reproducing the paper figures
//!   (`smoke`, `fig01`, `fig10`, `fig18`, `ablations`) plus the
//!   multi-session `serve` contention sweep, the open-loop `fleet`
//!   sweep (arrival process × scheduler × admission bound) and the
//!   `perf` decode-throughput proof (wall-clock tokens/sec,
//!   Markdown-only),
//! * [`runner`] — the multi-threaded sweep executor (results are
//!   thread-count invariant),
//! * [`report`] — stable-schema `BENCH_<name>.json` plus Markdown with
//!   baseline deltas.
//!
//! Driven from the CLI: `ripple bench --preset fig18 --baseline
//! BENCH_prev.json --out report/`. The determinism contract: given the
//! same matrix, the JSON bytes are identical run-to-run and across
//! `--threads` values, so two reports can be diffed (or delta'd via
//! `--baseline`) to see exactly what a PR changed.

#![warn(missing_docs)]

pub mod presets;
pub mod report;
pub mod runner;
pub mod scenario;

pub use presets::{preset, preset_names};
pub use report::{delta_pct, Baseline, BaselineMetrics, ScenarioResult, SweepReport};
pub use report::{fmt_delta, SCHEMA_VERSION};
pub use runner::{
    default_threads, run_matrix, run_matrix_with, run_scenario, split_thread_budget,
};
pub use scenario::{
    derive_seed, ArrivalSpec, FleetPoint, PrefetchPoint, ScenarioMatrix, ScenarioSpec,
    ServePoint,
};
