//! Named scenario presets for the paper figures.
//!
//! Each preset is a [`ScenarioMatrix`] whose expansion reproduces one
//! of the historical bench binaries (same workload construction, same
//! seed 7, same sweep order), so `ripple bench --preset fig18`
//! reports the same numbers as `cargo bench --bench fig18_overlap`
//! did. `smoke` is a minutes-free CI-sized sweep over the fig10 axes.

use crate::bench::workloads::System;
use crate::cache::Admission;
use crate::coordinator::{ArbiterPolicy, FleetScheduler};

use super::scenario::{
    ArrivalSpec, FleetPoint, PrefetchPoint, ScenarioMatrix, ScenarioSpec, ServePoint,
};

/// Every preset name `preset` accepts.
pub fn preset_names() -> &'static [&'static str] {
    &[
        "smoke",
        "fig01",
        "fig10",
        "fig18",
        "ablations",
        "cachelab",
        "serve",
        "serve-prefetch",
        "fleet",
        "perf",
        "trace",
    ]
}

/// Resolve a preset name to its matrix.
pub fn preset(name: &str) -> anyhow::Result<ScenarioMatrix> {
    Ok(match name {
        "smoke" => smoke(),
        "fig01" => fig01(),
        "fig10" => fig10(),
        "fig18" => fig18(),
        "ablations" => ablations(),
        "cachelab" => cachelab(),
        "serve" => serve(),
        "serve-prefetch" => serve_prefetch(),
        "fleet" => fleet(),
        "perf" => perf(),
        "trace" => trace(),
        _ => anyhow::bail!(
            "unknown preset `{name}` (available: {})",
            preset_names().join("|")
        ),
    })
}

fn all_models() -> Vec<String> {
    ["OPT-350M", "OPT-1.3B", "OPT-6.7B", "Llama2-7B", "Mistral-7B"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

fn all_datasets() -> Vec<String> {
    ["alpaca", "openwebtext", "wikitext"].iter().map(|s| s.to_string()).collect()
}

/// CI-sized sweep over the fig10 axes (one model, all systems) plus one
/// overlapped-prefetch point; runs in seconds.
fn smoke() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("smoke");
    m.systems = vec![System::LlamaCpp, System::LlmFlash, System::Ripple];
    let mut pf = ScenarioSpec::new("smoke-prefetch", "OPT-350M", System::Ripple);
    pf.prefetch = PrefetchPoint::budget_kb(64);
    m.extra.push(pf);
    // 2 sim layers so the prefetch point has a next layer to speculate on
    m.scale_down(96, 24, 2, 16);
    m
}

/// Figure 1: bandwidth utilization, LLMFlash baseline vs RIPPLE, all
/// models (OnePlus 12, alpaca).
fn fig01() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("fig01");
    m.models = all_models();
    m.systems = vec![System::LlmFlash, System::Ripple];
    m
}

/// Figure 10: overall latency + effective bandwidth, all models x all
/// datasets x three systems (OnePlus 12, cache 0.1).
fn fig10() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("fig10");
    m.models = all_models();
    m.datasets = all_datasets();
    m.systems = vec![System::LlamaCpp, System::LlmFlash, System::Ripple];
    m
}

/// Figure 18 (repo extension): the overlapped pipeline — prefetch
/// budget x cache ratio on RIPPLE (part a), plus the collapse x
/// prefetch toggle rows (part b) as extras.
fn fig18() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("fig18");
    m.models = vec!["OPT-350M".to_string(), "OPT-1.3B".to_string()];
    m.cache_ratios = vec![0.05, 0.1, 0.2];
    m.prefetch = vec![
        PrefetchPoint::sync(),
        PrefetchPoint::budget_kb(64),
        PrefetchPoint::budget_kb(256),
        PrefetchPoint::budget_kb(1024),
    ];
    for collapse in [false, true] {
        for prefetch in [false, true] {
            let name = format!(
                "collapse-{}/prefetch-{}",
                if collapse { "on" } else { "off" },
                if prefetch { "on" } else { "off" }
            );
            let mut s = ScenarioSpec::new(&name, "OPT-350M", System::Ripple);
            s.collapse = Some(collapse);
            s.cache_policy = Some(if collapse { "linking" } else { "s3fifo" }.to_string());
            if prefetch {
                s.prefetch = PrefetchPoint::budget_kb(256);
            }
            m.extra.push(s);
        }
    }
    m
}

/// Multi-session serving sweep (DESIGN.md §Serving): sessions ×
/// arrival spacing × shared-vs-private cache on RIPPLE (OPT-350M,
/// OnePlus 12, alpaca — the hot-overlap workload: statistically
/// identical users whose hot sets coincide). The leading
/// `s1c4-a0ms-shared` row is the continuity anchor — with one session
/// and a shared cache the serving loop reduces bit-for-bit to the
/// single-stream fig10 experiment (pinned by
/// `rust/tests/harness_golden.rs`).
fn serve() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("serve");
    m.systems = vec![System::Ripple];
    let mut points = vec![Some(ServePoint::shared(1))];
    for sessions in [2usize, 4, 8] {
        for spacing_ms in [0.0, 25.0] {
            for shared in [true, false] {
                let base = if shared {
                    ServePoint::shared(sessions)
                } else {
                    ServePoint::private(sessions)
                };
                points.push(Some(ServePoint { arrival_spacing_ms: spacing_ms, ..base }));
            }
        }
    }
    m.serve = points;
    m
}

/// Multi-session speculative prefetch under contention: {sync,
/// 256 KiB prefetch} × session count on shared-cache RIPPLE points,
/// plus hand-written arbiter-policy × global-budget rows at the
/// 4-session maximum-contention point. The sync rows are the
/// prefetch-off contention baselines the report deltas anchor on; the
/// `s1` prefetch row is the continuity anchor that reduces bit-for-bit
/// to the single-stream overlapped experiment (pinned by
/// `rust/tests/harness_golden.rs`).
fn serve_prefetch() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("serve-prefetch");
    m.systems = vec![System::Ripple];
    m.prefetch = vec![PrefetchPoint::sync(), PrefetchPoint::budget_kb(256)];
    m.serve = vec![
        Some(ServePoint::shared(1)),
        Some(ServePoint::shared(2)),
        Some(ServePoint::shared(4)),
        Some(ServePoint::shared(8)),
    ];
    // product rows stay on the fair-share default (arbiter knobs are
    // rejected on the sync rows); policy and budget variants are
    // hand-written on the contended 4-session point
    for (label, point) in [
        (
            "s4-deadline",
            ServePoint::shared(4)
                .with_arbiter(ArbiterPolicy::DeadlineAware { target_ns: 2e6 }),
        ),
        (
            "s4-fair-g128",
            ServePoint::shared(4)
                .with_arbiter(ArbiterPolicy::FairShare)
                .with_global_budget(128 * 1024),
        ),
        (
            "s4-deadline-g128",
            ServePoint::shared(4)
                .with_arbiter(ArbiterPolicy::DeadlineAware { target_ns: 2e6 })
                .with_global_budget(128 * 1024),
        ),
    ] {
        let mut s = ScenarioSpec::new(label, "OPT-350M", System::Ripple);
        s.prefetch = PrefetchPoint::budget_kb(256);
        s.serve = Some(point);
        m.extra.push(s);
    }
    m
}

/// Fleet-scale open-loop serving sweep (DESIGN.md §Fleet) on the
/// AOT-served opt-micro model, synchronous timeline: a fixed-spacing
/// FIFO anchor (the degenerate configuration `harness_golden` pins
/// bit-for-bit to the round-based serve path), a Poisson load ramp
/// under both schedulers with a 40 ms SLO, bursty and diurnal traffic
/// shapes, a bounded-admission overload point, and one 10k-session
/// stress point behind an admission bound.
fn fleet() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("fleet");
    m.models = vec!["opt-micro".to_string()];
    m.systems = vec![System::Ripple];
    // short per-session streams keep the 10k-session point tractable
    m.scale_down(96, 4, 2, 16);
    let mut points = vec![Some(FleetPoint::fixed(8, 0.0))];
    for sched in [FleetScheduler::Fifo, FleetScheduler::ShortestRemaining] {
        for per_s in [200.0, 1000.0, 4000.0] {
            points.push(Some(
                FleetPoint::poisson(64, per_s).with_scheduler(sched).with_slo_ms(40.0),
            ));
        }
    }
    points.push(Some(
        FleetPoint {
            arrival: ArrivalSpec::Bursty { per_s: 1000.0, burst: 8 },
            ..FleetPoint::fixed(64, 0.0)
        }
        .with_slo_ms(40.0),
    ));
    points.push(Some(
        FleetPoint {
            arrival: ArrivalSpec::Diurnal { per_s: 1000.0, period_s: 0.05, depth: 0.8 },
            ..FleetPoint::fixed(64, 0.0)
        }
        .with_slo_ms(40.0),
    ));
    points.push(Some(FleetPoint::poisson(64, 4000.0).with_bound(16).with_slo_ms(40.0)));
    m.fleet = points;
    // the 10k-session stress point rides as a hand-written extra with a
    // 2-token stream so the whole preset stays CI-sized
    let mut s = ScenarioSpec::new("stress", "opt-micro", System::Ripple);
    s.calib_tokens = 96;
    s.eval_tokens = 2;
    s.sim_layers = 2;
    s.knn = 16;
    s.fleet =
        Some(FleetPoint::poisson(10_000, 20_000.0).with_bound(2_048).with_slo_ms(40.0));
    m.extra.push(s);
    m
}

/// Decode-throughput proof preset (§Perf, DESIGN.md): long eval
/// streams over the fig10 point so the simulator's own speed is
/// measurable — the three systems' synchronous decode loops, one
/// overlapped-prefetch point, and one shared-cache serving point. The
/// simulated metrics in `BENCH_perf.json` stay deterministic and
/// byte-diffable; wall-clock simulated-tokens/sec appears ONLY in the
/// Markdown report's "Decode throughput" section.
///
/// The `perf-fleet-dt{1,8}` pair is the parallel-decode speedup gauge
/// (DESIGN.md §Parallel-decode): the fleet preset's 10k-session point
/// widened to 32 concurrent overlapped-prefetch sessions, identical in
/// every knob except the decode-thread count. Both rows report
/// identical JSON (results are pool-invariant); the wall-clock
/// tokens/sec ratio between them in the Markdown section is the
/// speedup claim.
fn perf() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("perf");
    m.systems = vec![System::LlamaCpp, System::LlmFlash, System::Ripple];
    m.eval_tokens = 512;
    let mut pf = ScenarioSpec::new("perf-prefetch", "OPT-350M", System::Ripple);
    pf.eval_tokens = 512;
    pf.prefetch = PrefetchPoint::budget_kb(256);
    m.extra.push(pf);
    let mut sv = ScenarioSpec::new("perf-serve", "OPT-350M", System::Ripple);
    sv.eval_tokens = 128;
    sv.serve = Some(ServePoint::shared(4));
    m.extra.push(sv);
    for dt in [1usize, 8] {
        let mut s =
            ScenarioSpec::new(&format!("perf-fleet-dt{dt}"), "opt-micro", System::Ripple);
        s.calib_tokens = 96;
        s.eval_tokens = 2;
        s.sim_layers = 2;
        s.knn = 16;
        s.prefetch = PrefetchPoint::budget_kb(256);
        s.fleet = Some(FleetPoint {
            max_concurrent: 32,
            ..FleetPoint::poisson(10_000, 20_000.0).with_bound(2_048).with_slo_ms(40.0)
        });
        s.decode_threads = dt;
        m.extra.push(s);
    }
    m
}

/// Flight-recorder demonstration preset (DESIGN.md §Observability):
/// one traced scenario per decode path — synchronous single-stream,
/// overlapped prefetch, arbitrated shared-cache serving, and open-loop
/// fleet — all CI-sized. Every row sets `trace`, so the report carries
/// the gated `attribution` objects and the Markdown attribution tables.
fn trace() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("trace");
    m.models.clear(); // every row is hand-written below
    let small = |name: &str| {
        let mut s = ScenarioSpec::new(name, "OPT-350M", System::Ripple);
        s.calib_tokens = 96;
        s.eval_tokens = 24;
        s.sim_layers = 2;
        s.knn = 16;
        s.trace = true;
        s
    };
    m.extra.push(small("trace-single"));
    let mut pf = small("trace-prefetch");
    pf.prefetch = PrefetchPoint::budget_kb(64);
    m.extra.push(pf);
    let mut sv = small("trace-serve");
    sv.prefetch = PrefetchPoint::budget_kb(64);
    sv.serve = Some(ServePoint::shared(4).with_arbiter(ArbiterPolicy::FairShare));
    m.extra.push(sv);
    let mut fl = small("trace-fleet");
    fl.fleet = Some(FleetPoint::poisson(8, 1000.0).with_slo_ms(40.0));
    m.extra.push(fl);
    m
}

/// Design-choice ablations (DESIGN.md §Experiment-index): kNN width,
/// fixed vs adaptive collapse threshold, linking admission segment_p,
/// calibration budget — all on OPT-1.3B, synchronous timeline.
fn ablations() -> ScenarioMatrix {
    let linking = Admission::Linking { segment_min: 4, segment_p: 0.25 };
    let mut m = ScenarioMatrix::new("ablations");
    m.models.clear(); // every row is hand-written below
    for knn in [4usize, 8, 16, 32, 64] {
        let mut s = ScenarioSpec::new(&format!("knn{knn:02}"), "OPT-1.3B", System::Ripple);
        s.knn = knn;
        s.admission = Some(linking);
        m.extra.push(s);
    }
    let thresholds: [(&str, Option<u32>, bool); 7] = [
        ("off", Some(0), false),
        ("t01", Some(1), true),
        ("t02", Some(2), true),
        ("t04", Some(4), true),
        ("t08", Some(8), true),
        ("t16", Some(16), true),
        ("adaptive", None, true),
    ];
    for (label, fixed, collapse) in thresholds {
        let name = format!("threshold-{label}");
        let mut s = ScenarioSpec::new(&name, "OPT-1.3B", System::Ripple);
        s.knn = 32;
        s.admission = Some(linking);
        s.collapse = Some(collapse);
        s.fixed_threshold = fixed;
        m.extra.push(s);
    }
    for p in [0.0, 0.25, 0.5, 1.0] {
        let mut s = ScenarioSpec::new(&format!("segp{p:.2}"), "OPT-1.3B", System::Ripple);
        s.knn = 32;
        s.admission = Some(Admission::Linking { segment_min: 4, segment_p: p });
        m.extra.push(s);
    }
    let mut s = ScenarioSpec::new("admit-all", "OPT-1.3B", System::Ripple);
    s.knn = 32;
    s.admission = Some(Admission::All);
    m.extra.push(s);
    for calib in [32usize, 64, 128, 256, 512] {
        let name = format!("calib{calib:03}");
        let mut s = ScenarioSpec::new(&name, "OPT-1.3B", System::Ripple);
        s.knn = 32;
        s.calib_tokens = calib;
        s.admission = Some(linking);
        m.extra.push(s);
    }
    m
}

/// Cache-architecture lab (DESIGN.md §Cache-lab): the four eviction
/// policies at equal DRAM — policy x capacity x device on RIPPLE over
/// the fig14 cache-ratio axes, synchronous timeline. Extras add a
/// set-associativity sweep (the only rows carrying the gated
/// `cache_ways` JSON key) and the Llama2-7B headline pair whose
/// cost-aware-vs-LRU e2e delta `rust/tests/harness_golden.rs` pins.
fn cachelab() -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new("cachelab");
    m.models = vec!["OPT-1.3B".to_string()];
    m.devices = vec!["OnePlus 12".to_string(), "OnePlus Ace 2".to_string()];
    m.systems = vec![System::Ripple];
    m.cache_policies = vec![
        Some("lru".to_string()),
        Some("victim".to_string()),
        Some("setassoc".to_string()),
        Some("costaware".to_string()),
    ];
    // fig14's cache-ratio axis; 0.00 is the no-DRAM sanity anchor where
    // every policy must coincide
    m.cache_ratios = vec![0.0, 0.05, 0.1, 0.2, 0.3, 0.4];
    for ways in [1usize, 8, 16] {
        let mut s =
            ScenarioSpec::new(&format!("ways{ways:02}"), "OPT-1.3B", System::Ripple);
        s.cache_policy = Some("setassoc".to_string());
        s.cache_ways = Some(ways);
        m.extra.push(s);
    }
    for pol in ["lru", "costaware"] {
        let mut s =
            ScenarioSpec::new(&format!("headline-{pol}"), "Llama2-7B", System::Ripple);
        s.cache_policy = Some(pol.to_string());
        m.extra.push(s);
    }
    // CI-sized rows (the product is 48 rows); knn 32 keeps placement
    // search cheap without collapsing the linked-run structure the
    // cost model keys on
    m.scale_down(128, 32, 2, 32);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_expands_with_unique_names() {
        for name in preset_names() {
            let m = preset(name).unwrap();
            let specs = m.expand();
            assert!(!specs.is_empty(), "{name} is empty");
            let mut names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
            names.sort_unstable();
            let before = names.len();
            names.dedup();
            assert_eq!(before, names.len(), "{name} has duplicate scenario names");
        }
        assert!(preset("bogus").is_err());
    }

    #[test]
    fn fig18_matches_the_historical_bench_shape() {
        let m = preset("fig18").unwrap();
        let specs = m.expand();
        // part (a): 2 models x 3 ratios x (sync + 3 budgets), then the
        // 4 collapse x prefetch rows of part (b)
        assert_eq!(specs.len(), 2 * 3 * 4 + 4);
        assert_eq!(specs[0].seed, 7, "bench workloads run on seed 7");
        assert_eq!(specs[0].calib_tokens, 256);
        assert_eq!(specs[0].eval_tokens, 64);
        assert_eq!(specs[0].sim_layers, 2);
        assert_eq!(specs[0].knn, 64);
        assert!(!specs[0].prefetch.enabled, "sync baseline comes first");
        assert!(specs[1].prefetch.enabled);
        assert_eq!(specs[1].prefetch.budget_bytes, 64 * 1024);
    }

    #[test]
    fn smoke_is_small() {
        let specs = preset("smoke").unwrap().expand();
        assert_eq!(specs.len(), 4);
        assert!(specs.iter().all(|s| s.eval_tokens <= 24 && s.sim_layers == 2));
        assert!(specs.iter().any(|s| s.prefetch.enabled));
    }

    #[test]
    fn serve_preset_covers_the_contention_axes() {
        let specs = preset("serve").unwrap().expand();
        // 1 anchor + 3 session counts x 2 spacings x shared/private
        assert_eq!(specs.len(), 1 + 3 * 2 * 2);
        let first = specs[0].serve.expect("anchor row is a serve point");
        assert_eq!(first.sessions, 1);
        assert!(first.shared_cache);
        assert_eq!(specs[0].seed, 7, "serve rows run on the bench seed");
        assert!(specs.iter().all(|s| s.serve.is_some() && !s.prefetch.enabled));
        // every shared row has a private partner at the same point
        for s in &specs {
            let sv = s.serve.unwrap();
            if sv.sessions > 1 && sv.shared_cache {
                assert!(
                    specs.iter().any(|o| {
                        let ov = o.serve.unwrap();
                        !ov.shared_cache && ov.pair_key() == sv.pair_key()
                    }),
                    "no private partner for {}",
                    s.name
                );
            }
        }
    }

    #[test]
    fn serve_prefetch_preset_sweeps_arbiter_budget_and_sessions() {
        let specs = preset("serve-prefetch").unwrap().expand();
        // {sync, pf256KB} x {1, 2, 4, 8} sessions + 3 arbiter extras
        assert_eq!(specs.len(), 2 * 4 + 3);
        assert!(specs.iter().all(|s| s.serve.is_some()));
        // sync rows are the prefetch-off contention baselines
        assert_eq!(specs.iter().filter(|s| !s.prefetch.enabled).count(), 4);
        // the single-session prefetch row is the single-stream anchor
        assert!(specs
            .iter()
            .any(|s| s.prefetch.enabled && s.serve.unwrap().sessions == 1));
        // both policies and an explicit global budget appear
        assert!(specs.iter().any(|s| matches!(
            s.serve.unwrap().arbiter,
            Some(ArbiterPolicy::DeadlineAware { .. })
        )));
        assert!(specs
            .iter()
            .any(|s| s.serve.unwrap().prefetch_global_budget == Some(128 * 1024)));
        // every row passes workload validation
        for s in &specs {
            s.workload().unwrap();
        }
        assert_eq!(specs[0].seed, 7, "rows run on the bench seed");
    }

    #[test]
    fn fleet_preset_covers_the_open_loop_axes() {
        let specs = preset("fleet").unwrap().expand();
        // anchor + 2 schedulers x 3 rates + bursty + diurnal + bounded
        // product rows, then the 10k-session stress extra
        assert_eq!(specs.len(), 1 + 2 * 3 + 3 + 1);
        assert!(specs.iter().all(|s| s.fleet.is_some() && !s.prefetch.enabled));
        let anchor = specs[0].fleet.unwrap();
        assert_eq!(anchor.arrival, ArrivalSpec::Fixed { spacing_ms: 0.0 });
        assert_eq!(anchor.scheduler, FleetScheduler::Fifo);
        assert!(anchor.admission_bound.is_none() && anchor.slo_ms.is_none());
        assert!(specs
            .iter()
            .any(|s| s.fleet.unwrap().scheduler == FleetScheduler::ShortestRemaining));
        assert!(specs
            .iter()
            .any(|s| matches!(s.fleet.unwrap().arrival, ArrivalSpec::Bursty { .. })));
        assert!(specs
            .iter()
            .any(|s| matches!(s.fleet.unwrap().arrival, ArrivalSpec::Diurnal { .. })));
        assert!(specs
            .iter()
            .any(|s| s.fleet.unwrap().admission_bound.is_some()
                && s.fleet.unwrap().sessions == 64));
        let stress = specs.iter().find(|s| s.name == "stress").unwrap();
        assert_eq!(stress.fleet.unwrap().sessions, 10_000);
        assert_eq!(stress.eval_tokens, 2, "stress point stays tractable");
        // every row passes workload validation
        for s in &specs {
            s.workload().unwrap();
        }
        assert_eq!(specs[0].seed, 7, "fleet rows run on the bench seed");
    }

    #[test]
    fn perf_preset_covers_every_decode_loop() {
        let specs = preset("perf").unwrap().expand();
        // 3 synchronous systems + prefetch + serve + fleet-gauge extras
        assert_eq!(specs.len(), 3 + 4);
        assert!(specs[..3].iter().all(|s| s.eval_tokens == 512 && !s.prefetch.enabled));
        let pf = specs.iter().find(|s| s.name == "perf-prefetch").unwrap();
        assert!(pf.prefetch.enabled);
        let sv = specs.iter().find(|s| s.name == "perf-serve").unwrap();
        assert_eq!(sv.serve.unwrap().sessions, 4);
        // the speedup gauge pair differs ONLY in decode-thread count,
        // so its JSON rows are byte-identical and the Markdown
        // wall-clock ratio is a controlled comparison
        let d1 = specs.iter().find(|s| s.name == "perf-fleet-dt1").unwrap();
        let d8 = specs.iter().find(|s| s.name == "perf-fleet-dt8").unwrap();
        assert_eq!(d1.decode_threads, 1);
        assert_eq!(d8.decode_threads, 8);
        let mut twin = d8.clone();
        twin.name = d1.name.clone();
        twin.decode_threads = 1;
        assert_eq!(&twin, d1);
        assert_eq!(d1.fleet.unwrap().sessions, 10_000);
        assert_eq!(d1.fleet.unwrap().max_concurrent, 32);
        assert!(d1.prefetch.enabled, "gauge rows exercise the overlapped planner");
        for s in [d1, d8] {
            s.workload().unwrap();
        }
        assert_eq!(specs[0].seed, 7, "perf rows run on the bench seed");
    }

    #[test]
    fn trace_preset_traces_every_decode_path() {
        let specs = preset("trace").unwrap().expand();
        assert_eq!(specs.len(), 4);
        assert!(specs.iter().all(|s| s.trace));
        assert!(specs.iter().any(|s| s.serve.is_some()));
        assert!(specs.iter().any(|s| s.fleet.is_some()));
        assert!(specs
            .iter()
            .any(|s| s.prefetch.enabled && s.serve.is_none() && s.fleet.is_none()));
        // no other preset traces: untraced reports stay byte-identical
        for name in preset_names().iter().filter(|&&n| n != "trace") {
            assert!(
                preset(name).unwrap().expand().iter().all(|s| !s.trace),
                "{name} must stay untraced"
            );
        }
        for s in &specs {
            s.workload().unwrap();
        }
    }

    #[test]
    fn cachelab_sweeps_policies_at_equal_dram() {
        let specs = preset("cachelab").unwrap().expand();
        // 2 devices x 4 policies x 6 ratios + 3 ways extras + 2 headline
        assert_eq!(specs.len(), 2 * 4 * 6 + 3 + 2);
        assert!(specs.iter().all(|s| !s.prefetch.enabled && !s.trace));
        // every policy appears at every ratio on every device: the
        // equal-DRAM comparison the headline depends on
        for pol in ["lru", "victim", "setassoc", "costaware"] {
            for ratio in [0.0, 0.05, 0.1, 0.2, 0.3, 0.4] {
                assert!(
                    specs.iter().any(|s| s.cache_policy.as_deref() == Some(pol)
                        && (s.cache_ratio - ratio).abs() < 1e-12),
                    "missing {pol} at ratio {ratio}"
                );
            }
        }
        // only the ways extras carry the associativity override
        let ways: Vec<_> =
            specs.iter().filter(|s| s.cache_ways.is_some()).collect();
        assert_eq!(ways.len(), 3);
        assert!(ways
            .iter()
            .all(|s| s.cache_policy.as_deref() == Some("setassoc")));
        // the headline pair differs only in eviction policy
        let lru = specs.iter().find(|s| s.name == "headline-lru").unwrap();
        let ca = specs.iter().find(|s| s.name == "headline-costaware").unwrap();
        assert_eq!(lru.model, ca.model);
        assert_eq!(lru.cache_ratio, ca.cache_ratio);
        assert_eq!(lru.seed, ca.seed);
        // every row passes workload + spec validation
        for s in &specs {
            s.workload().unwrap();
            s.system_spec(2).unwrap();
        }
        assert_eq!(specs[0].seed, 7, "cachelab rows run on the bench seed");
    }

    #[test]
    fn ablations_cover_all_four_axes() {
        let specs = preset("ablations").unwrap().expand();
        assert!(specs.iter().any(|s| s.name.starts_with("knn")));
        assert!(specs.iter().any(|s| s.name.starts_with("threshold-")));
        assert!(specs.iter().any(|s| s.name.starts_with("segp")));
        assert!(specs.iter().any(|s| s.name == "admit-all"));
        assert!(specs.iter().any(|s| s.name.starts_with("calib")));
        assert!(specs.iter().all(|s| !s.prefetch.enabled));
    }
}
