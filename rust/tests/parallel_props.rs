//! Parallel plan/commit determinism properties (DESIGN.md
//! §Parallel-decode).
//!
//! The decode pool must be invisible in every reported number: a round
//! plans session I/O in parallel but commits cache admissions, flash
//! submits, prefetch grants and stats in fixed session order, so hit
//! and miss outcomes, `UfsSim` timelines, and the report JSON are
//! byte-identical at every decode-thread count. These tests pin that
//! contract at widths {1, 2, 8} over randomized serve and fleet
//! configurations (a seeded xorshift generator — the property is a
//! sweep, not one golden point), and pin the report-level corollary CI
//! relies on: `run_matrix_with` at different pool widths emits
//! byte-identical JSON.

use ripple::bench::workloads::{ExperimentResult, System};
use ripple::coordinator::{ArbiterPolicy, FleetScheduler};
use ripple::harness::{
    run_matrix_with, run_scenario, ArrivalSpec, FleetPoint, PrefetchPoint,
    ScenarioMatrix, ScenarioSpec, ServePoint,
};

/// Deterministic xorshift64 — the configs are random-looking but fixed,
/// so a failure is reproducible from the test source alone.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    /// Uniform-ish pick in `lo..=hi`.
    fn pick(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }

    fn chance(&mut self) -> bool {
        self.next() & 1 == 0
    }
}

/// A CI-sized spec on the tiny AOT model.
fn small_spec(name: &str) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(name, "opt-micro", System::Ripple);
    s.calib_tokens = 64;
    s.eval_tokens = 8;
    s.sim_layers = 2;
    s.knn = 8;
    s
}

/// Assert two results agree bit-for-bit on everything the report
/// serializes: aggregate totals, the serve summary, and (when present)
/// the fleet summary.
fn assert_bit_identical(a: &ExperimentResult, b: &ExperimentResult, what: &str) {
    assert_eq!(
        a.metrics.totals.elapsed_ns.to_bits(),
        b.metrics.totals.elapsed_ns.to_bits(),
        "{what}: elapsed_ns diverged"
    );
    assert_eq!(a.metrics.totals.commands, b.metrics.totals.commands, "{what}: commands");
    assert_eq!(a.metrics.totals.bytes, b.metrics.totals.bytes, "{what}: bytes");
    assert_eq!(
        a.metrics.totals.cached_bundles, b.metrics.totals.cached_bundles,
        "{what}: cache hits"
    );
    assert_eq!(
        a.metrics.totals.prefetch_hit_bundles, b.metrics.totals.prefetch_hit_bundles,
        "{what}: prefetch hits"
    );
    assert_eq!(
        a.metrics.totals.prefetch_wasted_bundles,
        b.metrics.totals.prefetch_wasted_bundles,
        "{what}: prefetch waste"
    );
    assert_eq!(a.serve, b.serve, "{what}: serve summary diverged");
    assert_eq!(a.fleet, b.fleet, "{what}: fleet summary diverged");
}

/// Run `spec` at decode-thread counts {1, 2, 8} and require bit
/// identity against the serial baseline.
fn assert_pool_invariant(mut spec: ScenarioSpec) {
    spec.decode_threads = 1;
    let base = run_scenario(&spec, 1).unwrap();
    for dt in [2usize, 8] {
        spec.decode_threads = dt;
        let pooled = run_scenario(&spec, 1).unwrap();
        assert_bit_identical(&base, &pooled, &format!("{} at dt={dt}", spec.name));
    }
}

#[test]
fn serve_rounds_are_decode_thread_invariant_on_randomized_configs() {
    let mut rng = Rng(0x5EED_CAFE);
    for i in 0..6 {
        let sessions = rng.pick(2, 6);
        let base = if rng.chance() {
            ServePoint::shared(sessions)
        } else {
            ServePoint::private(sessions)
        };
        let mut point = ServePoint {
            max_concurrent: rng.pick(1, sessions),
            arrival_spacing_ms: if rng.chance() { 0.0 } else { 10.0 },
            ..base
        };
        let mut spec = small_spec(&format!("serve-rand-{i}"));
        // prefetch exercises the prepared-prediction path; the arbiter
        // and a global budget vary the per-round grants the plan phase
        // must agree with
        if rng.chance() {
            spec.prefetch = PrefetchPoint::budget_kb(64);
            if rng.chance() {
                point = point.with_arbiter(ArbiterPolicy::FairShare);
            }
            if rng.chance() {
                point = point.with_global_budget(32 * 1024);
            }
        }
        spec.serve = Some(point);
        assert_pool_invariant(spec);
    }
}

#[test]
fn fleet_steps_are_decode_thread_invariant_on_randomized_configs() {
    let mut rng = Rng(0xF1EE_7000_0000_0001);
    for i in 0..6 {
        let sessions = rng.pick(4, 10);
        let arrival = match rng.pick(0, 2) {
            0 => ArrivalSpec::Fixed { spacing_ms: 0.0 },
            1 => ArrivalSpec::Poisson { per_s: 1000.0 },
            _ => ArrivalSpec::Bursty { per_s: 1000.0, burst: 3 },
        };
        let mut point = FleetPoint {
            max_concurrent: rng.pick(2, 4),
            arrival,
            ..FleetPoint::fixed(sessions, 0.0)
        };
        if rng.chance() {
            point = point.with_scheduler(FleetScheduler::ShortestRemaining);
        }
        if rng.chance() {
            point = point.with_bound(sessions.div_ceil(2));
        }
        if rng.chance() {
            point = point.with_slo_ms(40.0);
        }
        let mut spec = small_spec(&format!("fleet-rand-{i}"));
        if rng.chance() {
            spec.prefetch = PrefetchPoint::budget_kb(64);
        }
        spec.fleet = Some(point);
        assert_pool_invariant(spec);
    }
}

#[test]
fn report_json_is_byte_identical_across_pool_widths() {
    // the exact property the CI parallel-determinism job byte-cmp's:
    // one matrix, re-run with every row's pool forced to 1 / 2 / 8,
    // must serialize to the same JSON bytes (wall-clock gauges live in
    // the Markdown only)
    let mut m = ScenarioMatrix::new("pool-cmp");
    m.models.clear(); // every row is a hand-written tiny extra
    let mut single = small_spec("single");
    single.prefetch = PrefetchPoint::budget_kb(64);
    m.extra.push(single);
    let mut sv = small_spec("serve");
    sv.prefetch = PrefetchPoint::budget_kb(64);
    sv.serve =
        Some(ServePoint::shared(4).with_arbiter(ArbiterPolicy::FairShare));
    m.extra.push(sv);
    let mut fl = small_spec("fleet");
    fl.fleet = Some(FleetPoint::poisson(6, 1000.0).with_slo_ms(40.0));
    m.extra.push(fl);
    let base = run_matrix_with(&m, 1, Some(1)).unwrap().json_string();
    for dt in [2usize, 8] {
        let pooled = run_matrix_with(&m, 2, Some(dt)).unwrap().json_string();
        assert_eq!(base, pooled, "report JSON diverged at decode_threads={dt}");
    }
}
