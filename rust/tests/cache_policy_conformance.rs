//! Cache-policy conformance suite: one parameterized battery of
//! trait-level contracts, run against every `CachePolicy` implementation
//! (LRU and S3-FIFO; the zero-capacity contract also covers NullCache).
//!
//! The battery asserts only what the *trait* promises — capacity
//! invariants, touch/insert semantics, eviction under pressure, no
//! phantom hits, side-effect-free `contains` — so any future policy
//! (ARC, CLOCK, ...) can be added to `POLICIES` and inherit the whole
//! suite.

use ripple::cache::{CachePolicy, Lru, NullCache, S3Fifo};
use ripple::util::rng::Rng;

type Ctor = fn(usize) -> Box<dyn CachePolicy>;

/// Every policy the suite covers. Add new implementations here.
const POLICIES: &[(&str, Ctor)] = &[
    ("lru", |cap| Box::new(Lru::new(cap))),
    ("s3fifo", |cap| Box::new(S3Fifo::new(cap))),
];

fn for_each_policy(mut f: impl FnMut(&str, Ctor)) {
    for &(name, ctor) in POLICIES {
        f(name, ctor);
    }
}

#[test]
fn capacity_never_exceeded_under_churn() {
    for_each_policy(|name, ctor| {
        for cap in [1usize, 2, 7, 16, 64] {
            let mut c = ctor(cap);
            let mut rng = Rng::new(0xCAFE ^ cap as u64);
            for i in 0..2_000u64 {
                c.insert(rng.below(cap * 5) as u64);
                if i % 3 == 0 {
                    c.touch(rng.below(cap * 5) as u64);
                }
                assert!(
                    c.len() <= cap,
                    "{name}: len {} > cap {cap} at op {i}",
                    c.len()
                );
                assert_eq!(c.capacity(), cap, "{name}: capacity drifted");
            }
        }
    });
}

#[test]
fn reported_capacity_matches_construction() {
    for_each_policy(|name, ctor| {
        for cap in [0usize, 1, 5, 100] {
            let c = ctor(cap);
            assert_eq!(c.capacity(), cap, "{name}");
            assert_eq!(c.len(), 0, "{name}: fresh cache not empty");
        }
    });
}

#[test]
fn touch_misses_before_insert_and_hits_after() {
    for_each_policy(|name, ctor| {
        let mut c = ctor(16);
        for k in 0..8u64 {
            assert!(!c.touch(k), "{name}: phantom hit on fresh cache");
        }
        for k in 0..8u64 {
            c.insert(k);
        }
        // no pressure (8 < 16): every inserted key must be resident
        for k in 0..8u64 {
            assert!(c.touch(k), "{name}: lost key {k} without pressure");
        }
        assert_eq!(c.len(), 8, "{name}");
    });
}

#[test]
fn touch_refresh_keeps_hot_key_alive_under_scan() {
    // A key re-referenced on every step must survive a cold scan of 20x
    // capacity: LRU via recency refresh, S3-FIFO via frequency promotion.
    for_each_policy(|name, ctor| {
        let mut c = ctor(10);
        c.insert(7);
        assert!(c.touch(7), "{name}");
        for i in 1_000..1_200u64 {
            c.insert(i);
            assert!(c.touch(7), "{name}: hot key evicted by scan at {i}");
        }
        assert!(c.len() <= 10, "{name}");
    });
}

#[test]
fn eviction_under_pressure_is_real() {
    // After inserting 3x capacity distinct keys, at most `cap` of them
    // can still hit — the rest must have been evicted, not hidden.
    for_each_policy(|name, ctor| {
        let cap = 12usize;
        let mut c = ctor(cap);
        let keys: Vec<u64> = (0..3 * cap as u64).collect();
        for &k in &keys {
            c.insert(k);
        }
        assert!(c.len() <= cap, "{name}");
        let resident = keys.iter().filter(|&&k| c.contains(k)).count();
        assert!(resident <= cap, "{name}: {resident} resident > cap {cap}");
        assert_eq!(resident, c.len(), "{name}: len disagrees with membership");
    });
}

#[test]
fn no_phantom_hits_under_random_ops() {
    // A hit may only occur for a key that was inserted earlier; randomized
    // mixed workload cross-checked against an oracle set of insertions.
    for_each_policy(|name, ctor| {
        for seed in 0..6u64 {
            let mut rng = Rng::new(0xBEEF ^ seed);
            let cap = rng.range(1, 24);
            let mut c = ctor(cap);
            let mut inserted = std::collections::HashSet::new();
            for _ in 0..1_500 {
                let key = rng.below(48) as u64;
                if rng.chance(0.5) {
                    c.insert(key);
                    inserted.insert(key);
                } else {
                    let hit = c.touch(key);
                    assert!(
                        !hit || inserted.contains(&key),
                        "{name}: hit on never-inserted key {key} (cap {cap}, seed {seed})"
                    );
                }
            }
        }
    });
}

#[test]
fn contains_is_consistent_and_side_effect_free() {
    for_each_policy(|name, ctor| {
        let mut rng = Rng::new(0x51DE);
        let mut c = ctor(8);
        for _ in 0..500 {
            let key = rng.below(24) as u64;
            if rng.chance(0.4) {
                c.insert(key);
            }
            // contains is repeatable (no internal state change)...
            let a = c.contains(key);
            let b = c.contains(key);
            assert_eq!(a, b, "{name}: contains not repeatable for {key}");
            // ...and agrees with what touch observes right after
            let hit = c.touch(key);
            assert_eq!(a, hit, "{name}: contains/touch disagree for {key}");
        }
    });
}

#[test]
fn reinsert_of_resident_key_does_not_grow() {
    for_each_policy(|name, ctor| {
        let mut c = ctor(8);
        c.insert(3);
        let len = c.len();
        for _ in 0..50 {
            c.insert(3);
        }
        assert_eq!(c.len(), len, "{name}: duplicate insert grew the cache");
        assert!(c.touch(3), "{name}");
    });
}

#[test]
fn zero_capacity_never_stores() {
    let null_ctor: Ctor = |_| Box::new(NullCache);
    let mut all: Vec<(&str, Ctor)> = POLICIES.to_vec();
    all.push(("null", null_ctor));
    for (name, ctor) in all {
        let mut c = ctor(0);
        for k in 0..32u64 {
            c.insert(k);
            assert!(!c.touch(k), "{name}: stored into zero-capacity cache");
            assert!(!c.contains(k), "{name}");
        }
        assert_eq!(c.len(), 0, "{name}");
    }
}
