//! Cache-policy conformance suite: one parameterized battery of
//! trait-level contracts, run against every `CachePolicy` implementation
//! (LRU, S3-FIFO, and the cache-lab trio — victim buffer,
//! set-associative, cost-aware; the zero-capacity contract also covers
//! NullCache).
//!
//! The battery asserts only what the *trait* promises — capacity
//! invariants, touch/insert semantics, eviction under pressure, no
//! phantom hits, side-effect-free `contains` — so any future policy
//! (ARC, CLOCK, ...) can be added to `POLICIES` and inherit the whole
//! suite.

use ripple::cache::{CachePolicy, CostAware, Lru, NullCache, S3Fifo, SetAssoc, Victim};
use ripple::util::rng::Rng;

type Ctor = fn(usize) -> Box<dyn CachePolicy>;

/// Every policy the suite covers. Add new implementations here.
const POLICIES: &[(&str, Ctor)] = &[
    ("lru", |cap| Box::new(Lru::new(cap))),
    ("s3fifo", |cap| Box::new(S3Fifo::new(cap))),
    ("victim", |cap| Box::new(Victim::new(cap))),
    ("setassoc", |cap| Box::new(SetAssoc::new(cap))),
    ("costaware", |cap| Box::new(CostAware::new(cap))),
];

fn for_each_policy(mut f: impl FnMut(&str, Ctor)) {
    for &(name, ctor) in POLICIES {
        f(name, ctor);
    }
}

#[test]
fn capacity_never_exceeded_under_churn() {
    for_each_policy(|name, ctor| {
        for cap in [1usize, 2, 7, 16, 64] {
            let mut c = ctor(cap);
            let mut rng = Rng::new(0xCAFE ^ cap as u64);
            for i in 0..2_000u64 {
                c.insert(rng.below(cap * 5) as u64);
                if i % 3 == 0 {
                    c.touch(rng.below(cap * 5) as u64);
                }
                assert!(
                    c.len() <= cap,
                    "{name}: len {} > cap {cap} at op {i}",
                    c.len()
                );
                assert_eq!(c.capacity(), cap, "{name}: capacity drifted");
            }
        }
    });
}

#[test]
fn reported_capacity_matches_construction() {
    for_each_policy(|name, ctor| {
        for cap in [0usize, 1, 5, 100] {
            let c = ctor(cap);
            assert_eq!(c.capacity(), cap, "{name}");
            assert_eq!(c.len(), 0, "{name}: fresh cache not empty");
        }
    });
}

#[test]
fn touch_misses_before_insert_and_hits_after() {
    for_each_policy(|name, ctor| {
        let mut c = ctor(16);
        for k in 0..8u64 {
            assert!(!c.touch(k), "{name}: phantom hit on fresh cache");
        }
        for k in 0..8u64 {
            c.insert(k);
        }
        // no pressure (8 < 16): every inserted key must be resident
        for k in 0..8u64 {
            assert!(c.touch(k), "{name}: lost key {k} without pressure");
        }
        assert_eq!(c.len(), 8, "{name}");
    });
}

#[test]
fn touch_refresh_keeps_hot_key_alive_under_scan() {
    // A key re-referenced on every step must survive a cold scan of 20x
    // capacity: LRU via recency refresh, S3-FIFO via frequency promotion.
    for_each_policy(|name, ctor| {
        let mut c = ctor(10);
        c.insert(7);
        assert!(c.touch(7), "{name}");
        for i in 1_000..1_200u64 {
            c.insert(i);
            assert!(c.touch(7), "{name}: hot key evicted by scan at {i}");
        }
        assert!(c.len() <= 10, "{name}");
    });
}

#[test]
fn eviction_under_pressure_is_real() {
    // After inserting 3x capacity distinct keys, at most `cap` of them
    // can still hit — the rest must have been evicted, not hidden.
    for_each_policy(|name, ctor| {
        let cap = 12usize;
        let mut c = ctor(cap);
        let keys: Vec<u64> = (0..3 * cap as u64).collect();
        for &k in &keys {
            c.insert(k);
        }
        assert!(c.len() <= cap, "{name}");
        let resident = keys.iter().filter(|&&k| c.contains(k)).count();
        assert!(resident <= cap, "{name}: {resident} resident > cap {cap}");
        assert_eq!(resident, c.len(), "{name}: len disagrees with membership");
    });
}

#[test]
fn no_phantom_hits_under_random_ops() {
    // A hit may only occur for a key that was inserted earlier; randomized
    // mixed workload cross-checked against an oracle set of insertions.
    for_each_policy(|name, ctor| {
        for seed in 0..6u64 {
            let mut rng = Rng::new(0xBEEF ^ seed);
            let cap = rng.range(1, 24);
            let mut c = ctor(cap);
            let mut inserted = std::collections::HashSet::new();
            for _ in 0..1_500 {
                let key = rng.below(48) as u64;
                if rng.chance(0.5) {
                    c.insert(key);
                    inserted.insert(key);
                } else {
                    let hit = c.touch(key);
                    assert!(
                        !hit || inserted.contains(&key),
                        "{name}: hit on never-inserted key {key} (cap {cap}, seed {seed})"
                    );
                }
            }
        }
    });
}

#[test]
fn contains_is_consistent_and_side_effect_free() {
    for_each_policy(|name, ctor| {
        let mut rng = Rng::new(0x51DE);
        let mut c = ctor(8);
        for _ in 0..500 {
            let key = rng.below(24) as u64;
            if rng.chance(0.4) {
                c.insert(key);
            }
            // contains is repeatable (no internal state change)...
            let a = c.contains(key);
            let b = c.contains(key);
            assert_eq!(a, b, "{name}: contains not repeatable for {key}");
            // ...and agrees with what touch observes right after
            let hit = c.touch(key);
            assert_eq!(a, hit, "{name}: contains/touch disagree for {key}");
        }
    });
}

#[test]
fn reinsert_of_resident_key_does_not_grow() {
    for_each_policy(|name, ctor| {
        let mut c = ctor(8);
        c.insert(3);
        let len = c.len();
        for _ in 0..50 {
            c.insert(3);
        }
        assert_eq!(c.len(), len, "{name}: duplicate insert grew the cache");
        assert!(c.touch(3), "{name}");
    });
}

// ---------------------------------------------------------------------------
// Shared-cache concurrency battery (DESIGN.md §Serving): a policy shared
// by N sessions must behave as a pure function of the merged op order —
// no hidden per-caller state — and `contains` probes from other sessions
// must never perturb it.
// ---------------------------------------------------------------------------

type SessionOp = (bool, u64); // (is_insert, key)

/// Deterministic per-session op streams over a shared hot keyspace.
fn gen_session_streams(rng: &mut Rng, n_sessions: usize) -> Vec<Vec<SessionOp>> {
    (0..n_sessions)
        .map(|_| {
            let len = rng.range(20, 120);
            (0..len).map(|_| (rng.chance(0.5), rng.below(40) as u64)).collect()
        })
        .collect()
}

/// Round-robin merge of the session streams — the canonical
/// "equivalent single-stream trace" of that interleaving.
fn round_robin_merge(streams: &[Vec<SessionOp>]) -> Vec<SessionOp> {
    let mut merged = Vec::new();
    let mut cursors = vec![0usize; streams.len()];
    loop {
        let mut progressed = false;
        for (s, stream) in streams.iter().enumerate() {
            if cursors[s] < stream.len() {
                merged.push(stream[cursors[s]]);
                cursors[s] += 1;
                progressed = true;
            }
        }
        if !progressed {
            return merged;
        }
    }
}

/// Driving a policy through interleaved multi-session streams gives the
/// same hit/miss outcomes AND the same end state as replaying the
/// merged trace single-stream: the policy keys carry all the state,
/// sessions add none.
#[test]
fn interleaved_session_streams_match_merged_single_stream() {
    for_each_policy(|name, ctor| {
        for seed in 0..6u64 {
            let mut rng = Rng::new(0x5E55_10 ^ seed);
            let cap = rng.range(2, 24);
            let n_sessions = rng.range(2, 5);
            let streams = gen_session_streams(&mut rng, n_sessions);
            let merged = round_robin_merge(&streams);

            // driver A: the multi-session scheduler (per-stream cursors)
            let mut a = ctor(cap);
            let mut outcomes_a = Vec::new();
            let mut cursors = vec![0usize; n_sessions];
            loop {
                let mut progressed = false;
                for (s, stream) in streams.iter().enumerate() {
                    if cursors[s] < stream.len() {
                        let (is_insert, key) = stream[cursors[s]];
                        cursors[s] += 1;
                        if is_insert {
                            a.insert(key);
                        } else {
                            outcomes_a.push((key, a.touch(key)));
                        }
                        progressed = true;
                    }
                }
                if !progressed {
                    break;
                }
            }

            // driver B: the merged trace, single stream
            let mut b = ctor(cap);
            let mut outcomes_b = Vec::new();
            for &(is_insert, key) in &merged {
                if is_insert {
                    b.insert(key);
                } else {
                    outcomes_b.push((key, b.touch(key)));
                }
            }

            assert_eq!(outcomes_a, outcomes_b, "{name}: outcomes diverged (seed {seed})");
            assert_eq!(a.len(), b.len(), "{name}: end sizes diverged (seed {seed})");
            for key in 0..40u64 {
                assert_eq!(
                    a.contains(key),
                    b.contains(key),
                    "{name}: end membership diverged at {key} (seed {seed})"
                );
            }
        }
    });
}

/// `contains` stays side-effect-free under interleaving: peppering the
/// stream with residency probes (another session peeking, as the
/// shared-cache prefetch filter does) changes neither the hit/miss
/// outcome sequence nor the final membership.
#[test]
fn contains_probes_never_perturb_an_interleaved_stream() {
    for_each_policy(|name, ctor| {
        for seed in 0..6u64 {
            let mut rng = Rng::new(0xD00D ^ seed);
            let cap = rng.range(2, 16);
            let ops: Vec<SessionOp> =
                (0..300).map(|_| (rng.chance(0.5), rng.below(32) as u64)).collect();
            let probes: Vec<u64> =
                (0..ops.len() * 3).map(|_| rng.below(32) as u64).collect();

            let mut clean = ctor(cap);
            let mut probed = ctor(cap);
            let mut outcomes_clean = Vec::new();
            let mut outcomes_probed = Vec::new();
            for (i, &(is_insert, key)) in ops.iter().enumerate() {
                // three foreign probes before every op on the probed copy
                for p in 0..3 {
                    let _ = probed.contains(probes[i * 3 + p]);
                }
                if is_insert {
                    clean.insert(key);
                    probed.insert(key);
                } else {
                    outcomes_clean.push(clean.touch(key));
                    outcomes_probed.push(probed.touch(key));
                }
            }
            assert_eq!(
                outcomes_clean, outcomes_probed,
                "{name}: contains() perturbed outcomes (seed {seed})"
            );
            assert_eq!(clean.len(), probed.len(), "{name} (seed {seed})");
            for key in 0..32u64 {
                assert_eq!(
                    clean.contains(key),
                    probed.contains(key),
                    "{name}: membership diverged at {key} (seed {seed})"
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Dense-vs-reference oracle battery (§Perf): the production policies
// index a direct-addressed Vec slot table; these randomized traces pin
// them against simple HashMap + VecDeque model oracles — identical
// hit/miss outcomes, identical eviction sequences, identical membership
// — for both the `bounded` (pre-sized) and `new` (grow-on-demand)
// constructions.
// ---------------------------------------------------------------------------

mod oracle {
    use std::collections::{HashMap, VecDeque};

    /// Textbook LRU: recency order in a VecDeque (back = MRU).
    pub struct RefLru {
        capacity: usize,
        order: VecDeque<u64>,
    }

    impl RefLru {
        pub fn new(capacity: usize) -> Self {
            Self { capacity, order: VecDeque::new() }
        }

        pub fn touch(&mut self, key: u64) -> bool {
            match self.order.iter().position(|&k| k == key) {
                Some(pos) => {
                    self.order.remove(pos);
                    self.order.push_back(key);
                    true
                }
                None => false,
            }
        }

        pub fn insert(&mut self, key: u64) -> Option<u64> {
            if self.capacity == 0 {
                return None;
            }
            if self.touch(key) {
                return None;
            }
            let mut evicted = None;
            if self.order.len() >= self.capacity {
                evicted = self.order.pop_front();
            }
            self.order.push_back(key);
            evicted
        }

        pub fn contains(&self, key: u64) -> bool {
            self.order.contains(&key)
        }

        pub fn len(&self) -> usize {
            self.order.len()
        }
    }

    const IN_SMALL: u8 = 0;
    const IN_MAIN: u8 = 1;
    const IN_GHOST: u8 = 2;
    const FREQ_CAP: u8 = 3;

    /// The historical HashMap-backed S3-FIFO, kept verbatim as the
    /// model oracle for the dense-indexed production implementation.
    pub struct RefS3Fifo {
        capacity: usize,
        small_cap: usize,
        small: VecDeque<u64>,
        main: VecDeque<u64>,
        ghost: VecDeque<u64>,
        ghost_cap: usize,
        table: HashMap<u64, (u8, u8)>,
    }

    impl RefS3Fifo {
        pub fn new(capacity: usize) -> Self {
            Self {
                capacity,
                small_cap: (capacity / 10).max(1).min(capacity),
                small: VecDeque::new(),
                main: VecDeque::new(),
                ghost: VecDeque::new(),
                ghost_cap: capacity,
                table: HashMap::new(),
            }
        }

        pub fn len(&self) -> usize {
            self.small.len() + self.main.len()
        }

        pub fn touch(&mut self, key: u64) -> bool {
            match self.table.get_mut(&key) {
                Some((freq, loc)) if *loc != IN_GHOST => {
                    *freq = (*freq + 1).min(FREQ_CAP);
                    true
                }
                _ => false,
            }
        }

        pub fn contains(&self, key: u64) -> bool {
            matches!(self.table.get(&key), Some((_, loc)) if *loc != IN_GHOST)
        }

        pub fn insert(&mut self, key: u64) -> Option<u64> {
            if self.capacity == 0 {
                return None;
            }
            match self.table.get(&key) {
                Some((_, loc)) if *loc != IN_GHOST => None,
                Some(_) => {
                    self.table.remove(&key);
                    let evicted = self.ensure_room();
                    self.main.push_back(key);
                    self.table.insert(key, (0, IN_MAIN));
                    evicted
                }
                None => {
                    let evicted = self.ensure_room();
                    self.small.push_back(key);
                    self.table.insert(key, (0, IN_SMALL));
                    evicted
                }
            }
        }

        fn ensure_room(&mut self) -> Option<u64> {
            let mut evicted = None;
            while self.len() >= self.capacity {
                let e = if self.small.len() >= self.small_cap || self.main.is_empty() {
                    self.evict_small()
                } else {
                    self.evict_main()
                };
                evicted = evicted.or(e);
            }
            evicted
        }

        fn evict_small(&mut self) -> Option<u64> {
            while let Some(key) = self.small.pop_front() {
                let Some(&(freq, loc)) = self.table.get(&key) else { continue };
                if loc != IN_SMALL {
                    continue;
                }
                if freq > 0 {
                    self.table.insert(key, (0, IN_MAIN));
                    self.main.push_back(key);
                    if self.len() < self.capacity {
                        return None;
                    }
                    continue;
                }
                self.table.insert(key, (0, IN_GHOST));
                self.ghost.push_back(key);
                self.trim_ghost();
                return Some(key);
            }
            None
        }

        fn evict_main(&mut self) -> Option<u64> {
            while let Some(key) = self.main.pop_front() {
                let Some(&(freq, loc)) = self.table.get(&key) else { continue };
                if loc != IN_MAIN {
                    continue;
                }
                if freq > 0 {
                    self.table.insert(key, (freq - 1, IN_MAIN));
                    self.main.push_back(key);
                    continue;
                }
                self.table.remove(&key);
                return Some(key);
            }
            None
        }

        fn trim_ghost(&mut self) {
            while self.ghost.len() > self.ghost_cap {
                if let Some(old) = self.ghost.pop_front() {
                    if matches!(self.table.get(&old), Some((_, loc)) if *loc == IN_GHOST) {
                        self.table.remove(&old);
                    }
                }
            }
        }
    }

    /// Victim-buffer model: `RefLru` main table plus an explicit FIFO
    /// side deque, mirroring the documented geometry (a `C / 8` slice
    /// clamped to `[1, 64]`, zero below capacity 2; promotion swaps the
    /// re-referenced victim with the key the main table demotes; FIFO
    /// overflow is the only real eviction).
    pub struct RefVictim {
        main: RefLru,
        fifo: VecDeque<u64>,
        victim_cap: usize,
        capacity: usize,
    }

    impl RefVictim {
        pub fn new(capacity: usize) -> Self {
            let victim_cap =
                if capacity >= 2 { (capacity / 8).clamp(1, 64) } else { 0 };
            Self {
                main: RefLru::new(capacity - victim_cap),
                fifo: VecDeque::new(),
                victim_cap,
                capacity,
            }
        }

        pub fn len(&self) -> usize {
            self.main.len() + self.fifo.len()
        }

        fn fifo_pos(&self, key: u64) -> Option<usize> {
            self.fifo.iter().position(|&k| k == key)
        }

        fn promote(&mut self, pos: usize, key: u64) {
            self.fifo.remove(pos);
            if let Some(demoted) = self.main.insert(key) {
                self.fifo.push_back(demoted);
            }
        }

        pub fn touch(&mut self, key: u64) -> bool {
            if self.main.touch(key) {
                return true;
            }
            match self.fifo_pos(key) {
                Some(pos) => {
                    self.promote(pos, key);
                    true
                }
                None => false,
            }
        }

        pub fn contains(&self, key: u64) -> bool {
            self.main.contains(key) || self.fifo_pos(key).is_some()
        }

        pub fn insert(&mut self, key: u64) -> Option<u64> {
            if self.capacity == 0 {
                return None;
            }
            if self.main.touch(key) {
                return None;
            }
            if let Some(pos) = self.fifo_pos(key) {
                self.promote(pos, key);
                return None;
            }
            let demoted = self.main.insert(key)?;
            if self.victim_cap == 0 {
                return Some(demoted);
            }
            self.fifo.push_back(demoted);
            if self.fifo.len() > self.victim_cap {
                self.fifo.pop_front()
            } else {
                None
            }
        }
    }

    /// Set-associative model: one recency deque per set (front = MRU),
    /// `capacity / ways` sets with the remainder rounded down, a key
    /// mapping to set `key % sets`, and conflict eviction dropping the
    /// set's back (least-recent) entry.
    pub struct RefSetAssoc {
        sets: Vec<VecDeque<u64>>,
        ways: usize,
    }

    impl RefSetAssoc {
        pub fn with_ways(capacity: usize, ways: usize) -> Self {
            let ways = ways.max(1).min(capacity.max(1));
            Self { sets: vec![VecDeque::new(); capacity / ways], ways }
        }

        pub fn len(&self) -> usize {
            self.sets.iter().map(|s| s.len()).sum()
        }

        fn set_of(&self, key: u64) -> usize {
            (key % self.sets.len() as u64) as usize
        }

        pub fn touch(&mut self, key: u64) -> bool {
            if self.sets.is_empty() {
                return false;
            }
            let set = self.set_of(key);
            let set = &mut self.sets[set];
            match set.iter().position(|&k| k == key) {
                Some(pos) => {
                    set.remove(pos);
                    set.push_front(key);
                    true
                }
                None => false,
            }
        }

        pub fn contains(&self, key: u64) -> bool {
            !self.sets.is_empty() && self.sets[self.set_of(key)].contains(&key)
        }

        pub fn insert(&mut self, key: u64) -> Option<u64> {
            if self.sets.is_empty() {
                return None;
            }
            if self.touch(key) {
                return None;
            }
            let ways = self.ways;
            let set = self.set_of(key);
            let set = &mut self.sets[set];
            let evicted = if set.len() >= ways { set.pop_back() } else { None };
            set.push_front(key);
            evicted
        }
    }

    /// Cost-aware model: a recency deque per log2 cost class (back =
    /// MRU) plus a key -> class map; eviction pops the front
    /// (least-recent) entry of the cheapest non-empty class, and
    /// re-inserting a resident key re-classes it without evicting.
    pub struct RefCostAware {
        class_of_key: HashMap<u64, usize>,
        classes: Vec<VecDeque<u64>>,
        capacity: usize,
    }

    impl RefCostAware {
        pub fn new(capacity: usize) -> Self {
            Self {
                class_of_key: HashMap::new(),
                classes: vec![VecDeque::new(); 32],
                capacity,
            }
        }

        fn class_of(cost: u32) -> usize {
            (cost.max(1).ilog2() as usize).min(31)
        }

        pub fn len(&self) -> usize {
            self.class_of_key.len()
        }

        pub fn contains(&self, key: u64) -> bool {
            self.class_of_key.contains_key(&key)
        }

        /// Remove `key` from its class deque, returning the class it
        /// was in (resident keys only).
        fn detach(&mut self, key: u64) -> Option<usize> {
            let class = self.class_of_key.get(&key).copied()?;
            let pos = self.classes[class]
                .iter()
                .position(|&k| k == key)
                .expect("map and deques out of sync");
            self.classes[class].remove(pos);
            Some(class)
        }

        pub fn touch(&mut self, key: u64) -> bool {
            match self.detach(key) {
                Some(class) => {
                    self.classes[class].push_back(key);
                    true
                }
                None => false,
            }
        }

        pub fn insert_with_cost(&mut self, key: u64, cost: u32) -> Option<u64> {
            if self.capacity == 0 {
                return None;
            }
            let class = Self::class_of(cost);
            if self.detach(key).is_some() {
                self.classes[class].push_back(key);
                self.class_of_key.insert(key, class);
                return None;
            }
            let evicted = if self.len() >= self.capacity {
                let cheapest = self
                    .classes
                    .iter()
                    .position(|q| !q.is_empty())
                    .expect("full cache with no classed entries");
                let victim = self.classes[cheapest].pop_front().unwrap();
                self.class_of_key.remove(&victim);
                Some(victim)
            } else {
                None
            };
            self.classes[class].push_back(key);
            self.class_of_key.insert(key, class);
            evicted
        }
    }
}

/// Drive a production policy and its oracle through the same randomized
/// trace, comparing hit/miss outcomes, eviction sequences, len, and a
/// full-membership sweep after every operation burst.
fn run_oracle_battery(
    name: &str,
    mut policy: Box<dyn CachePolicy>,
    mut oracle_touch: impl FnMut(u64) -> bool,
    mut oracle_insert: impl FnMut(u64) -> Option<u64>,
    mut oracle_contains: impl FnMut(u64) -> bool,
    mut oracle_len: impl FnMut() -> usize,
    seed: u64,
    key_bound: u64,
) {
    let mut rng = Rng::new(seed);
    for i in 0..2_500u64 {
        let key = rng.below(key_bound as usize) as u64;
        if rng.chance(0.5) {
            assert_eq!(
                policy.insert(key),
                oracle_insert(key),
                "{name}: eviction sequence diverged at op {i} (seed {seed})"
            );
        } else {
            assert_eq!(
                policy.touch(key),
                oracle_touch(key),
                "{name}: hit/miss diverged at op {i} (seed {seed})"
            );
        }
        assert_eq!(policy.len(), oracle_len(), "{name}: len diverged at op {i}");
        if i % 250 == 0 {
            for k in 0..key_bound {
                assert_eq!(
                    policy.contains(k),
                    oracle_contains(k),
                    "{name}: membership diverged at key {k}, op {i} (seed {seed})"
                );
            }
        }
    }
}

#[test]
fn dense_lru_matches_hashmap_oracle_on_random_traces() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(0x0DAC1E ^ seed);
        let cap = rng.range(1, 24);
        let bound = 40u64;
        // both constructions must match the oracle exactly
        for bounded in [false, true] {
            let dense: Box<dyn CachePolicy> = if bounded {
                Box::new(Lru::bounded(cap, bound as usize))
            } else {
                Box::new(Lru::new(cap))
            };
            let mut oracle = oracle::RefLru::new(cap);
            // sharing one oracle across closures is clumsy; use a cell
            let o = std::cell::RefCell::new(&mut oracle);
            run_oracle_battery(
                if bounded { "lru(bounded)" } else { "lru" },
                dense,
                |k| o.borrow_mut().touch(k),
                |k| o.borrow_mut().insert(k),
                |k| o.borrow().contains(k),
                || o.borrow().len(),
                seed,
                bound,
            );
        }
    }
}

#[test]
fn dense_s3fifo_matches_hashmap_oracle_on_random_traces() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(0x53F1F0 ^ seed);
        let cap = rng.range(1, 24);
        let bound = 40u64;
        for bounded in [false, true] {
            let dense: Box<dyn CachePolicy> = if bounded {
                Box::new(S3Fifo::bounded(cap, bound as usize))
            } else {
                Box::new(S3Fifo::new(cap))
            };
            let mut oracle = oracle::RefS3Fifo::new(cap);
            let o = std::cell::RefCell::new(&mut oracle);
            run_oracle_battery(
                if bounded { "s3fifo(bounded)" } else { "s3fifo" },
                dense,
                |k| o.borrow_mut().touch(k),
                |k| o.borrow_mut().insert(k),
                |k| o.borrow().contains(k),
                || o.borrow().len(),
                seed,
                bound,
            );
        }
    }
}

#[test]
fn dense_victim_matches_reference_oracle_on_random_traces() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(0x71C71A ^ seed);
        let cap = rng.range(1, 24);
        let bound = 40u64;
        for bounded in [false, true] {
            let dense: Box<dyn CachePolicy> = if bounded {
                Box::new(Victim::bounded(cap, bound as usize))
            } else {
                Box::new(Victim::new(cap))
            };
            let mut oracle = oracle::RefVictim::new(cap);
            let o = std::cell::RefCell::new(&mut oracle);
            run_oracle_battery(
                if bounded { "victim(bounded)" } else { "victim" },
                dense,
                |k| o.borrow_mut().touch(k),
                |k| o.borrow_mut().insert(k),
                |k| o.borrow().contains(k),
                || o.borrow().len(),
                seed,
                bound,
            );
        }
    }
}

#[test]
fn dense_setassoc_matches_reference_oracle_on_random_traces() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(0x5E7A55 ^ seed);
        let cap = rng.range(1, 24);
        let bound = 40u64;
        // direct-mapped, low-assoc, the harness default, fully-assoc —
        // plus the `bounded` constructor (identical to `new` for this
        // policy: there is no key-indexed table to pre-size)
        for ways in [1usize, 2, ripple::cache::DEFAULT_WAYS, cap] {
            let dense: Box<dyn CachePolicy> = Box::new(SetAssoc::with_ways(cap, ways));
            let mut oracle = oracle::RefSetAssoc::with_ways(cap, ways);
            let o = std::cell::RefCell::new(&mut oracle);
            run_oracle_battery(
                &format!("setassoc(ways={ways})"),
                dense,
                |k| o.borrow_mut().touch(k),
                |k| o.borrow_mut().insert(k),
                |k| o.borrow().contains(k),
                || o.borrow().len(),
                seed,
                bound,
            );
        }
        let dense: Box<dyn CachePolicy> = Box::new(SetAssoc::bounded(cap, bound as usize));
        let mut oracle = oracle::RefSetAssoc::with_ways(cap, ripple::cache::DEFAULT_WAYS);
        let o = std::cell::RefCell::new(&mut oracle);
        run_oracle_battery(
            "setassoc(bounded)",
            dense,
            |k| o.borrow_mut().touch(k),
            |k| o.borrow_mut().insert(k),
            |k| o.borrow().contains(k),
            || o.borrow().len(),
            seed,
            bound,
        );
    }
}

/// With uniform (cost-oblivious) inserts every entry shares one cost
/// class, so cost-aware eviction must degenerate to EXACT LRU — pinned
/// against the independent `RefLru` oracle, not a mirror of itself.
#[test]
fn dense_costaware_with_uniform_costs_matches_the_lru_oracle() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(0xC057A0 ^ seed);
        let cap = rng.range(1, 24);
        let bound = 40u64;
        for bounded in [false, true] {
            let dense: Box<dyn CachePolicy> = if bounded {
                Box::new(CostAware::bounded(cap, bound as usize))
            } else {
                Box::new(CostAware::new(cap))
            };
            let mut oracle = oracle::RefLru::new(cap);
            let o = std::cell::RefCell::new(&mut oracle);
            run_oracle_battery(
                if bounded { "costaware(bounded)" } else { "costaware" },
                dense,
                |k| o.borrow_mut().touch(k),
                |k| o.borrow_mut().insert(k),
                |k| o.borrow().contains(k),
                || o.borrow().len(),
                seed,
                bound,
            );
        }
    }
}

/// The cost-carrying battery: random re-read costs spanning the linked-
/// run-to-singleton range drive `CachePolicy::insert_with_cost` through
/// the trait (pinning the dispatch, not just the inherent method) and
/// must match the class-bucketed reference model op for op.
#[test]
fn dense_costaware_matches_cost_class_oracle_under_mixed_costs() {
    // cost spread mirrors `NeuronCache::run_cost`: 256 / run_len for
    // runs of 1, 32, 4, and 256 bundles
    const COSTS: [u32; 4] = [256, 8, 64, 1];
    for seed in 0..8u64 {
        let mut rng = Rng::new(0xC057C1 ^ seed);
        let cap = rng.range(1, 24);
        let bound = 40usize;
        let mut dense: Box<dyn CachePolicy> = Box::new(CostAware::bounded(cap, bound));
        let mut oracle = oracle::RefCostAware::new(cap);
        for i in 0..2_500u64 {
            let key = rng.below(bound) as u64;
            if rng.chance(0.5) {
                let cost = COSTS[rng.below(COSTS.len())];
                assert_eq!(
                    dense.insert_with_cost(key, cost),
                    oracle.insert_with_cost(key, cost),
                    "costaware: eviction diverged at op {i} (seed {seed}, cost {cost})"
                );
            } else {
                assert_eq!(
                    dense.touch(key),
                    oracle.touch(key),
                    "costaware: hit/miss diverged at op {i} (seed {seed})"
                );
            }
            assert_eq!(dense.len(), oracle.len(), "costaware: len diverged at op {i}");
            if i % 250 == 0 {
                for k in 0..bound as u64 {
                    assert_eq!(
                        dense.contains(k),
                        oracle.contains(k),
                        "costaware: membership diverged at key {k}, op {i} (seed {seed})"
                    );
                }
            }
        }
    }
}

/// For every policy that does NOT specialize `insert_with_cost`, the
/// trait default must route to plain `insert` — costs are advisory, and
/// a cost-oblivious policy driven through the costed entry point has to
/// behave byte-for-byte like one driven through `insert`.
#[test]
fn trait_default_insert_with_cost_is_cost_oblivious() {
    for_each_policy(|name, ctor| {
        if name == "costaware" {
            return; // the one policy whose costs are load-bearing
        }
        let mut rng = Rng::new(0xDEFA);
        let mut plain = ctor(8);
        let mut costed = ctor(8);
        for i in 0..600 {
            let key = rng.below(24) as u64;
            let cost = 1 + rng.below(512) as u32;
            if rng.chance(0.5) {
                assert_eq!(
                    plain.insert(key),
                    costed.insert_with_cost(key, cost),
                    "{name}: default insert_with_cost diverged at op {i}"
                );
            } else {
                assert_eq!(plain.touch(key), costed.touch(key), "{name} at op {i}");
            }
        }
        assert_eq!(plain.len(), costed.len(), "{name}");
        for k in 0..24u64 {
            assert_eq!(plain.contains(k), costed.contains(k), "{name}: key {k}");
        }
    });
}

#[test]
fn zero_capacity_never_stores() {
    let null_ctor: Ctor = |_| Box::new(NullCache);
    let mut all: Vec<(&str, Ctor)> = POLICIES.to_vec();
    all.push(("null", null_ctor));
    for (name, ctor) in all {
        let mut c = ctor(0);
        for k in 0..32u64 {
            c.insert(k);
            assert!(!c.touch(k), "{name}: stored into zero-capacity cache");
            assert!(!c.contains(k), "{name}");
        }
        assert_eq!(c.len(), 0, "{name}");
    }
}
