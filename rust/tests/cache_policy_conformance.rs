//! Cache-policy conformance suite: one parameterized battery of
//! trait-level contracts, run against every `CachePolicy` implementation
//! (LRU and S3-FIFO; the zero-capacity contract also covers NullCache).
//!
//! The battery asserts only what the *trait* promises — capacity
//! invariants, touch/insert semantics, eviction under pressure, no
//! phantom hits, side-effect-free `contains` — so any future policy
//! (ARC, CLOCK, ...) can be added to `POLICIES` and inherit the whole
//! suite.

use ripple::cache::{CachePolicy, Lru, NullCache, S3Fifo};
use ripple::util::rng::Rng;

type Ctor = fn(usize) -> Box<dyn CachePolicy>;

/// Every policy the suite covers. Add new implementations here.
const POLICIES: &[(&str, Ctor)] = &[
    ("lru", |cap| Box::new(Lru::new(cap))),
    ("s3fifo", |cap| Box::new(S3Fifo::new(cap))),
];

fn for_each_policy(mut f: impl FnMut(&str, Ctor)) {
    for &(name, ctor) in POLICIES {
        f(name, ctor);
    }
}

#[test]
fn capacity_never_exceeded_under_churn() {
    for_each_policy(|name, ctor| {
        for cap in [1usize, 2, 7, 16, 64] {
            let mut c = ctor(cap);
            let mut rng = Rng::new(0xCAFE ^ cap as u64);
            for i in 0..2_000u64 {
                c.insert(rng.below(cap * 5) as u64);
                if i % 3 == 0 {
                    c.touch(rng.below(cap * 5) as u64);
                }
                assert!(
                    c.len() <= cap,
                    "{name}: len {} > cap {cap} at op {i}",
                    c.len()
                );
                assert_eq!(c.capacity(), cap, "{name}: capacity drifted");
            }
        }
    });
}

#[test]
fn reported_capacity_matches_construction() {
    for_each_policy(|name, ctor| {
        for cap in [0usize, 1, 5, 100] {
            let c = ctor(cap);
            assert_eq!(c.capacity(), cap, "{name}");
            assert_eq!(c.len(), 0, "{name}: fresh cache not empty");
        }
    });
}

#[test]
fn touch_misses_before_insert_and_hits_after() {
    for_each_policy(|name, ctor| {
        let mut c = ctor(16);
        for k in 0..8u64 {
            assert!(!c.touch(k), "{name}: phantom hit on fresh cache");
        }
        for k in 0..8u64 {
            c.insert(k);
        }
        // no pressure (8 < 16): every inserted key must be resident
        for k in 0..8u64 {
            assert!(c.touch(k), "{name}: lost key {k} without pressure");
        }
        assert_eq!(c.len(), 8, "{name}");
    });
}

#[test]
fn touch_refresh_keeps_hot_key_alive_under_scan() {
    // A key re-referenced on every step must survive a cold scan of 20x
    // capacity: LRU via recency refresh, S3-FIFO via frequency promotion.
    for_each_policy(|name, ctor| {
        let mut c = ctor(10);
        c.insert(7);
        assert!(c.touch(7), "{name}");
        for i in 1_000..1_200u64 {
            c.insert(i);
            assert!(c.touch(7), "{name}: hot key evicted by scan at {i}");
        }
        assert!(c.len() <= 10, "{name}");
    });
}

#[test]
fn eviction_under_pressure_is_real() {
    // After inserting 3x capacity distinct keys, at most `cap` of them
    // can still hit — the rest must have been evicted, not hidden.
    for_each_policy(|name, ctor| {
        let cap = 12usize;
        let mut c = ctor(cap);
        let keys: Vec<u64> = (0..3 * cap as u64).collect();
        for &k in &keys {
            c.insert(k);
        }
        assert!(c.len() <= cap, "{name}");
        let resident = keys.iter().filter(|&&k| c.contains(k)).count();
        assert!(resident <= cap, "{name}: {resident} resident > cap {cap}");
        assert_eq!(resident, c.len(), "{name}: len disagrees with membership");
    });
}

#[test]
fn no_phantom_hits_under_random_ops() {
    // A hit may only occur for a key that was inserted earlier; randomized
    // mixed workload cross-checked against an oracle set of insertions.
    for_each_policy(|name, ctor| {
        for seed in 0..6u64 {
            let mut rng = Rng::new(0xBEEF ^ seed);
            let cap = rng.range(1, 24);
            let mut c = ctor(cap);
            let mut inserted = std::collections::HashSet::new();
            for _ in 0..1_500 {
                let key = rng.below(48) as u64;
                if rng.chance(0.5) {
                    c.insert(key);
                    inserted.insert(key);
                } else {
                    let hit = c.touch(key);
                    assert!(
                        !hit || inserted.contains(&key),
                        "{name}: hit on never-inserted key {key} (cap {cap}, seed {seed})"
                    );
                }
            }
        }
    });
}

#[test]
fn contains_is_consistent_and_side_effect_free() {
    for_each_policy(|name, ctor| {
        let mut rng = Rng::new(0x51DE);
        let mut c = ctor(8);
        for _ in 0..500 {
            let key = rng.below(24) as u64;
            if rng.chance(0.4) {
                c.insert(key);
            }
            // contains is repeatable (no internal state change)...
            let a = c.contains(key);
            let b = c.contains(key);
            assert_eq!(a, b, "{name}: contains not repeatable for {key}");
            // ...and agrees with what touch observes right after
            let hit = c.touch(key);
            assert_eq!(a, hit, "{name}: contains/touch disagree for {key}");
        }
    });
}

#[test]
fn reinsert_of_resident_key_does_not_grow() {
    for_each_policy(|name, ctor| {
        let mut c = ctor(8);
        c.insert(3);
        let len = c.len();
        for _ in 0..50 {
            c.insert(3);
        }
        assert_eq!(c.len(), len, "{name}: duplicate insert grew the cache");
        assert!(c.touch(3), "{name}");
    });
}

// ---------------------------------------------------------------------------
// Shared-cache concurrency battery (DESIGN.md §Serving): a policy shared
// by N sessions must behave as a pure function of the merged op order —
// no hidden per-caller state — and `contains` probes from other sessions
// must never perturb it.
// ---------------------------------------------------------------------------

type SessionOp = (bool, u64); // (is_insert, key)

/// Deterministic per-session op streams over a shared hot keyspace.
fn gen_session_streams(rng: &mut Rng, n_sessions: usize) -> Vec<Vec<SessionOp>> {
    (0..n_sessions)
        .map(|_| {
            let len = rng.range(20, 120);
            (0..len).map(|_| (rng.chance(0.5), rng.below(40) as u64)).collect()
        })
        .collect()
}

/// Round-robin merge of the session streams — the canonical
/// "equivalent single-stream trace" of that interleaving.
fn round_robin_merge(streams: &[Vec<SessionOp>]) -> Vec<SessionOp> {
    let mut merged = Vec::new();
    let mut cursors = vec![0usize; streams.len()];
    loop {
        let mut progressed = false;
        for (s, stream) in streams.iter().enumerate() {
            if cursors[s] < stream.len() {
                merged.push(stream[cursors[s]]);
                cursors[s] += 1;
                progressed = true;
            }
        }
        if !progressed {
            return merged;
        }
    }
}

/// Driving a policy through interleaved multi-session streams gives the
/// same hit/miss outcomes AND the same end state as replaying the
/// merged trace single-stream: the policy keys carry all the state,
/// sessions add none.
#[test]
fn interleaved_session_streams_match_merged_single_stream() {
    for_each_policy(|name, ctor| {
        for seed in 0..6u64 {
            let mut rng = Rng::new(0x5E55_10 ^ seed);
            let cap = rng.range(2, 24);
            let n_sessions = rng.range(2, 5);
            let streams = gen_session_streams(&mut rng, n_sessions);
            let merged = round_robin_merge(&streams);

            // driver A: the multi-session scheduler (per-stream cursors)
            let mut a = ctor(cap);
            let mut outcomes_a = Vec::new();
            let mut cursors = vec![0usize; n_sessions];
            loop {
                let mut progressed = false;
                for (s, stream) in streams.iter().enumerate() {
                    if cursors[s] < stream.len() {
                        let (is_insert, key) = stream[cursors[s]];
                        cursors[s] += 1;
                        if is_insert {
                            a.insert(key);
                        } else {
                            outcomes_a.push((key, a.touch(key)));
                        }
                        progressed = true;
                    }
                }
                if !progressed {
                    break;
                }
            }

            // driver B: the merged trace, single stream
            let mut b = ctor(cap);
            let mut outcomes_b = Vec::new();
            for &(is_insert, key) in &merged {
                if is_insert {
                    b.insert(key);
                } else {
                    outcomes_b.push((key, b.touch(key)));
                }
            }

            assert_eq!(outcomes_a, outcomes_b, "{name}: outcomes diverged (seed {seed})");
            assert_eq!(a.len(), b.len(), "{name}: end sizes diverged (seed {seed})");
            for key in 0..40u64 {
                assert_eq!(
                    a.contains(key),
                    b.contains(key),
                    "{name}: end membership diverged at {key} (seed {seed})"
                );
            }
        }
    });
}

/// `contains` stays side-effect-free under interleaving: peppering the
/// stream with residency probes (another session peeking, as the
/// shared-cache prefetch filter does) changes neither the hit/miss
/// outcome sequence nor the final membership.
#[test]
fn contains_probes_never_perturb_an_interleaved_stream() {
    for_each_policy(|name, ctor| {
        for seed in 0..6u64 {
            let mut rng = Rng::new(0xD00D ^ seed);
            let cap = rng.range(2, 16);
            let ops: Vec<SessionOp> =
                (0..300).map(|_| (rng.chance(0.5), rng.below(32) as u64)).collect();
            let probes: Vec<u64> =
                (0..ops.len() * 3).map(|_| rng.below(32) as u64).collect();

            let mut clean = ctor(cap);
            let mut probed = ctor(cap);
            let mut outcomes_clean = Vec::new();
            let mut outcomes_probed = Vec::new();
            for (i, &(is_insert, key)) in ops.iter().enumerate() {
                // three foreign probes before every op on the probed copy
                for p in 0..3 {
                    let _ = probed.contains(probes[i * 3 + p]);
                }
                if is_insert {
                    clean.insert(key);
                    probed.insert(key);
                } else {
                    outcomes_clean.push(clean.touch(key));
                    outcomes_probed.push(probed.touch(key));
                }
            }
            assert_eq!(
                outcomes_clean, outcomes_probed,
                "{name}: contains() perturbed outcomes (seed {seed})"
            );
            assert_eq!(clean.len(), probed.len(), "{name} (seed {seed})");
            for key in 0..32u64 {
                assert_eq!(
                    clean.contains(key),
                    probed.contains(key),
                    "{name}: membership diverged at {key} (seed {seed})"
                );
            }
        }
    });
}

#[test]
fn zero_capacity_never_stores() {
    let null_ctor: Ctor = |_| Box::new(NullCache);
    let mut all: Vec<(&str, Ctor)> = POLICIES.to_vec();
    all.push(("null", null_ctor));
    for (name, ctor) in all {
        let mut c = ctor(0);
        for k in 0..32u64 {
            c.insert(k);
            assert!(!c.touch(k), "{name}: stored into zero-capacity cache");
            assert!(!c.contains(k), "{name}");
        }
        assert_eq!(c.len(), 0, "{name}");
    }
}
